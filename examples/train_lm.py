"""End-to-end driver: train a ~100M-parameter RWKV6 for a few hundred steps.

Demonstrates the full substrate: config system, data pipeline, AdamW +
cosine schedule, microbatch accumulation, checkpoint/restart.  Run time is
CPU-bound; shrink --steps for a faster pass.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import dataclasses
import time
import warnings

warnings.filterwarnings("ignore")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.config import RecurrentConfig
from repro.models.transformer import init_params
from repro.train import checkpoint as ckpt
from repro.train.data import SyntheticLM
from repro.train.optim import adamw, cosine_schedule
from repro.train.steps import init_train_state, make_train_step


def hundred_m_config():
    """~100M-param RWKV6 (12L, d=768) — the 'few hundred steps' driver."""
    base = get_config("rwkv6_1b6")
    return dataclasses.replace(
        base,
        name="rwkv6-100m",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=2688,
        vocab_size=32_768,
        recurrent=RecurrentConfig(rwkv_head_dim=64, rwkv_decay_lora=32),
        remat="none",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = hundred_m_config()
    params = init_params(jax.random.key(0), cfg)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"{cfg.name}: {n_params/1e6:.1f}M parameters")

    opt = adamw(cosine_schedule(6e-4, warmup=20, total=args.steps))
    state = init_train_state(params, opt)
    start = 0
    if ckpt.latest_step(args.ckpt_dir) is not None:
        state, start = ckpt.restore(args.ckpt_dir, template=state)
        print(f"resumed at step {start}")

    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=0,
                       process_index=0, process_count=1)
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))

    t0, losses = time.time(), []
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % 20 == 0:
            tok_s = args.batch * args.seq * 20 / (time.time() - t0)
            print(f"step {step+1:4d}  loss {np.mean(losses[-20:]):.4f}  tok/s {tok_s:,.0f}")
            t0 = time.time()
        if (step + 1) % 100 == 0:
            ckpt.save(args.ckpt_dir, step + 1, state)

    first, last = np.mean(losses[:20]), np.mean(losses[-20:])
    print(f"\nloss: {first:.4f} -> {last:.4f} "
          f"({'LEARNING' if last < first - 0.05 else 'check config'})")


if __name__ == "__main__":
    main()
