"""Batched serving example: prefill + decode with every cache type.

Serves three smoke-scale architectures covering the three cache families
(KV ring-buffer local attention, MLA compressed latents, RWKV6 recurrent
state) through the same ServeEngine.

    PYTHONPATH=src python examples/serve_batch.py
"""

import dataclasses
import time
import warnings

warnings.filterwarnings("ignore")

import jax

from repro.configs import get_config
from repro.models.transformer import init_params
from repro.serve.engine import ServeEngine


def main():
    for arch in ("gemma3_27b", "deepseek_v2_lite_16b", "rwkv6_1b6"):
        cfg = dataclasses.replace(get_config(arch, smoke=True), compute_dtype="float32")
        params = init_params(jax.random.key(0), cfg)
        engine = ServeEngine(cfg, params, max_len=48, temperature=0.8)
        prompt = jax.random.randint(jax.random.key(1), (4, 12), 0, cfg.vocab_size)
        t0 = time.time()
        out = engine.generate(prompt, steps=24, key=jax.random.key(2))
        dt = time.time() - t0
        print(
            f"{cfg.name:28s} batch=4 prompt=12 +24 tokens -> {tuple(out.shape)} "
            f"in {dt:5.2f}s  (cache family: "
            f"{'KV+ring' if 'gemma' in arch else 'MLA latent' if 'v2' in arch else 'recurrent state'})"
        )


if __name__ == "__main__":
    main()
