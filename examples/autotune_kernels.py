"""Beyond-paper example: EvoEngineer autotunes the Pallas kernel genomes.

Runs the evolution loop over (block_q, block_k) / (block_m, block_n,
block_k) / chunk against the TPU v5e roofline model, then validates the
winning genome numerically via the interpret-mode kernel vs the oracle.

    PYTHONPATH=src python examples/autotune_kernels.py
"""

import warnings

warnings.filterwarnings("ignore")

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref, tuned
from repro.launch.autotune import tune


def main():
    for kernel in ("flash", "matmul", "wkv6"):
        res = tune(kernel, trials=40)
        print(
            f"{kernel:8s} best genome {res['best_genome']} "
            f"modeled {res['best_modeled_us']:.1f}us "
            f"(valid proposals: {res['valid_rate']:.0%})"
        )

    # the registry round-trip: what ops.py would use as defaults right now
    print(f"registry defaults: flash={tuned.get_tuned('flash')} "
          f"(file: {tuned.genomes_path()})")

    # numerically validate the tuned flash genome in interpret mode
    res = tune("flash", trials=40)
    g = res["best_genome"]
    b, s, h, d = 1, 512, 2, 64
    key = jax.random.key(0)
    q = jax.random.normal(key, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d), jnp.float32)
    got = ops.flash_attention(
        q, k, v, block_q=min(g["block_q"], s), block_k=min(g["block_k"], s)
    )
    want = ref.flash_attention_ref(q, k, v)
    err = float(np.max(np.abs(np.asarray(got) - np.asarray(want))))
    print(f"tuned flash genome validates vs oracle: max err {err:.2e}")


if __name__ == "__main__":
    main()
