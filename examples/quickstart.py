"""Quickstart: evolve one kernel with EvoEngineer in under a minute.

    PYTHONPATH=src python examples/quickstart.py
"""

import warnings

warnings.filterwarnings("ignore")

from repro.core import EvolutionEngine, get_method
from repro.evaluation import EvalConfig, Evaluator
from repro.tasks import get_task


def main():
    task = get_task("mm_square_m")
    print(f"Task: {task.name} — {task.description}")
    print("Initial (naive) implementation:")
    print("\n".join("  " + l for l in task.initial_source.splitlines()[-10:]))

    evaluator = Evaluator(EvalConfig(timing_runs=7))
    print(f"\nnaive runtime: {evaluator.baseline_us(task):.0f} us")

    for method_key in ("evoengineer-free", "evoengineer-full"):
        method = get_method(method_key)
        engine = EvolutionEngine(task, method, evaluator=evaluator, seed=0)
        result = engine.run(max_trials=45)
        print(
            f"\n{method.name}: best speedup {result.best_speedup:.2f}x | "
            f"validity {result.validity_rate:.0%} | "
            f"tokens {result.ledger.total:,}"
        )
        print("best kernel:")
        print("\n".join("  " + l for l in result.best.source.splitlines()[-8:]))


if __name__ == "__main__":
    main()
