"""Activation sharding constraints (GSPMD guide rails).

Without explicit constraints, XLA's sharding propagation is free to
replicate the batch dimension of intermediate activations inside the layer
scan — which it happily does (observed: full global-batch f32 activations
all-reduced per layer, 45 GiB peaks).  ``constrain(x, kind)`` pins the
canonical layout at module boundaries:

    btd    (B, S, D)        batch -> (pod, data)
    bshd   (B, S, H, Dh)    batch -> (pod, data), heads -> model
    bsf    (B, S, F)        batch -> (pod, data), features -> model
    ecd    (E, C, D)        experts -> model, capacity -> (pod, data)
    logits (B, S, [C,] V)   batch -> (pod, data), vocab -> model

Constraints are inert (identity) unless a mesh has been activated via
``activation_sharding(mesh)`` — single-device tests and the evolution
engine's kernel tasks never see them.  Every rule passes through
sharding._fit, so non-divisible dims gracefully drop axes.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import DP, TP, _fit

_STATE = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, *, seq_parallel: bool = False):
    """seq_parallel=True shards the residual stream's sequence dim over the
    model axis (Megatron sequence parallelism): layer inputs/outputs (and
    therefore the remat saves) shrink by the TP degree; the per-layer
    all-gather before QKV / reduce-scatter after the MLP is XLA's job."""
    old = (getattr(_STATE, "mesh", None), getattr(_STATE, "seq_parallel", False))
    _STATE.mesh = mesh
    _STATE.seq_parallel = seq_parallel
    try:
        yield
    finally:
        _STATE.mesh, _STATE.seq_parallel = old


_RULES = {
    "btd": (DP, None, None),
    "td": (DP, None),  # flattened token-major 2D tensors (MoE dispatch)
    "bshd": (DP, None, TP, None),
    "bsf": (DP, None, TP),
    "ecd": (TP, DP, None),
    "bd": (DP, None),
}

# cache entries: kv-heads on model when divisible, else the sequence axis
# (context-parallel cache) — mirrors parallel.sharding._cache_rule
_CACHE_RULES = {
    "cache_kv": ((DP, None, TP, None), (DP, TP, None, None)),  # (B,S,KV,D)
    "cache_latent": ((DP, TP, None), (DP, TP, None)),  # (B,S,r)
    "cache_state": ((DP, TP, None, None), (DP, None, None, None)),  # (B,H,k,k)
}


def constrain(x: jax.Array, kind: str) -> jax.Array:
    mesh = current_mesh()
    if mesh is None:
        return x
    if kind == "logits":
        rule = (DP,) + (None,) * (x.ndim - 2) + (TP,)
    elif kind == "btd" and getattr(_STATE, "seq_parallel", False):
        rule = (DP, TP, None)
    elif kind == "bshd" and x.shape[2] % mesh.shape.get("model", 1) != 0:
        # heads don't divide TP (e.g. 40 heads / 16): context-parallel
        # attention — shard the sequence dim instead of replicating heads
        rule = (DP, TP, None, None)
    elif kind in _CACHE_RULES:
        primary, fallback = _CACHE_RULES[kind]
        spec = _fit(mesh, tuple(x.shape), primary)
        # if the head axis could not shard, fall back to sequence sharding
        if kind == "cache_kv" and spec[2] is None:
            spec = _fit(mesh, tuple(x.shape), fallback)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    else:
        rule = _RULES[kind]
    spec = _fit(mesh, tuple(x.shape), rule)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
