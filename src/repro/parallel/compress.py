"""Gradient compression for the data-parallel all-reduce.

int8 quantization with per-tensor scales: grads are quantized, summed across
the ``data`` (and ``pod``) axes inside a shard_map, and dequantized — cutting
DP all-reduce wire bytes 4x vs fp32 (2x vs bf16).  Error feedback (residual
carrying) keeps the optimizer trajectory close to the uncompressed one.

This lives OUTSIDE the autodiff path: the train-step builder calls
``compressed_psum`` on the already-computed local gradients when
``grad_compression="int8"`` is enabled — i.e. grads must arrive UNREDUCED
(per-microbatch shard), which the shard_map'd trainer variant provides.
The dry-run measures the wire-byte reduction in the compiled HLO
(EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def psum_int8(x: jax.Array, axis_names) -> jax.Array:
    """Quantize -> all-reduce int8 (widened to int32 for the sum) -> dequant.

    Scales are all-reduced (max) first so every shard quantizes onto a common
    grid; the int32 sum is then exact over the quantized values.
    """
    amax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis_names)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    total = jax.lax.psum(q.astype(jnp.int32), axis_names)
    return total.astype(jnp.float32) * scale


def compressed_psum_tree(grads: Any, axis_names=("data",)) -> Any:
    """Tree-wide int8-compressed psum (inside shard_map)."""
    return jax.tree.map(lambda g: psum_int8(g, axis_names), grads)


def make_compressed_allreduce(mesh, specs, axis_names=("data",)):
    """shard_map'd gradient all-reduce with int8 wire format.

    specs: PartitionSpec pytree of the gradients (model-parallel axes stay
    sharded; the data axis is reduced).
    """
    from jax.experimental.shard_map import shard_map

    def inner(grads):
        return compressed_psum_tree(grads, axis_names)

    return shard_map(
        inner, mesh=mesh, in_specs=specs, out_specs=specs, check_rep=False
    )
