"""Sharding rules: param/activation/cache PartitionSpecs for the production mesh.

Mesh axes (launch/mesh.py):
    single-pod : (data=16, model=16)
    multi-pod  : (pod=2, data=16, model=16)

Strategy (megatron tensor-parallel + ZeRO-style fsdp on the data axis):
    * every weight matrix shards its "parallel" dimension (heads / d_ff /
      experts / vocab) over ``model`` and its d_model-ish dimension over
      ``data`` (fully-sharded params; XLA all-gathers at use — ZeRO-3);
    * activations shard batch over ``(pod, data)`` and heads/vocab over
      ``model``;
    * decode caches shard batch over ``(pod, data)`` and kv-heads over
      ``model`` when divisible, falling back to the cache sequence axis
      (context-parallel decode), falling back to replication.

Every rule passes through `_fit`, which drops a mesh axis from a dimension
whose size it does not divide — so one rule set covers all ten architectures
(e.g. kv=1 MQA caches can never shard kv-heads and fall back to sequence).
"""

from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis]
    n = 1
    for a in axis:
        n *= mesh.shape[a]
    return n


def _fit(mesh: Mesh, shape: Sequence[int], spec: Sequence[Axis]) -> P:
    """Drop axes that don't divide the corresponding dim (or don't exist)."""
    fitted = []
    for dim, axis in zip(shape, spec):
        if axis is None:
            fitted.append(None)
            continue
        names = (axis,) if isinstance(axis, str) else tuple(axis)
        names = tuple(a for a in names if a in mesh.shape)
        while names and dim % _axis_size(mesh, names) != 0:
            names = names[:-1]  # drop innermost first
        fitted.append(None if not names else (names[0] if len(names) == 1 else names))
    # pad remaining dims with None
    fitted += [None] * (len(shape) - len(fitted))
    return P(*fitted)


DP = ("pod", "data")  # the batch axes
FSDP = "data"
TP = "model"


# --------------------------------------------------------------------------
# Parameter rules: (path regex, spec builder by rank/shape)
# --------------------------------------------------------------------------
def _param_rule(path: str, shape: Tuple[int, ...]) -> Tuple[Axis, ...]:
    """Returns the desired axis per dimension (pre-fit)."""
    # ---- embeddings / heads -------------------------------------------
    if re.search(r"embed/table$", path):
        if len(shape) == 3:  # (C, V, D) multi-codebook
            return (None, TP, FSDP)
        return (TP, FSDP)  # (V, D)
    if re.search(r"lm_head/w$", path):
        if len(shape) == 3:  # (C, D, V)
            return (None, FSDP, TP)
        return (FSDP, TP)  # (D, V)
    # ---- attention ------------------------------------------------------
    if re.search(r"mixer/w_[qkv]$", path):
        return (FSDP, TP, None)  # (D, H, Dh)
    if re.search(r"mixer/w_o$", path):
        return (TP, None, FSDP)  # (H, Dh, D)
    if re.search(r"mixer/b_[qkv]$", path):
        return (TP, None)  # (H, Dh)
    # ---- MLA -------------------------------------------------------------
    if re.search(r"mixer/w_dkv$", path):
        return (FSDP, None)  # (D, r)
    if re.search(r"mixer/w_(uk|uv)$", path):
        return (None, TP, None)  # (r, H, dh)
    if re.search(r"mixer/w_kr$", path):
        return (FSDP, None)  # (D, dr)
    # ---- MoE --------------------------------------------------------------
    if re.search(r"mlp/router$", path):
        return (None, None)  # (D, E): small; replicated for shard_map dispatch
    if re.search(r"mlp/w_(gate|up)$", path) and len(shape) == 3:
        return (TP, FSDP, None)  # (E, D, F): expert parallel + ZeRO-3 on D
    if re.search(r"mlp/w_down$", path) and len(shape) == 3:
        return (TP, None, FSDP)  # (E, F, D)
    if re.search(r"shared/w_(gate|up)$", path):
        return (FSDP, TP)  # (D, Fs)
    if re.search(r"shared/w_down$", path):
        return (TP, FSDP)  # (Fs, D)
    # ---- dense MLP ----------------------------------------------------------
    if re.search(r"mlp/w_(gate|up|k)$", path):
        return (FSDP, TP)  # (D, F)
    if re.search(r"mlp/w_(down|v)$", path):
        return (TP, FSDP)  # (F, D)
    if re.search(r"mlp/w_r$", path):
        return (FSDP, TP)  # rwkv cmix receptance (D, D)
    # ---- RG-LRU ---------------------------------------------------------------
    if re.search(r"mixer/w_[yx]$", path):
        return (FSDP, TP)  # (D, W)
    if re.search(r"mixer/conv_w$", path):
        return (None, TP)  # (K, W)
    if re.search(r"mixer/conv_b$", path):
        return (TP,)
    if re.search(r"mixer/w_[ai]$", path):
        return (FSDP, TP)  # (W, W)
    if re.search(r"mixer/lambda$", path):
        return (TP,)
    if re.search(r"mixer/w_out$", path):
        return (TP, FSDP)  # (W, D)
    # ---- RWKV6 -------------------------------------------------------------------
    if re.search(r"mixer/w_[rkvgo]$", path):
        return (FSDP, TP)  # (D, D)
    if re.search(r"mixer/decay_a$", path):
        return (FSDP, None)
    if re.search(r"mixer/decay_b$", path):
        return (None, TP)
    # ---- everything small (norms, mus, gains, bonus): replicate -------------------
    return tuple(None for _ in shape)


def _tree_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for keypath, leaf in flat:
        path = "/".join(_key_str(k) for k in keypath)
        yield path, leaf
    return


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    if hasattr(k, "name"):
        return str(k.name)
    return str(k)


def param_sharding(mesh: Mesh, params: Any, *, stacked_prefixes=("blocks",)) -> Any:
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs).

    Parameters under a stacked prefix (the scan-stacked pattern blocks) have a
    leading n_blocks dim that is never sharded; rules apply to the rest.
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for keypath, leaf in flat:
        path = "/".join(_key_str(k) for k in keypath)
        shape = tuple(leaf.shape)
        stacked = any(path.startswith(p + "/") or path == p for p in stacked_prefixes)
        eff_shape = shape[1:] if stacked else shape
        rule = _param_rule(path, eff_shape)
        spec = _fit(mesh, eff_shape, rule)
        if stacked:
            spec = P(None, *spec)
        specs.append(spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


# --------------------------------------------------------------------------
# Activation / input specs
# --------------------------------------------------------------------------
def activation_specs(mesh: Mesh, inputs: Any) -> Any:
    """Batch over (pod, data) for every input array; aux dims replicated.

    ``inputs`` is the input_specs() dict: tokens/targets/image_embeds etc.,
    all with leading batch.
    """

    def one(x):
        return _fit(mesh, tuple(x.shape), (DP,) + (None,) * (len(x.shape) - 1))

    return jax.tree_util.tree_map(one, inputs)


def logits_spec(mesh: Mesh) -> P:
    return _fit(mesh, (1 << 30, 1 << 30, 1 << 30), (DP, None, TP))


# --------------------------------------------------------------------------
# Decode-cache rules
# --------------------------------------------------------------------------
def _cache_rule(path: str, shape: Tuple[int, ...]) -> Tuple[Axis, ...]:
    # shapes WITHOUT the stacked n_blocks dim
    if re.search(r"/(k|v)$", path):  # (B, S, KV, Dh)
        b, s, kv, dh = shape
        return (DP, (TP,), None, None) if False else (DP, None, TP, None)
    if re.search(r"/ckv$", path):  # (B, S, r)
        return (DP, TP, None)
    if re.search(r"/kr$", path):  # (B, S, dr)
        return (DP, TP, None)
    if re.search(r"/state$", path):  # (B, H, k, k)
        return (DP, TP, None, None)
    if re.search(r"/conv$", path):  # (B, K-1, W)
        return (DP, None, TP)
    if re.search(r"/h$", path):  # (B, W)
        return (DP, TP)
    if re.search(r"/(shift|cmix_shift)$", path):  # (B, D)
        return (DP, TP)
    return tuple(None for _ in shape)


def cache_specs_sharding(mesh: Mesh, cache: Any) -> Any:
    """Cache PartitionSpecs: batch over (pod,data); kv-heads over model when
    divisible, else the cache sequence axis (context-parallel decode)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = []
    for keypath, leaf in flat:
        path = "/".join(_key_str(k) for k in keypath)
        shape = tuple(leaf.shape)
        stacked = path.startswith("blocks/") or "/blocks/" in path
        eff_shape = shape[1:] if stacked else shape
        rule = list(_cache_rule(path, eff_shape))
        spec = _fit(mesh, eff_shape, tuple(rule))
        # fallback: if this is a k/v cache and kv-heads could not shard,
        # shard the sequence axis instead (context-parallel decode)
        if re.search(r"/(k|v)$", path) and len(eff_shape) == 4:
            if spec[2] is None and eff_shape[1] % mesh.shape.get("model", 1) == 0:
                spec = P(spec[0], TP, None, None)
        specs.append(P(None, *spec) if stacked else spec)
    return jax.tree_util.tree_unflatten(treedef, specs)


# --------------------------------------------------------------------------
# Introspection helper
# --------------------------------------------------------------------------
def shard_info(mesh: Mesh, tree: Any, specs: Any) -> str:
    """Human-readable table of leaf shapes, specs and per-device bytes."""
    lines = []
    total = 0
    flat_t, _ = jax.tree_util.tree_flatten_with_path(tree)
    flat_s = jax.tree_util.tree_leaves(specs, is_leaf=lambda x: isinstance(x, P))
    for (keypath, leaf), spec in zip(flat_t, flat_s):
        path = "/".join(_key_str(k) for k in keypath)
        n_shards = 1
        for axis in spec:
            if axis is not None:
                n_shards *= _axis_size(mesh, axis)
        nbytes = leaf.size * leaf.dtype.itemsize // max(n_shards, 1)
        total += nbytes
        lines.append(f"{path:70s} {str(leaf.shape):28s} {str(spec):40s} {nbytes/2**20:10.2f} MiB")
    lines.append(f"{'TOTAL per device':70s} {'':28s} {'':40s} {total/2**30:10.2f} GiB")
    return "\n".join(lines)


def named(mesh: Mesh, specs: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs, is_leaf=lambda x: isinstance(x, P)
    )
