"""Distribution layer: mesh axes, sharding rules, gradient compression."""

from repro.parallel.sharding import (
    activation_specs,
    cache_specs_sharding,
    param_sharding,
    shard_info,
)

__all__ = [
    "activation_specs",
    "cache_specs_sharding",
    "param_sharding",
    "shard_info",
]
