"""jit'd dispatch wrappers around the Pallas kernels.

backend="pallas_interpret" executes the kernel bodies in Python on CPU
(correctness); on a real TPU the same code path runs with interpret=False.
backend="xla" falls back to the pure-jnp reference — the path the dry-run
and CPU smoke tests compile.

Block/chunk arguments left as ``None`` resolve through the tuned-genome
registry (`repro.kernels.tuned`), i.e. the `launch/autotune.py --save`
winners are the live defaults; explicit arguments always override.  The
registry is device-aware: an entry measured on the attached backend's
``device_kind`` outranks the device-agnostic (roofline-modeled) layer,
which outranks the builtin fallbacks.  Resolution happens at trace time —
the values are static, so each (shape, genome) signature compiles once.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax

from repro.kernels import ref as _ref
from repro.kernels import tuned as _tuned
from repro.kernels.blocked_matmul import matmul_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.flash_decode import flash_decode_pallas
from repro.kernels.rglru import rglru_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.wkv6 import wkv6_pallas

INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"


def _interpret() -> bool:
    """Interpret iff no real accelerator is attached — the same rule
    `launch/autotune.py` uses for its bench thunks (the old hand-flipped
    module constant silently ran the Python interpreter on TPUs).  The
    ``REPRO_PALLAS_INTERPRET`` env var (0/1) overrides for tests.

    Resolution happens at *trace* time, like the tuned-genome defaults:
    a signature the jit wrappers already compiled keeps its baked-in
    interpret flag, so an env change mid-process only affects call
    signatures not yet traced (``jax.clear_caches()`` forces
    re-resolution)."""
    env = os.environ.get(INTERPRET_ENV)
    if env is not None:
        return env.strip().lower() not in ("0", "false", "")
    from repro.evaluation.timing import has_accelerator

    return not has_accelerator()


def _dispatch(backend: str):
    if backend not in ("xla", "pallas_interpret", "pallas"):
        raise ValueError(backend)
    return backend != "xla"


def _fit(kernel, knob, value, fallback, dim):
    """Resolve a block knob against the actual dimension: explicit `value`
    is honored verbatim (the caller owns divisibility, as before); a tuned
    registry value that does not tile `dim` degrades to the builtin
    default, so autotuned genomes — modeled at one benchmark shape — never
    break shapes the stock defaults handled."""
    if value is not None:
        return value
    for cand in (_tuned.resolve(kernel, knob, None, fallback), fallback):
        c = min(cand, dim)
        if dim % c == 0:
            return c
    return dim


@functools.partial(jax.jit, static_argnames=("logit_cap", "block_q", "block_k", "backend"))
def flash_attention(q, k, v, *, logit_cap=None, block_q=None, block_k=None, backend="pallas_interpret"):
    s = q.shape[1]
    block_q = _fit("flash", "block_q", block_q, 128, s)
    block_k = _fit("flash", "block_k", block_k, 128, s)
    if _dispatch(backend):
        return flash_attention_pallas(
            q, k, v, logit_cap=logit_cap, block_q=block_q, block_k=block_k,
            interpret=_interpret(),
        )
    return _ref.flash_attention_ref(q, k, v, logit_cap=logit_cap)


@functools.partial(
    jax.jit, static_argnames=("logit_cap", "block_pages", "backend")
)
def flash_decode(
    q, k_pages, v_pages, block_tables, lengths, *,
    logit_cap=None, block_pages=None, backend="pallas_interpret",
):
    """Paged decode attention.  q: (B, T, H, D) — T == 1 is classic
    single-query decode, T > 1 a speculative verify tile (query row t
    sits at position lengths-1+t); pools: (KV, P, page_size, D);
    block_tables: (B, max_pages); lengths: (B,).

    ``block_pages`` (pages fused per compute tile) resolves through the
    tuned registry and degrades to a divisor of max_pages; ``page_size``
    is a *layout* knob — it is baked into the pool shapes by
    `serve.paged_cache`, which reads the same tuned genome."""
    mp = block_tables.shape[1]
    block_pages = _fit("flash_decode", "block_pages", block_pages, 4, mp)
    if _dispatch(backend):
        return flash_decode_pallas(
            q, k_pages, v_pages, block_tables, lengths,
            logit_cap=logit_cap, block_pages=block_pages,
            interpret=_interpret(),
        )
    return _ref.flash_decode_ref(
        q, k_pages, v_pages, block_tables, lengths, logit_cap=logit_cap
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "backend"))
def matmul(a, b, *, block_m=None, block_n=None, block_k=None, backend="pallas_interpret"):
    block_m = _fit("matmul", "block_m", block_m, 256, a.shape[0])
    block_n = _fit("matmul", "block_n", block_n, 256, b.shape[1])
    block_k = _fit("matmul", "block_k", block_k, 256, a.shape[1])
    if _dispatch(backend):
        return matmul_pallas(
            a, b, block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=_interpret(),
        )
    return _ref.matmul_ref(a, b)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "backend"))
def rmsnorm(x, scale, *, eps=1e-6, block_rows=None, backend="pallas_interpret"):
    # rmsnorm_pallas halves block_rows itself until it tiles the row count
    block_rows = _tuned.resolve("rmsnorm", "block_rows", block_rows, 128)
    if _dispatch(backend):
        return rmsnorm_pallas(
            x, scale, eps=eps, block_rows=block_rows, interpret=_interpret()
        )
    return _ref.rmsnorm_ref(x, scale, eps=eps)


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def wkv6(r, k, v, log_w, u, *, chunk=None, backend="pallas_interpret"):
    chunk = _fit("wkv6", "chunk", chunk, 64, r.shape[1])
    if _dispatch(backend):
        return wkv6_pallas(r, k, v, log_w, u, chunk=chunk, interpret=_interpret())
    return _ref.wkv6_ref(r, k, v, log_w, u, chunk=chunk)


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def rglru(a, b, *, chunk=None, backend="pallas_interpret"):
    chunk = _fit("rglru", "chunk", chunk, 64, a.shape[1])
    if _dispatch(backend):
        return rglru_pallas(a, b, chunk=chunk, interpret=_interpret())
    return _ref.rglru_ref(a, b)
