"""jit'd dispatch wrappers around the Pallas kernels.

backend="pallas_interpret" executes the kernel bodies in Python on CPU
(correctness); on a real TPU the same code path runs with interpret=False.
backend="xla" falls back to the pure-jnp reference — the path the dry-run
and CPU smoke tests compile.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import ref as _ref
from repro.kernels.blocked_matmul import matmul_pallas
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.rglru import rglru_pallas
from repro.kernels.rmsnorm import rmsnorm_pallas
from repro.kernels.wkv6 import wkv6_pallas

_INTERPRET = True  # flip to False on real TPU hardware


def _dispatch(backend: str):
    if backend not in ("xla", "pallas_interpret", "pallas"):
        raise ValueError(backend)
    return backend != "xla"


@functools.partial(jax.jit, static_argnames=("logit_cap", "block_q", "block_k", "backend"))
def flash_attention(q, k, v, *, logit_cap=None, block_q=128, block_k=128, backend="pallas_interpret"):
    if _dispatch(backend):
        return flash_attention_pallas(
            q, k, v, logit_cap=logit_cap, block_q=block_q, block_k=block_k,
            interpret=_INTERPRET,
        )
    return _ref.flash_attention_ref(q, k, v, logit_cap=logit_cap)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k", "backend"))
def matmul(a, b, *, block_m=256, block_n=256, block_k=256, backend="pallas_interpret"):
    if _dispatch(backend):
        return matmul_pallas(
            a, b, block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=_INTERPRET,
        )
    return _ref.matmul_ref(a, b)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "backend"))
def rmsnorm(x, scale, *, eps=1e-6, block_rows=128, backend="pallas_interpret"):
    if _dispatch(backend):
        return rmsnorm_pallas(
            x, scale, eps=eps, block_rows=block_rows, interpret=_INTERPRET
        )
    return _ref.rmsnorm_ref(x, scale, eps=eps)


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def wkv6(r, k, v, log_w, u, *, chunk=64, backend="pallas_interpret"):
    if _dispatch(backend):
        return wkv6_pallas(r, k, v, log_w, u, chunk=chunk, interpret=_INTERPRET)
    return _ref.wkv6_ref(r, k, v, log_w, u, chunk=chunk)


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def rglru(a, b, *, chunk=64, backend="pallas_interpret"):
    if _dispatch(backend):
        return rglru_pallas(a, b, chunk=chunk, interpret=_INTERPRET)
    return _ref.rglru_ref(a, b)
