"""Tuned-genome registry: autotuner winners become dispatch defaults.

`launch/autotune.py --save` persists each kernel's best genome here
(``tuned_genomes.json`` beside this module, overridable with the
``REPRO_TUNED_GENOMES`` env var), and the `ops.py` wrappers resolve any
block/chunk argument left as ``None`` through `get_tuned` — so an
autotune run upgrades every caller's defaults instead of ending life as
print-only JSON.  Passing explicit block sizes always wins.

Entries are layered per device kind.  On-disk schema per kernel:

    {"flash": {
        "block_q": 512, "block_k": 256,          # device-agnostic (modeled)
        "_meta": {"source": "modeled", ...},
        "_by_device": {
            "tpu_v5e": {"block_q": 256, "block_k": 256,
                         "_meta": {"source": "measured", "runs": 15,
                                   "noise_floor_us": 1.2, ...}}}}}

`get_tuned` resolution: knobs merge builtin fallbacks, then the flat
device-agnostic entry, then the entry matching the *current* device kind
(``repro.evaluation.timing.device_kind()``, overridable per call) — so a
CPU host running the roofline autotuner can never silently shadow a
TPU-measured winner: the modeled result lands in the device-agnostic
layer while the measured one stays pinned to its device key.  On top of
that, `save_tuned` refuses to overwrite a ``source="measured"`` entry
with a ``source="modeled"`` one for the same device kind.

``_meta`` keys record provenance (measured vs modeled, run count, noise
floor, trials, seed) and are ignored by knob resolution; read them with
`get_tuned_meta`.

The in-memory registry caches per *path*: changing ``REPRO_TUNED_GENOMES``
mid-process triggers a re-read on the next lookup (an explicit
`invalidate` is only needed when the file changes underneath an unchanged
path).

Note: the jit'd dispatch wrappers resolve tuned defaults at trace time;
a registry update during a process's lifetime only affects call
signatures not yet traced (``jax.clear_caches()`` forces re-resolution).
"""

from __future__ import annotations

import os
import warnings
from typing import Any, Dict, Optional

from repro.ioutil import merge_json, read_json

ENV_VAR = "REPRO_TUNED_GENOMES"
_DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "tuned_genomes.json")

_BUILTIN: Dict[str, Dict[str, Any]] = {
    "flash": {"block_q": 128, "block_k": 128},
    # page_size is consumed by serve.paged_cache at cache-construction
    # time; block_pages by the ops.flash_decode dispatch at trace time
    "flash_decode": {"page_size": 64, "block_pages": 4},
    "matmul": {"block_m": 256, "block_n": 256, "block_k": 256},
    "wkv6": {"chunk": 64},
    "rmsnorm": {"block_rows": 128},
    "rglru": {"chunk": 64},
}

# normalized form: {kernel: {"base": knobs, "base_meta": meta|None,
#                            "devices": {kind: {"genome": knobs, "meta": meta}}}}
_loaded: Optional[Dict[str, Dict[str, Any]]] = None
_loaded_path: Optional[str] = None


def genomes_path() -> str:
    return os.environ.get(ENV_VAR, _DEFAULT_PATH)


def invalidate() -> None:
    """Drop the in-memory registry; next access re-reads the file."""
    global _loaded, _loaded_path
    _loaded = None
    _loaded_path = None


def current_device_kind() -> str:
    """The attached backend's normalized device kind (lazy import so this
    module stays importable without initializing jax)."""
    from repro.evaluation.timing import device_kind

    return device_kind()


def _knobs(entry: Dict[str, Any]) -> Dict[str, Any]:
    return {k: v for k, v in entry.items() if not k.startswith("_")}


def _normalize(raw: Dict[str, Any]) -> Dict[str, Dict[str, Any]]:
    out: Dict[str, Dict[str, Any]] = {}
    for kernel, entry in raw.items():
        if not isinstance(entry, dict):
            continue
        devices: Dict[str, Dict[str, Any]] = {}
        for kind, sub in (entry.get("_by_device") or {}).items():
            if isinstance(sub, dict):
                devices[kind] = {"genome": _knobs(sub), "meta": sub.get("_meta") or {}}
        out[kernel] = {
            "base": _knobs(entry),
            "base_meta": entry.get("_meta"),
            "devices": devices,
        }
    return out


def _load() -> Dict[str, Dict[str, Any]]:
    global _loaded, _loaded_path
    path = genomes_path()
    if _loaded is None or path != _loaded_path:
        raw = read_json(path) if os.path.exists(path) else {}
        _loaded = _normalize(raw)
        _loaded_path = path
    return _loaded


def get_tuned(kernel: str, device_kind: Optional[str] = None) -> Dict[str, Any]:
    """The tuned genome for `kernel` on `device_kind` (default: the
    attached backend).  Precedence per knob: device-matched entry >
    device-agnostic entry > builtin fallback."""
    entry = _load().get(kernel, {})
    out = dict(_BUILTIN.get(kernel, {}))
    out.update(entry.get("base", {}))
    if entry.get("devices"):
        kind = device_kind or current_device_kind()
        dev = entry["devices"].get(kind)
        if dev:
            out.update(dev["genome"])
    return out


def get_tuned_meta(
    kernel: str, device_kind: Optional[str] = None
) -> Optional[Dict[str, Any]]:
    """Provenance of the entry `get_tuned` would resolve knobs from:
    ``{"layer": "device"|"base", "device_kind": ..., "meta": {...}}``, or
    ``None`` when only builtin fallbacks exist."""
    entry = _load().get(kernel)
    if not entry:
        return None
    if entry.get("devices"):
        kind = device_kind or current_device_kind()
        dev = entry["devices"].get(kind)
        if dev:
            return {"layer": "device", "device_kind": kind, "meta": dict(dev["meta"])}
    if entry.get("base"):
        return {"layer": "base", "device_kind": None, "meta": dict(entry.get("base_meta") or {})}
    return None


def resolve(
    kernel: str,
    knob: str,
    value: Any,
    fallback: Any,
    device_kind: Optional[str] = None,
) -> Any:
    """Dispatch helper: explicit `value` wins, else tuned (device-aware),
    else `fallback`."""
    if value is not None:
        return value
    return get_tuned(kernel, device_kind=device_kind).get(knob, fallback)


def _source(meta: Optional[Dict[str, Any]]) -> str:
    """Provenance class of a _meta dict; anything not explicitly measured
    (including legacy pre-schema entries) counts as modeled."""
    return "measured" if (meta or {}).get("source") == "measured" else "modeled"


def save_tuned(
    kernel: str,
    genome: Dict[str, Any],
    meta: Optional[Dict[str, Any]] = None,
    path: Optional[str] = None,
    device_kind: Optional[str] = None,
) -> str:
    """Persist `genome` as the tuned default for `kernel` (atomic write).

    With `device_kind` the genome lands in that device's layer; without,
    in the device-agnostic layer (where only modeled entries belong —
    measured saves must carry their device kind, and `launch/autotune.py`
    always passes it for wall-clock runs).  A modeled save can never
    overwrite a measured entry for the same device kind: the measured
    entry is kept and a RuntimeWarning is emitted.
    """
    path = path or genomes_path()
    if _source(meta) == "measured" and device_kind is None:
        raise ValueError(
            "measured genomes are device-specific: save_tuned requires "
            "device_kind when meta['source'] == 'measured'"
        )

    refused = []

    # the per-kernel merge runs against the content read inside the
    # atomic rewrite — building the entry from a separate earlier read
    # would let a concurrent saver's device layers be silently dropped
    def merge(existing: Dict[str, Any]) -> Dict[str, Any]:
        entry = dict(existing.get(kernel) or {})
        if device_kind is not None:
            by_dev = dict(entry.get("_by_device") or {})
            prev = by_dev.get(device_kind)
            if (
                isinstance(prev, dict)
                and _source(prev.get("_meta")) == "measured"
                and _source(meta) == "modeled"
            ):
                refused.append(device_kind)
                return existing
            sub = dict(genome)
            if meta:
                sub["_meta"] = meta
            by_dev[device_kind] = sub
            entry["_by_device"] = by_dev
        else:
            by_dev = entry.get("_by_device")
            entry = dict(genome)
            if meta:
                entry["_meta"] = meta
            if by_dev:  # device layers survive a device-agnostic (modeled) save
                entry["_by_device"] = by_dev
        return {**existing, kernel: entry}

    merge_json(path, merge)
    if refused:
        warnings.warn(
            f"save_tuned({kernel!r}, device_kind={device_kind!r}): refusing "
            "to overwrite a measured entry with a modeled one — re-run "
            "with --timing wall on that device to replace it",
            RuntimeWarning,
            stacklevel=2,
        )
    invalidate()
    return path
