"""Tuned-genome registry: autotuner winners become dispatch defaults.

`launch/autotune.py --save` persists each kernel's best genome here
(``tuned_genomes.json`` beside this module, overridable with the
``REPRO_TUNED_GENOMES`` env var), and the `ops.py` wrappers resolve any
block/chunk argument left as ``None`` through `get_tuned` — so an
autotune run upgrades every caller's defaults instead of ending life as
print-only JSON.  Passing explicit block sizes always wins.

Entries merge over `_BUILTIN` (the safe hand-picked fallbacks), so a
partial file or an unknown kernel never breaks dispatch.  ``_meta`` keys
inside an entry record provenance (modeled time, trials, seed) and are
ignored by `get_tuned`.

Note: the jit'd dispatch wrappers resolve tuned defaults at trace time;
a registry update during a process's lifetime only affects call
signatures not yet traced (``jax.clear_caches()`` forces re-resolution).
"""

from __future__ import annotations

import copy
import os
from typing import Any, Dict, Optional

from repro.ioutil import read_json, update_json

ENV_VAR = "REPRO_TUNED_GENOMES"
_DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "tuned_genomes.json")

_BUILTIN: Dict[str, Dict[str, Any]] = {
    "flash": {"block_q": 128, "block_k": 128},
    "matmul": {"block_m": 256, "block_n": 256, "block_k": 256},
    "wkv6": {"chunk": 64},
    "rmsnorm": {"block_rows": 128},
    "rglru": {"chunk": 64},
}

_loaded: Optional[Dict[str, Dict[str, Any]]] = None


def genomes_path() -> str:
    return os.environ.get(ENV_VAR, _DEFAULT_PATH)


def invalidate() -> None:
    """Drop the in-memory registry; next access re-reads the file."""
    global _loaded
    _loaded = None


def _load() -> Dict[str, Dict[str, Any]]:
    global _loaded
    if _loaded is None:
        _loaded = copy.deepcopy(_BUILTIN)
        path = genomes_path()
        if os.path.exists(path):
            for kernel, genome in read_json(path).items():
                if isinstance(genome, dict):
                    _loaded.setdefault(kernel, {}).update(
                        {k: v for k, v in genome.items() if not k.startswith("_")}
                    )
    return _loaded


def get_tuned(kernel: str) -> Dict[str, Any]:
    """The tuned genome for `kernel` (builtin fallbacks merged under file)."""
    return dict(_load().get(kernel, {}))


def resolve(kernel: str, knob: str, value: Any, fallback: Any) -> Any:
    """Dispatch helper: explicit `value` wins, else tuned, else `fallback`."""
    if value is not None:
        return value
    return _load().get(kernel, {}).get(knob, fallback)


def save_tuned(
    kernel: str,
    genome: Dict[str, Any],
    meta: Optional[Dict[str, Any]] = None,
    path: Optional[str] = None,
) -> str:
    """Persist `genome` as the tuned default for `kernel` (atomic write)."""
    path = path or genomes_path()
    entry = dict(genome)
    if meta:
        entry["_meta"] = meta
    update_json(path, {kernel: entry})
    invalidate()
    return path
