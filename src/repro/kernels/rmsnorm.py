"""Pallas fused RMSNorm: one HBM read, fp32 statistics, (1+scale) gain.

Grid over row tiles; the full feature dim stays in VMEM (d * block_rows * 2B
must fit — the autotuner's constraint).  Fusing norm + scale halves HBM
traffic vs the unfused XLA pair, which is what makes this a hot-spot kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    normed = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (normed * (1.0 + s_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jax.Array,
    scale: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """x: (..., D); scale: (D,).  Normalizes the last dim."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for s in x.shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    while rows % br:
        br //= 2
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
