"""Pallas chunked WKV6 (RWKV6 linear attention with data-dependent decay).

Grid (B*H, S/chunk) with the chunk axis sequential: the (K x K) state matrix
lives in VMEM scratch and is carried across chunk steps — the TPU-native
version of the recurrence, replacing CUDA's per-warp state registers with
VMEM persistence (hardware-adaptation note in DESIGN.md).

Per chunk the kernel computes the same math as models/recurrent.wkv6_chunked:
inter-chunk term through the carried state, intra-chunk lower-triangular
attention with decay ratios, and the state update — all MXU-shaped matmuls.
`chunk` is the kernel genome (VMEM working set ~ 5*C*K + K*K fp32).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_scr, *, chunk):
    c_i = pl.program_id(1)

    @pl.when(c_i == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    r = r_ref[0].astype(jnp.float32)  # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)  # (1, K) bonus

    cum = jnp.cumsum(lw, axis=0)
    cum_excl = cum - lw
    total = cum[-1:, :]

    state = state_scr[...]
    r_dec = r * jnp.exp(cum_excl)
    o_inter = jax.lax.dot_general(
        r_dec, state, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    k_dec = k * jnp.exp(jnp.minimum(-cum, 30.0))
    m = jax.lax.dot_general(
        r_dec, k_dec, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    idx_r = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    idx_c = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    m = jnp.where(idx_r > idx_c, m, 0.0)
    diag = jnp.sum(r * u * k, axis=1, keepdims=True)
    o_intra = jax.lax.dot_general(
        m, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) + diag * v

    k_state = k * jnp.exp(total - cum)
    state_scr[...] = jnp.exp(total).T * state + jax.lax.dot_general(
        k_state, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    o_ref[0] = (o_inter + o_intra).astype(o_ref.dtype)


def wkv6_pallas(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    log_w: jax.Array,
    u: jax.Array,
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """r/k/v/log_w: (B, S, H, K); u: (H, K).  Returns (B, S, H, K) fp32."""
    b, s, h, kd = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def flat(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, kd)

    rf, kf, vf, lwf = flat(r), flat(k), flat(v), flat(log_w)
    uf = jnp.broadcast_to(u[None, :, :], (b, h, kd)).reshape(b * h, 1, kd)

    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, kd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, kd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, kd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, kd), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1, kd), lambda bh, ci: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, kd), lambda bh, ci: (bh, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, kd), jnp.float32),
        scratch_shapes=[pltpu.VMEM((kd, kd), jnp.float32)],
        interpret=interpret,
    )(rf, kf, vf, lwf, uf)
    return out.reshape(b, h, s, kd).transpose(0, 2, 1, 3)
