"""Pallas flash-decode: single-query attention over a *paged* KV cache.

Serving decode is the one attention shape the training kernels cannot
serve well: one new query token per sequence against a long, ragged,
append-only KV history.  A dense cache pads every sequence to the decode
horizon and re-reads the padding every step; this kernel instead reads a
page pool — fixed-size pages shared by all sequences, wired together by a
per-sequence *block table* (the vLLM layout) — so HBM traffic per step is
proportional to the tokens actually cached.

Structure (the TPU paged-attention idiom):

* Pools stay in HBM (``memory_space=ANY``): shape (KV, P, page_size, D),
  contiguous per (kv head, page) so a page fetch is one simple DMA.
* The block table and per-sequence lengths ride in as *scalar prefetch*
  arguments (`pltpu.PrefetchScalarGridSpec`) — available before the body
  runs, exactly what the DMA source indices need.
* Grid is (B * KV_heads, num_page_chunks) with the page-chunk axis
  innermost and sequential: a split-K sweep over the sequence.  Each step
  gathers ``block_pages`` pages into a VMEM buffer with per-page async
  copies, then runs one online-softmax update; the (m, l, acc) state
  lives in VMEM scratch across chunks and is finalized on the last chunk
  (the same merge structure as flash_attention.py).
* GQA is zero-copy by construction: one grid step loads a kv head's
  pages ONCE and applies all ``h // kv_heads`` query heads of the group
  against them as rows of a single (g, page_tokens) dot — the decode-side
  analogue of flash_attention.py's ``bh // group`` index_map trick
  (there: g query-head programs share one kv tile; here: one program
  carries the g query rows).  Nothing ever materializes repeated K/V.
* Chunks entirely past a sequence's length are skipped (`pl.when`), so
  short sequences cost proportionally less even inside a long grid.

``(page_size, block_pages)`` is the kernel genome: page_size sets the
allocator granularity and DMA size, block_pages how many pages are fused
into one compute tile.  `launch/autotune.py --kernel flash_decode`
searches both (roofline model in `repro.evaluation.timing`, measured
wall-clock on hardware) and `repro.kernels.tuned` persists the winners.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_decode_kernel(
    bt_ref,      # scalar prefetch: (B, MP) int32 block tables
    len_ref,     # scalar prefetch: (B,) int32 valid lengths (query row 0)
    q_ref,       # (1, nq*g, d) query rows of one kv group, nq tokens
    k_hbm,       # (KV, P, ps, d) page pool, HBM-resident
    v_hbm,       # (KV, P, ps, dv) page pool, HBM-resident
    o_ref,       # (1, nq*g, dv)
    k_buf,       # VMEM (bp*ps, d) gather buffer
    v_buf,       # VMEM (bp*ps, dv)
    m_scr,       # VMEM (nq*g, 1) running max
    l_scr,       # VMEM (nq*g, 1) running denom
    acc_scr,     # VMEM (nq*g, dv) output accumulator
    k_sem,
    v_sem,
    *,
    bp: int,
    ps: int,
    kvh: int,
    scale: float,
    cap: Optional[float],
    nc: int,
    nq: int,
    g: int,
):
    i = pl.program_id(0)  # b * kvh + kv
    c = pl.program_id(1)  # page chunk (sequential split-K axis)
    b = i // kvh
    kv = i % kvh

    @pl.when(c == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ln = len_ref[b]
    start = c * bp * ps

    # chunks entirely past this sequence's history contribute nothing:
    # skip the DMAs and the update, leave the scratch state untouched.
    # With nq query tokens the deepest row sees ln + nq - 1 positions.
    @pl.when(start < ln + (nq - 1))
    def _body():
        for j in range(bp):  # static unroll: per-page gather DMAs
            pg = bt_ref[b, c * bp + j]
            ck = pltpu.make_async_copy(
                k_hbm.at[kv, pg], k_buf.at[pl.ds(j * ps, ps)], k_sem
            )
            cv = pltpu.make_async_copy(
                v_hbm.at[kv, pg], v_buf.at[pl.ds(j * ps, ps)], v_sem
            )
            ck.start()
            cv.start()
            ck.wait()
            cv.wait()
        # one DMA gather serves all nq query tokens — that is the whole
        # speculative-verify win in the DMA-bound decode regime.  The
        # softmax update stays a static per-token unroll, each iteration
        # op-for-op the nq == 1 body over a (g, chunk) tile with its own
        # skip (query token t causally sees ln + t positions), so every
        # row's (m, l, acc) trajectory is bit-identical to a sequential
        # single-token sweep — a fused (nq*g, chunk) dot is NOT bitwise
        # row-stable under XLA and would break the stream-identity gate.
        for t in range(nq):
            @pl.when(start < ln + t)
            def _upd(t=t):
                sl = pl.ds(t * g, g)
                q = q_ref[0, sl]  # (g, d)
                s = jax.lax.dot_general(
                    q, k_buf[...], (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32,
                )
                s = s * scale
                if cap is not None:
                    s = cap * jnp.tanh(s / cap)
                tpos = start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
                s = jnp.where(tpos < ln + t, s, NEG_INF)
                # chunk 0 always holds token 0, so by the time a fully-
                # masked tile could update the state, m is already finite —
                # exp(NEG_INF - m) underflows to exactly 0 and masked slots
                # never pollute l/acc.
                m_prev = m_scr[sl]
                m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
                p = jnp.exp(s - m_new)
                corr = jnp.exp(m_prev - m_new)
                l_scr[sl] = l_scr[sl] * corr + jnp.sum(p, axis=1, keepdims=True)
                m_scr[sl] = m_new
                pv = jax.lax.dot_general(
                    p.astype(v_buf.dtype), v_buf[...], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                )
                acc_scr[sl] = acc_scr[sl] * corr + pv

    @pl.when(c == nc - 1)
    def _finalize():
        # a never-admitted slot (length 0) skipped every chunk: l == 0 and
        # the guarded divide emits exact zeros instead of NaN
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(
            o_ref.dtype
        )


def flash_decode_pallas(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    lengths: jax.Array,
    *,
    logit_cap: Optional[float] = None,
    block_pages: int = 4,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, T, H, D) — T causally ordered query tokens per sequence
    (T == 1 is classic decode; T > 1 is a speculative verify tile where
    query t sits at position lengths-1+t, so its valid history is
    lengths+t); pools: (KV, P, page_size, D); block_tables: (B, max_pages)
    int32 page ids (0 = the reserved null page); lengths: (B,) valid token
    counts for query row 0.  Returns (B, T, H, Dv)."""
    b, nq, h, d = q.shape
    kvh, _, ps, _ = k_pages.shape
    dv = v_pages.shape[-1]
    mp = block_tables.shape[1]
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    bp = min(block_pages, mp)
    assert mp % bp == 0, (mp, bp)
    nc = mp // bp

    # heads of one kv group are contiguous in H, so the (B*KV, nq*g, d)
    # view only permutes the token axis inside a group; for nq == 1 it is
    # a pure reshape — no transpose, no copy
    qf = (
        q.reshape(b, nq, kvh, g, d)
        .transpose(0, 2, 1, 3, 4)
        .reshape(b * kvh, nq * g, d)
    )

    kernel = functools.partial(
        _flash_decode_kernel,
        bp=bp, ps=ps, kvh=kvh, scale=d**-0.5, cap=logit_cap, nc=nc,
        nq=nq, g=g,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b * kvh, nc),
        in_specs=[
            pl.BlockSpec((1, nq * g, d), lambda i, c, bt, ln: (i, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec(
            (1, nq * g, dv), lambda i, c, bt, ln: (i, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((bp * ps, d), k_pages.dtype),
            pltpu.VMEM((bp * ps, dv), v_pages.dtype),
            pltpu.VMEM((nq * g, 1), jnp.float32),
            pltpu.VMEM((nq * g, 1), jnp.float32),
            pltpu.VMEM((nq * g, dv), jnp.float32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * kvh, nq * g, dv), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), qf,
      k_pages, v_pages)
    return (
        out.reshape(b, kvh, nq, g, dv)
        .transpose(0, 2, 1, 3, 4)
        .reshape(b, nq, h, dv)
    )
