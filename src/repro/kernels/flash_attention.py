"""Pallas flash attention (causal, GQA, optional logit soft-cap).

TPU-native tiling: the grid is (batch*q_heads, S/bq, S/bk) with the kv-block
dimension innermost and *sequential* — the (m, l, acc) online-softmax state
lives in VMEM scratch and persists across kv steps of one q tile (the
standard TPU flash structure; HBM->VMEM streaming of K/V tiles is expressed
by the BlockSpecs, MXU work by the two dots per step).

GQA is zero-copy: K/V stay at their natural (B*KV, S, D) layout and the
K/V BlockSpec index_maps send every q head of a group to the SAME kv-head
tiles (``bh // group``).  Nothing materializes a per-q-head repeated copy —
the old ``jnp.repeat`` pre-pass cost G× the K/V HBM footprint and traffic
(tests assert the repeat-free jaxpr).

Block shapes (bq, bk) are the kernel genome — multiples of 128 keep the MXU
systolic array full; the autotuner searches them against the v5e cost model
and `repro.kernels.tuned` persists the winners as dispatch defaults.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, bq, bk, scale, cap, nk
):
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]  # (bq, d)
    k = k_ref[0]  # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    s = s * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    q_pos = q_i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    s = jnp.where(q_pos >= k_pos, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_new
    pv = jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_scr[...] = acc_scr[...] * corr + pv

    @pl.when(kv_i == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    logit_cap: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """q: (B, S, H, D); k/v: (B, S, KV, D).  Returns (B, S, H, Dv)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    dv = v.shape[-1]
    assert h % kvh == 0, (h, kvh)
    g = h // kvh
    bq = min(block_q, s)
    bk = min(block_k, s)
    assert s % bq == 0 and s % bk == 0, (s, bq, bk)
    nq, nk = s // bq, s // bk

    # Query heads flatten to (B*H, S, D) with head h belonging to kv head
    # h // g (the reference's grouping).  K/V are NOT repeated: they keep
    # their (B*KV, S, D) layout and the index_maps below stream the same
    # kv tile to all g query heads of a group — zero-copy GQA.
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, s, dv)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, scale=d**-0.5, cap=logit_cap, nk=nk
    )
    # bh = b_idx * h + h_idx and h = kvh * g, so bh // g = b_idx * kvh + kv
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda bh, qi, ki: (bh // g, ki, 0)),
            pl.BlockSpec((1, bk, dv), lambda bh, qi, ki: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dv), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom
            pltpu.VMEM((bq, dv), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, dv).transpose(0, 2, 1, 3)
