"""Pallas RG-LRU linear-recurrence scan.

Grid (B, S/chunk) with the chunk axis sequential and the hidden state h in
VMEM scratch.  Within a chunk the recurrence h_t = a_t * h_{t-1} + b_t runs
as a log2(C)-step Blelloch-style doubling over VMEM tiles (vectorized over
the width dim on the VPU), so the sequential depth is log(C) rather than C.
`chunk` is the genome knob.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, b_ref, o_ref, h_scr, *, chunk):
    c_i = pl.program_id(1)

    @pl.when(c_i == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0].astype(jnp.float32)  # (C, W)
    b = b_ref[0].astype(jnp.float32)

    # inclusive scan of the affine recurrence by doubling:
    # (A, B) composed with shift-by-2^k of itself
    steps = int(math.log2(chunk))
    A, B = a, b
    for k in range(steps):
        sh = 1 << k
        A_prev = jnp.concatenate([jnp.ones((sh, A.shape[1]), A.dtype), A[:-sh]], 0)
        B_prev = jnp.concatenate([jnp.zeros((sh, B.shape[1]), B.dtype), B[:-sh]], 0)
        B = A * B_prev + B
        A = A * A_prev
    # fold in the carried state: h_t = A_t * h_in + B_t
    out = A * h_scr[...] + B
    h_scr[...] = out[-1]
    o_ref[0] = out.astype(o_ref.dtype)


def rglru_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    chunk: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """Linear recurrence h_t = a_t*h_{t-1} + b_t.  a, b: (B, S, W) -> (B, S, W)."""
    bsz, s, w = a.shape
    chunk = min(chunk, s)
    while s % chunk or (chunk & (chunk - 1)):
        chunk //= 2
    nc = s // chunk
    kernel = functools.partial(_rglru_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(bsz, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, w), lambda bi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, w), lambda bi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, w), lambda bi, ci: (bi, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, s, w), a.dtype),
        scratch_shapes=[pltpu.VMEM((w,), jnp.float32)],
        interpret=interpret,
    )(a, b)
