"""Pallas blocked matmul with fp32 VMEM accumulator.

Grid (M/bm, N/bn, K/bk), K innermost and sequential: each (i, j) output tile
is revisited across K steps accumulating in VMEM scratch — the canonical MXU
tiling.  (bm, bn, bk) are the kernel genome; 128-multiples keep the 128x128
systolic array saturated and the (bm*bk + bk*bn + bm*bn) working set must
fit VMEM (checked by `vmem_bytes`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_scr, *, nk):
    k_i = pl.program_id(2)

    @pl.when(k_i == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_i == nk - 1)
    def _done():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def vmem_bytes(bm: int, bn: int, bk: int, itemsize: int = 2) -> int:
    return (bm * bk + bk * bn) * itemsize + bm * bn * 4


def matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    interpret: bool = True,
) -> jax.Array:
    """a: (M, K) @ b: (K, N) -> (M, N)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    nk = k // bk
    kernel = functools.partial(_mm_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
