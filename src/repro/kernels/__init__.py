"""Pallas TPU kernels for the compute hot spots.

Each kernel ships as a triple:
    <name>.py  — pl.pallas_call + explicit BlockSpec VMEM tiling
    ops.py     — jit'd dispatch wrappers (backend="xla" | "pallas_interpret")
    ref.py     — pure-jnp oracles the tests sweep against

Block shapes are genome knobs: launch/autotune.py drives the EvoEngineer
engine over them with the TPU v5e cost model as f(p) (see DESIGN.md §3 —
the paper's own future-work item, "co-evolving kernels with their
compilation parameters").  Winners persist in tuned.py's registry
(tuned_genomes.json) and become the ops-layer dispatch defaults.
"""

__all__ = ["ops", "ref", "tuned"]
