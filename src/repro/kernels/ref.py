"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, logit_cap: Optional[float] = None):
    from repro.models.attention import full_attention

    return full_attention(q, k, v, causal=True, logit_cap=logit_cap)


def flash_decode_ref(
    q, k_pages, v_pages, block_tables, lengths, *, logit_cap: Optional[float] = None
):
    """Gather-then-attend oracle for the paged decode kernel.

    q: (B, T, H, D); pools: (KV, P, page_size, D); block_tables: (B, MP)
    int32; lengths: (B,) valid tokens for query row 0 (row t sees
    lengths + t, causally).  The gather reconstructs each sequence's
    cache in page order, so when max_pages * page_size equals a dense
    cache's max_len the T == 1 path is bit-identical to
    `decode_attention` over the dense cache (the paged==dense parity
    contract); T > 1 (speculative verify) routes through
    `chunk_decode_attention`.
    """
    from repro.models.attention import chunk_decode_attention, decode_attention

    kvh, _, ps, d = k_pages.shape
    b, mp = block_tables.shape
    # (KV, B, MP, ps, D) -> (B, MP*ps, KV, D): token order within a page
    # and page order within the table both preserved
    k = k_pages[:, block_tables].transpose(1, 2, 3, 0, 4).reshape(b, mp * ps, kvh, d)
    v = v_pages[:, block_tables].transpose(1, 2, 3, 0, 4).reshape(
        b, mp * ps, kvh, v_pages.shape[-1]
    )
    if q.shape[1] == 1:
        return decode_attention(q, k, v, lengths=lengths, logit_cap=logit_cap)
    return chunk_decode_attention(q, k, v, start=lengths - 1, logit_cap=logit_cap)


def matmul_ref(a, b):
    return jnp.dot(a, b, preferred_element_type=a.dtype)


def rmsnorm_ref(x, scale, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * (1.0 + scale)).astype(x.dtype)


def wkv6_ref(r, k, v, log_w, u, *, chunk: int = 16):
    from repro.models.recurrent import wkv6_chunked

    out, _ = wkv6_chunked(r, k, v, log_w, u, chunk=chunk)
    return out


def wkv6_sequential_ref(r, k, v, log_w, u):
    """Step-by-step recurrence — the ground-truth oracle."""
    from repro.models.recurrent import wkv6_step

    b, s, h, kd = r.shape
    state = jnp.zeros((b, h, kd, kd), jnp.float32)
    outs = []
    for t in range(s):
        o, state = wkv6_step(
            r[:, t : t + 1], k[:, t : t + 1], v[:, t : t + 1],
            log_w[:, t : t + 1], u, state,
        )
        outs.append(o[:, 0])
    return jnp.stack(outs, axis=1)


def rglru_ref(a, b):
    """h_t = a_t * h_{t-1} + b_t via associative scan."""

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
