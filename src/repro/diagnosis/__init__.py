"""Profiler-in-the-loop diagnosis: *why* is this candidate slow?

EvoEngineer's evolution loop historically fed only a scalar runtime back
to the proposer.  This package closes that feedback loop (the ROADMAP's
"Profiler-in-the-loop evolution" item): every evaluated candidate gets a
structured `PerfDiagnosis` — bound regime, achieved-vs-roofline %, VMEM
pressure, dominant HLO ops by cost share, tile/grid knobs, DMA-vs-compute
breakdown, collective wire traffic — produced by `diagnose()` from three
sources fused together:

* `repro.launch.hlo_analysis.analyze_compiled` — trip-count-corrected
  FLOPs / HBM bytes / wire bytes / per-op byte shares of the compiled
  candidate;
* the `RooflineTiming` v5e machine model (`repro.evaluation.timing`) —
  peak FLOP/s, HBM bandwidth, ridge point, VMEM budget;
* the candidate's measured (or simulated) timing statistics.

Degradation is graceful by design: when compilation or cost analysis is
unavailable (interpret mode, CPU backends without cost analysis, exotic
candidates), `diagnose()` returns a partial diagnosis with its `level`
field naming what is missing — it NEVER raises into the evaluator, so a
valid candidate can never be turned invalid by its own diagnosis.
"""

from repro.diagnosis.record import (
    DIAG_PROMPT_BUDGET,
    PerfDiagnosis,
    render_diagnosis_section,
)
from repro.diagnosis.pipeline import classify_bound, diagnose, diagnose_jitted

__all__ = [
    "DIAG_PROMPT_BUDGET",
    "PerfDiagnosis",
    "classify_bound",
    "diagnose",
    "diagnose_jitted",
    "render_diagnosis_section",
]
