"""The `diagnose()` pipeline: fuse HLO costs + roofline model + timing.

Three fusion sources, all optional — the pipeline produces the best
diagnosis the available signals allow and records what was missing in
`PerfDiagnosis.notes` instead of raising:

* HLO costs from `repro.launch.hlo_analysis.analyze_compiled` (trip-count
  corrected FLOPs / HBM bytes / wire bytes / per-op byte shares);
* the v5e machine model from `repro.evaluation.timing` (peak FLOP/s, HBM
  bandwidth — their ratio is the ridge point — and the VMEM budget);
* the candidate's `Measurement` verdict (runtime, mode, noise floor).

`diagnose_jitted()` is the evaluator-facing entry: it compiles the
already-traced jitted candidate, runs cost + memory analysis, and fuses.
EVERY exception — including a SIGALRM `TimeoutError` from the evaluator's
per-candidate deadline firing mid-diagnosis — is caught and degraded to a
partial diagnosis, so diagnosing a valid candidate can never invalidate
it.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.diagnosis.record import _TOP_OPS, PerfDiagnosis
from repro.evaluation.timing import VMEM_BUDGET, _peaks


def classify_bound(
    flops: float,
    bytes_accessed: float,
    peak: Optional[float] = None,
    bw: Optional[float] = None,
) -> str:
    """Roofline verdict for a (flops, HBM bytes) workload: "compute" when
    its arithmetic intensity meets the machine's ridge point, "memory"
    below it, "unknown" when the workload is degenerate (no bytes moved —
    nothing to classify)."""
    if bytes_accessed <= 0.0 or flops < 0.0:
        return "unknown"
    if peak is None or bw is None:
        peak, bw = _peaks()
    return "compute" if flops / bytes_accessed >= peak / bw else "memory"


def diagnose(
    *,
    costs: Optional[Dict[str, Any]] = None,
    runtime_us: Optional[float] = None,
    timing_mode: str = "",
    noise_floor_us: Optional[float] = None,
    vmem_peak_bytes: Optional[int] = None,
    grid: Optional[Dict[str, Any]] = None,
    notes: Optional[List[str]] = None,
) -> PerfDiagnosis:
    """Fuse whatever signals are present into one PerfDiagnosis.

    ``costs`` is an `analyze_compiled` result dict (or None when
    compilation / cost analysis was unavailable); ``runtime_us`` the
    candidate's timing verdict (or None).  Never raises.
    """
    notes = list(notes or [])
    d = PerfDiagnosis(
        runtime_us=runtime_us,
        timing_mode=timing_mode,
        noise_floor_us=noise_floor_us,
        grid=dict(grid) if grid else None,
        notes=notes,
    )
    try:
        peak, bw = _peaks()
    except Exception as e:  # noqa: BLE001 — machine model is best-effort
        peak = bw = None
        notes.append(f"machine model unavailable: {type(e).__name__}")
    if costs:
        d.flops = float(costs.get("flops", 0.0))
        d.bytes_accessed = float(costs.get("bytes_accessed", 0.0))
        d.transcendentals = float(costs.get("transcendentals", 0.0))
        d.wire_bytes = float(costs.get("wire_bytes", 0.0))
        d.dominant_ops = _dominant_ops(costs.get("op_bytes") or {})
        if d.bytes_accessed > 0.0:
            d.arithmetic_intensity = d.flops / d.bytes_accessed
        if peak and bw:
            d.ridge_intensity = peak / bw
            d.bound = classify_bound(d.flops, d.bytes_accessed, peak, bw)
            d.roofline_us = max(d.flops / peak, d.bytes_accessed / bw) * 1e6
            if runtime_us and d.roofline_us > 0.0:
                d.achieved_pct = min(100.0, 100.0 * d.roofline_us / runtime_us)
    if vmem_peak_bytes is not None:
        d.vmem_peak_bytes = int(vmem_peak_bytes)
        d.vmem_budget = VMEM_BUDGET
        d.vmem_pressure = vmem_peak_bytes / VMEM_BUDGET
        d.vmem_ok = vmem_peak_bytes <= VMEM_BUDGET
    if timing_mode == "simulated" and d.achieved_pct is not None:
        notes.append("simulated timing: roofline % is indicative only")
    d.level = _level(costs is not None, runtime_us is not None)
    return d


def _level(have_costs: bool, have_timing: bool) -> str:
    if have_costs and have_timing:
        return "full"
    if have_costs:
        return "costs_only"
    if have_timing:
        return "timing_only"
    return "empty"


def _dominant_ops(op_bytes: Dict[str, float]) -> List[Tuple[str, float]]:
    total = sum(op_bytes.values())
    if total <= 0.0:
        return []
    ranked = sorted(op_bytes.items(), key=lambda kv: (-kv[1], kv[0]))
    return [(op, b / total) for op, b in ranked[:_TOP_OPS]]


def diagnose_jitted(
    task,
    jfn,
    *,
    runtime_us: Optional[float] = None,
    timing_mode: str = "",
    noise_floor_us: Optional[float] = None,
    input_seed: int = 10_000,
    grid: Optional[Dict[str, Any]] = None,
) -> PerfDiagnosis:
    """Evaluator entry point: compile the (already successfully traced)
    jitted candidate against the task's input shapes, extract HLO costs +
    a VMEM-peak proxy, and fuse with the timing verdict.  Degrades
    gracefully — any failure (CPU backends without cost analysis,
    interpret-mode Pallas candidates, the SIGALRM deadline firing
    mid-analysis) lands in `notes`, never propagates."""
    costs: Optional[Dict[str, Any]] = None
    vmem: Optional[int] = None
    notes: List[str] = []
    try:
        compiled = jfn.lower(*task.make_inputs(input_seed)).compile()
    except Exception as e:  # noqa: BLE001 — incl. TimeoutError: degrade, never fail
        compiled = None
        notes.append(f"compile unavailable: {type(e).__name__}")
    if compiled is not None:
        try:
            from repro.launch.hlo_analysis import analyze_compiled

            costs = analyze_compiled(compiled, n_devices=1)
        except Exception as e:  # noqa: BLE001
            notes.append(f"cost analysis unavailable: {type(e).__name__}")
        try:
            # temp buffers are the closest portable proxy for on-chip
            # working-set pressure; CPU backends report it too
            ma = compiled.memory_analysis()
            vmem = int(getattr(ma, "temp_size_in_bytes"))
        except Exception:  # noqa: BLE001 — older jax / exotic backends
            pass
    return diagnose(
        costs=costs,
        runtime_us=runtime_us,
        timing_mode=timing_mode,
        noise_floor_us=noise_floor_us,
        vmem_peak_bytes=vmem,
        grid=grid,
        notes=notes,
    )
