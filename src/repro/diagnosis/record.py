"""The `PerfDiagnosis` record: one structured why-is-it-slow verdict.

A diagnosis is a plain dataclass with a stable JSON form (`to_dict` /
`from_dict`, floats rounded so serialization is platform-stable), a
hand-rolled schema validator (no external jsonschema dependency — the
container must not grow new packages), and a *bounded* prompt rendering:
`render()` and `render_diagnosis_section()` never exceed their character
budget, so a diagnosis-augmented prompt cannot blow past `LLMClient`
token-budget estimates no matter how many HLO op kinds a candidate
compiles into.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

# Hard ceiling (characters) for the whole "Performance diagnosis" prompt
# section: parent diagnosis + delta-vs-baseline line.  ~900 chars is
# ~220 tokens under the 4-chars/token estimate LLMClient budgets with.
DIAG_PROMPT_BUDGET = 900

# how many HLO op kinds the dominant-op breakdown keeps
_TOP_OPS = 3

_LEVELS = ("full", "costs_only", "timing_only", "empty")
_BOUNDS = ("compute", "memory", "unknown")


@dataclasses.dataclass
class PerfDiagnosis:
    """Why a candidate runs at the speed it does.

    ``level`` names which signal sources were available:
      full        — HLO cost analysis AND a timing verdict were fused
      costs_only  — compiled + analyzed, but no runtime to compare against
      timing_only — runtime known, but compilation/cost analysis was
                    unavailable (interpret mode, CPU backends, exotic
                    candidates); roofline fields are absent
      empty       — neither source; only notes explaining why
    """

    level: str = "empty"
    # -- bound regime (roofline verdict) -------------------------------
    bound: str = "unknown"  # "compute" | "memory" | "unknown"
    arithmetic_intensity: Optional[float] = None  # flops / HBM byte
    ridge_intensity: Optional[float] = None  # machine balance point
    # -- HLO cost totals (per device, trip-count corrected) ------------
    flops: Optional[float] = None
    bytes_accessed: Optional[float] = None
    transcendentals: Optional[float] = None
    wire_bytes: Optional[float] = None  # collective traffic
    dominant_ops: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    # -- roofline vs measured ------------------------------------------
    roofline_us: Optional[float] = None  # max(compute, memory) bound
    runtime_us: Optional[float] = None  # the candidate's verdict
    achieved_pct: Optional[float] = None  # roofline_us / runtime_us * 100
    timing_mode: str = ""  # "wall" | "simulated" | ""
    noise_floor_us: Optional[float] = None
    # -- VMEM pressure --------------------------------------------------
    vmem_peak_bytes: Optional[int] = None
    vmem_budget: Optional[int] = None
    vmem_pressure: Optional[float] = None  # peak / budget
    vmem_ok: Optional[bool] = None
    # -- launch shape ---------------------------------------------------
    grid: Optional[Dict[str, Any]] = None  # genome / tile knobs if known
    notes: List[str] = dataclasses.field(default_factory=list)

    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """Stable JSON form: None fields omitted, floats rounded so the
        serialization is byte-stable across platforms and re-runs."""
        out: Dict[str, Any] = {"level": self.level, "bound": self.bound}
        for field, digits in _FLOAT_FIELDS:
            v = getattr(self, field)
            if v is not None:
                out[field] = round(float(v), digits)
        if self.dominant_ops:
            out["dominant_ops"] = [
                [op, round(float(share), 4)] for op, share in self.dominant_ops
            ]
        if self.timing_mode:
            out["timing_mode"] = self.timing_mode
        if self.vmem_peak_bytes is not None:
            out["vmem_peak_bytes"] = int(self.vmem_peak_bytes)
        if self.vmem_budget is not None:
            out["vmem_budget"] = int(self.vmem_budget)
        if self.vmem_ok is not None:
            out["vmem_ok"] = bool(self.vmem_ok)
        if self.grid is not None:
            out["grid"] = dict(self.grid)
        if self.notes:
            out["notes"] = list(self.notes)
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "PerfDiagnosis":
        known = {f.name for f in dataclasses.fields(cls)}
        kwargs = {k: v for k, v in d.items() if k in known}
        if "dominant_ops" in kwargs:
            kwargs["dominant_ops"] = [
                (op, float(share)) for op, share in kwargs["dominant_ops"]
            ]
        return cls(**kwargs)

    # ------------------------------------------------------------------
    def render(self, char_budget: int = DIAG_PROMPT_BUDGET) -> str:
        """Human/LLM-readable summary, hard-capped at ``char_budget``."""
        lines: List[str] = []
        if self.level == "empty":
            lines.append("diagnosis unavailable")
        else:
            head = f"bound={self.bound}"
            if self.achieved_pct is not None:
                head += f", achieving {self.achieved_pct:.1f}% of roofline"
            if self.roofline_us is not None and self.runtime_us is not None:
                head += (
                    f" (roofline {_fmt_us(self.roofline_us)},"
                    f" measured {_fmt_us(self.runtime_us)}"
                    f"{' ' + self.timing_mode if self.timing_mode else ''})"
                )
            lines.append(head)
            if self.arithmetic_intensity is not None and self.ridge_intensity is not None:
                lines.append(
                    f"intensity {self.arithmetic_intensity:.2f} flop/B"
                    f" vs ridge {self.ridge_intensity:.1f};"
                    f" HBM {_fmt_bytes(self.bytes_accessed)}"
                    f", wire {_fmt_bytes(self.wire_bytes)}"
                )
            if self.vmem_pressure is not None:
                lines.append(
                    f"vmem {_fmt_bytes(self.vmem_peak_bytes)}"
                    f"/{_fmt_bytes(self.vmem_budget)}"
                    f" ({100.0 * self.vmem_pressure:.1f}%"
                    f"{' ok' if self.vmem_ok else ' OVER BUDGET'})"
                )
            if self.dominant_ops:
                ops = ", ".join(
                    f"{op} {100.0 * share:.0f}%" for op, share in self.dominant_ops
                )
                lines.append(f"dominant ops: {ops}")
            if self.grid:
                lines.append(f"grid/tile: {self.grid}")
        for n in self.notes:
            lines.append(f"note: {n}")
        return _clip("\n".join(lines), char_budget)


# (field, rounding digits) for the float members of the JSON form
_FLOAT_FIELDS: Tuple[Tuple[str, int], ...] = (
    ("arithmetic_intensity", 4),
    ("ridge_intensity", 4),
    ("flops", 1),
    ("bytes_accessed", 1),
    ("transcendentals", 1),
    ("wire_bytes", 1),
    ("roofline_us", 3),
    ("runtime_us", 3),
    ("achieved_pct", 2),
    ("noise_floor_us", 3),
    ("vmem_pressure", 4),
)


def _fmt_us(v: Optional[float]) -> str:
    if v is None:
        return "?"
    if v >= 1000.0:
        return f"{v / 1000.0:.2f}ms"
    return f"{v:.1f}us"


def _fmt_bytes(v: Optional[float]) -> str:
    if v is None:
        return "?"
    v = float(v)
    for unit, div in (("GB", 2**30), ("MB", 2**20), ("KB", 2**10)):
        if v >= div:
            return f"{v / div:.1f}{unit}"
    return f"{v:.0f}B"


def _clip(text: str, budget: int) -> str:
    if len(text) <= budget:
        return text
    return text[: max(0, budget - 3)] + "..."


# --------------------------------------------------------------------------
# hand-rolled schema (the CI smoke job validates every emitted diagnosis)
# --------------------------------------------------------------------------

# field -> (allowed python types, required)
SCHEMA: Dict[str, Tuple[Tuple[type, ...], bool]] = {
    "level": ((str,), True),
    "bound": ((str,), True),
    "arithmetic_intensity": ((int, float), False),
    "ridge_intensity": ((int, float), False),
    "flops": ((int, float), False),
    "bytes_accessed": ((int, float), False),
    "transcendentals": ((int, float), False),
    "wire_bytes": ((int, float), False),
    "dominant_ops": ((list,), False),
    "roofline_us": ((int, float), False),
    "runtime_us": ((int, float), False),
    "achieved_pct": ((int, float), False),
    "timing_mode": ((str,), False),
    "noise_floor_us": ((int, float), False),
    "vmem_peak_bytes": ((int,), False),
    "vmem_budget": ((int,), False),
    "vmem_pressure": ((int, float), False),
    "vmem_ok": ((bool,), False),
    "grid": ((dict,), False),
    "notes": ((list,), False),
}


def validate(d: Dict[str, Any]) -> None:
    """Raise ValueError unless ``d`` is a valid serialized PerfDiagnosis."""
    if not isinstance(d, dict):
        raise ValueError(f"diagnosis must be a dict, got {type(d).__name__}")
    for key, (types, required) in SCHEMA.items():
        if key not in d:
            if required:
                raise ValueError(f"diagnosis missing required field {key!r}")
            continue
        v = d[key]
        # bool is an int subclass: reject True masquerading as a number
        if isinstance(v, bool) and bool not in types:
            raise ValueError(f"diagnosis field {key!r} has bool, wants {types}")
        if not isinstance(v, types):
            raise ValueError(
                f"diagnosis field {key!r} has {type(v).__name__}, wants {types}"
            )
    unknown = set(d) - set(SCHEMA)
    if unknown:
        raise ValueError(f"diagnosis has unknown fields {sorted(unknown)}")
    if d["level"] not in _LEVELS:
        raise ValueError(f"diagnosis level {d['level']!r} not in {_LEVELS}")
    if d["bound"] not in _BOUNDS:
        raise ValueError(f"diagnosis bound {d['bound']!r} not in {_BOUNDS}")
    for pair in d.get("dominant_ops", []):
        if (
            not isinstance(pair, (list, tuple))
            or len(pair) != 2
            or not isinstance(pair[0], str)
            or isinstance(pair[1], bool)
            or not isinstance(pair[1], (int, float))
        ):
            raise ValueError(f"dominant_ops entry {pair!r} is not [op, share]")
    for n in d.get("notes", []):
        if not isinstance(n, str):
            raise ValueError(f"notes entry {n!r} is not a string")


# --------------------------------------------------------------------------
# prompt section (parent diagnosis + delta vs baseline)
# --------------------------------------------------------------------------


def render_diagnosis_section(
    parent: Optional[Dict[str, Any]],
    baseline: Optional[Dict[str, Any]] = None,
    char_budget: int = DIAG_PROMPT_BUDGET,
) -> str:
    """The prompt-facing section body: the parent candidate's diagnosis
    plus a one-line delta against the task baseline's diagnosis.  Total
    output never exceeds ``char_budget`` characters."""
    if not parent:
        return ""
    pd = PerfDiagnosis.from_dict(parent)
    delta = _delta_line(pd, PerfDiagnosis.from_dict(baseline) if baseline else None)
    body = pd.render(char_budget - len(delta) - 1 if delta else char_budget)
    if delta:
        body = f"{body}\n{delta}" if body else delta
    return _clip(body, char_budget)


def _delta_line(parent: PerfDiagnosis, base: Optional[PerfDiagnosis]) -> str:
    if base is None:
        return ""
    parts: List[str] = []
    if parent.runtime_us and base.runtime_us:
        parts.append(f"{base.runtime_us / parent.runtime_us:.2f}x vs baseline")
    if parent.achieved_pct is not None and base.achieved_pct is not None:
        parts.append(
            f"roofline {base.achieved_pct:.1f}% -> {parent.achieved_pct:.1f}%"
        )
    if parent.bound != base.bound and base.bound != "unknown":
        parts.append(f"regime {base.bound} -> {parent.bound}")
    if not parts:
        return ""
    return _clip("delta: " + "; ".join(parts), 200)
