"""Train / eval step builders.

``make_train_step`` closes over (cfg, optimizer) and returns a pure function
``step(state, batch) -> (state, metrics)`` suitable for jit with explicit
shardings.  Supports gradient-accumulation microbatching (scan over
microbatches — per-microbatch grads are accumulated in fp32) and optional
int8 gradient compression on the data axis (parallel/compress.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import config as C
from repro.models.transformer import forward
from repro.train.loss import cross_entropy_loss
from repro.train.optim import Optimizer, global_norm


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def init_train_state(params: Any, optimizer: Optimizer) -> TrainState:
    return TrainState(
        params=params,
        opt_state=optimizer.init(params),
        step=jnp.zeros((), jnp.int32),
    )


def _cast_params(cfg: C.ModelConfig, params):
    """Mixed precision: fp32 master weights are cast to the compute dtype at
    the step boundary, so weight all-gathers (ZeRO-3) and their
    reduce-scatter transposes move 2-byte payloads.  Norm scales and other
    small vectors stay fp32 (numerics)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    if dtype == jnp.float32:
        return params
    return jax.tree.map(
        lambda p: p.astype(dtype) if p.ndim >= 2 and p.dtype == jnp.float32 else p,
        params,
    )


def _loss_fn(cfg: C.ModelConfig, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux, _ = forward(
        cfg, _cast_params(cfg, params), batch["tokens"], image_embeds=batch.get("image_embeds")
    )
    if cfg.padded_vocab != cfg.vocab_size:
        # mask the padding ids out of the softmax support
        valid = jnp.arange(logits.shape[-1]) < cfg.vocab_size
        logits = jnp.where(valid, logits, -1e30)
    targets = batch["targets"]
    if cfg.num_prefix_embeds > 0:
        # prefix positions carry no next-token loss; mask by prepending -1s
        b = targets.shape[0]
        pre = jnp.full((b, cfg.num_prefix_embeds) + targets.shape[2:], -1, targets.dtype)
        targets = jnp.concatenate([pre, targets], axis=1)
    ce, n_tok = cross_entropy_loss(logits, targets)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux, "n_tok": n_tok}


def make_train_step(
    cfg: C.ModelConfig,
    optimizer: Optimizer,
    *,
    microbatches: int = 1,
):
    """Returns step(state, batch) -> (new_state, metrics)."""

    def single_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: _loss_fn(cfg, p, batch), has_aux=True
        )(params)
        return loss, metrics, grads

    def step(state: TrainState, batch: Dict[str, jax.Array]):
        if microbatches == 1:
            loss, metrics, grads = single_grads(state.params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            micro = jax.tree.map(reshape, batch)

            def acc_fn(carry, mb):
                loss_a, grads_a = carry
                loss, metrics, grads = single_grads(state.params, mb)
                grads_a = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grads_a, grads
                )
                return (loss_a + loss, grads_a), metrics

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (loss_sum, grads), metrics = jax.lax.scan(
                acc_fn, (jnp.zeros(()), zero_grads), micro
            )
            loss = loss_sum / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        new_params, new_opt = optimizer.update(
            grads, state.opt_state, state.params, state.step
        )
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = global_norm(grads)
        new_state = TrainState(new_params, new_opt, state.step + 1)
        return new_state, metrics

    return step


def make_eval_step(cfg: C.ModelConfig):
    def step(params, batch):
        loss, metrics = _loss_fn(cfg, params, batch)
        return {"loss": loss, **metrics}

    return step
