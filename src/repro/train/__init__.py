"""Training substrate: optimizers, loss, data pipeline, checkpointing, steps."""

from repro.train.optim import adafactor, adamw, sgd
from repro.train.steps import make_eval_step, make_train_step
from repro.train.loss import cross_entropy_loss

__all__ = [
    "adafactor",
    "adamw",
    "sgd",
    "cross_entropy_loss",
    "make_eval_step",
    "make_train_step",
]
