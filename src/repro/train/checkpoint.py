"""Checkpointing: atomic save/restore of arbitrary pytrees + retention.

Format: one ``.npz`` holding the flattened leaves (keyed by index) plus a
JSON sidecar with the treedef structure, dtypes and metadata.  Writes are
atomic (tmp file + rename) so a crash mid-save never corrupts the latest
checkpoint — the fault-tolerance contract is: restart always finds either
the previous or the new complete checkpoint.

Used by both the training loop (params / opt state / step / data offset)
and the evolution engine (population / RNG / trial ledger).
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^ckpt_(\d+)$")


def _leaf_to_np(x):
    if isinstance(x, (int, float, bool, str)):
        return x
    return np.asarray(x)


def save(directory: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Atomically write ``tree`` as checkpoint ``step``; prune old ones."""
    os.makedirs(directory, exist_ok=True)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    arrays = {}
    scalars = {}
    for i, leaf in enumerate(leaves):
        v = _leaf_to_np(leaf)
        if isinstance(v, np.ndarray):
            arrays[f"leaf_{i}"] = v
        else:
            scalars[str(i)] = v
    meta = {
        "step": int(step),
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "scalars": scalars,
        "structure": jax.tree_util.tree_structure(tree).num_leaves,
    }

    final = os.path.join(directory, f"ckpt_{step}")
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        with open(os.path.join(tmp, "leaves.npz"), "wb") as f:
            np.savez(f, **arrays)
        # serialize treedef via example pytree of leaf indices
        idx_tree = jax.tree_util.tree_unflatten(treedef, list(range(len(leaves))))
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({**meta, "index_tree": _to_jsonable(idx_tree)}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(directory, keep)
    return final


def _to_jsonable(tree):
    if isinstance(tree, dict):
        return {"__dict__": {k: _to_jsonable(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        tag = "__list__" if isinstance(tree, list) else "__tuple__"
        return {tag: [_to_jsonable(v) for v in tree]}
    if hasattr(tree, "_fields"):  # namedtuple
        return {
            "__namedtuple__": type(tree).__name__,
            "fields": {k: _to_jsonable(v) for k, v in tree._asdict().items()},
        }
    return tree  # leaf index (int)


def _prune(directory: str, keep: int) -> None:
    steps = sorted(all_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"ckpt_{s}"), ignore_errors=True)


def all_steps(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, "meta.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(directory: str) -> Optional[int]:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: Optional[int] = None, *, template: Any = None) -> Tuple[Any, int]:
    """Load checkpoint ``step`` (default latest).  Returns (tree, step).

    With ``template`` given, leaves are restored into the template's pytree
    structure (and cast to template dtypes) — the safe path when the code's
    pytree classes (NamedTuples) are not reconstructible from JSON alone.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"ckpt_{step}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    with np.load(os.path.join(path, "leaves.npz"), allow_pickle=False) as z:
        arrays = {k: z[k] for k in z.files}
    scalars = meta.get("scalars", {})
    leaves = []
    for i in range(meta["n_leaves"]):
        if f"leaf_{i}" in arrays:
            leaves.append(arrays[f"leaf_{i}"])
        else:
            leaves.append(scalars[str(i)])
    if template is not None:
        t_leaves, treedef = jax.tree_util.tree_flatten(template)
        if len(t_leaves) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves; template has {len(t_leaves)}"
            )
        cast = [
            np.asarray(l).astype(t.dtype) if hasattr(t, "dtype") else l
            for l, t in zip(leaves, t_leaves)
        ]
        return jax.tree_util.tree_unflatten(treedef, cast), step
    tree = _from_jsonable(meta["index_tree"], leaves)
    return tree, step


def _from_jsonable(node, leaves):
    if isinstance(node, dict):
        if "__dict__" in node:
            return {k: _from_jsonable(v, leaves) for k, v in node["__dict__"].items()}
        if "__list__" in node:
            return [_from_jsonable(v, leaves) for v in node["__list__"]]
        if "__tuple__" in node:
            return tuple(_from_jsonable(v, leaves) for v in node["__tuple__"])
        if "__namedtuple__" in node:
            return {k: _from_jsonable(v, leaves) for k, v in node["fields"].items()}
    return leaves[node]
