"""Elastic scaling: re-mesh a training run onto a different device count.

The fault-tolerance story for node loss at fleet scale: checkpoints are
device-layout-agnostic (train/checkpoint.py stores plain host arrays), so a
job restarted on a smaller or larger slice rebuilds its mesh from whatever
``jax.devices()`` reports and reshards the restored state.  Two invariants
make this sound:

  * the GLOBAL batch is part of the run config, not the mesh — a restart on
    half the chips doubles per-device batch (or raises grad-accum
    microbatches via the same escalation ladder as the dry-run), so the
    optimization trajectory (in units of steps) is unchanged;
  * the data pipeline is (seed, step, process)-deterministic, and host
    sharding re-partitions the same global batch over the new process set.

``plan_elastic_config`` computes the new mesh + microbatching; ``reshard``
places a restored host-side state onto it.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    microbatches: int
    per_device_batch: int
    note: str


def plan_elastic_config(
    global_batch: int,
    *,
    devices: Optional[int] = None,
    model_parallel: int = 1,
    prev_microbatches: int = 1,
) -> ElasticPlan:
    """Choose (data, model) mesh + microbatching for the available devices.

    Keeps the model-parallel degree (weights layout) and resizes the data
    axis; if per-device batch would exceed what the previous configuration
    implied, scales microbatches so the activation footprint stays bounded.
    """
    n = devices if devices is not None else jax.device_count()
    if n % model_parallel != 0:
        # degrade model parallelism to the largest divisor that fits
        mp = model_parallel
        while mp > 1 and n % mp != 0:
            mp //= 2
        note = f"model_parallel {model_parallel} -> {mp} (devices={n})"
        model_parallel = mp
    else:
        note = ""
    data = n // model_parallel
    if global_batch % data != 0:
        # shrink the data axis to a divisor of the global batch
        d = data
        while d > 1 and global_batch % d != 0:
            d -= 1
        note += f" data {data} -> {d} (global_batch {global_batch})"
        data = d
    per_device = global_batch // data
    # keep the per-microbatch slice no larger than before the resize
    micro = prev_microbatches
    while per_device // micro > max(1, per_device // prev_microbatches // 2) * 2:
        micro *= 2
    micro = min(micro, per_device)
    while per_device % micro:
        micro -= 1
    return ElasticPlan(
        mesh_shape=(data, model_parallel),
        axis_names=("data", "model"),
        microbatches=max(1, micro),
        per_device_batch=per_device,
        note=note.strip() or "clean fit",
    )


def build_mesh(plan: ElasticPlan) -> Mesh:
    n = int(np.prod(plan.mesh_shape))
    devs = np.array(jax.devices()[:n]).reshape(plan.mesh_shape)
    return Mesh(devs, plan.axis_names)


def reshard(tree: Any, mesh: Mesh, specs: Any) -> Any:
    """Place a host-side (restored) pytree onto the mesh per the specs."""
    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(
        put, tree, specs, is_leaf=lambda x: not isinstance(x, (dict, list, tuple))
    )


def resume_elastic(
    ckpt_dir: str,
    template: Any,
    param_spec_fn,
    *,
    global_batch: int,
    model_parallel: int = 1,
    prev_microbatches: int = 1,
) -> Tuple[Any, int, Mesh, ElasticPlan]:
    """Restore the latest checkpoint and re-mesh it onto current devices.

    param_spec_fn(mesh) -> PartitionSpec pytree for the state.
    Returns (state_on_mesh, step, mesh, plan).
    """
    from repro.train import checkpoint as ckpt

    plan = plan_elastic_config(
        global_batch,
        model_parallel=model_parallel,
        prev_microbatches=prev_microbatches,
    )
    mesh = build_mesh(plan)
    state, step = ckpt.restore(ckpt_dir, template=template)
    specs = param_spec_fn(mesh)
    return reshard(state, mesh, specs), step, mesh, plan
