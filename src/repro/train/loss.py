"""Cross-entropy loss, computed without materializing full log-softmax.

loss = logsumexp(logits) - logit[target], masked where target < 0.
Handles multi-codebook logits (B, S, C, V) with targets (B, S, C).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def cross_entropy_loss(
    logits: jax.Array,
    targets: jax.Array,
    *,
    z_loss: float = 0.0,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (mean_loss, n_valid_tokens).  targets < 0 are masked."""
    logits = logits.astype(jnp.float32)
    mask = (targets >= 0).astype(jnp.float32)
    safe_targets = jnp.maximum(targets, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    target_logit = jnp.take_along_axis(
        logits, safe_targets[..., None], axis=-1
    )[..., 0]
    nll = (lse - target_logit) * mask
    if z_loss > 0.0:
        nll = nll + z_loss * jnp.square(lse) * mask
    n = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum(nll) / n, n


def shift_labels(tokens: jax.Array, pad: int = -1) -> jax.Array:
    """Next-token targets: labels[t] = tokens[t+1]; last position masked."""
    if tokens.ndim == 2:
        return jnp.concatenate(
            [tokens[:, 1:], jnp.full_like(tokens[:, :1], pad)], axis=1
        )
    return jnp.concatenate(
        [tokens[:, 1:, :], jnp.full_like(tokens[:, :1, :], pad)], axis=1
    )
