"""Data pipeline: deterministic synthetic stream + memory-mapped file source.

Determinism contract (fault tolerance): batch ``i`` of a stream is a pure
function of (seed, i) — a restarted job that resumes at step N sees exactly
the batches it would have seen without the failure.  Host-sharding: each
process materializes only its slice of the global batch (process_index /
process_count), so the pipeline scales to multi-host without change.

The file source reads token shards via np.memmap — no copies until batching.
A background prefetch thread keeps ``prefetch`` batches ready.
"""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np


class SyntheticLM:
    """Deterministic synthetic next-token data (zipf-ish token marginals)."""

    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        *,
        seed: int = 0,
        num_codebooks: int = 1,
        prefix_embeds: int = 0,
        d_model: int = 0,
        process_index: Optional[int] = None,
        process_count: Optional[int] = None,
    ):
        self.vocab = vocab_size
        self.seq = seq_len
        self.gb = global_batch
        self.seed = seed
        self.codebooks = num_codebooks
        self.prefix = prefix_embeds
        self.d_model = d_model
        self.pi = process_index if process_index is not None else jax.process_index()
        self.pc = process_count if process_count is not None else jax.process_count()
        assert global_batch % self.pc == 0, (global_batch, self.pc)
        self.local_batch = global_batch // self.pc

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, index, self.pi))
        shape = (self.local_batch, self.seq)
        if self.codebooks > 1:
            shape = shape + (self.codebooks,)
        # zipf-ish marginal: squash uniform^2 toward low token ids
        u = rng.random(shape)
        tokens = (u * u * self.vocab).astype(np.int32)
        targets = np.concatenate(
            [tokens[:, 1:], np.full_like(tokens[:, :1], -1)], axis=1
        )
        out = {"tokens": tokens, "targets": targets}
        if self.prefix:
            out["image_embeds"] = rng.standard_normal(
                (self.local_batch, self.prefix, self.d_model), dtype=np.float32
            ) * 0.02
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


class TokenFileSource:
    """Sharded token file(s) -> fixed-length LM examples via memmap."""

    def __init__(
        self,
        paths,
        seq_len: int,
        global_batch: int,
        *,
        dtype=np.int32,
        seed: int = 0,
        process_index: Optional[int] = None,
        process_count: Optional[int] = None,
    ):
        self.maps = [np.memmap(p, dtype=dtype, mode="r") for p in paths]
        self.total = sum(m.shape[0] for m in self.maps)
        self.seq = seq_len
        self.gb = global_batch
        self.seed = seed
        self.pi = process_index if process_index is not None else jax.process_index()
        self.pc = process_count if process_count is not None else jax.process_count()
        self.local_batch = global_batch // self.pc
        self.n_examples = self.total // (seq_len + 1)

    def _example(self, idx: int) -> np.ndarray:
        start = idx * (self.seq + 1)
        # find shard
        for m in self.maps:
            if start + self.seq + 1 <= m.shape[0]:
                return np.asarray(m[start : start + self.seq + 1])
            start -= m.shape[0] // (self.seq + 1) * (self.seq + 1)
        raise IndexError(idx)

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, index))
        # one global permutation draw; every host slices its own rows
        ids = rng.integers(0, self.n_examples, size=(self.gb,))
        mine = ids[self.pi * self.local_batch : (self.pi + 1) * self.local_batch]
        rows = np.stack([self._example(int(i)) for i in mine])
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "targets": rows[:, 1:].astype(np.int32),
        }

    def __iter__(self):
        i = 0
        while True:
            yield self.batch(i)
            i += 1


class Prefetcher:
    """Background-thread prefetch wrapper around any indexed source."""

    def __init__(self, source, start_index: int = 0, prefetch: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self.index = start_index
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._worker, daemon=True)
        self.thread.start()

    def _worker(self):
        i = self.index
        while not self._stop.is_set():
            try:
                self.q.put((i, self.source.batch(i)), timeout=0.5)
                i += 1
            except queue.Full:
                continue

    def __iter__(self):
        return self

    def __next__(self):
        i, batch = self.q.get()
        return batch

    def close(self):
        self._stop.set()
