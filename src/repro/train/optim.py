"""Optimizers as pure (init, update) pairs over parameter pytrees.

Built in-house (no optax dependency): AdamW (moment pytrees shaped like the
params, so they inherit param sharding), Adafactor (factored second moment —
the memory-lean option for the biggest models), and SGD+momentum.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], Tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def _tree_zeros_like(tree):
    return jax.tree.map(lambda p: jnp.zeros_like(p), tree)


# --------------------------------------------------------------------------
# AdamW
# --------------------------------------------------------------------------
def adamw(
    lr: float | Callable[[jax.Array], jax.Array] = 3e-4,
    *,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    grad_clip_norm: Optional[float] = 1.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(params):
        return {"mu": _tree_zeros_like(params), "nu": _tree_zeros_like(params)}

    def update(grads, state, params, step):
        step_f = step.astype(jnp.float32) + 1.0
        if grad_clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip_norm / jnp.maximum(gnorm, 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1**step_f
        bc2 = 1.0 - b2**step_f

        def upd(p, g, mu, nu):
            g = g.astype(jnp.float32)
            mu_n = b1 * mu + (1 - b1) * g
            nu_n = b2 * nu + (1 - b2) * jnp.square(g)
            mu_hat = mu_n / bc1
            nu_hat = nu_n / bc2
            delta = mu_hat / (jnp.sqrt(nu_hat) + eps) + weight_decay * p
            return (p - lr_t * delta).astype(p.dtype), mu_n, nu_n

        out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"mu": new_mu, "nu": new_nu}

    return Optimizer(init, update)


# --------------------------------------------------------------------------
# Adafactor (factored second moments for >=2D params)
# --------------------------------------------------------------------------
def adafactor(
    lr: float | Callable[[jax.Array], jax.Array] = 1e-2,
    *,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda step: jnp.asarray(lr, jnp.float32))

    def init(params):
        def one(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return jax.tree.map(one, params)

    def update(grads, state, params, step):
        step_f = step.astype(jnp.float32) + 1.0
        beta = 1.0 - step_f**-decay
        lr_t = lr_fn(step)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                r = (vr / jnp.maximum(denom, eps))[..., None]
                u = g / jnp.sqrt(jnp.maximum(r * vc[..., None, :], eps))
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(jnp.maximum(v, eps))
                new_s = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            new_p = p - lr_t * (u + weight_decay * p)
            return new_p.astype(p.dtype), new_s

        leaves_is = lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        out = jax.tree.map(upd, params, grads, state, is_leaf=None)
        new_params = jax.tree.map(
            lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple)
        )
        new_state = jax.tree.map(
            lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple)
        )
        return new_params, new_state

    return Optimizer(init, update)


# --------------------------------------------------------------------------
# SGD (+momentum)
# --------------------------------------------------------------------------
def sgd(lr: float = 1e-2, *, momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"m": _tree_zeros_like(params)}

    def update(grads, state, params, step):
        del step
        if momentum == 0.0:
            new_params = jax.tree.map(lambda p, g: p - lr * g, params, grads)
            return new_params, state
        new_m = jax.tree.map(lambda m, g: momentum * m + g, state["m"], grads)
        new_params = jax.tree.map(lambda p, m: p - lr * m, params, new_m)
        return new_params, {"m": new_m}

    return Optimizer(init, update)


# --------------------------------------------------------------------------
# Schedules & utilities
# --------------------------------------------------------------------------
def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return fn


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )
