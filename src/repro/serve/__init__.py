"""Serving substrate: KV-cache engines, prefill/decode steps, paging.

Two generation loops share the model's decode step and sampling rule:
`ServeEngine` (fixed batch, dense cache — the lock-step baseline) and
`ContinuousBatchingEngine` (admission queue + slot recycling over a
paged or dense cache — the production loop).
"""

from repro.serve.engine import (
    ServeEngine,
    make_decode_step,
    make_prefill_step,
    sample_tokens,
)
from repro.serve.paged_cache import BlockTables, PageAllocator, required_pages
from repro.serve.scheduler import Completion, ContinuousBatchingEngine, Request

__all__ = [
    "BlockTables",
    "Completion",
    "ContinuousBatchingEngine",
    "PageAllocator",
    "Request",
    "ServeEngine",
    "make_decode_step",
    "make_prefill_step",
    "required_pages",
    "sample_tokens",
]
