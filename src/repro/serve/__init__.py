"""Serving substrate: KV-cache engines, prefill/decode steps, paging.

Two generation loops share the model's decode step and sampling rule:
`ServeEngine` (fixed batch, dense cache — the lock-step baseline) and
`ContinuousBatchingEngine` (admission queue + slot recycling over a
paged or dense cache — the production loop).  `fleet` scales the latter
to N leased worker processes on shared storage with crash-safe token
journals (`repro.serve.fleet`).
"""

from repro.serve.engine import (
    ServeEngine,
    StepWatchdog,
    make_decode_step,
    make_prefill_step,
    sample_tokens,
)
from repro.serve.fleet import FleetSpec, FleetWorker, merge_streams, serve_serial
from repro.serve.paged_cache import BlockTables, PageAllocator, required_pages
from repro.serve.scheduler import (
    AdmissionTimeout,
    Completion,
    ContinuousBatchingEngine,
    EngineHooks,
    Request,
)
from repro.serve.speculative import (
    DraftModelProposer,
    DraftProposer,
    NGramProposer,
    SpeculativeConfig,
)

__all__ = [
    "AdmissionTimeout",
    "BlockTables",
    "Completion",
    "ContinuousBatchingEngine",
    "DraftModelProposer",
    "DraftProposer",
    "EngineHooks",
    "FleetSpec",
    "FleetWorker",
    "NGramProposer",
    "PageAllocator",
    "Request",
    "ServeEngine",
    "SpeculativeConfig",
    "StepWatchdog",
    "make_decode_step",
    "make_prefill_step",
    "merge_streams",
    "required_pages",
    "sample_tokens",
    "serve_serial",
]
