"""Speculative decoding for the serve engines: draft proposers + config.

Speculative decode turns the DMA-bound decode loop's bandwidth into
accepted tokens: a cheap *proposer* guesses k draft tokens per slot, the
target model scores current-token + drafts in ONE width-(k+1)
`decode_multi` pass (`kernels/flash_decode.py` gathers each page chunk
once and serves every query row from it), and exact greedy verification
accepts the longest prefix where the target's argmax equals the draft —
plus the target's own next token as the free correction.  Rejected draft
writes are rewound by `models/transformer.commit_multi`, so a request's
token stream is bit-identical to non-speculative greedy decode; only the
number of model dispatches per emitted token changes.  The same
accept-or-fall-back discipline the kernel-evolution loop applies to
candidate kernels applies here to candidate tokens: speculate freely,
verify exactly, never emit an unverified token.

Two built-in proposers:

* `NGramProposer` — prompt-lookup decoding: scan the slot's own token
  history (prompt + emitted) for the longest recent suffix match and
  propose its continuation, re-running the lookup on ``history + drafts
  so far`` for each draft token (a single lookup truncates at the end of
  history exactly when the stream is most repetitive — the iterative
  form proposes through cycles).  Zero parameters, pure host work; wins
  on echo-heavy traffic where outputs repeat the prompt or themselves.
* `DraftModelProposer` — a small dense-cache model (global-attention
  families only) runs k greedy steps per speculation round, batched over
  all live slots and catching up on accepted-but-unseen tokens first.
  Costs real dispatches per round, so it only pays off when the draft is
  much cheaper than the target AND agrees with it often — the benchmark
  reports this honestly.

Verification itself lives in `scheduler.ContinuousBatchingEngine` (the
jitted spec step built on `decode_multi`/`commit_multi`); this module is
the host-side draft machinery.  Speculation is greedy-only by contract:
the verifier compares argmaxes, so `SpeculativeConfig` on a
temperature > 0 engine raises instead of silently diverging.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Protocol, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import config as C

NO_DRAFT = -1  # proposer slots with nothing to propose (width degrades)


class DraftProposer(Protocol):
    """Host-side draft source for the speculative decode loop.

    The scheduler calls ``admit`` when a prefilled request goes live
    (prompt plus its first sampled token), ``propose_batch`` once per
    speculation round for every live slot, ``extend`` with the tokens the
    verifier actually emitted (accepted drafts + correction — NOT the
    raw proposal), and ``release`` at retirement.  Proposals shorter
    than k are padded with ``NO_DRAFT``; the scheduler shrinks that
    slot's verify width accordingly."""

    def admit(self, slot: int, prompt: Sequence[int], first_token: int) -> None: ...

    def extend(self, slot: int, tokens: Sequence[int]) -> None: ...

    def release(self, slot: int) -> None: ...

    def propose_batch(self, slots: Sequence[int], k: int) -> Dict[int, List[int]]: ...


@dataclasses.dataclass(frozen=True)
class SpeculativeConfig:
    """Speculation knobs for `ContinuousBatchingEngine`.

    ``k`` draft tokens are verified per step (a width-(k+1) decode).
    ``proposer`` picks a built-in ("ngram" or "draft_model");
    ``make_proposer`` overrides it with a custom `DraftProposer` factory
    (called per run with (slots, max_len) — proposer state is per-run,
    like the prefix cache).  The draft-model arm needs ``draft_cfg`` +
    ``draft_params``."""

    k: int = 3
    proposer: str = "ngram"
    max_ngram: int = 3
    min_ngram: int = 1
    draft_cfg: Any = None
    draft_params: Any = None
    make_proposer: Optional[Callable[[int, int], "DraftProposer"]] = None

    def __post_init__(self):
        if self.k < 1:
            raise ValueError(f"speculative k must be >= 1, got {self.k}")
        if self.proposer not in ("ngram", "draft_model"):
            raise ValueError(f"unknown proposer {self.proposer!r}")
        if self.min_ngram < 1 or self.max_ngram < self.min_ngram:
            raise ValueError(
                f"need 1 <= min_ngram <= max_ngram, got "
                f"[{self.min_ngram}, {self.max_ngram}]"
            )
        if self.proposer == "draft_model" and self.make_proposer is None and (
            self.draft_cfg is None or self.draft_params is None
        ):
            raise ValueError("draft_model proposer needs draft_cfg and draft_params")

    def build(self, slots: int, max_len: int) -> "DraftProposer":
        if self.make_proposer is not None:
            return self.make_proposer(slots, max_len)
        if self.proposer == "ngram":
            return NGramProposer(max_n=self.max_ngram, min_n=self.min_ngram)
        return DraftModelProposer(
            self.draft_cfg, self.draft_params, slots=slots,
            max_len=max_len + self.k,
        )


# --------------------------------------------------------------------------
# n-gram / prompt-lookup proposer
# --------------------------------------------------------------------------
def _lookup_next(hist: List[int], max_n: int, min_n: int) -> int:
    """Longest-suffix prompt lookup: find the most recent earlier
    occurrence of the history's n-token suffix (longest n first) and
    return the token that followed it; NO_DRAFT when nothing matches."""
    ln = len(hist)
    for n in range(max_n, min_n - 1, -1):
        if ln < n + 1:
            continue
        suffix = hist[ln - n:]
        for i in range(ln - n - 1, -1, -1):
            if hist[i:i + n] == suffix:
                return hist[i + n]
    return NO_DRAFT


class NGramProposer:
    """Prompt-lookup drafts from each slot's own token history.

    Each draft token re-runs the suffix lookup on ``history + drafts so
    far``: when the stream sits in a cycle (the echo-heavy regime) the
    virtual history extends the cycle and every draft continues it, where
    a single longest-match lookup would truncate at the end of history
    after one token."""

    def __init__(self, max_n: int = 3, min_n: int = 1):
        self.max_n = max_n
        self.min_n = min_n
        self._hist: Dict[int, List[int]] = {}

    def admit(self, slot: int, prompt: Sequence[int], first_token: int) -> None:
        self._hist[slot] = [int(t) for t in prompt] + [int(first_token)]

    def extend(self, slot: int, tokens: Sequence[int]) -> None:
        self._hist[slot].extend(int(t) for t in tokens)

    def release(self, slot: int) -> None:
        self._hist.pop(slot, None)

    def propose_batch(self, slots: Sequence[int], k: int) -> Dict[int, List[int]]:
        out = {}
        for slot in slots:
            h = list(self._hist[slot])
            drafts: List[int] = []
            for _ in range(k):
                t = _lookup_next(h, self.max_n, self.min_n)
                drafts.append(t)
                if t == NO_DRAFT:
                    break
                h.append(t)
            out[slot] = drafts + [NO_DRAFT] * (k - len(drafts))
        return out


# --------------------------------------------------------------------------
# draft-model proposer
# --------------------------------------------------------------------------
def _insert_row(cache: Any, row: Any, slot: int) -> Any:
    """Overwrite batch row `slot` of a dense decode cache with a batch-1
    cache (leaves under "blocks" carry batch at axis 1, "rem" at 0)."""
    out = {}
    if "blocks" in cache:
        out["blocks"] = {
            uk: {
                name: leaf.at[:, slot].set(
                    row["blocks"][uk][name][:, 0].astype(leaf.dtype)
                )
                for name, leaf in cache["blocks"][uk].items()
            }
            for uk in cache["blocks"]
        }
    if "rem" in cache:
        out["rem"] = {
            rk: {
                name: leaf.at[slot].set(
                    row["rem"][rk][name][0].astype(leaf.dtype)
                )
                for name, leaf in cache["rem"][rk].items()
            }
            for rk in cache["rem"]
        }
    return out


class DraftModelProposer:
    """Greedy drafts from a small dense-cache model sharing the target's
    tokenizer (vocab ids must line up — same vocab_size enforced).

    Restricted to pure global-attention configs: a dense K/V slab is
    positional, so re-feeding a position with the *true* token simply
    overwrites the stale draft write — the catch-up pass needs no
    explicit rollback.  Recurrent/shift/ring families would need the
    full staged-rewind machinery the *target* uses; a draft model is
    supposed to be cheap, so they are rejected at construction.

    Per speculation round the proposer (a) catches up on tokens the
    verifier emitted since the last round, then (b) rolls k greedy steps
    — one batched `decode_step` per host step over every live slot, with
    slots at different catch-up depths fed their own (token, position)
    lanes.  Dead lanes park at position 0 feeding token 0; admission
    overwrites the whole cache row."""

    def __init__(self, cfg: C.ModelConfig, params: Any, *, slots: int,
                 max_len: int):
        from repro.models.transformer import decode_step, forward, init_cache

        if cfg.num_codebooks != 1:
            raise ValueError("draft model must be text-only")
        for mixer, mlp in cfg.pattern:
            if mixer != C.GLOBAL_ATTN or mlp == C.RWKV_CHANNEL_MIX:
                raise ValueError(
                    "draft model must be a pure global-attention config: "
                    f"unit {(mixer, mlp)} keeps cache state outside the "
                    "positional K/V slab, which the catch-up overwrite "
                    "discipline cannot rewind"
                )
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = init_cache(cfg, slots, max_len)
        self._hist: Dict[int, List[int]] = {}
        self._cached: Dict[int, int] = {}  # true-token positions written
        # one prefill shape: right-pad to max_len; causal attention keeps
        # positions < prompt_len exact, later garbage is masked by the
        # decode length and overwritten before it is ever read
        self._prefill = jax.jit(
            lambda p, t: forward(cfg, p, t, return_cache=True, last_only=True)
        )
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos)
        )
        self._insert = jax.jit(_insert_row, donate_argnums=(0,),
                               static_argnums=(2,))

    def admit(self, slot: int, prompt: Sequence[int], first_token: int) -> None:
        prompt = [int(t) for t in prompt]
        buf = np.zeros((1, self.max_len), np.int32)
        buf[0, :len(prompt)] = prompt
        _, _, row = self._prefill(self.params, jnp.asarray(buf))
        self.cache = self._insert(self.cache, row, slot)
        self._hist[slot] = prompt + [int(first_token)]
        self._cached[slot] = len(prompt)

    def extend(self, slot: int, tokens: Sequence[int]) -> None:
        self._hist[slot].extend(int(t) for t in tokens)

    def release(self, slot: int) -> None:
        self._hist.pop(slot, None)
        self._cached.pop(slot, None)

    def propose_batch(self, slots: Sequence[int], k: int) -> Dict[int, List[int]]:
        if not slots:
            return {}
        feeds = {s: self._hist[s][self._cached[s]:] for s in slots}
        assert all(feeds.values()), "proposer extend/admit invariant broken"
        # cap total steps so draft positions stay inside the draft horizon
        budget = {
            s: max(0, self.max_len - self._cached[s] - len(feeds[s]))
            for s in slots
        }
        steps = max(
            len(feeds[s]) + min(k - 1, budget[s]) for s in slots
        )
        cur = np.zeros(self.slots, np.int32)
        pos = np.zeros(self.slots, np.int32)
        drafts: Dict[int, List[int]] = {s: [] for s in slots}
        for i in range(steps):
            for s in slots:
                f = feeds[s]
                if i < len(f):
                    cur[s] = f[i]
                elif i > len(f) - 1 + min(k - 1, budget[s]):
                    cur[s] = 0  # horizon-parked: draft already complete
                pos[s] = min(self._cached[s] + i, self.max_len - 1)
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(cur)[:, None],
                jnp.asarray(pos),
            )
            nxt = np.asarray(
                jnp.argmax(
                    jnp.where(
                        jnp.arange(logits.shape[-1]) < self.cfg.vocab_size,
                        logits[:, 0], -jnp.inf,
                    ),
                    axis=-1,
                )
            ).astype(np.int32)
            for s in slots:
                f = feeds[s]
                if i >= len(f) - 1 and len(drafts[s]) < k:
                    drafts[s].append(int(nxt[s]))
                if i >= len(f):
                    cur[s] = nxt[s]  # greedy chain beyond true history
        for s in slots:
            self._cached[s] = len(self._hist[s])
        return {
            s: drafts[s] + [NO_DRAFT] * (k - len(drafts[s])) for s in slots
        }
