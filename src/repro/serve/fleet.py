"""Fault-tolerant serving fleet: leased requests + crash-safe token journals.

N independent worker processes on shared storage serve one request set
with no coordinator.  Each worker loops: scan the merged journals for
streams that are already complete, lease a batch of the remaining
requests (`repro.sweep.lease` — TTL + heartbeat + steal-with-readback),
serve them through its own `ContinuousBatchingEngine`, and append every
emitted token chunk to a private per-worker journal using the
`repro.sweep.merge` O_APPEND torn-tail-healing discipline.  A worker that
dies mid-stream simply stops heartbeating; any other worker steals the
expired lease and replays the request *from scratch* — that is the reaper
path, it needs no dedicated process.

Correctness is determinism + merge, not mutual exclusion:

* decoding is deterministic (greedy, or per-uid-seeded sampling keyed off
  the spec seed), and per-request streams are batching-invariant, so any
  worker — or two workers racing the same request through the lease
  layer's documented TOCTOU window — produces the *same* token at the
  same ``(uid, token_index)``;
* `merge_streams` assembles streams cell-by-cell with last-write-wins
  dedup by ``(uid, token_index)``; duplicated work collapses, a dead
  worker's prefix is subsumed by its thief's replay, and the merged
  output is byte-identical to a single-engine serial run
  (`serve_serial`) — the fleet's chaos gate.

Inside each worker, three degradation paths keep one bad request or one
sick device from taking the worker (or its peers' requests) down:

* a `StepWatchdog` (`repro.serve.engine`) detects a wedged decode window
  and immediately releases the worker's leases — peers steal the
  requests now instead of after TTL — then cancels its own streams per
  the lost-ownership contract (`repro.sweep.lease`);
* page-pool exhaustion sheds the starved admission with a retryable
  ``status="shed"`` (no journal record, lease released → re-admitted
  later) instead of spinning (`AdmissionTimeout`, ``on_starved="shed"``);
* non-finite logits retire the poisoned slot with a terminal
  ``status="error"`` journal record — deterministically, so every worker
  agrees the request is poison and nobody retries it forever.

CLI: ``python -m repro.serve.fleet {run,merge,status}`` (see `main`).
Importing this module stays light; the jax/engine stack loads only when
a worker actually serves.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.ioutil import tmp_suffix
from repro.sweep.lease import LeaseStore
from repro.sweep.merge import append_jsonl, read_jsonl

SPEC_NAME = "fleet.json"
LEASE_DIR = "leases"
JOURNAL_PREFIX = "journal-"


# --------------------------------------------------------------------------
# spec: the one JSON every worker must agree on
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Deterministic request-set + engine description.

    Everything a worker needs to rebuild the exact engine and request
    list: prompts are generated per-uid from ``default_rng((seed, uid))``
    and params from ``jax.random.key(seed)``, so every worker — and the
    serial reference — sees identical inputs.  ``num_pages=None`` sizes
    the pool so it can never starve; a small explicit pool exercises the
    shed/backpressure path.
    """

    arch: str
    prompt_lens: Tuple[int, ...]
    max_new_tokens: Tuple[int, ...]
    seed: int = 0
    slots: int = 2
    max_len: int = 32
    page_size: int = 4
    sync_interval: int = 2
    temperature: float = 0.0
    num_pages: Optional[int] = None
    smoke: bool = True

    def __post_init__(self):
        object.__setattr__(self, "prompt_lens", tuple(self.prompt_lens))
        object.__setattr__(self, "max_new_tokens", tuple(self.max_new_tokens))
        if len(self.prompt_lens) != len(self.max_new_tokens):
            raise ValueError("prompt_lens and max_new_tokens must align")
        for s0, mn in zip(self.prompt_lens, self.max_new_tokens):
            if s0 + mn > self.max_len:
                raise ValueError(f"request ({s0}+{mn}) exceeds max_len {self.max_len}")

    @property
    def n_requests(self) -> int:
        return len(self.prompt_lens)

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "FleetSpec":
        return cls(**d)


def spec_path(root: str) -> str:
    return os.path.join(root, SPEC_NAME)


def publish_spec(root: str, spec: FleetSpec) -> FleetSpec:
    """Create-or-verify: first writer wins atomically (temp + os.link);
    later writers must agree byte-for-byte with the published spec, so a
    fleet can never split-brain on what the request set is."""
    os.makedirs(root, exist_ok=True)
    path = spec_path(root)
    tmp = path + tmp_suffix()
    with open(tmp, "w") as f:
        json.dump(spec.to_dict(), f, indent=1, sort_keys=True)
    try:
        os.link(tmp, path)
        return spec
    except FileExistsError:
        pass
    finally:
        try:
            os.unlink(tmp)
        except FileNotFoundError:
            pass
    existing = load_spec(root)
    if existing != spec:
        raise RuntimeError(f"fleet root {root} already holds a different spec")
    return existing


def load_spec(root: str) -> FleetSpec:
    with open(spec_path(root)) as f:
        return FleetSpec.from_dict(json.load(f))


def request_slug(uid: int) -> str:
    return f"req-{uid:05d}"


def journal_path(root: str, owner: str) -> str:
    return os.path.join(root, f"{JOURNAL_PREFIX}{owner}.jsonl")


def journal_paths(root: str) -> List[str]:
    try:
        names = sorted(os.listdir(root))
    except FileNotFoundError:
        return []
    return [
        os.path.join(root, n)
        for n in names
        if n.startswith(JOURNAL_PREFIX) and n.endswith(".jsonl")
    ]


# --------------------------------------------------------------------------
# engine construction + the serial reference
# --------------------------------------------------------------------------
def build_engine(spec: FleetSpec, *, params: Any = None,
                 admission_timeout_s: Optional[float] = 5.0,
                 on_starved: str = "shed"):
    """(cfg, params, engine) for a spec — identical on every worker.
    ``params`` overrides the seeded init (tests inject poisoned params)."""
    import dataclasses as _dc

    import jax

    from repro.configs import get_config
    from repro.models.transformer import init_params
    from repro.serve.scheduler import ContinuousBatchingEngine

    cfg = get_config(spec.arch, smoke=spec.smoke)
    if spec.smoke:
        cfg = _dc.replace(cfg, compute_dtype="float32")
    if params is None:
        params = init_params(jax.random.key(spec.seed), cfg)
    engine = ContinuousBatchingEngine(
        cfg, params, slots=spec.slots, max_len=spec.max_len,
        cache_layout="paged", page_size=spec.page_size,
        num_pages=spec.num_pages, temperature=spec.temperature,
        sync_interval=spec.sync_interval, seed=spec.seed,
        admission_timeout_s=admission_timeout_s, on_starved=on_starved,
    )
    return cfg, params, engine


def build_requests(spec: FleetSpec, vocab_size: int, uids: Optional[List[int]] = None):
    """The spec's deterministic request list (optionally a uid subset)."""
    from repro.serve.scheduler import Request

    out = []
    for uid in uids if uids is not None else range(spec.n_requests):
        rng = np.random.default_rng((spec.seed, uid))
        prompt = rng.integers(0, vocab_size, spec.prompt_lens[uid])
        out.append(Request(uid=uid, prompt=prompt,
                           max_new_tokens=spec.max_new_tokens[uid]))
    return out


def completion_record(comp, prompt_len: int) -> Dict:
    return {
        "uid": comp.uid,
        "prompt_len": prompt_len,
        "status": comp.status,
        "error": comp.error,
        "n": len(comp.tokens),
        "tokens": [int(t) for t in comp.tokens],
    }


def serve_serial(spec: FleetSpec, *, params: Any = None) -> Dict[int, Dict]:
    """The reference: one engine, every request, uid order.  The pool is
    sized to never starve (token streams are pool-size-invariant, so this
    matches any fleet worker's streams byte-for-byte)."""
    ample = dataclasses.replace(spec, num_pages=None)
    cfg, _, engine = build_engine(
        ample, params=params, admission_timeout_s=None, on_starved="raise"
    )
    reqs = build_requests(spec, cfg.vocab_size)
    comps = engine.run(reqs)
    return {c.uid: completion_record(c, len(reqs[c.uid].prompt)) for c in comps}


# --------------------------------------------------------------------------
# journal merge: (uid, token_index) cells -> streams
# --------------------------------------------------------------------------
def merge_streams(root: str, *, strict: bool = False) -> Tuple[Dict[int, Dict], Dict]:
    """Merge every worker journal under `root` into per-uid streams.

    Token chunks expand into ``(uid, token_index)`` cells, deduped
    last-write-wins in (sorted file, line) order; terminal records dedupe
    by uid the same way.  Determinism means duplicates are identical —
    ``conflicts`` counts the times they were not (and with ``strict``
    raises instead), which is the divergence alarm the chaos tests
    assert stays at zero.  A stream is ``complete`` only when its
    terminal record exists and every cell ``0..n-1`` is present.
    """
    cells: Dict[Tuple[int, int], int] = {}
    ends: Dict[int, Dict] = {}
    conflicts = partial = nrecords = 0

    def note_conflict(what: str) -> None:
        nonlocal conflicts
        conflicts += 1
        if strict:
            raise RuntimeError(f"divergent fleet journals: {what}")

    for path in journal_paths(root):
        records, p = read_jsonl(path)
        partial += p
        for rec in records:
            if not isinstance(rec, dict):
                partial += 1
                continue
            kind, uid = rec.get("kind"), rec.get("uid")
            if not isinstance(uid, int):
                partial += 1
                continue
            nrecords += 1
            if kind == "tokens":
                start, toks = rec.get("start", 0), rec.get("toks", [])
                for i, tok in enumerate(toks):
                    key = (uid, start + i)
                    if key in cells and cells[key] != tok:
                        note_conflict(
                            f"uid {uid} token {start + i}: "
                            f"{cells[key]} vs {tok} ({path})"
                        )
                    cells[key] = tok
            elif kind == "end":
                prev = ends.get(uid)
                if prev is not None and (
                    prev.get("n") != rec.get("n")
                    or prev.get("status") != rec.get("status")
                ):
                    note_conflict(f"uid {uid} terminal records disagree ({path})")
                ends[uid] = rec
            else:
                partial += 1

    streams: Dict[int, Dict] = {}
    for uid in sorted(set(ends) | {u for u, _ in cells}):
        end = ends.get(uid)
        n = end.get("n") if end else None
        toks = [cells.get((uid, i)) for i in range(n)] if n is not None else [
            cells[k] for k in sorted(cells) if k[0] == uid
        ]
        complete = end is not None and all(t is not None for t in toks)
        streams[uid] = {
            "uid": uid,
            "prompt_len": end.get("prompt_len") if end else None,
            "status": end.get("status") if end else None,
            "error": end.get("error") if end else None,
            "n": n,
            "tokens": toks,
            "complete": complete,
        }
    info = {"records": nrecords, "conflicts": conflicts, "partial": partial}
    return streams, info


def done_uids(root: str) -> set:
    streams, _ = merge_streams(root)
    return {u for u, s in streams.items() if s["complete"]}


# --------------------------------------------------------------------------
# worker
# --------------------------------------------------------------------------
class FleetWorker:
    """One serving worker: lease, serve, journal, repeat until the fleet
    is done.

    Fault-injection knobs (tests only): ``throttle_s`` sleeps between
    decode windows (slows a victim so a SIGKILL lands mid-stream);
    ``wedge_uid``/``wedge_s`` fakes one wedged window while that uid is
    being served (exercises the watchdog); ``max_batches`` bounds the
    loop.
    """

    def __init__(
        self,
        root: str,
        owner: Optional[str] = None,
        *,
        ttl: float = 30.0,
        heartbeat_s: float = 1.0,
        poll_s: float = 0.2,
        step_timeout_s: Optional[float] = None,
        admission_timeout_s: Optional[float] = 5.0,
        throttle_s: float = 0.0,
        wedge_uid: Optional[int] = None,
        wedge_s: float = 0.0,
        max_batches: Optional[int] = None,
        params: Any = None,
    ):
        self.root = root
        self.owner = owner or f"worker{tmp_suffix()}"
        self.spec = load_spec(root)
        self.store = LeaseStore(os.path.join(root, LEASE_DIR), self.owner, ttl)
        self.journal = journal_path(root, self.owner)
        self.heartbeat_s = heartbeat_s
        self.poll_s = poll_s
        self.step_timeout_s = step_timeout_s
        self.admission_timeout_s = admission_timeout_s
        self.throttle_s = throttle_s
        self.wedge_uid = wedge_uid
        self.wedge_s = wedge_s
        self.wedge_pending = wedge_uid is not None and wedge_s > 0
        self.max_batches = max_batches
        self._params = params
        self._engine = None
        self._cfg = None
        self.stats = {
            "batches": 0, "ok": 0, "error": 0, "shed": 0,
            "cancelled": 0, "watchdog_fired": 0, "stolen_from_us": 0,
        }

    # ------------------------------------------------------------------
    def _ensure_engine(self):
        if self._engine is None:
            self._cfg, self._params, self._engine = build_engine(
                self.spec, params=self._params,
                admission_timeout_s=self.admission_timeout_s,
                on_starved="shed",
            )
        return self._cfg, self._engine

    def _claim(self, done: set) -> List[int]:
        claimed = []
        for uid in range(self.spec.n_requests):
            if len(claimed) >= self.spec.slots:
                break
            if uid in done:
                continue
            if self.store.try_acquire(request_slug(uid)):
                claimed.append(uid)
        if claimed:
            # recheck-done: someone may have finished a uid between our
            # scan and the acquire — drop it rather than re-serve
            done2 = done_uids(self.root)
            for uid in [u for u in claimed if u in done2]:
                self.store.release(request_slug(uid))
                claimed.remove(uid)
        return claimed

    def _serve_batch(self, claimed: List[int]) -> None:
        from repro.serve.engine import StepWatchdog
        from repro.serve.scheduler import EngineHooks

        cfg, engine = self._ensure_engine()
        reqs = build_requests(self.spec, cfg.vocab_size, claimed)
        prompt_lens = {r.uid: len(r.prompt) for r in reqs}
        lost: set = set()
        lost_lock = threading.Lock()

        def mark_lost(uid: int) -> None:
            with lost_lock:
                lost.add(uid)

        # heartbeat thread: a False bump means the lease was stolen — the
        # lost-ownership contract (sweep.lease) says stop emitting NOW
        halt = threading.Event()

        def beat() -> None:
            while not halt.wait(self.heartbeat_s):
                for uid in claimed:
                    with lost_lock:
                        if uid in lost:
                            continue
                    if not self.store.heartbeat(request_slug(uid)):
                        mark_lost(uid)
                        self.stats["stolen_from_us"] += 1

        def on_wedged(waited: float) -> None:
            # wedged decode window: free the requests for stealing right
            # away instead of making peers wait out the TTL, and cancel
            # our own streams if the window ever unwedges
            self.stats["watchdog_fired"] += 1
            for uid in claimed:
                mark_lost(uid)
                self.store.release(request_slug(uid))

        watchdog = (
            StepWatchdog(self.step_timeout_s, on_wedged)
            if self.step_timeout_s is not None
            else None
        )

        def on_window_start() -> None:
            if watchdog is not None:
                watchdog.arm()
            if self.wedge_pending and self.wedge_uid in claimed:
                self.wedge_pending = False
                time.sleep(self.wedge_s)

        def on_window_end() -> None:
            if watchdog is not None:
                watchdog.disarm()
            if self.throttle_s > 0:
                time.sleep(self.throttle_s)

        def on_tokens(uid: int, start: int, toks: List[int]) -> None:
            with lost_lock:
                if uid in lost:
                    return
            append_jsonl(self.journal, {
                "kind": "tokens", "uid": uid, "start": start,
                "toks": [int(t) for t in toks],
            })

        def should_cancel(uid: int) -> bool:
            with lost_lock:
                return uid in lost

        def on_retire(comp) -> None:
            self.stats[comp.status] = self.stats.get(comp.status, 0) + 1
            with lost_lock:
                if comp.uid in lost:
                    return
            if comp.status in ("ok", "error"):
                append_jsonl(self.journal, {
                    "kind": "end", "uid": comp.uid, "n": len(comp.tokens),
                    "status": comp.status, "error": comp.error,
                    "prompt_len": prompt_lens[comp.uid],
                })
            # "shed" / "cancelled": no record — the request stays pending
            # and is re-admitted by whoever leases it next

        hooks = EngineHooks(
            on_tokens=on_tokens, should_cancel=should_cancel,
            on_retire=on_retire, on_window_start=on_window_start,
            on_window_end=on_window_end,
        )
        hb = threading.Thread(target=beat, daemon=True)
        hb.start()
        try:
            engine.run(reqs, hooks=hooks)
        finally:
            halt.set()
            hb.join(timeout=10.0)
            if watchdog is not None:
                watchdog.stop()
            for uid in claimed:
                self.store.release(request_slug(uid))  # no-op if stolen

    # ------------------------------------------------------------------
    def run(self) -> Dict:
        """Serve until every stream is complete (or max_batches).  Returns
        the worker's stats."""
        while True:
            done = done_uids(self.root)
            if len(done) >= self.spec.n_requests:
                break
            if (
                self.max_batches is not None
                and self.stats["batches"] >= self.max_batches
            ):
                break
            claimed = self._claim(done)
            if not claimed:
                time.sleep(self.poll_s)  # live leases elsewhere: wait/steal
                continue
            self.stats["batches"] += 1
            self._serve_batch(claimed)
        return dict(self.stats)


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------
def _cmd_run(args) -> int:
    if args.spec:
        with open(args.spec) as f:
            publish_spec(args.root, FleetSpec.from_dict(json.load(f)))
    worker = FleetWorker(
        args.root, args.owner, ttl=args.ttl, heartbeat_s=args.heartbeat,
        poll_s=args.poll, step_timeout_s=args.step_timeout,
        admission_timeout_s=args.admission_timeout,
        throttle_s=args.throttle, wedge_uid=args.wedge_uid,
        wedge_s=args.wedge_s, max_batches=args.max_batches,
    )
    stats = worker.run()
    print(json.dumps({"owner": worker.owner, **stats}))
    return 0


def _cmd_merge(args) -> int:
    streams, info = merge_streams(args.root, strict=args.strict)
    out = {"streams": [streams[u] for u in sorted(streams)], "info": info}
    if args.out:
        from repro.ioutil import atomic_write

        atomic_write(args.out, lambda f: json.dump(out, f, indent=1), mode="w")
    print(json.dumps(out["info"] | {
        "streams": len(streams),
        "complete": sum(s["complete"] for s in streams.values()),
    }))
    return 0


def _cmd_status(args) -> int:
    spec = load_spec(args.root)
    done = done_uids(args.root)
    store = LeaseStore(os.path.join(args.root, LEASE_DIR), "<status>", 1.0,
                       create=False)
    leases = store.all_leases()
    print(json.dumps({
        "requests": spec.n_requests,
        "complete": len(done),
        "leased": len(leases),
        "expired": sum(l.expired() for l in leases),
        "owners": sorted({l.owner for l in leases}),
    }))
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(prog="repro.serve.fleet", description=__doc__)
    sub = p.add_subparsers(dest="cmd", required=True)

    r = sub.add_parser("run", help="run one serving worker to completion")
    r.add_argument("--root", required=True)
    r.add_argument("--owner", default=None)
    r.add_argument("--spec", default=None, help="publish this spec JSON first")
    r.add_argument("--ttl", type=float, default=30.0)
    r.add_argument("--heartbeat", type=float, default=1.0)
    r.add_argument("--poll", type=float, default=0.2)
    r.add_argument("--step-timeout", type=float, default=None)
    r.add_argument("--admission-timeout", type=float, default=5.0)
    r.add_argument("--throttle", type=float, default=0.0)
    r.add_argument("--wedge-uid", type=int, default=None)
    r.add_argument("--wedge-s", type=float, default=0.0)
    r.add_argument("--max-batches", type=int, default=None)
    r.set_defaults(fn=_cmd_run)

    m = sub.add_parser("merge", help="merge worker journals into streams")
    m.add_argument("--root", required=True)
    m.add_argument("--out", default=None)
    m.add_argument("--strict", action="store_true")
    m.set_defaults(fn=_cmd_merge)

    s = sub.add_parser("status", help="fleet progress + lease health")
    s.add_argument("--root", required=True)
    s.set_defaults(fn=_cmd_status)

    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
