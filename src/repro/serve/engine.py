"""Serving: prefill + decode step builders and a batched generation engine.

``make_prefill_step`` returns logits for the last position plus a cache
padded to the decode horizon; ``make_decode_step`` advances one token for the
whole batch.  The decode cells of the dry-run lower exactly
``make_decode_step``'s function (one new token against a seq_len cache), per
the assignment.

ServeEngine drives continuous batched generation (greedy or temperature
sampling) with per-sequence stop handling — the minimal production loop.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import config as C
from repro.models.transformer import decode_step, forward, init_cache


class StepWatchdog:
    """Detect wedged decode windows and fire a callback *before* lease TTL.

    A serving worker that hangs inside a decode window (device fault,
    deadlocked transfer) would otherwise sit on its request leases until
    they time out — the fleet's reaper frees them only after TTL.  The
    watchdog arms around each window; a background thread fires
    ``on_wedged`` once a window has been open longer than
    ``step_timeout_s``, letting the worker release its leases immediately
    so another worker can steal the requests without waiting out the TTL.

    ``on_wedged`` runs on the watchdog thread while the worker thread is
    (by hypothesis) stuck, so it must touch only thread-safe state —
    releasing lease files and setting flags is fine; JAX calls are not.
    Fires at most once per arm(); a disarm() re-arms eligibility.
    ``clock`` is injectable for deterministic tests.
    """

    def __init__(
        self,
        step_timeout_s: float,
        on_wedged: Callable[[float], None],
        *,
        poll_s: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if step_timeout_s <= 0:
            raise ValueError("step_timeout_s must be positive")
        self.step_timeout_s = step_timeout_s
        self.on_wedged = on_wedged
        self.poll_s = poll_s if poll_s is not None else min(0.05, step_timeout_s / 4)
        self._clock = clock
        self._lock = threading.Lock()
        self._armed_at: Optional[float] = None
        self._fired = False
        self.fired_count = 0
        self._halt = threading.Event()
        self._thread = threading.Thread(target=self._watch, daemon=True)
        self._thread.start()

    def arm(self) -> None:
        """A window is starting: begin the countdown."""
        with self._lock:
            self._armed_at = self._clock()
            self._fired = False

    def disarm(self) -> None:
        """The window completed in time: stop the countdown."""
        with self._lock:
            self._armed_at = None

    def stop(self) -> None:
        self._halt.set()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "StepWatchdog":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def _watch(self) -> None:
        while not self._halt.wait(self.poll_s):
            fire_with: Optional[float] = None
            with self._lock:
                if self._armed_at is not None and not self._fired:
                    waited = self._clock() - self._armed_at
                    if waited > self.step_timeout_s:
                        self._fired = True
                        self.fired_count += 1
                        fire_with = waited
            if fire_with is not None:
                try:
                    self.on_wedged(fire_with)
                except Exception:
                    pass  # a crashing handler must not kill the watchdog


def _pad_cache_to(cfg: C.ModelConfig, cache: Any, batch: int, max_len: int) -> Any:
    """Pad a prefill cache out to the decode-horizon shapes.

    Target shapes come from cache_specs(cfg, batch, max_len) so ring-buffer
    local caches stay window-sized while global caches grow to max_len.
    Padding appends at the end of the sequence axis, matching the decode
    write position (pos continues from the prefill length).
    """
    from repro.models.transformer import cache_specs

    specs = cache_specs(cfg, batch, max_len)

    def pad(x, spec):
        if tuple(x.shape) == tuple(spec.shape):
            return x
        widths = [(0, t - c) for c, t in zip(x.shape, spec.shape)]
        assert all(w[1] >= 0 for w in widths), (x.shape, spec.shape)
        return jnp.pad(x, widths)

    return jax.tree.map(pad, cache, specs)


def make_prefill_step(cfg: C.ModelConfig, *, max_len: Optional[int] = None):
    """prefill(params, tokens[, image_embeds]) -> (last_logits, cache)."""

    def prefill(params, tokens, image_embeds=None):
        logits, _, cache = forward(
            cfg, params, tokens, image_embeds=image_embeds, return_cache=True,
            last_only=True,
        )
        last = logits[:, -1]
        if max_len is not None:
            cache = _pad_cache_to(cfg, cache, tokens.shape[0], max_len)
        return last, cache

    return prefill


def make_decode_step(cfg: C.ModelConfig):
    """decode(params, cache, tokens, pos) -> (logits, new_cache).

    This is the ``serve_step`` lowered by the decode dry-run cells.
    """

    def step(params, cache, tokens, pos):
        return decode_step(cfg, params, cache, tokens, pos)

    return step


def sample_tokens(
    logits: jax.Array,
    *,
    vocab_size: int,
    temperature: float = 0.0,
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Greedy/temperature sampling with padded-vocab masking — the one
    sampling rule shared by the fixed-batch engine and the continuous-
    batching scheduler (token-level equivalence between the two depends
    on it)."""
    if logits.shape[-1] != vocab_size:  # mask padded vocab ids
        valid = jnp.arange(logits.shape[-1]) < vocab_size
        logits = jnp.where(valid, logits, -jnp.inf)
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


@dataclasses.dataclass
class ServeEngine:
    """Batched greedy/temperature generation over a fixed request batch.

    Per-sequence stop handling stays on device: a ``done`` mask freezes
    finished sequences (they emit ``pad_id`` instead of live samples) and
    the host only checks for all-done every ``sync_interval`` steps — the
    old per-token ``bool(done.all())`` blocked the dispatch queue on a
    device->host transfer between every two decode steps.  ``last_stats``
    records the decode-step count of the most recent `generate` call (the
    serve benchmark's simulated-clock tick counter).
    """

    cfg: C.ModelConfig
    params: Any
    max_len: int
    temperature: float = 0.0
    eos_id: Optional[int] = None
    pad_id: Optional[int] = None  # defaults to eos_id
    sync_interval: int = 8

    def __post_init__(self):
        self._prefill = jax.jit(make_prefill_step(self.cfg, max_len=self.max_len))
        self._decode = jax.jit(make_decode_step(self.cfg))
        self.last_stats: Dict[str, int] = {}

    def generate(
        self,
        tokens: jax.Array,
        *,
        steps: int,
        key: Optional[jax.Array] = None,
        uids: Optional[jax.Array] = None,
        image_embeds: Optional[jax.Array] = None,
    ) -> jax.Array:
        """tokens: (B, S0) prompt.  Returns (B, S0+steps) completed tokens
        (fewer when every sequence hit eos at a sync point).

        ``uids`` (B,) int32 — optional per-request ids for temperature
        sampling: token *i* of request ``uid`` draws from
        ``fold_in(fold_in(key, uid), i)``, the same chain the
        continuous-batching scheduler uses, so fixed-engine and scheduler
        streams stay token-level equivalent at temperature > 0 too.
        Without uids the legacy batch-shared ``fold_in(key, i)`` applies
        (rows of one batch then share each step's key)."""
        cfg = self.cfg
        b, s0 = tokens.shape[0], tokens.shape[1]
        if image_embeds is not None:
            last, cache = self._prefill(self.params, tokens, image_embeds)
        else:
            last, cache = self._prefill(self.params, tokens)
        pos0 = s0 + cfg.num_prefix_embeds
        pad = self.pad_id if self.pad_id is not None else self.eos_id
        out = [tokens]
        done = jnp.zeros((b,), bool)
        cur = self._sample(last, key, 0, uids)
        if self.eos_id is not None:
            done = done | (cur == self.eos_id)
        t = 0
        for t in range(steps):
            nt = cur[:, None] if cfg.num_codebooks == 1 else cur[:, None, :]
            out.append(nt)
            logits, cache = self._decode(
                self.params, cache, nt, jnp.int32(pos0 + t)
            )
            cur = self._sample(logits[:, 0], key, t + 1, uids)
            if self.eos_id is not None:
                # past-eos sequences emit pad, not live samples; the eos
                # reduction stays on device — the host sync is hoisted to
                # every sync_interval steps
                cur = jnp.where(done, jnp.int32(pad), cur)
                done = done | (cur == self.eos_id)
                if (t + 1) % self.sync_interval == 0 and bool(done.all()):
                    break
        self.last_stats = {"decode_steps": t + 1 if steps else 0, "batch": b}
        return jnp.concatenate(out, axis=1)

    def _sample(self, logits: jax.Array, key, t: int, uids=None) -> jax.Array:
        if key is None:
            k = None
        elif uids is None:
            k = jax.random.fold_in(key, t)
        else:
            keys = jax.vmap(
                lambda u: jax.random.fold_in(jax.random.fold_in(key, u), t)
            )(jnp.asarray(uids, jnp.int32))
            return jax.vmap(
                lambda k_, l_: sample_tokens(
                    l_, vocab_size=self.cfg.vocab_size,
                    temperature=self.temperature, key=k_,
                )
            )(keys, logits)
        return sample_tokens(
            logits, vocab_size=self.cfg.vocab_size,
            temperature=self.temperature, key=k,
        )
