"""Continuous-batching serve loop: admission queue, slot recycling, paging.

The fixed-batch `ServeEngine` stalls the whole batch on its longest
request: a slot that finishes early sits idle until everyone is done, and
the next batch cannot start until then.  This scheduler instead treats
the batch as ``slots`` independent lanes:

* **Admission queue** — requests wait in arrival order; whenever a slot
  is free (at startup or after a retirement) the next request is
  prefilled (batch-of-1, exact prompt length — no padding) and its cache
  is scattered into the slot.
* **Prefill/decode interleaving** — admissions happen at sync points
  between decode windows, so prefills and decode steps share the device
  serially, and the decode hot loop itself stays free of host syncs.
* **Slot recycling** — a sequence that hits eos or its token budget is
  frozen device-side by the ``done`` mask (it emits pad and stops
  advancing), retired at the next sync, its pages freed, and its slot
  handed to the admission queue — no whole-batch stall.
* **Device-side stop handling** — the eos reduction lives in the jitted
  step; the host looks at ``done``/``gen`` only every ``sync_interval``
  steps.  A finished slot therefore idles for at most
  ``sync_interval - 1`` steps before its lane is recycled: the
  throughput/latency knob of the whole engine.

``cache_layout="paged"`` stores global-attention K/V in a shared page
pool (`repro.serve.paged_cache` block tables + the
`kernels/flash_decode.py` kernel); ``"dense"`` keeps per-slot dense
slabs with the same scheduling (the ablation arm of
`benchmarks/serve_throughput.py`).  With greedy sampling both layouts
produce token streams identical to the fixed-batch engine — per-request
decode is batching-invariant — which is the scheduler's correctness
gate in tests/test_serve_paged.py.
"""

from __future__ import annotations

import collections
import dataclasses
import functools
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import config as C
from repro.models.transformer import decode_step, forward, init_cache
from repro.serve.engine import sample_tokens
from repro.serve.paged_cache import BlockTables, pages_for, required_pages


@dataclasses.dataclass(frozen=True)
class Request:
    uid: int
    prompt: Any  # (S0,) int array
    max_new_tokens: int


@dataclasses.dataclass
class Completion:
    uid: int
    prompt_len: int
    tokens: List[int]  # generated tokens, eos included when hit


@dataclasses.dataclass
class _SlotState:
    uid: int
    prompt_len: int
    max_new: int


# --------------------------------------------------------------------------
# cache insertion: scatter one prefilled request into a batch slot
# --------------------------------------------------------------------------
def _set_row(dst, src, slot, stacked):
    """dst (L?, B, *rest), src (L?, 1, *rest'): pad rest' up to rest with
    zeros (end-padding, matching `_pad_cache_to`) and overwrite the whole
    slot row — recycled slots must not leak the previous occupant."""
    off = 1 if stacked else 0
    widths = [(0, 0)] * src.ndim
    for ax in range(off + 1, src.ndim):
        widths[ax] = (0, dst.shape[ax] - src.shape[ax])
    row = jnp.pad(src, widths)
    row = row[:, 0] if stacked else row[0]
    if stacked:
        return dst.at[:, slot].set(row.astype(dst.dtype))
    return dst.at[slot].set(row.astype(dst.dtype))


def _scatter_pages(pool, row, pages, stacked):
    """pool (L?, KV, P, ps, D), row (L?, 1, S0, KV, D): write the prompt's
    K/V into the allocated pages (zero-padded to whole pages)."""
    ps = pool.shape[-2]
    n = pages.shape[0]
    if stacked:
        nl, _, s0, kv, d = row.shape
        r = jnp.pad(row[:, 0], ((0, 0), (0, n * ps - s0), (0, 0), (0, 0)))
        r = r.reshape(nl, n, ps, kv, d).transpose(0, 3, 1, 2, 4)
        return pool.at[:, :, pages].set(r.astype(pool.dtype))
    _, s0, kv, d = row.shape
    r = jnp.pad(row[0], ((0, n * ps - s0), (0, 0), (0, 0)))
    r = r.reshape(n, ps, kv, d).transpose(2, 0, 1, 3)
    return pool.at[:, pages].set(r.astype(pool.dtype))


def _insert_unit(dst: dict, src: dict, slot, pages, stacked):
    out = {}
    for key, leaf in dst.items():
        if key in ("k_pages", "v_pages"):
            out[key] = _scatter_pages(leaf, src[key[0]], pages, stacked)
        else:
            out[key] = _set_row(leaf, src[key], slot, stacked)
    return out


def _insert_prefill(cache: dict, pre: dict, slot, pages):
    out: Dict[str, Any] = {}
    if "blocks" in cache:
        out["blocks"] = {
            uk: _insert_unit(cache["blocks"][uk], pre["blocks"][uk], slot, pages, True)
            for uk in cache["blocks"]
        }
    if "rem" in cache:
        out["rem"] = {
            rk: _insert_unit(cache["rem"][rk], pre["rem"][rk], slot, pages, False)
            for rk in cache["rem"]
        }
    return out


# --------------------------------------------------------------------------
# engine
# --------------------------------------------------------------------------
class ContinuousBatchingEngine:
    """Continuous-batching generation over a request queue.

    Restrictions vs the research model surface: text-only
    (``num_codebooks == 1``, no prefix embeds), and every request must
    satisfy ``prompt_len + max_new_tokens <= max_len``.

    `run(requests)` is self-resetting — the engine (and its compiled
    steps) can be reused across runs; prefill/insert functions retrace
    per distinct prompt length, so traces amortize across requests and
    runs.
    """

    def __init__(
        self,
        cfg: C.ModelConfig,
        params: Any,
        *,
        slots: int,
        max_len: int,
        cache_layout: str = "paged",
        page_size: Optional[int] = None,
        num_pages: Optional[int] = None,
        temperature: float = 0.0,
        eos_id: Optional[int] = None,
        pad_id: int = 0,
        sync_interval: int = 8,
        seed: int = 0,
    ):
        assert cfg.num_codebooks == 1 and cfg.num_prefix_embeds == 0, (
            "continuous batching serves text-only configs"
        )
        if cache_layout not in ("paged", "dense"):
            raise ValueError(cache_layout)
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache_layout = cache_layout
        if cache_layout == "paged":
            if page_size is None:
                from repro.kernels import tuned

                page_size = int(tuned.get_tuned("flash_decode")["page_size"])
            if num_pages is None:
                # worst case plus per-slot sync-lag over-allocation slack
                num_pages = required_pages(slots, max_len, page_size) + slots
        self.page_size = page_size
        self.num_pages = num_pages
        self.temperature = temperature
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.sync_interval = max(1, sync_interval)
        self.key = jax.random.key(seed)
        self.stats: Dict[str, Any] = {}

        self._prefill = jax.jit(
            lambda p, t: forward(cfg, p, t, return_cache=True, last_only=True)
        )
        self._insert = jax.jit(_insert_prefill, donate_argnums=(0,))
        self._step = self._make_step()

    # -- jitted decode step ------------------------------------------------
    def _make_step(self):
        cfg = self.cfg
        paged = self.cache_layout == "paged"
        temperature = self.temperature
        eos_id = self.eos_id
        pad_id = self.pad_id

        def step(params, cache, cur, pos, done, gen, max_new, uids, bt, key):
            logits, cache = decode_step(
                cfg, params, cache, cur[:, None], pos,
                block_tables=bt if paged else None,
            )
            lg = logits[:, 0]
            if temperature > 0.0:
                keys = jax.vmap(
                    lambda u, g: jax.random.fold_in(jax.random.fold_in(key, u), g)
                )(uids, gen)
                nxt = jax.vmap(
                    lambda k_, l_: sample_tokens(
                        l_, vocab_size=cfg.vocab_size,
                        temperature=temperature, key=k_,
                    )
                )(keys, lg)
            else:
                nxt = sample_tokens(lg, vocab_size=cfg.vocab_size)
            live = ~done
            emit = jnp.where(live, nxt, jnp.int32(pad_id))
            gen1 = gen + live
            done1 = done | (live & (gen1 >= max_new))
            if eos_id is not None:
                done1 = done1 | (live & (emit == eos_id))
            cur1 = jnp.where(done1, jnp.int32(pad_id), emit)
            pos1 = pos + live
            return cache, emit, cur1, pos1, done1, gen1

        return jax.jit(step, donate_argnums=(1,))

    # -- host loop ---------------------------------------------------------
    def run(self, requests: List[Request]) -> List[Completion]:
        cfg, b = self.cfg, self.slots
        for r in requests:
            assert len(r.prompt) + r.max_new_tokens <= self.max_len, (
                r.uid, len(r.prompt), r.max_new_tokens, self.max_len
            )
            assert r.max_new_tokens >= 1, r.uid

        paged = self.cache_layout == "paged"
        if paged:
            tables = BlockTables.with_pool(
                b, self.max_len, self.page_size, self.num_pages
            )
            cache = init_cache(
                cfg, b, self.max_len, layout="paged",
                num_pages=self.num_pages, page_size=self.page_size,
            )
            bt_dev = jnp.asarray(tables.table)
        else:
            tables = None
            cache = init_cache(cfg, b, self.max_len)
            bt_dev = jnp.zeros((b, 1), jnp.int32)  # unused placeholder

        pos = jnp.zeros((b,), jnp.int32)
        done = jnp.ones((b,), bool)  # empty slots are frozen
        gen = jnp.zeros((b,), jnp.int32)
        max_new = jnp.ones((b,), jnp.int32)
        uids = jnp.zeros((b,), jnp.int32)
        cur = jnp.full((b,), self.pad_id, jnp.int32)

        queue = collections.deque(requests)
        active: List[Optional[_SlotState]] = [None] * b
        free = list(range(b - 1, -1, -1))  # pop() yields lowest slot first
        results: Dict[int, List[int]] = {}
        pos_h = np.zeros(b, np.int64)  # optimistic host mirror of pos
        gen_prev = np.zeros(b, np.int64)
        decode_steps = prefills = 0
        peak_pages = 0
        step_key = jax.random.fold_in(self.key, 1)  # per-row keys fold uid/gen

        def admit(slot: int, req: Request):
            nonlocal cache, pos, done, gen, max_new, uids, cur, bt_dev, prefills
            prompt = jnp.asarray(np.asarray(req.prompt, np.int32))[None, :]
            s0 = prompt.shape[1]
            last, _, pre = self._prefill(self.params, prompt)
            if paged:
                pages = jnp.asarray(
                    np.asarray(tables.admit(slot, s0), np.int32)
                )
                bt_dev = jnp.asarray(tables.table)
            else:
                pages = jnp.zeros((0,), jnp.int32)
            cache = self._insert(cache, pre, slot, pages)
            if self.temperature > 0.0:
                k0 = jax.random.fold_in(
                    jax.random.fold_in(self.key, req.uid), 0
                )
            else:
                k0 = None
            tok0 = sample_tokens(
                last[0, -1], vocab_size=cfg.vocab_size,
                temperature=self.temperature, key=k0,
            )
            t0 = int(tok0)
            finished = (req.max_new_tokens <= 1) or (
                self.eos_id is not None and t0 == self.eos_id
            )
            pos = pos.at[slot].set(s0)
            done = done.at[slot].set(finished)
            gen = gen.at[slot].set(1)
            max_new = max_new.at[slot].set(req.max_new_tokens)
            uids = uids.at[slot].set(req.uid)
            cur = cur.at[slot].set(self.pad_id if finished else t0)
            active[slot] = _SlotState(req.uid, s0, req.max_new_tokens)
            results[req.uid] = [t0]
            pos_h[slot] = s0
            gen_prev[slot] = 1
            prefills += 1

        while queue or any(s is not None for s in active):
            # admissions at the sync boundary: prefill into every free
            # slot — unless the page pool cannot hold the prompt yet, in
            # which case the request waits for a retirement to free pages
            while queue and free:
                need = pages_for(len(queue[0].prompt) + 1, self.page_size or 1)
                if paged and tables.allocator.available < need:
                    if not any(s is not None for s in active):
                        raise RuntimeError(
                            f"request {queue[0].uid} needs {need} pages but "
                            f"only {tables.allocator.available} exist free "
                            "with no active sequences to retire — pool too "
                            "small (see paged_cache.required_pages)"
                        )
                    break
                admit(free.pop(), queue.popleft())
            if paged:
                peak_pages = max(peak_pages, tables.pages_in_use)

            emits = []
            for _ in range(self.sync_interval):
                if paged:
                    grew = False
                    for slot, st in enumerate(active):
                        if st is None:
                            continue
                        # alloc-on-write: the next decode writes at pos;
                        # clamp covers done-but-unretired slots whose host
                        # mirror over-advanced past the horizon
                        wpos = min(int(pos_h[slot]), self.max_len - 1)
                        grew |= tables.ensure(slot, wpos)
                    if grew:
                        bt_dev = jnp.asarray(tables.table)
                        peak_pages = max(peak_pages, tables.pages_in_use)
                cache, emit, cur, pos, done, gen = self._step(
                    self.params, cache, cur, pos, done, gen, max_new,
                    uids, bt_dev, step_key,
                )
                decode_steps += 1
                emits.append(emit)
                for slot, st in enumerate(active):
                    if st is not None:
                        pos_h[slot] += 1

            # sync: pull the window's verdicts, distribute tokens, retire
            done_h = np.asarray(done)
            gen_h = np.asarray(gen)
            pos_dev = np.asarray(pos)
            em = np.stack([np.asarray(e) for e in emits])  # (W, B)
            for slot, st in enumerate(active):
                if st is None:
                    continue
                n_new = int(gen_h[slot] - gen_prev[slot])
                results[st.uid].extend(int(t) for t in em[:n_new, slot])
                gen_prev[slot] = gen_h[slot]
                pos_h[slot] = int(pos_dev[slot])
                if done_h[slot]:
                    if paged:
                        tables.release(slot)
                    active[slot] = None
                    free.append(slot)
                    free.sort(reverse=True)

        self.stats = {
            "decode_steps": decode_steps,
            "prefills": prefills,
            "emitted_tokens": sum(len(t) for t in results.values()),
            "slots": b,
            "sync_interval": self.sync_interval,
            "cache_layout": self.cache_layout,
            "peak_pages": peak_pages,
            "page_size": self.page_size,
        }
        return [
            Completion(r.uid, len(r.prompt), results[r.uid]) for r in requests
        ]
