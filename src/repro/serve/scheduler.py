"""Continuous-batching serve loop: admission queue, slot recycling, paging.

The fixed-batch `ServeEngine` stalls the whole batch on its longest
request: a slot that finishes early sits idle until everyone is done, and
the next batch cannot start until then.  This scheduler instead treats
the batch as ``slots`` independent lanes:

* **Admission queue** — requests wait in arrival order; whenever a slot
  is free (at startup or after a retirement) the next request starts
  prefilling into the slot.
* **Chunked prefill** — prompts prefill ``prefill_chunk_tokens`` at a
  time against a fixed-shape batch-1 carry, one chunk per pending
  request per decode window.  A long admission therefore never stalls
  in-flight streams, and the jit cache holds exactly one prefill shape
  (the old exact-length prefill retraced per distinct prompt length).
  The final, partially-valid chunk is padded and masked exactly: padded
  positions contribute 0 attention probability and are state no-ops for
  the recurrent families (`models/transformer.py prefill_chunk`).
* **Radix prefix cache** (paged layout) — full, finalized prompt pages
  are registered in a `PrefixIndex` keyed by exact token bytes, with the
  prefill carry snapshotted at chunk boundaries.  A repeated
  system-prompt admission becomes a block-table copy (the shared pages
  are incref'd, never rewritten) plus a suffix-only prefill resumed from
  the snapshot.  Cache-hit streams are bit-identical to cold ones: the
  snapshot is exactly what the same jitted chunk computed for the donor.
* **Slot recycling** — a sequence that hits eos or its token budget is
  frozen device-side by the ``done`` mask (it emits pad and stops
  advancing), retired at the next sync, its pages decref'd (shared
  prefix pages survive for their other owners), and its slot handed to
  the admission queue — no whole-batch stall.
* **Device-side stop handling** — the eos reduction lives in the jitted
  step; the host looks at ``done``/``gen`` only every ``sync_interval``
  steps.  A finished slot therefore idles for at most
  ``sync_interval - 1`` steps before its lane is recycled: the
  throughput/latency knob of the whole engine.

``cache_layout="paged"`` stores global-attention K/V in a shared page
pool (`repro.serve.paged_cache` block tables + the
`kernels/flash_decode.py` kernel); ``"dense"`` keeps per-slot dense
slabs with the same scheduling (the ablation arm of
`benchmarks/serve_throughput.py`).  With greedy sampling both layouts
produce identical token streams — they share the same chunked-prefill
computation bit for bit, and per-request decode is batching-invariant —
which is the scheduler's correctness gate in tests/test_serve_paged.py.

**Graceful degradation** (the serving fleet's requirements, usable
standalone):

* *Typed admission failure* — a request the page pool can never hold
  fails immediately, and one starved past ``admission_timeout_s`` fails
  on its deadline, both as `AdmissionTimeout` (no bare spin loops).
  ``on_starved="shed"`` converts the failure into a `Completion` with a
  retryable ``status="shed"`` (or terminal ``"error"`` when the request
  could never fit) instead of raising, so one oversized request cannot
  take down the worker's other streams.
* *Malformed-request containment* — request validation happens at
  admission, not as a bare assert: an over-length or empty request
  retires with ``status="error"`` (and `BlockTables` raises the typed
  `PageOverflowError`, live under ``python -O``) instead of crashing
  co-scheduled streams.
* *Non-finite-logit detection* — the jitted step flags rows whose logits
  went NaN/inf; at the next sync the poisoned slot is retired with
  ``status="error"`` and the garbage token is dropped, instead of
  streaming it.  Deterministic: a poisoned request errors identically in
  a serial run and on any fleet worker.
* *Streaming hooks* (`EngineHooks`) — per-sync token callbacks, a
  cancellation predicate consulted at every sync (the lease
  lost-ownership contract: stop emitting immediately), and window
  start/end callbacks a watchdog can arm against (`serve/engine.py`
  `StepWatchdog`).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import config as C
from repro.models.transformer import (
    commit_multi,
    decode_multi,
    decode_step,
    finish_prefill_carry,
    init_cache,
    init_prefill_carry,
    prefill_cap,
    prefill_chunk,
)
from repro.serve.engine import sample_tokens
from repro.serve.speculative import NO_DRAFT, SpeculativeConfig
from repro.serve.paged_cache import (
    NULL_PAGE,
    BlockTables,
    PageOverflowError,
    PrefixIndex,
    pages_for,
    required_pages,
)


@dataclasses.dataclass(frozen=True)
class Request:
    uid: int
    prompt: Any  # (S0,) int array
    max_new_tokens: int


@dataclasses.dataclass
class Completion:
    uid: int
    prompt_len: int
    tokens: List[int]  # generated tokens, eos included when hit
    # "ok" | "error" (terminal: poisoned logits / malformed or impossible
    # admission) | "shed" (retryable: admission starved past its deadline) |
    # "cancelled" (caller's should_cancel — e.g. a lost lease)
    status: str = "ok"
    error: Optional[str] = None


class AdmissionTimeout(RuntimeError):
    """Admission could not be satisfied: the prompt needs more pages than
    the pool will ever have (``reason="impossible"``), every holder
    retired and there is still not enough (``"starved"``), or the
    configured ``admission_timeout_s`` elapsed first (``"timeout"``)."""

    def __init__(self, uid: int, needed: int, available: int, reason: str,
                 waited_s: float = 0.0):
        self.uid = uid
        self.needed = needed
        self.available = available
        self.reason = reason
        self.waited_s = waited_s
        detail = {
            "impossible": f"needs {needed} page(s) but the pool only ever has "
                          f"{available} allocatable",
            "starved": f"needs {needed} page(s), {available} free with no "
                       "active sequences left to retire",
            "timeout": f"needs {needed} page(s), {available} free after "
                       f"waiting {waited_s:.2f}s (admission_timeout_s)",
        }[reason]
        super().__init__(f"admission of request {uid} failed ({reason}): {detail}")


@dataclasses.dataclass
class EngineHooks:
    """Streaming integration points for `ContinuousBatchingEngine.run`.

    All callbacks fire on the host loop thread at sync granularity; every
    field is optional.  ``on_tokens(uid, start, tokens)`` reports the
    tokens newly finalized for a stream (``start`` = index of the first
    one, so a journal can dedupe by ``(uid, token_index)``);
    ``should_cancel(uid)`` is consulted per stream at every sync and at
    admission — True drops the stream immediately with no further
    ``on_tokens`` (the lease lost-ownership contract); ``on_retire``
    fires once per request with its final `Completion`;
    ``on_window_start``/``on_window_end`` bracket one admission + decode
    window + sync pass (arm a `StepWatchdog` across them)."""

    on_tokens: Optional[Callable[[int, int, List[int]], None]] = None
    should_cancel: Optional[Callable[[int], bool]] = None
    on_retire: Optional[Callable[[Completion], None]] = None
    on_window_start: Optional[Callable[[], None]] = None
    on_window_end: Optional[Callable[[], None]] = None


@dataclasses.dataclass
class _SlotState:
    uid: int
    prompt_len: int
    max_new: int


@dataclasses.dataclass
class _PendingPrefill:
    """A request mid-prefill: owns its slot (and pages) but is not yet
    decoding.  ``carry`` is the batch-1 chunked-prefill state;
    ``snapshots`` keeps (page_depth, carry) at full-chunk boundaries for
    the prefix index."""

    req: Request
    prompt: np.ndarray  # int32
    carry: Any
    next_start: int  # first token position the next chunk will prefill
    pages: List[int]  # paged layout: all pages the slot owns, position order
    shared_tokens: int  # leading tokens satisfied by the prefix cache
    snapshots: List[Tuple[int, Any]] = dataclasses.field(default_factory=list)
    last_logits: Any = None  # (1, C, V) logits of the most recent chunk
    last_start: int = 0


# --------------------------------------------------------------------------
# cache insertion: scatter one prefilled request into a batch slot
# --------------------------------------------------------------------------
def _set_row(dst, src, slot, stacked):
    """dst (L?, B, *rest), src (L?, 1, *rest'): pad rest' up to rest with
    zeros (end-padding, matching `_pad_cache_to`) and overwrite the whole
    slot row — recycled slots must not leak the previous occupant."""
    off = 1 if stacked else 0
    widths = [(0, 0)] * src.ndim
    for ax in range(off + 1, src.ndim):
        widths[ax] = (0, dst.shape[ax] - src.shape[ax])
    row = jnp.pad(src, widths)
    row = row[:, 0] if stacked else row[0]
    if stacked:
        return dst.at[:, slot].set(row.astype(dst.dtype))
    return dst.at[slot].set(row.astype(dst.dtype))


def _scatter_pages(pool, row, pages, stacked):
    """pool (L?, KV, P, ps, D), row (L?, 1, S0, KV, D): write the prompt's
    K/V into the allocated pages (zero-padded to whole pages)."""
    ps = pool.shape[-2]
    n = pages.shape[0]
    if stacked:
        nl, _, s0, kv, d = row.shape
        r = jnp.pad(row[:, 0], ((0, 0), (0, n * ps - s0), (0, 0), (0, 0)))
        r = r.reshape(nl, n, ps, kv, d).transpose(0, 3, 1, 2, 4)
        return pool.at[:, :, pages].set(r.astype(pool.dtype))
    _, s0, kv, d = row.shape
    r = jnp.pad(row[0], ((0, n * ps - s0), (0, 0), (0, 0)))
    r = r.reshape(n, ps, kv, d).transpose(2, 0, 1, 3)
    return pool.at[:, pages].set(r.astype(pool.dtype))


def _insert_unit(dst: dict, src: dict, slot, pages, stacked):
    out = {}
    for key, leaf in dst.items():
        if key in ("k_pages", "v_pages"):
            # empty pages = the chunked prefill already scattered this
            # unit's K/V page by page; nothing to insert at finalize
            out[key] = leaf if pages.shape[0] == 0 else _scatter_pages(
                leaf, src[key[0]], pages, stacked
            )
        else:
            out[key] = _set_row(leaf, src[key], slot, stacked)
    return out


def _insert_prefill(cache: dict, pre: dict, slot, pages):
    out: Dict[str, Any] = {}
    if "blocks" in cache:
        out["blocks"] = {
            uk: _insert_unit(cache["blocks"][uk], pre["blocks"][uk], slot, pages, True)
            for uk in cache["blocks"]
        }
    if "rem" in cache:
        out["rem"] = {
            rk: _insert_unit(cache["rem"][rk], pre["rem"][rk], slot, pages, False)
            for rk in cache["rem"]
        }
    return out


def _scatter_chunk_unit(dst: dict, src: dict, start, pages, stacked, chunk):
    """Write positions [start, start+chunk) of a batch-1 prefill carry's
    global-attention slab into its pages.  Other leaves pass through —
    they are inserted once at finalize.  ``pages`` may contain NULL_PAGE
    for positions past the table horizon (padded final chunk): that
    garbage lands in the null page, which no live sequence ever reads —
    the same convention as parked dead slots."""
    if "k_pages" not in dst:
        return dst
    out = dict(dst)
    for pk, sk in (("k_pages", "k"), ("v_pages", "v")):
        pool, slab = dst[pk], src[sk]
        ps = pool.shape[-2]
        n = chunk // ps
        if stacked:
            nl, _, _, kv, d = slab.shape
            r = jax.lax.dynamic_slice_in_dim(slab, start, chunk, axis=2)
            r = r[:, 0].reshape(nl, n, ps, kv, d).transpose(0, 3, 1, 2, 4)
            out[pk] = pool.at[:, :, pages].set(r.astype(pool.dtype))
        else:
            _, _, kv, d = slab.shape
            r = jax.lax.dynamic_slice_in_dim(slab, start, chunk, axis=1)
            r = r[0].reshape(n, ps, kv, d).transpose(2, 0, 1, 3)
            out[pk] = pool.at[:, pages].set(r.astype(pool.dtype))
    return out


def _scatter_chunk_pages(cache: dict, pre: dict, start, pages, *, chunk: int):
    out: Dict[str, Any] = {}
    if "blocks" in cache:
        out["blocks"] = {
            uk: _scatter_chunk_unit(
                cache["blocks"][uk], pre["blocks"][uk], start, pages, True, chunk
            )
            for uk in cache["blocks"]
        }
    if "rem" in cache:
        out["rem"] = {
            rk: _scatter_chunk_unit(
                cache["rem"][rk], pre["rem"][rk], start, pages, False, chunk
            )
            for rk in cache["rem"]
        }
    return out


# --------------------------------------------------------------------------
# engine
# --------------------------------------------------------------------------
class ContinuousBatchingEngine:
    """Continuous-batching generation over a request queue.

    Restrictions vs the research model surface: text-only
    (``num_codebooks == 1``, no prefix embeds).  A request violating
    ``1 <= prompt_len`` / ``max_new_tokens >= 1`` /
    ``prompt_len + max_new_tokens <= max_len`` retires with
    ``status="error"`` at admission; it never reaches the device.

    `run(requests)` is self-resetting — the engine (and its compiled
    steps) can be reused across runs.  The prefix cache is per-run
    (every run measures from a cold cache); compiled chunk/insert/step
    functions amortize across requests and runs, with exactly one
    prefill trace regardless of prompt lengths.
    """

    def __init__(
        self,
        cfg: C.ModelConfig,
        params: Any,
        *,
        slots: int,
        max_len: int,
        cache_layout: str = "paged",
        page_size: Optional[int] = None,
        num_pages: Optional[int] = None,
        prefill_chunk_tokens: Optional[int] = None,
        prefix_cache: bool = True,
        temperature: float = 0.0,
        eos_id: Optional[int] = None,
        pad_id: int = 0,
        sync_interval: int = 8,
        seed: int = 0,
        admission_timeout_s: Optional[float] = None,
        on_starved: str = "raise",
        clock: Callable[[], float] = time.monotonic,
        speculative: Optional[SpeculativeConfig] = None,
    ):
        assert cfg.num_codebooks == 1 and cfg.num_prefix_embeds == 0, (
            "continuous batching serves text-only configs"
        )
        if cache_layout not in ("paged", "dense"):
            raise ValueError(cache_layout)
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache_layout = cache_layout
        if cache_layout == "paged":
            if page_size is None:
                from repro.kernels import tuned

                page_size = int(tuned.get_tuned("flash_decode")["page_size"])
            if num_pages is None:
                # worst case plus per-slot sync-lag over-allocation slack
                num_pages = required_pages(slots, max_len, page_size) + slots
        self.page_size = page_size
        self.num_pages = num_pages
        # chunk size: a fixed multiple of the page size so every chunk
        # boundary is a page boundary (prefix matches resume on chunks)
        chunk = prefill_chunk_tokens or 4 * (page_size or 4)
        if cache_layout == "paged":
            chunk = -(-chunk // page_size) * page_size
        self.prefill_chunk_tokens = int(chunk)
        self.prefix_cache = bool(prefix_cache) and cache_layout == "paged"
        self.temperature = temperature
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.sync_interval = max(1, sync_interval)
        self.key = jax.random.key(seed)
        if on_starved not in ("raise", "shed"):
            raise ValueError(on_starved)
        self.admission_timeout_s = admission_timeout_s
        self.on_starved = on_starved
        self._clock = clock
        if speculative is not None:
            if temperature > 0.0:
                # the verifier compares argmaxes; at temperature > 0 the
                # draft/target token distributions differ and "acceptance"
                # would silently change the sampled stream
                raise ValueError(
                    "speculative decoding is greedy-only: the exact "
                    "accept rule verifies argmax equality — run with "
                    "temperature=0.0"
                )
            if (speculative.proposer == "draft_model"
                    and speculative.draft_cfg is not None
                    and speculative.draft_cfg.vocab_size != cfg.vocab_size):
                raise ValueError(
                    "draft model vocab_size "
                    f"{speculative.draft_cfg.vocab_size} != target "
                    f"{cfg.vocab_size}: drafts would not be token ids"
                )
        self.spec = speculative
        self.stats: Dict[str, Any] = {}

        cap = prefill_cap(max_len, self.prefill_chunk_tokens)
        # zero carry template: chunk steps never donate their carry (the
        # prefix index snapshots alias it), so one template serves every
        # admission
        self._carry0 = init_prefill_carry(cfg, 1, cap)
        self._pchunk = jax.jit(
            lambda p, c, t, s, ln: prefill_chunk(cfg, p, c, t, s, ln)
        )
        self._finish = jax.jit(
            lambda c, ln: finish_prefill_carry(cfg, c, ln, max_len)
        )
        self._insert = jax.jit(_insert_prefill, donate_argnums=(0,))
        if cache_layout == "paged":
            self._scatter = jax.jit(
                functools.partial(
                    _scatter_chunk_pages, chunk=self.prefill_chunk_tokens
                ),
                donate_argnums=(0,),
            )
        self._step = self._make_step()
        self._spec_step = (
            self._make_spec_step() if self.spec is not None else None
        )

    # -- jitted decode step ------------------------------------------------
    def _make_step(self):
        cfg = self.cfg
        paged = self.cache_layout == "paged"
        temperature = self.temperature
        eos_id = self.eos_id
        pad_id = self.pad_id

        def step(params, cache, cur, pos, done, gen, max_new, uids, bt, key):
            logits, cache = decode_step(
                cfg, params, cache, cur[:, None], pos,
                block_tables=bt if paged else None,
            )
            lg = logits[:, 0]
            live = ~done
            # poisoned rows: NaN/inf logits on a live lane.  The flag rides
            # back to the host with the window's emits; the sync pass drops
            # the garbage token and retires the slot with a typed error.
            bad = live & ~jnp.isfinite(lg).all(axis=-1)
            if temperature > 0.0:
                keys = jax.vmap(
                    lambda u, g: jax.random.fold_in(jax.random.fold_in(key, u), g)
                )(uids, gen)
                nxt = jax.vmap(
                    lambda k_, l_: sample_tokens(
                        l_, vocab_size=cfg.vocab_size,
                        temperature=temperature, key=k_,
                    )
                )(keys, lg)
            else:
                nxt = sample_tokens(lg, vocab_size=cfg.vocab_size)
            emit = jnp.where(live, nxt, jnp.int32(pad_id))
            gen1 = gen + live
            done1 = done | (live & (gen1 >= max_new)) | bad
            if eos_id is not None:
                done1 = done1 | (live & (emit == eos_id))
            cur1 = jnp.where(done1, jnp.int32(pad_id), emit)
            pos1 = pos + live
            return cache, emit, bad, cur1, pos1, done1, gen1

        return jax.jit(step, donate_argnums=(1,))

    # -- jitted speculative step -------------------------------------------
    def _make_spec_step(self):
        """Width-K verified decode: score [cur, d_1..d_k] in one
        `decode_multi`, accept the longest draft prefix matching the
        target argmaxes plus the target's correction token, rewind
        rejected cache writes with `commit_multi`.

        Exactness: row 0 sees the committed cache, so target[0] is the
        plain step's token; row t's logits are only *used* when drafts
        0..t-1 all matched — in which case its inputs equal the plain
        sequential history bit-for-bit (`decode_multi`'s per-token
        contract).  Emissions are always target tokens, never raw
        drafts, and truncate at eos / token budget / non-finite rows
        exactly where the plain loop would stop — so speculative streams
        are bit-identical to non-speculative greedy decode and
        speculation is pure latency."""
        cfg = self.cfg
        paged = self.cache_layout == "paged"
        eos_id = self.eos_id
        pad_id = self.pad_id
        K = self.spec.k + 1

        def step(params, cache, cur, draft, width, pos, done, gen, max_new, bt):
            # draft: (B, K-1) proposer tokens (NO_DRAFT-padded); width:
            # (B,) in [1, K] — rows past a slot's width (degraded pool
            # cover, short proposal, budget) are scored but never used
            toks = jnp.concatenate([cur[:, None], draft], axis=1)
            logits, cache, staged = decode_multi(
                cfg, params, cache, toks, pos,
                block_tables=bt if paged else None,
            )
            live = ~done
            targets = sample_tokens(logits, vocab_size=cfg.vocab_size)
            tidx = jnp.arange(K)[None, :]
            in_w = tidx < width[:, None]
            match = (draft == targets[:, :-1]) & (
                tidx[:, : K - 1] < width[:, None] - 1
            )
            acc = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
            n = acc + 1  # accepted drafts + the correction token
            # poison: the plain loop emits the garbage token flagged, and
            # the host sync drops it — emit through the first bad row
            bad_rows = ~jnp.isfinite(logits).all(axis=-1) & in_w
            first_bad = jnp.where(
                bad_rows.any(axis=1), jnp.argmax(bad_rows, axis=1), K
            )
            n = jnp.minimum(n, first_bad + 1)
            if eos_id is not None:
                is_eos = (targets == eos_id) & in_w
                first_eos = jnp.where(
                    is_eos.any(axis=1), jnp.argmax(is_eos, axis=1), K
                )
                n = jnp.minimum(n, first_eos + 1)
            n = jnp.minimum(n, max_new - gen)
            n = jnp.where(live, jnp.maximum(n, 1), 0)
            emit_mask = tidx < n[:, None]
            emit = jnp.where(emit_mask, targets, jnp.int32(pad_id))
            bad = bad_rows & emit_mask
            gen1 = gen + n
            done1 = done | (live & (gen1 >= max_new)) | bad.any(axis=1)
            if eos_id is not None:
                done1 = done1 | (live[:, None] & is_eos & emit_mask).any(axis=1)
            last = jnp.take_along_axis(
                targets, jnp.clip(n - 1, 0, K - 1)[:, None], axis=1
            )[:, 0]
            cur1 = jnp.where(done1, jnp.int32(pad_id), last)
            pos1 = pos + n
            # rewind rejected writes; frozen rows keep step 0 (their lane
            # writes pad-token state at a fixed pos, same as the plain
            # loop's dead lanes — discarded at re-admission)
            cache = commit_multi(
                cfg, cache, staged, jnp.clip(n, 1, K), pos
            )
            return cache, emit, bad, n, cur1, pos1, done1, gen1

        return jax.jit(step, donate_argnums=(1,))

    # -- host loop ---------------------------------------------------------
    def run(
        self,
        requests: List[Request],
        *,
        hooks: Optional[EngineHooks] = None,
    ) -> List[Completion]:
        hooks = hooks or EngineHooks()
        cfg, b = self.cfg, self.slots
        chunk = self.prefill_chunk_tokens

        paged = self.cache_layout == "paged"
        if paged:
            tables = BlockTables.with_pool(
                b, self.max_len, self.page_size, self.num_pages
            )
            index = (
                PrefixIndex(self.page_size, tables.allocator)
                if self.prefix_cache else None
            )
            cache = init_cache(
                cfg, b, self.max_len, layout="paged",
                num_pages=self.num_pages, page_size=self.page_size,
            )
            bt_dev = jnp.asarray(tables.table)
            horizon = tables.max_pages * self.page_size
        else:
            tables = None
            index = None
            cache = init_cache(cfg, b, self.max_len)
            bt_dev = jnp.zeros((b, 1), jnp.int32)  # unused placeholder
            horizon = prefill_cap(self.max_len, chunk)

        pos = jnp.zeros((b,), jnp.int32)
        done = jnp.ones((b,), bool)  # empty slots are frozen
        gen = jnp.zeros((b,), jnp.int32)
        max_new = jnp.ones((b,), jnp.int32)
        uids = jnp.zeros((b,), jnp.int32)
        cur = jnp.full((b,), self.pad_id, jnp.int32)

        queue = collections.deque(requests)
        active: List[Optional[_SlotState]] = [None] * b
        pending: Dict[int, _PendingPrefill] = {}
        free = list(range(b - 1, -1, -1))  # pop() yields lowest slot first
        results: Dict[int, List[int]] = {}
        comps: Dict[int, Completion] = {}
        reported: Dict[int, int] = {}  # tokens already handed to on_tokens
        prompt_lens = {r.uid: len(r.prompt) for r in requests}
        pos_h = np.zeros(b, np.int64)  # optimistic host mirror of pos
        gen_prev = np.zeros(b, np.int64)
        decode_steps = prefills = prefill_chunks = 0
        peak_pages = shed = cancelled = errors = 0
        wait_uid: Optional[int] = None  # head-of-queue starvation tracking
        wait_t0 = 0.0
        # per-row sampling keys are fold_in(fold_in(key, uid), token_index)
        # — token 0 folds the base key at `finalize`, so the step must use
        # the SAME base (an extra fold here once made scheduler streams
        # diverge from the fixed engine's per-uid chain at temperature > 0)
        step_key = self.key
        proposer = (
            self.spec.build(b, self.max_len) if self.spec is not None else None
        )
        spec_k = self.spec.k if self.spec is not None else 0
        spec_steps = spec_drafted = spec_accepted = spec_degraded = 0

        def emit_tokens(uid: int) -> None:
            """Report any not-yet-reported tokens of a stream."""
            cur_n = reported.get(uid, 0)
            full = results[uid]
            if hooks.on_tokens is not None and len(full) > cur_n:
                hooks.on_tokens(uid, cur_n, list(full[cur_n:]))
            reported[uid] = len(full)

        def finish(uid: int, status: str, error: Optional[str] = None) -> None:
            nonlocal shed, cancelled, errors
            if status in ("ok", "error"):
                emit_tokens(uid)
            shed += status == "shed"
            cancelled += status == "cancelled"
            errors += status == "error"
            comp = Completion(
                uid, prompt_lens[uid], results.setdefault(uid, []), status, error
            )
            comps[uid] = comp
            if hooks.on_retire is not None:
                hooks.on_retire(comp)

        def cancel_requested(uid: int) -> bool:
            return hooks.should_cancel is not None and hooks.should_cancel(uid)

        def has_active() -> bool:
            return any(s is not None for s in active)

        def starve(req: Request, reason: str, need: int, avail: int,
                   waited: float) -> None:
            """A request admission cannot satisfy: raise, or shed it with a
            retryable (timeout/starved) or terminal (impossible) status."""
            if self.on_starved == "raise":
                raise AdmissionTimeout(req.uid, need, avail, reason, waited)
            err = AdmissionTimeout(req.uid, need, avail, reason, waited)
            queue.popleft()
            finish(req.uid, "error" if reason == "impossible" else "shed", str(err))

        def validate(req: Request) -> Optional[str]:
            pl = len(req.prompt)
            if pl < 1:
                return f"request {req.uid}: empty prompt"
            if req.max_new_tokens < 1:
                return (f"request {req.uid}: max_new_tokens "
                        f"{req.max_new_tokens} < 1")
            if pl + req.max_new_tokens > self.max_len:
                return (f"request {req.uid}: prompt_len {pl} + max_new_tokens "
                        f"{req.max_new_tokens} exceeds max_len {self.max_len}")
            return None

        def release_slot(slot: int) -> None:
            if paged:
                tables.release(slot)
            if proposer is not None:
                proposer.release(slot)
            free.append(slot)
            free.sort(reverse=True)

        def admit(slot: int, req: Request, m_tok: int,
                  shared_pages: List[int], carry0: Any,
                  cover: Optional[int]) -> None:
            nonlocal bt_dev, prefills
            prompt = np.ascontiguousarray(np.asarray(req.prompt, np.int32))
            pages: List[int] = []
            if paged:
                try:
                    pages = tables.admit(
                        slot, len(prompt), shared=shared_pages,
                        cover_tokens=cover,
                    )
                except PageOverflowError as e:
                    # unreachable for validated requests; kept as the typed
                    # -O-safe backstop of the poison discipline
                    results.setdefault(req.uid, [])
                    finish(req.uid, "error", str(e))
                    free.append(slot)
                    free.sort(reverse=True)
                    return
                bt_dev = jnp.asarray(tables.table)
            prefills += 1
            pending[slot] = _PendingPrefill(
                req=req, prompt=prompt, carry=carry0, next_start=m_tok,
                pages=list(pages), shared_tokens=m_tok,
            )

        def finalize(slot: int) -> None:
            nonlocal cache, pos, done, gen, max_new, uids, cur
            pp = pending.pop(slot)
            req = pp.req
            pl = len(pp.prompt)
            last_row = pp.last_logits[0, (pl - 1) - pp.last_start]
            if not np.isfinite(np.asarray(last_row)).all():
                # poisoned before the first token: typed error, slot unused
                results.setdefault(req.uid, [])
                finish(req.uid, "error",
                       f"non-finite prefill logits for request {req.uid}")
                release_slot(slot)
                return
            fin = self._finish(pp.carry, jnp.asarray([pl], jnp.int32))
            cache = self._insert(cache, fin, slot, jnp.zeros((0,), jnp.int32)
                                 if paged else jnp.zeros((0,), jnp.int32))
            if index is not None:
                # register the prompt's full pages; boundary snapshots let a
                # later admission resume its suffix prefill mid-prompt
                payloads = dict(pp.snapshots)
                for d in range(pp.shared_tokens // self.page_size,
                               pl // self.page_size):
                    index.insert(pp.prompt, d, pp.pages[d], payloads.get(d))
            if self.temperature > 0.0:
                k0 = jax.random.fold_in(
                    jax.random.fold_in(self.key, req.uid), 0
                )
            else:
                k0 = None
            tok0 = sample_tokens(
                last_row, vocab_size=cfg.vocab_size,
                temperature=self.temperature, key=k0,
            )
            t0 = int(tok0)
            finished = (req.max_new_tokens <= 1) or (
                self.eos_id is not None and t0 == self.eos_id
            )
            pos = pos.at[slot].set(pl)
            done = done.at[slot].set(finished)
            gen = gen.at[slot].set(1)
            max_new = max_new.at[slot].set(req.max_new_tokens)
            uids = uids.at[slot].set(req.uid)
            cur = cur.at[slot].set(self.pad_id if finished else t0)
            active[slot] = _SlotState(req.uid, pl, req.max_new_tokens)
            results[req.uid] = [t0]
            pos_h[slot] = pl
            gen_prev[slot] = 1
            if proposer is not None and not finished:
                proposer.admit(slot, pp.prompt.tolist(), t0)

        def step_prefill(slot: int) -> None:
            nonlocal cache, prefill_chunks
            pp = pending[slot]
            pl = len(pp.prompt)
            s0 = pp.next_start
            vlen = min(pl - s0, chunk)
            buf = np.zeros((1, chunk), np.int32)
            buf[0, :vlen] = pp.prompt[s0:s0 + vlen]
            pp.last_logits, pp.carry = self._pchunk(
                self.params, pp.carry, jnp.asarray(buf),
                jnp.asarray([s0], jnp.int32), jnp.asarray([vlen], jnp.int32),
            )
            prefill_chunks += 1
            if paged:
                ps = self.page_size
                pg = [
                    pp.pages[d] if d < len(pp.pages) else NULL_PAGE
                    for d in range(s0 // ps, (s0 + chunk) // ps)
                ]
                cache2 = self._scatter(
                    cache, pp.carry, jnp.int32(s0), jnp.asarray(pg, jnp.int32)
                )
                cache = cache2
            if index is not None and vlen == chunk:
                pp.snapshots.append(
                    ((s0 + chunk) // self.page_size - 1, pp.carry)
                )
            pp.last_start = s0
            pp.next_start = s0 + chunk
            if s0 + vlen >= pl:
                finalize(slot)

        while queue or pending or has_active():
            if hooks.on_window_start is not None:
                hooks.on_window_start()
            # cancellation sweep over mid-prefill requests (lost lease):
            # drop before spending another chunk on them
            for slot in list(pending):
                if cancel_requested(pending[slot].req.uid):
                    pp = pending.pop(slot)
                    results.setdefault(pp.req.uid, [])
                    release_slot(slot)
                    finish(pp.req.uid, "cancelled")
            # admissions at the sync boundary: start a chunked prefill in
            # every free slot — unless the page pool cannot hold the prompt
            # yet, in which case the request waits for a retirement to free
            # pages (bounded by admission_timeout_s / reachability, never a
            # bare spin: see AdmissionTimeout)
            while queue and free:
                req = queue[0]
                if cancel_requested(req.uid):
                    queue.popleft()
                    finish(req.uid, "cancelled")
                    continue
                err = validate(req)
                if err is not None:
                    queue.popleft()
                    results.setdefault(req.uid, [])
                    finish(req.uid, "error", err)
                    continue
                pl = len(req.prompt)
                if paged:
                    n_chunks = -(-pl // chunk)
                    cover = max(pl + 1, min(n_chunks * chunk, horizon))
                    m_tok, shared_pages, carry0 = 0, [], self._carry0
                    if index is not None:
                        chain = index.match(
                            np.asarray(req.prompt, np.int32),
                            max_blocks=(pl - 1) // self.page_size,
                        )
                        # resume only at a chunk boundary with a snapshot,
                        # leaving at least the last prompt token to prefill
                        m_tok = min(len(chain) * self.page_size, pl - 1)
                        m_tok = m_tok // chunk * chunk
                        while (m_tok > 0 and
                               chain[m_tok // self.page_size - 1].payload is None):
                            m_tok -= chunk
                        if m_tok > 0:
                            shared_pages = [
                                nd.page
                                for nd in chain[: m_tok // self.page_size]
                            ]
                            carry0 = chain[m_tok // self.page_size - 1].payload
                    need = pages_for(cover, self.page_size) - len(shared_pages)
                    if need > tables.allocator.capacity:
                        starve(req, "impossible", need,
                               tables.allocator.capacity, 0.0)
                        wait_uid = None
                        continue
                    if tables.allocator.available < need and index is not None:
                        # pool pressure: drop index-only pages, deepest
                        # first, pinning the chain this admission reuses
                        index.evict(need - tables.allocator.available,
                                    keep=shared_pages)
                    if tables.allocator.available < need:
                        now = self._clock()
                        if wait_uid != req.uid:
                            wait_uid, wait_t0 = req.uid, now
                        avail = tables.allocator.available
                        if not has_active() and not pending:
                            starve(req, "starved", need, avail, now - wait_t0)
                            wait_uid = None
                            continue
                        if (
                            self.admission_timeout_s is not None
                            and now - wait_t0 > self.admission_timeout_s
                        ):
                            starve(req, "timeout", need, avail, now - wait_t0)
                            wait_uid = None
                            continue
                        break  # wait for a retirement to free pages
                    admit(free.pop(), queue.popleft(), m_tok, shared_pages,
                          carry0, cover)
                else:
                    admit(free.pop(), queue.popleft(), 0, [], self._carry0,
                          None)
                wait_uid = None
            # prefill progress: one chunk per pending per window interleaves
            # prefill with decode; with no lane decoding, drain until one
            # goes live so the device never idles
            for slot in sorted(pending):
                if slot in pending:
                    step_prefill(slot)
            while not has_active() and pending:
                for slot in sorted(pending):
                    if slot in pending:
                        step_prefill(slot)
            if paged:
                peak_pages = max(peak_pages, tables.pages_in_use)
            if not has_active():
                # everything shed/cancelled/errored at admission; nothing
                # on device to step
                if hooks.on_window_end is not None:
                    hooks.on_window_end()
                continue

            if proposer is not None:
                # -- speculative window: one verified width-K step, then
                # sync.  The proposer needs the verified tokens before it
                # can draft the next round, so speculation syncs every
                # step — the window amortizes dispatches across the K
                # token positions instead of across sync_interval steps.
                # done-but-unretired slots (first sampled token was eos or
                # the budget was 1) were never admitted to the proposer:
                # they ride the verified step at width 1, masked, and
                # retire in this round's sync
                done_now = np.asarray(done)
                live_slots = [
                    s for s, st in enumerate(active)
                    if st is not None and not done_now[s]
                ]
                props = proposer.propose_batch(live_slots, spec_k)
                draft_h = np.full((b, spec_k), self.pad_id, np.int32)
                width_h = np.ones(b, np.int32)
                grew = False
                for slot in live_slots:
                    st = active[slot]
                    budget = int(st.max_new - gen_prev[slot])
                    dr = props[slot]
                    usable = 0
                    while usable < spec_k and dr[usable] != NO_DRAFT:
                        usable += 1
                    w = max(1, min(spec_k + 1, budget, 1 + usable))
                    if paged:
                        wpos = int(pos_h[slot])
                        want = max(1, min(w, self.max_len - wpos))
                        cov, g = tables.cover(slot, wpos, want)
                        grew |= g
                        spec_degraded += cov < w
                        w = cov
                    width_h[slot] = w
                    draft_h[slot, : w - 1] = dr[: w - 1]
                if grew:
                    bt_dev = jnp.asarray(tables.table)
                    peak_pages = max(peak_pages, tables.pages_in_use)
                cache, em, bf, nv, cur, pos, done, gen = self._spec_step(
                    self.params, cache, cur, jnp.asarray(draft_h),
                    jnp.asarray(width_h), pos, done, gen, max_new, bt_dev,
                )
                decode_steps += 1
                spec_steps += 1
                done_h = np.asarray(done)
                gen_h = np.asarray(gen)
                pos_dev = np.asarray(pos)
                em_h = np.asarray(em)  # (B, K)
                bf_h = np.asarray(bf)
                n_h = np.asarray(nv)
                for slot, st in enumerate(active):
                    if st is None:
                        continue
                    if cancel_requested(st.uid):
                        done = done.at[slot].set(True)
                        cur = cur.at[slot].set(self.pad_id)
                        active[slot] = None
                        release_slot(slot)
                        finish(st.uid, "cancelled")
                        continue
                    n_new = int(n_h[slot])
                    toks = em_h[slot, :n_new]
                    badw = bf_h[slot, :n_new]
                    poisoned = bool(badw.any())
                    if poisoned:
                        toks = toks[: int(np.argmax(badw))]
                    results[st.uid].extend(int(t) for t in toks)
                    spec_drafted += int(width_h[slot]) - 1
                    spec_accepted += max(0, n_new - 1)
                    gen_prev[slot] = gen_h[slot]
                    pos_h[slot] = int(pos_dev[slot])
                    if done_h[slot]:
                        active[slot] = None
                        release_slot(slot)
                        if poisoned:
                            finish(
                                st.uid, "error",
                                f"non-finite logits for request {st.uid} at "
                                f"token index {len(results[st.uid])}",
                            )
                        else:
                            finish(st.uid, "ok")
                    else:
                        proposer.extend(slot, [int(t) for t in toks])
                        emit_tokens(st.uid)
                if hooks.on_window_end is not None:
                    hooks.on_window_end()
                continue

            emits = []
            bads = []
            for _ in range(self.sync_interval):
                if paged:
                    grew = False
                    for slot, st in enumerate(active):
                        if st is None:
                            continue
                        # alloc-on-write: the next decode writes at pos;
                        # clamp covers done-but-unretired slots whose host
                        # mirror over-advanced past the horizon
                        wpos = min(int(pos_h[slot]), self.max_len - 1)
                        grew |= tables.ensure(slot, wpos)
                    if grew:
                        bt_dev = jnp.asarray(tables.table)
                        peak_pages = max(peak_pages, tables.pages_in_use)
                cache, emit, bad, cur, pos, done, gen = self._step(
                    self.params, cache, cur, pos, done, gen, max_new,
                    uids, bt_dev, step_key,
                )
                decode_steps += 1
                emits.append(emit)
                bads.append(bad)
                for slot, st in enumerate(active):
                    if st is not None:
                        # optimistic mirror of the device pos, bounded by
                        # the request's true final write position: the
                        # device freezes pos at retirement, so advancing
                        # the mirror past prompt_len + max_new would make
                        # alloc-on-write ensure pages the jitted step
                        # never writes (a retiring-at-the-boundary slot
                        # once allocated pages all the way to the clamped
                        # horizon while scattering at its frozen pos)
                        pos_h[slot] = min(
                            pos_h[slot] + 1, st.prompt_len + st.max_new
                        )

            # sync: pull the window's verdicts, distribute tokens, retire
            done_h = np.asarray(done)
            gen_h = np.asarray(gen)
            pos_dev = np.asarray(pos)
            em = np.stack([np.asarray(e) for e in emits])  # (W, B)
            bm = np.stack([np.asarray(x) for x in bads])  # (W, B)
            for slot, st in enumerate(active):
                if st is None:
                    continue
                if cancel_requested(st.uid):
                    # lost-ownership contract: drop the stream NOW — the
                    # window's tokens are never reported, the device lane
                    # is frozen and recycled
                    done = done.at[slot].set(True)
                    cur = cur.at[slot].set(self.pad_id)
                    active[slot] = None
                    release_slot(slot)
                    finish(st.uid, "cancelled")
                    continue
                n_new = int(gen_h[slot] - gen_prev[slot])
                toks = em[:n_new, slot]
                badw = bm[:n_new, slot]
                poisoned = bool(badw.any())
                if poisoned:
                    toks = toks[: int(np.argmax(badw))]  # drop garbage token(s)
                results[st.uid].extend(int(t) for t in toks)
                gen_prev[slot] = gen_h[slot]
                pos_h[slot] = int(pos_dev[slot])
                if done_h[slot]:
                    active[slot] = None
                    release_slot(slot)
                    if poisoned:
                        finish(
                            st.uid, "error",
                            f"non-finite logits for request {st.uid} at "
                            f"token index {len(results[st.uid])}",
                        )
                    else:
                        finish(st.uid, "ok")
                else:
                    emit_tokens(st.uid)
            if hooks.on_window_end is not None:
                hooks.on_window_end()

        self.stats = {
            "decode_steps": decode_steps,
            "prefills": prefills,
            "prefill_chunks": prefill_chunks,
            "prefill_chunk_tokens": chunk,
            "emitted_tokens": sum(len(t) for t in results.values()),
            "slots": b,
            "sync_interval": self.sync_interval,
            "cache_layout": self.cache_layout,
            "peak_pages": peak_pages,
            "page_size": self.page_size,
            "shed": shed,
            "cancelled": cancelled,
            "errors": errors,
        }
        if self.spec is not None:
            self.stats.update({
                "spec_k": spec_k,
                "spec_steps": spec_steps,
                "spec_drafted": spec_drafted,
                "spec_accepted": spec_accepted,
                "spec_degraded": spec_degraded,
                "spec_acceptance_rate": round(spec_accepted / spec_drafted, 4)
                if spec_drafted else 0.0,
            })
        if index is not None:
            self.stats.update(index.stats())
        return [comps[r.uid] for r in requests]
