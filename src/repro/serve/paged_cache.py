"""Paged KV cache bookkeeping: refcounted allocator, block tables, prefix cache.

The device side of paging lives in `models/transformer.py` (pool-shaped
cache leaves) and `kernels/flash_decode.py` (the attention kernel); this
module is the *host* side — the part that decides which physical page
holds which token.  It is deliberately plain Python: allocation decisions
are made once per page (amortized over ``page_size`` tokens and every
layer, which share one block table), so there is nothing to win by
putting them on device, and a synchronous free list is trivially
deterministic — the same admission order always produces the same page
assignment, which the paged==dense parity tests rely on.

Conventions:

* Page 0 is the reserved **null page**: never allocated, and every empty
  block-table entry points at it.  Dead batch slots park at position 0,
  so their (masked) decode writes land in the null page instead of a
  live sequence's memory.
* ``alloc`` hands out the lowest free page id (heap-ordered) —
  deterministic under any completion order.
* Pages are **refcounted**: ``alloc`` grants a page at refcount 1,
  ``share`` increments (a second owner — another slot's block table, or
  the prefix index), ``free`` decrements and only the last owner returns
  the page to the free heap.  A page with refcount >= 2 is *shared* and
  by convention immutable (only full, finalized prefix pages are ever
  shared).
* Alloc-on-write: `ensure(slot, pos)` grows a slot's table just-in-time
  when decode crosses a page boundary; `release(slot)` decrefs every
  page on eos/retirement — shared prefix pages survive a peer's eos.
* `PrefixIndex` is the radix-style prefix cache over the pool: a chain
  of full-page token blocks, each mapping the *exact* token bytes of the
  prompt prefix up to that block boundary to the physical page holding
  its K/V (exact-match chaining — no hash collisions to reason about at
  this scale).  ``admit``-time matching turns a repeated system-prompt
  prefill into a block-table copy plus a suffix-only prefill.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

NULL_PAGE = 0


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold `tokens` cache entries (at least one, so even
    an empty admission owns a distinct write target)."""
    return max(1, -(-tokens // page_size))


def required_pages(slots: int, max_len: int, page_size: int) -> int:
    """Pool size (pages, incl. the null page) that can never OOM: every
    slot simultaneously at the full decode horizon."""
    return 1 + slots * pages_for(max_len, page_size)


class PageOverflowError(RuntimeError):
    """A sequence asked for a cache position past its table's horizon.

    Raised (never assert'ed — it must fire under ``python -O`` too) by
    `BlockTables.ensure`/`admit` when a request would need more pages
    than ``max_pages``.  The scheduler catches it and retires the one
    malformed request with ``status="error"`` instead of letting a bad
    length crash every co-scheduled stream.
    """

    def __init__(self, slot: int, pos: int, max_len: int):
        self.slot = slot
        self.pos = pos
        self.max_len = max_len
        super().__init__(
            f"slot {slot}: cache position {pos} is past the decode horizon "
            f"(max_len={max_len}) — request length was not validated"
        )


class PageAllocator:
    """Lowest-id-first refcounted allocator over ``num_pages`` pages.

    Tracks per-page refcounts alongside the free heap so grant/return
    bugs fail at the faulty call instead of corrupting a live sequence's
    memory: allocating a page that is already held (double-grant),
    sharing one that isn't held, or freeing past refcount zero
    (double-free / foreign page) raises immediately.  Checkable
    invariants at every point (the serving fleet's paged_cache fuzz
    leans on them): ``held + available == capacity`` and
    ``sum(refcounts of held pages) >= held`` (every held page has at
    least one owner)."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need at least one allocatable page beyond the null page")
        self.num_pages = num_pages
        self._free: List[int] = list(range(1, num_pages))  # 0 = null page
        heapq.heapify(self._free)
        self._ref: Dict[int, int] = {}  # page -> refcount (held pages only)

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def held(self) -> int:
        """Distinct pages currently granted and not yet fully returned."""
        return len(self._ref)

    @property
    def total_refs(self) -> int:
        """Sum of refcounts across held pages (>= held)."""
        return sum(self._ref.values())

    @property
    def capacity(self) -> int:
        """Allocatable pages (the pool minus the reserved null page) —
        the ceiling admission backpressure checks a prompt against."""
        return self.num_pages - 1

    def refcount(self, page: int) -> int:
        return self._ref.get(page, 0)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"KV page pool exhausted: asked {n}, {len(self._free)} free "
                f"of {self.num_pages} (size the pool with required_pages())"
            )
        pages = [heapq.heappop(self._free) for _ in range(n)]
        for p in pages:
            if p == NULL_PAGE or p in self._ref:
                raise RuntimeError(f"allocator double-granted page {p}")
            self._ref[p] = 1
        return pages

    def share(self, pages: Sequence[int]) -> None:
        """Add one owner to each page (prefix reuse).  Only held pages
        can gain owners — sharing a free or null page is a bug."""
        for p in pages:
            if p == NULL_PAGE:
                raise RuntimeError("sharing the null page")
            if p not in self._ref:
                raise RuntimeError(f"sharing page {p} that is not held")
        for p in pages:
            self._ref[p] += 1

    def free(self, pages: Sequence[int]) -> None:
        """Drop one owner per page; the last owner returns it to the pool."""
        for p in pages:
            if p == NULL_PAGE:
                raise RuntimeError("freeing the null page")
            if p not in self._ref:
                raise RuntimeError(
                    f"freeing page {p} that is not held (double-free?)"
                )
        for p in pages:
            self._ref[p] -= 1
            if self._ref[p] == 0:
                del self._ref[p]
                heapq.heappush(self._free, p)


# --------------------------------------------------------------------------
# Prefix cache: exact-match chain of full-page token blocks
# --------------------------------------------------------------------------
@dataclasses.dataclass
class PrefixNode:
    """One cached full-page block: the page holding K/V for tokens
    ``[depth*page_size, (depth+1)*page_size)`` of every prompt whose
    prefix bytes match ``key``.  ``payload`` is an opaque engine-owned
    snapshot of non-paged model state at the block boundary (the chunked
    prefill carry) — what lets a suffix-only prefill resume mid-prompt
    for cache families that keep state outside the page pool (local-ring
    K/V, MLA latents, recurrent states)."""

    key: bytes
    depth: int  # block index: this node covers tokens [depth*ps, (depth+1)*ps)
    page: int
    payload: Any = None


class PrefixIndex:
    """Radix-style prefix cache over a `PageAllocator`'s page pool.

    Keys are the exact bytes of the token prefix up to each full-page
    boundary, chained: block *i* of a prompt is cached under
    ``tokens[:(i+1)*page_size].tobytes()``.  ``match`` walks the chain
    from the root and returns the longest run of cached blocks;
    ``insert`` registers a freshly prefilled block and increfs its page
    (the index is an owner, so cached pages survive the prefilling
    slot's retirement); ``evict`` drops index-only pages (refcount 1 —
    no slot is using them) deepest-first under pool pressure.
    """

    def __init__(self, page_size: int, allocator: PageAllocator):
        self.page_size = page_size
        self.allocator = allocator
        self._nodes: Dict[bytes, PrefixNode] = {}
        self.queries = 0
        self.hits = 0
        self.hit_tokens = 0

    def __len__(self) -> int:
        return len(self._nodes)

    def _key(self, tokens: np.ndarray, depth: int) -> bytes:
        return np.ascontiguousarray(
            tokens[: (depth + 1) * self.page_size], dtype=np.int32
        ).tobytes()

    def match(self, tokens: np.ndarray, *, max_blocks: Optional[int] = None
              ) -> List[PrefixNode]:
        """Longest chain of cached full-page blocks prefixing `tokens`,
        capped at ``max_blocks`` (admission must leave at least the last
        prompt token to prefill, so it can sample the first output)."""
        self.queries += 1
        limit = len(tokens) // self.page_size
        if max_blocks is not None:
            limit = min(limit, max_blocks)
        chain: List[PrefixNode] = []
        for depth in range(limit):
            node = self._nodes.get(self._key(tokens, depth))
            if node is None:
                break
            chain.append(node)
        if chain:
            self.hits += 1
            self.hit_tokens += len(chain) * self.page_size
        return chain

    def insert(self, tokens: np.ndarray, depth: int, page: int,
               payload: Any = None) -> bool:
        """Register block `depth` of `tokens` as cached in `page`.
        Increfs the page (the index becomes an owner).  Returns False
        when the block is already cached (a racing identical prompt
        prefilled it privately) — the caller keeps its private page."""
        key = self._key(tokens, depth)
        if key in self._nodes:
            return False
        self.allocator.share([page])
        self._nodes[key] = PrefixNode(key, depth, page, payload)
        return True

    def evict(self, n_pages: int, *, keep: Iterable[int] = ()) -> int:
        """Free up to ``n_pages`` pages held only by the index
        (refcount 1), deepest blocks first so chains break from the leaf
        end.  ``keep`` pins pages about to be shared by an in-flight
        admission.  Returns the number of pages returned to the pool."""
        if n_pages <= 0:
            return 0
        pinned = set(keep)
        freed = 0
        for key, node in sorted(
            self._nodes.items(), key=lambda kv: -kv[1].depth
        ):
            if freed >= n_pages:
                break
            if node.page in pinned:
                continue
            if self.allocator.refcount(node.page) == 1:
                self.allocator.free([node.page])
                del self._nodes[key]
                freed += 1
        return freed

    def stats(self) -> Dict[str, Any]:
        return {
            "prefix_queries": self.queries,
            "prefix_hits": self.hits,
            "prefix_hit_tokens": self.hit_tokens,
            "prefix_hit_rate": round(self.hits / self.queries, 4)
            if self.queries else 0.0,
            "prefix_blocks_cached": len(self._nodes),
        }


@dataclasses.dataclass
class BlockTables:
    """Per-slot block tables over a shared `PageAllocator`.

    ``table`` is the (slots, max_pages) int32 host mirror handed to the
    device each step (empty entries = NULL_PAGE); ``owned[slot]`` lists
    the pages a slot holds, in position order.  A slot's leading pages
    may be *shared* (prefix-cache hits, refcount >= 2): `release`
    decrefs rather than frees, so a peer slot (or the prefix index)
    keeps them alive.
    """

    slots: int
    max_len: int
    page_size: int
    allocator: PageAllocator

    def __post_init__(self):
        self.max_pages = pages_for(self.max_len, self.page_size)
        self.table = np.zeros((self.slots, self.max_pages), np.int32)
        self.owned: List[List[int]] = [[] for _ in range(self.slots)]
        # leading pages of `owned[slot]` that were admitted shared (their
        # content is immutable — the suffix prefill must not write them)
        self.shared_prefix: List[int] = [0] * self.slots

    @classmethod
    def with_pool(cls, slots: int, max_len: int, page_size: int,
                  num_pages: int) -> "BlockTables":
        return cls(slots, max_len, page_size, PageAllocator(num_pages))

    def admit(self, slot: int, prompt_len: int,
              shared: Sequence[int] = (),
              cover_tokens: Optional[int] = None) -> List[int]:
        """Allocate pages covering a prompt of `prompt_len` tokens plus
        the first decode write (position `prompt_len`).

        ``shared`` — leading pages already holding this prompt's prefix
        (from `PrefixIndex.match`); they are incref'd, not re-allocated.
        ``cover_tokens`` — widen the covered span (chunked prefill
        scatters whole fixed-size chunks, so the admission must own the
        pages under the final, partially-valid chunk too).
        """
        assert not self.owned[slot], f"slot {slot} not released"
        cover = max(prompt_len + 1, cover_tokens or 0)
        n = pages_for(cover, self.page_size)
        if n > self.max_pages:
            raise PageOverflowError(slot, cover - 1, self.max_len)
        if len(shared) > n:
            raise RuntimeError(
                f"slot {slot}: {len(shared)} shared prefix pages exceed the "
                f"{n} pages the prompt needs"
            )
        own = self.allocator.alloc(n - len(shared))
        self.allocator.share(shared)
        pages = list(shared) + own
        self.owned[slot] = pages
        self.shared_prefix[slot] = len(shared)
        self.table[slot, :n] = pages
        return pages

    def ensure(self, slot: int, pos: int) -> bool:
        """Alloc-on-write: make sure position `pos` has a page.  Returns
        True when the table changed (the device copy is stale).  Raises
        `PageOverflowError` (typed, -O-safe) past the horizon."""
        needed = pos // self.page_size + 1
        if needed > self.max_pages:
            raise PageOverflowError(slot, pos, self.max_len)
        grew = False
        while len(self.owned[slot]) < needed:
            (page,) = self.allocator.alloc(1)
            self.table[slot, len(self.owned[slot])] = page
            self.owned[slot].append(page)
            grew = True
        return grew

    def cover(self, slot: int, pos: int, want: int) -> Tuple[int, bool]:
        """Best-effort lookahead allocation for speculative decode: try to
        ensure pages for writes at ``pos .. pos + want - 1``.  Returns
        ``(covered, grew)`` — how many leading positions actually have
        pages (in ``[1, want]``) and whether the table changed.

        Position ``pos`` itself is guaranteed (a plain `ensure`, which may
        raise the usual typed `PageOverflowError` past the horizon); the
        lookahead degrades page by page instead of raising when the pool
        cannot cover it — the scheduler shrinks the speculation window to
        the covered width rather than stalling the whole batch on draft
        pages."""
        if want < 1:
            raise ValueError(f"cover wants at least one position, got {want}")
        grew = self.ensure(slot, pos)
        covered = 1
        while covered < want:
            nxt = pos + covered
            needed = nxt // self.page_size + 1
            if needed > self.max_pages:
                break  # horizon: lookahead writes past it are null-routed
            if len(self.owned[slot]) < needed and self.allocator.available < 1:
                break  # pool dry: degrade instead of stealing live pages
            grew |= self.ensure(slot, nxt)
            covered += 1
        return covered, grew

    def release(self, slot: int) -> None:
        """Drop a finished slot's ownership (eos/retirement): decref all
        pages; unshared ones return to the pool, shared prefix pages
        survive for their other owners."""
        if self.owned[slot]:
            self.allocator.free(self.owned[slot])
        self.owned[slot] = []
        self.shared_prefix[slot] = 0
        self.table[slot, :] = NULL_PAGE

    @property
    def pages_in_use(self) -> int:
        """Distinct pages referenced by live slots (shared pages counted
        once per owning slot — the *logical* footprint; the allocator's
        ``held`` is the physical one)."""
        return sum(len(p) for p in self.owned)
