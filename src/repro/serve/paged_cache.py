"""Paged KV cache bookkeeping: free-list allocator + per-slot block tables.

The device side of paging lives in `models/transformer.py` (pool-shaped
cache leaves) and `kernels/flash_decode.py` (the attention kernel); this
module is the *host* side — the part that decides which physical page
holds which token.  It is deliberately plain Python: allocation decisions
are made once per page (amortized over ``page_size`` tokens and every
layer, which share one block table), so there is nothing to win by
putting them on device, and a synchronous free list is trivially
deterministic — the same admission order always produces the same page
assignment, which the paged==dense parity tests rely on.

Conventions:

* Page 0 is the reserved **null page**: never allocated, and every empty
  block-table entry points at it.  Dead batch slots park at position 0,
  so their (masked) decode writes land in the null page instead of a
  live sequence's memory.
* ``alloc`` hands out the lowest free page id (heap-ordered) —
  deterministic under any completion order.
* Alloc-on-write: `ensure(slot, pos)` grows a slot's table just-in-time
  when decode crosses a page boundary; `release(slot)` returns every
  page on eos/retirement.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Set

import numpy as np

NULL_PAGE = 0


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold `tokens` cache entries (at least one, so even
    an empty admission owns a distinct write target)."""
    return max(1, -(-tokens // page_size))


def required_pages(slots: int, max_len: int, page_size: int) -> int:
    """Pool size (pages, incl. the null page) that can never OOM: every
    slot simultaneously at the full decode horizon."""
    return 1 + slots * pages_for(max_len, page_size)


class PageAllocator:
    """Lowest-id-first free-list allocator over ``num_pages`` pages.

    Tracks the held set alongside the free heap so grant/return bugs fail
    at the faulty call instead of corrupting a live sequence's memory:
    allocating a page that is already held (double-grant) or freeing one
    that isn't (double-free / foreign page) raises immediately, and
    ``held + available == capacity`` is a checkable invariant at every
    point (the serving fleet's paged_cache fuzz leans on it)."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError("need at least one allocatable page beyond the null page")
        self.num_pages = num_pages
        self._free: List[int] = list(range(1, num_pages))  # 0 = null page
        heapq.heapify(self._free)
        self._held: Set[int] = set()

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def held(self) -> int:
        """Pages currently granted and not yet returned."""
        return len(self._held)

    @property
    def capacity(self) -> int:
        """Allocatable pages (the pool minus the reserved null page) —
        the ceiling admission backpressure checks a prompt against."""
        return self.num_pages - 1

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise RuntimeError(
                f"KV page pool exhausted: asked {n}, {len(self._free)} free "
                f"of {self.num_pages} (size the pool with required_pages())"
            )
        pages = [heapq.heappop(self._free) for _ in range(n)]
        for p in pages:
            if p == NULL_PAGE or p in self._held:
                raise RuntimeError(f"allocator double-granted page {p}")
        self._held.update(pages)
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p == NULL_PAGE:
                raise RuntimeError("freeing the null page")
            if p not in self._held:
                raise RuntimeError(
                    f"freeing page {p} that is not held (double-free?)"
                )
            self._held.discard(p)
            heapq.heappush(self._free, p)


@dataclasses.dataclass
class BlockTables:
    """Per-slot block tables over a shared `PageAllocator`.

    ``table`` is the (slots, max_pages) int32 host mirror handed to the
    device each step (empty entries = NULL_PAGE); ``owned[slot]`` lists
    the pages a slot holds, in position order.
    """

    slots: int
    max_len: int
    page_size: int
    allocator: PageAllocator

    def __post_init__(self):
        self.max_pages = pages_for(self.max_len, self.page_size)
        self.table = np.zeros((self.slots, self.max_pages), np.int32)
        self.owned: List[List[int]] = [[] for _ in range(self.slots)]

    @classmethod
    def with_pool(cls, slots: int, max_len: int, page_size: int,
                  num_pages: int) -> "BlockTables":
        return cls(slots, max_len, page_size, PageAllocator(num_pages))

    def admit(self, slot: int, prompt_len: int) -> List[int]:
        """Allocate pages covering a prompt of `prompt_len` tokens plus
        the first decode write (position `prompt_len`)."""
        assert not self.owned[slot], f"slot {slot} not released"
        n = pages_for(prompt_len + 1, self.page_size)
        pages = self.allocator.alloc(n)
        self.owned[slot] = pages
        self.table[slot, :n] = pages
        return pages

    def ensure(self, slot: int, pos: int) -> bool:
        """Alloc-on-write: make sure position `pos` has a page.  Returns
        True when the table changed (the device copy is stale)."""
        needed = pos // self.page_size + 1
        assert needed <= self.max_pages, (pos, self.max_len)
        grew = False
        while len(self.owned[slot]) < needed:
            (page,) = self.allocator.alloc(1)
            self.table[slot, len(self.owned[slot])] = page
            self.owned[slot].append(page)
            grew = True
        return grew

    def release(self, slot: int) -> None:
        """Return a finished slot's pages to the pool (eos/retirement)."""
        if self.owned[slot]:
            self.allocator.free(self.owned[slot])
        self.owned[slot] = []
        self.table[slot, :] = NULL_PAGE

    @property
    def pages_in_use(self) -> int:
        return sum(len(p) for p in self.owned)
