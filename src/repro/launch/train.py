"""Training driver: end-to-end loop with checkpoint/restart fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch rwkv6-1.6b --smoke \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Restart the same command after a kill: it resumes from the latest
checkpoint and (because the data pipeline is (seed, step)-deterministic)
reproduces the exact trajectory the uninterrupted run would have taken.
On multi-host deployments each process runs this same program; the mesh
comes from jax.devices() and the data pipeline shards per process.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, get_config
from repro.launch.mesh import make_host_mesh
from repro.models.transformer import init_params
from repro.parallel import sharding as sh
from repro.parallel.act_sharding import activation_sharding
from repro.train import checkpoint as ckpt
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.optim import adamw, cosine_schedule
from repro.train.steps import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-1.6b")
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(ALIASES.get(args.arch, args.arch), smoke=args.smoke)
    mesh = make_host_mesh(model_axis=args.model_parallel)
    print(f"arch={cfg.name} devices={jax.device_count()} mesh={dict(mesh.shape)}")

    opt = adamw(cosine_schedule(args.lr, args.warmup, args.steps))
    params = init_params(jax.random.key(args.seed), cfg)
    state = init_train_state(params, opt)

    start_step = 0
    if args.ckpt_dir and ckpt.latest_step(args.ckpt_dir) is not None:
        state, start_step = ckpt.restore(args.ckpt_dir, template=state)
        print(f"resumed from checkpoint at step {start_step}")

    data = SyntheticLM(
        cfg.vocab_size, args.seq, args.batch, seed=args.seed,
        num_codebooks=cfg.num_codebooks,
        prefix_embeds=cfg.num_prefix_embeds, d_model=cfg.d_model,
    )
    prefetch = Prefetcher(data, start_index=start_step)

    p_shard = sh.param_sharding(mesh, jax.eval_shape(lambda: params))
    step_fn = make_train_step(cfg, opt, microbatches=args.microbatches)
    with jax.set_mesh(mesh), activation_sharding(mesh):
        jit_step = jax.jit(step_fn, donate_argnums=(0,))
        t0 = time.time()
        losses = []
        for step in range(start_step, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(prefetch).items()}
            state, metrics = jit_step(state, batch)
            losses.append(float(metrics["loss"]))
            if (step + 1) % args.log_every == 0:
                dt = time.time() - t0
                tok_s = args.batch * args.seq * args.log_every / dt
                print(
                    f"step {step+1:5d} loss {np.mean(losses[-args.log_every:]):.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} tok/s {tok_s:,.0f}",
                    flush=True,
                )
                t0 = time.time()
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                path = ckpt.save(args.ckpt_dir, step + 1, state)
                print(f"checkpoint -> {path}")
    prefetch.close()
    if args.ckpt_dir:
        ckpt.save(args.ckpt_dir, args.steps, state)
    print(f"final loss {np.mean(losses[-10:]):.4f} (first 10: {np.mean(losses[:10]):.4f})")


if __name__ == "__main__":
    main()
