"""Serving driver: batched generation with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --smoke \
        --batch 4 --prompt-len 16 --steps 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ALIASES, get_config
from repro.models.transformer import init_params
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(ALIASES.get(args.arch, args.arch), smoke=args.smoke)
    params = init_params(jax.random.key(args.seed), cfg)
    engine = ServeEngine(
        cfg, params, max_len=args.prompt_len + args.steps + cfg.num_prefix_embeds,
        temperature=args.temperature,
    )
    key = jax.random.key(args.seed + 1)
    shape = (args.batch, args.prompt_len)
    if cfg.num_codebooks > 1:
        shape = shape + (cfg.num_codebooks,)
    prompt2d = jax.random.randint(key, shape[:2], 0, cfg.vocab_size)
    kwargs = {}
    if cfg.num_prefix_embeds:
        kwargs["image_embeds"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.num_prefix_embeds, cfg.d_model), jnp.float32
        )

    t0 = time.time()
    out = engine.generate(prompt2d, steps=args.steps, key=key, **kwargs)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s incl. compile)")
    print("sample row:", out[0, : args.prompt_len + 8].tolist())


if __name__ == "__main__":
    main()
