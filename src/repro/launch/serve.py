"""Serving driver: fixed-batch or continuous-batching generation.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --smoke \
        --batch 4 --prompt-len 16 --steps 32

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --smoke \
        --continuous --requests 12 --slots 4 --cache-layout paged

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b --smoke \
        --fleet 3 --requests 12 --slots 2
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, get_config
from repro.models.transformer import init_params
from repro.serve.engine import ServeEngine


def run_fleet(args, arch: str) -> None:
    """Spawn N worker subprocesses over one shared fleet root and merge."""
    from repro.serve.fleet import FleetSpec, merge_streams, publish_spec

    rng = np.random.default_rng(args.seed)
    lens = [int(x) for x in rng.integers(2, args.steps + 1, args.requests)]
    spec = FleetSpec(
        arch=arch, smoke=args.smoke,
        prompt_lens=tuple([args.prompt_len] * args.requests),
        max_new_tokens=tuple(lens), seed=args.seed, slots=args.slots,
        max_len=args.prompt_len + args.steps + 1,
        temperature=args.temperature, sync_interval=args.sync_interval,
    )
    root = args.fleet_root or tempfile.mkdtemp(prefix="serve-fleet-")
    publish_spec(root, spec)
    t0 = time.time()
    procs = [
        subprocess.Popen(
            [sys.executable, "-m", "repro.serve.fleet", "run",
             "--root", root, "--owner", f"w{i}"],
            env=dict(os.environ),
        )
        for i in range(args.fleet)
    ]
    codes = [p.wait() for p in procs]
    dt = time.time() - t0
    streams, info = merge_streams(root, strict=True)
    complete = sum(s["complete"] for s in streams.values())
    tok = sum(len(s["tokens"]) for s in streams.values() if s["complete"])
    print(
        f"fleet of {args.fleet} workers served {complete}/{args.requests} "
        f"requests ({tok} tokens) in {dt:.2f}s incl. per-worker compile — "
        f"journals: {info['records']} records, {info['conflicts']} conflicts, "
        f"{info['partial']} partial lines (root: {root})"
    )
    if any(codes) or complete < args.requests:
        raise SystemExit(f"fleet incomplete: exit codes {codes}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching over a mixed-length request "
                         "queue (slot recycling + paged/dense KV cache) "
                         "instead of one fixed batch")
    ap.add_argument("--requests", type=int, default=12,
                    help="--continuous: queued requests (max_new mixed "
                         "over [2, --steps])")
    ap.add_argument("--slots", type=int, default=4,
                    help="--continuous: concurrent decode lanes")
    ap.add_argument("--cache-layout", choices=["paged", "dense"], default="paged")
    ap.add_argument("--sync-interval", type=int, default=8)
    ap.add_argument("--fleet", type=int, default=0,
                    help="spawn N leased fleet workers (repro.serve.fleet) "
                         "over one shared root instead of serving in-process")
    ap.add_argument("--fleet-root", default=None,
                    help="--fleet: shared storage root (default: a tempdir)")
    args = ap.parse_args()

    if args.fleet:
        run_fleet(args, ALIASES.get(args.arch, args.arch))
        return

    cfg = get_config(ALIASES.get(args.arch, args.arch), smoke=args.smoke)
    params = init_params(jax.random.key(args.seed), cfg)
    key = jax.random.key(args.seed + 1)

    if args.continuous:
        from repro.serve.scheduler import ContinuousBatchingEngine, Request

        rng = np.random.default_rng(args.seed)
        lens = rng.integers(2, args.steps + 1, args.requests)
        prompts = rng.integers(
            0, cfg.vocab_size, (args.requests, args.prompt_len)
        )
        eng = ContinuousBatchingEngine(
            cfg, params, slots=args.slots,
            max_len=args.prompt_len + args.steps + 1,
            cache_layout=args.cache_layout,
            temperature=args.temperature,
            sync_interval=args.sync_interval,
            seed=args.seed,
        )
        reqs = [
            Request(uid=i, prompt=prompts[i], max_new_tokens=int(lens[i]))
            for i in range(args.requests)
        ]
        t0 = time.time()
        comps = eng.run(reqs)
        dt = time.time() - t0
        tok = sum(len(c.tokens) for c in comps)
        st = eng.stats
        print(
            f"served {args.requests} requests ({tok} tokens) in {dt:.2f}s "
            f"incl. compile — {tok / dt:.1f} tok/s, "
            f"{tok / (st['decode_steps'] * args.slots):.2f} tok/slot-step, "
            f"{st['prefills']} prefills, layout={st['cache_layout']}"
            + (f", peak pages={st['peak_pages']}" if args.cache_layout == "paged" else "")
        )
        print("sample completion:", comps[0].tokens[:12])
        return

    engine = ServeEngine(
        cfg, params, max_len=args.prompt_len + args.steps + cfg.num_prefix_embeds,
        temperature=args.temperature,
    )
    shape = (args.batch, args.prompt_len)
    if cfg.num_codebooks > 1:
        shape = shape + (cfg.num_codebooks,)
    prompt2d = jax.random.randint(key, shape[:2], 0, cfg.vocab_size)
    kwargs = {}
    if cfg.num_prefix_embeds:
        kwargs["image_embeds"] = 0.1 * jax.random.normal(
            key, (args.batch, cfg.num_prefix_embeds, cfg.d_model), jnp.float32
        )

    t0 = time.time()
    out = engine.generate(prompt2d, steps=args.steps, key=key, **kwargs)
    dt = time.time() - t0
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s incl. compile)")
    print("sample row:", out[0, : args.prompt_len + 8].tolist())


if __name__ == "__main__":
    main()
