"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required because the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
while smoke tests and benchmarks must see the real single device.
"""

from __future__ import annotations

import jax

try:  # jax >= 0.5 names explicit/auto axis types; older versions have none
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover — version-dependent
    AxisType = None


def _axis_types(n: int):
    return {"axis_types": (AxisType.Auto,) * n} if AxisType is not None else {}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (one v5e pod slice).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types(len(axes)))


def make_host_mesh(model_axis: int = 1):
    """Whatever devices exist locally, as (data, model) — used by examples
    and integration tests on CPU."""
    n = jax.device_count()
    assert n % model_axis == 0, (n, model_axis)
    return jax.make_mesh(
        (n // model_axis, model_axis), ("data", "model"), **_axis_types(2)
    )


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
