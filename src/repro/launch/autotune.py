"""EvoEngineer-driven kernel autotuning (beyond-paper integration).

The paper's future-work §A.7.2 asks for "co-evolving kernels with their
compilation parameters".  This driver runs the SAME evolution engine over
the Pallas kernel genomes (block shapes / chunk sizes), scored through
the unified timing subsystem (`repro.evaluation.timing`):

* ``--timing wall`` — measured on-hardware: each genome's kernel is built
  at the benchmark shape and timed by `WallClockTiming` (warmup, IQR
  outlier rejection, median of kept runs) *interleaved* with a baseline
  run of the builtin genome, so slow clock drift cancels in the ranking
  ratio.  The winner is saved per device kind with
  ``_meta.source="measured"`` plus the run count and noise floor.
* ``--timing roofline`` — the analytic TPU v5e model (`RooflineTiming`):
  modeled kernel time (compute term vs HBM term with a VMEM-fit
  constraint as g(p)).  The offline path; winners save device-agnostic
  with ``_meta.source="modeled"`` and can never shadow a measured entry
  (see `repro.kernels.tuned`).
* ``--timing auto`` (default) — wall when `jax.devices()` reports a real
  accelerator, roofline otherwise.

    PYTHONPATH=src python -m repro.launch.autotune --kernel flash --trials 40

``--save`` persists the winning genome into the `repro.kernels.tuned`
registry, where the ops-layer dispatch wrappers pick it up as the default
block/chunk configuration (no more print-only JSON).
"""

from __future__ import annotations

import argparse
import json
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.evaluation.timing import (
    Measurement,
    RooflineTiming,
    TimingProvider,
    TimingRequest,
    WallClockTiming,
    device_kind,
    resolve_timing_mode,
)

# genome search spaces (the roofline models themselves live in
# repro.evaluation.timing.ROOFLINE_MODELS)
SPACES: Dict[str, Dict[str, list]] = {
    "flash": {"block_q": [64, 128, 256, 512], "block_k": [64, 128, 256, 512]},
    "flash_decode": {"page_size": [16, 32, 64, 128], "block_pages": [1, 2, 4, 8]},
    "matmul": {"block_m": [64, 128, 256, 512], "block_n": [64, 128, 256, 512], "block_k": [64, 128, 256, 512]},
    "wkv6": {"chunk": [16, 32, 64, 128, 256]},
}

# wall-mode benchmark shapes.  "paper" mirrors the roofline models'
# defaults (what a v5e would be tuned at); "small" keeps interpret-mode
# CPU measurement tractable so `--timing wall` works on any backend.
BENCH_SHAPES: Dict[str, Dict[str, Dict[str, int]]] = {
    "paper": {
        "flash": dict(b=1, s=8192, h=32, d=128),
        "flash_decode": dict(b=32, s=8192, h=32, kvh=8, d=128),
        "matmul": dict(m=8192, n=8192, k=8192),
        "wkv6": dict(b=8, s=8192, h=32, kd=64),
    },
    "small": {
        "flash": dict(b=1, s=256, h=2, d=32),
        "flash_decode": dict(b=2, s=128, h=4, kvh=2, d=16),
        "matmul": dict(m=256, n=256, k=256),
        "wkv6": dict(b=1, s=256, h=2, kd=16),
    },
}


def _bench_thunk(kernel: str, genome: Dict[str, Any], shapes: Dict[str, int]) -> Optional[Callable[[], Any]]:
    """A zero-arg callable running the kernel once with `genome`'s blocks
    at the benchmark shape (blocking until the result is ready), or
    ``None`` when the genome does not tile the shape.

    The Pallas kernels are called directly (not through the ops wrappers)
    with ``interpret`` resolved from the attached backend — the same rule
    as ``ops._interpret()``, minus its env override: compiled on a real
    accelerator, interpreter on CPU — a TPU "measured" entry must time
    the compiled kernel, never the Python interpreter."""
    import jax
    import jax.numpy as jnp

    from repro.evaluation.timing import has_accelerator
    from repro.kernels.blocked_matmul import matmul_pallas
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.kernels.wkv6 import wkv6_pallas

    interpret = not has_accelerator()
    key = jax.random.key(0)
    if kernel == "flash":
        b, s, h, d = shapes["b"], shapes["s"], shapes["h"], shapes["d"]
        if s % genome["block_q"] or s % genome["block_k"]:
            return None
        q = jax.random.normal(key, (b, s, h, d), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d), jnp.float32)
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d), jnp.float32)
        fn = jax.jit(
            lambda q, k, v: flash_attention_pallas(
                q, k, v, block_q=genome["block_q"], block_k=genome["block_k"],
                interpret=interpret,
            )
        )
        return lambda: jax.block_until_ready(fn(q, k, v))
    if kernel == "flash_decode":
        from repro.kernels.flash_decode import flash_decode_pallas

        b, s, h, kvh, d = (
            shapes["b"], shapes["s"], shapes["h"], shapes["kvh"], shapes["d"]
        )
        ps, bp = genome["page_size"], genome["block_pages"]
        if s % ps or (s // ps) % bp:
            return None
        mp = s // ps
        # every sequence fully cached: pools laid out page-contiguous per
        # sequence (page 0 reserved as null), identity-ish block tables
        q = jax.random.normal(key, (b, 1, h, d), jnp.float32)
        kp = jax.random.normal(
            jax.random.fold_in(key, 1), (kvh, 1 + b * mp, ps, d), jnp.float32
        )
        vp = jax.random.normal(
            jax.random.fold_in(key, 2), (kvh, 1 + b * mp, ps, d), jnp.float32
        )
        bt = 1 + jnp.arange(b * mp, dtype=jnp.int32).reshape(b, mp)
        lengths = jnp.full((b,), s, jnp.int32)
        fn = jax.jit(
            lambda q, kp, vp, bt, ln: flash_decode_pallas(
                q, kp, vp, bt, ln, block_pages=bp, interpret=interpret
            )
        )
        return lambda: jax.block_until_ready(fn(q, kp, vp, bt, lengths))
    if kernel == "matmul":
        m, n, k_ = shapes["m"], shapes["n"], shapes["k"]
        if m % genome["block_m"] or n % genome["block_n"] or k_ % genome["block_k"]:
            return None
        a = jax.random.normal(key, (m, k_), jnp.float32)
        b_ = jax.random.normal(jax.random.fold_in(key, 1), (k_, n), jnp.float32)
        fn = jax.jit(
            lambda a, b: matmul_pallas(
                a, b, block_m=genome["block_m"], block_n=genome["block_n"],
                block_k=genome["block_k"], interpret=interpret,
            )
        )
        return lambda: jax.block_until_ready(fn(a, b_))
    if kernel == "wkv6":
        b, s, h, kd = shapes["b"], shapes["s"], shapes["h"], shapes["kd"]
        if s % genome["chunk"]:
            return None
        mk = lambda i: jax.random.normal(jax.random.fold_in(key, i), (b, s, h, kd)) * 0.5
        r, k_, v = mk(1), mk(2), mk(3)
        lw = -jnp.exp(mk(4) - 4.0)
        u = jax.random.normal(jax.random.fold_in(key, 5), (h, kd)) * 0.1
        fn = jax.jit(
            lambda r, k, v, lw, u: wkv6_pallas(
                r, k, v, lw, u, chunk=genome["chunk"], interpret=interpret
            )
        )
        return lambda: jax.block_until_ready(fn(r, k_, v, lw, u))
    raise KeyError(f"no wall-clock bench for kernel {kernel!r}")


def _make_scorer(
    kernel: str,
    provider: TimingProvider,
    bench: Optional[Callable[[Dict[str, Any]], Optional[Callable[[], Any]]]] = None,
) -> Callable[[Dict[str, Any]], Optional[Measurement]]:
    """genome -> Measurement|None through `provider`.  Roofline scores the
    genome analytically; wall builds (or takes, for tests) a bench thunk
    per genome and interleaves it with the builtin-genome baseline."""
    if provider.mode == "roofline":
        return lambda g: provider.measure(TimingRequest(kernel=kernel, genome=g))
    if bench is None:
        raise ValueError(f"timing mode {provider.mode!r} needs a bench builder")

    from repro.kernels.tuned import _BUILTIN

    baseline_thunk = bench(dict(_BUILTIN[kernel]))

    def score(g: Dict[str, Any]) -> Optional[Measurement]:
        thunk = bench(g)
        if thunk is None:
            return None
        return provider.measure(
            TimingRequest(thunk=thunk, baseline_thunk=baseline_thunk)
        )

    return score


def tune(
    kernel: str,
    trials: int,
    seed: int = 0,
    provider: Optional[TimingProvider] = None,
    bench: Optional[Callable[[Dict[str, Any]], Optional[Callable[[], Any]]]] = None,
) -> Dict[str, Any]:
    """Hill-climb with the EvoEngineer-Full information regime: elite
    population + measured-gain insights biasing knob selection.

    The search trajectory depends only on ``(kernel, trials, seed)`` and
    the scores: with the default `RooflineTiming` provider it reproduces
    the historical modeled winners bit-for-bit (the scores are the same
    analytic model values in the same trial order)."""
    provider = provider or RooflineTiming()
    space = SPACES[kernel]
    rng = np.random.default_rng(seed)
    history = []
    elite: list = []  # (rank_key, genome, measurement)
    score = _make_scorer(kernel, provider, bench=bench)
    # memoize by genome: revisited genomes (common — the spaces are small
    # and 70% of trials mutate an elite) reuse their measurement instead
    # of re-paying warmup+runs kernel executions in wall mode.  Scores are
    # per-genome constants either way, so the search trajectory — and the
    # roofline mode's bit-identity with the historical winners — is
    # unchanged.  Elite may hold duplicate genomes, exactly as the
    # historical algorithm did (deduping would change the trajectory).
    memo: Dict[tuple, Optional[Measurement]] = {}

    def scored(g: Dict[str, Any]) -> Optional[Measurement]:
        gkey = tuple(sorted(g.items()))
        if gkey not in memo:
            memo[gkey] = score(g)
        return memo[gkey]

    for trial in range(trials):
        if elite and rng.random() < 0.7:
            base = dict(elite[int(rng.integers(len(elite)))][1])
            knob = list(space)[int(rng.integers(len(space)))]
            base[knob] = space[knob][int(rng.integers(len(space[knob])))]
            g = base
        else:
            g = {k: v[int(rng.integers(len(v)))] for k, v in space.items()}
        m = scored(g)
        history.append(
            {"trial": trial, "genome": g, "time_us": None if m is None else m.runtime_us}
        )
        if m is not None:
            elite.append((m.rank, g, m))
            elite.sort(key=lambda e: e[0])
            del elite[4:]
    if not elite:
        raise RuntimeError(
            f"autotune({kernel}): no feasible genome in {trials} trials"
        )
    _, best_g, best_m = elite[0]
    res = {
        "kernel": kernel,
        "timing": provider.mode,
        "device_kind": device_kind(),
        "best_genome": best_g,
        "best_us": best_m.runtime_us,
        "best_measurement": best_m,
        "valid_rate": sum(1 for h in history if h["time_us"]) / len(history),
        "history": history,
    }
    if provider.mode == "roofline":
        # legacy key for historical consumers — modeled numbers only; a
        # measured wall-clock must never masquerade as a roofline estimate
        res["best_modeled_us"] = best_m.runtime_us
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", choices=sorted(SPACES), default="flash")
    ap.add_argument("--trials", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--timing", choices=["auto", "wall", "roofline"], default="auto",
        help="genome scoring: measured wall-clock, the analytic roofline "
             "model, or auto (wall iff a real accelerator is attached)",
    )
    ap.add_argument(
        "--bench-shape", choices=["auto", "small", "paper"], default="auto",
        help="--timing wall benchmark shape: paper-scale (TPU) or small "
             "(tractable in interpret mode); auto picks by backend",
    )
    ap.add_argument("--timing-runs", type=int, default=15,
                    help="--timing wall: timed repeats per genome")
    ap.add_argument("--warmup-runs", type=int, default=2,
                    help="--timing wall: untimed warmups per genome")
    ap.add_argument("--out", default=None)
    ap.add_argument(
        "--save", action="store_true",
        help="persist the best genome into the repro.kernels.tuned registry",
    )
    ap.add_argument(
        "--save-path", default=None,
        help="registry file to write (default: the active tuned_genomes.json)",
    )
    args = ap.parse_args(argv)

    mode = resolve_timing_mode(args.timing)
    kind = device_kind()
    if mode == "wall":
        from repro.evaluation.timing import has_accelerator

        shape_preset = args.bench_shape
        if shape_preset == "auto":
            shape_preset = "paper" if has_accelerator() else "small"
        provider: TimingProvider = WallClockTiming(
            timing_runs=args.timing_runs, warmup_runs=args.warmup_runs
        )
        bench = lambda g: _bench_thunk(args.kernel, g, BENCH_SHAPES[shape_preset][args.kernel])
        res = tune(args.kernel, args.trials, args.seed, provider=provider, bench=bench)
        res["bench_shape"] = shape_preset
    else:
        res = tune(args.kernel, args.trials, args.seed, provider=RooflineTiming())

    m: Measurement = res["best_measurement"]
    noise = f" noise_floor={m.noise_floor_us:.1f}us" if mode == "wall" else ""
    print(
        f"kernel={res['kernel']} timing={mode} device={kind} "
        f"best={res['best_genome']} {'measured' if mode == 'wall' else 'modeled'}"
        f"={res['best_us']:.1f}us{noise} valid={res['valid_rate']:.2f}"
    )
    if args.out:
        out = {k: v for k, v in res.items() if k != "best_measurement"}
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2)
    if args.save:
        from repro.kernels import tuned

        meta = m.provenance()
        meta.update({"trials": args.trials, "seed": args.seed})
        if mode == "wall":
            meta.update({
                "device_kind": kind,
                "measured_us": round(res["best_us"], 1),
                "bench_shape": res["bench_shape"],
            })
        else:
            meta.update({
                "modeled_us": round(res["best_us"], 1),
                "model": "v5e roofline",
            })
        path = tuned.save_tuned(
            args.kernel,
            res["best_genome"],
            meta=meta,
            path=args.save_path,
            device_kind=kind if mode == "wall" else None,
        )
        print(f"saved tuned genome -> {path}")


if __name__ == "__main__":
    main()
