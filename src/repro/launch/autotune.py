"""EvoEngineer-driven kernel autotuning (beyond-paper integration).

The paper's future-work §A.7.2 asks for "co-evolving kernels with their
compilation parameters".  This driver runs the SAME evolution engine over
the Pallas kernel genomes (block shapes / chunk sizes), scored by the
analytic TPU v5e roofline model — CPU wall-clock cannot rank MXU tilings,
so f(p) here is the modeled kernel time (compute term vs HBM term with a
VMEM-fit constraint as g(p)).

    PYTHONPATH=src python -m repro.launch.autotune --kernel flash --trials 40

``--save`` persists the winning genome into the `repro.kernels.tuned`
registry, where the ops-layer dispatch wrappers pick it up as the default
block/chunk configuration (no more print-only JSON).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Any, Dict

import numpy as np

from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

VMEM_BYTES = 128 * 2**20  # v5e VMEM per core (we budget half for double-buffering)
VMEM_BUDGET = VMEM_BYTES // 2


# --------------------------------------------------------------------------
# analytic kernel models: (genome) -> (seconds, vmem_bytes)
# --------------------------------------------------------------------------
def model_flash(g, *, s=8192, h=32, d=128, b=1):
    bq, bk = g["block_q"], g["block_k"]
    if s % bq or s % bk:
        return None
    n_tiles = (s // bq) * (s // bk) * h * b
    flops_tile = 2 * bq * bk * d * 2  # qk^T and pv
    bytes_tile = (bq * d + 2 * bk * d) * 2  # q stays resident per q row
    # causal: ~half the tiles contribute
    t_compute = 0.5 * n_tiles * flops_tile / PEAK_FLOPS_BF16
    t_memory = 0.5 * n_tiles * bytes_tile / HBM_BW
    # MXU alignment penalty: dims below 128 underfill the systolic array
    util = min(bq, 128) / 128 * min(bk, 128) / 128
    t_compute /= max(util, 1e-3)
    vmem = (bq * d + bk * d * 2) * 2 + bq * (d + 2) * 4
    return max(t_compute, t_memory), vmem


def model_matmul(g, *, m=8192, n=8192, k=8192):
    bm, bn, bk = g["block_m"], g["block_n"], g["block_k"]
    if m % bm or n % bn or k % bk:
        return None
    tiles = (m // bm) * (n // bn) * (k // bk)
    t_compute = 2 * m * n * k / PEAK_FLOPS_BF16
    bytes_total = tiles * (bm * bk + bk * bn) * 2 + (m // bm) * (n // bn) * bm * bn * 2
    t_memory = bytes_total / HBM_BW
    util = min(bm, 128) / 128 * min(bn, 128) / 128 * min(bk, 128) / 128
    vmem = (bm * bk + bk * bn) * 2 + bm * bn * 4
    return max(t_compute / max(util, 1e-3), t_memory), vmem


def model_wkv6(g, *, s=8192, h=32, kd=64, b=8):
    c = g["chunk"]
    if s % c:
        return None
    n_chunks = (s // c) * h * b
    flops = n_chunks * (2 * c * kd * kd * 3 + 2 * c * c * kd * 2)
    bytes_ = n_chunks * (4 * c * kd * 2 + c * kd * 4)
    vmem = 5 * c * kd * 4 + kd * kd * 4
    # small chunks underfill the MXU on the (c x c) intra matmul
    util = min(c, 128) / 128
    return max(flops / PEAK_FLOPS_BF16 / max(util, 1e-3), bytes_ / HBM_BW), vmem


KERNELS = {
    "flash": (model_flash, {"block_q": [64, 128, 256, 512], "block_k": [64, 128, 256, 512]}),
    "matmul": (model_matmul, {"block_m": [64, 128, 256, 512], "block_n": [64, 128, 256, 512], "block_k": [64, 128, 256, 512]}),
    "wkv6": (model_wkv6, {"chunk": [16, 32, 64, 128, 256]}),
}


def tune(kernel: str, trials: int, seed: int = 0) -> Dict[str, Any]:
    """Hill-climb with the EvoEngineer-Full information regime: elite
    population + measured-gain insights biasing knob selection."""
    model, space = KERNELS[kernel]
    rng = np.random.default_rng(seed)
    history = []
    elite: list = []  # (time, genome)

    def score(g):
        out = model(g)
        if out is None:
            return None
        t, vmem = out
        if vmem > VMEM_BUDGET:  # g(p) != 0: VMEM violation
            return None
        return t

    for trial in range(trials):
        if elite and rng.random() < 0.7:
            base = dict(elite[int(rng.integers(len(elite)))][1])
            knob = list(space)[int(rng.integers(len(space)))]
            base[knob] = space[knob][int(rng.integers(len(space[knob])))]
            g = base
        else:
            g = {k: v[int(rng.integers(len(v)))] for k, v in space.items()}
        t = score(g)
        history.append({"trial": trial, "genome": g, "time_us": None if t is None else t * 1e6})
        if t is not None:
            elite.append((t, g))
            elite.sort(key=lambda e: e[0])
            del elite[4:]
    best_t, best_g = elite[0]
    return {
        "kernel": kernel,
        "best_genome": best_g,
        "best_modeled_us": best_t * 1e6,
        "valid_rate": sum(1 for h in history if h["time_us"]) / len(history),
        "history": history,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", choices=sorted(KERNELS), default="flash")
    ap.add_argument("--trials", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument(
        "--save", action="store_true",
        help="persist the best genome into the repro.kernels.tuned registry",
    )
    ap.add_argument(
        "--save-path", default=None,
        help="registry file to write (default: the active tuned_genomes.json)",
    )
    args = ap.parse_args()
    res = tune(args.kernel, args.trials, args.seed)
    print(f"kernel={res['kernel']} best={res['best_genome']} "
          f"modeled={res['best_modeled_us']:.1f}us valid={res['valid_rate']:.2f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=2)
    if args.save:
        from repro.kernels import tuned

        path = tuned.save_tuned(
            args.kernel,
            res["best_genome"],
            meta={
                "modeled_us": round(res["best_modeled_us"], 1),
                "trials": args.trials,
                "seed": args.seed,
                "source": "repro.launch.autotune (v5e roofline model)",
            },
            path=args.save_path,
        )
        print(f"saved tuned genome -> {path}")


if __name__ == "__main__":
    main()
