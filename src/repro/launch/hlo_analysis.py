"""Trip-count-corrected cost analysis over compiled (post-SPMD) HLO.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE — for a
model that scans its layers (and chunk-scans its attention), reported FLOPs
would be off by the trip counts.  This module fixes that exactly:

1.  Parse ``compiled.as_text()`` into computations.
2.  For every while op, recover the trip count from its condition
    computation (scan conditions compare the induction variable against an
    s32 constant).
3.  Extract each while-body computation (plus its transitive callees) as a
    standalone HLO module, re-parse it with ``hlo_module_from_text`` and run
    XLA's own ``hlo_module_cost_analysis`` on it.
4.  Correct recursively:   total(comp) = xla(comp)
                           + Σ_whiles (trip·total(body) − xla(body))
    (xla(comp) already contains body-once costs, nested whiles handled by
    recursion).

Collective wire bytes are computed by our own parser over the same
structure with per-op formulas (per-device shapes, post-partitioning):
    all-gather        result_bytes * (gs-1)/gs      received bytes
    all-reduce        2 * result_bytes * (gs-1)/gs  ring RS+AG
    reduce-scatter    result_bytes * (gs-1)         sends input≈result*gs
    all-to-all        result_bytes * (gs-1)/gs
    collective-permute result_bytes
where gs = replica group size parsed from the op's replica_groups.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0, "e4m3": 1,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")
_DEF_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\("
)
_CALLED_RE = re.compile(r"(?:calls=|to_apply=|condition=|body=)%?([\w\.\-\"]+)")
_CALLED_LIST_RE = re.compile(r"called_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_bytes(type_str: str) -> int:
    """Total bytes of (possibly tuple) shaped type text."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instruction:
    name: str
    op: str
    type_str: str
    line: str
    called: Tuple[str, ...]


@dataclasses.dataclass
class Computation:
    name: str
    header: str
    lines: List[str]
    instructions: List[Instruction]
    is_entry: bool


def parse_computations(txt: str) -> Dict[str, Computation]:
    lines = txt.splitlines()
    comps: Dict[str, Computation] = {}
    i = 0
    while i < len(lines):
        line = lines[i]
        stripped = line.strip()
        if stripped.endswith("{") and ") -> " in stripped:
            m = re.match(r"(ENTRY\s+)?%?([\w\.\-]+)\s*\(", stripped)
            if m:
                name = m.group(2)
                is_entry = bool(m.group(1)) or stripped.startswith("ENTRY")
                body: List[str] = [line]
                i += 1
                while i < len(lines) and not lines[i].startswith("}"):
                    body.append(lines[i])
                    i += 1
                if i < len(lines):
                    body.append(lines[i])
                instrs = []
                for raw in body[1:-1]:
                    bl = _COMMENT_RE.sub("", raw)
                    dm = _DEF_RE.match(bl)
                    if not dm:
                        continue
                    iname, type_str, op = dm.group(2), dm.group(3).strip(), dm.group(4)
                    called = [c.strip('"') for c in _CALLED_RE.findall(bl)]
                    for lst in _CALLED_LIST_RE.findall(bl):
                        called += [
                            c.strip().lstrip("%").strip('"')
                            for c in lst.split(",")
                            if c.strip()
                        ]
                    called = tuple(called)
                    instrs.append(Instruction(iname, op, type_str, bl, called))
                comps[name] = Computation(name, body[0], body, instrs, is_entry)
        i += 1
    return comps


def _entry_name(comps: Dict[str, Computation]) -> str:
    for name, c in comps.items():
        if c.is_entry:
            return name
    raise ValueError("no ENTRY computation found")


def _transitive_callees(comps: Dict[str, Computation], root: str) -> List[str]:
    """Transitive callee computations in POST-ORDER (callees before callers),
    as the HLO text parser requires define-before-use."""
    order: List[str] = []
    seen = set()

    def walk(name: str):
        for ins in comps[name].instructions:
            for c in ins.called:
                if c in comps and c not in seen:
                    seen.add(c)
                    walk(c)
                    order.append(c)

    walk(root)
    return order


def extract_module_text(comps: Dict[str, Computation], root: str) -> str:
    deps = _transitive_callees(comps, root)
    parts = ["HloModule extracted\n"]
    for d in deps:
        parts.append("\n".join(comps[d].lines))
        parts.append("")
    root_text = "\n".join(comps[root].lines)
    root_text = root_text.lstrip()
    if root_text.startswith("ENTRY"):
        parts.append(root_text)
    else:
        parts.append("ENTRY " + root_text)
    return "\n\n".join(parts)


def _while_ops(
    comps: Dict[str, Computation], comp: str
) -> List[Tuple[str, str, str]]:
    """(cond, body, line) of whiles reachable from comp WITHOUT passing
    through another while body."""
    found: List[Tuple[str, str, str]] = []
    visited = set()

    def walk(name: str):
        if name in visited:
            return
        visited.add(name)
        for ins in comps[name].instructions:
            if ins.op == "while":
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                if cm and bm:
                    found.append((cm.group(1), bm.group(1), ins.line))
            else:
                for c in ins.called:
                    if c in comps:
                        walk(c)

    walk(comp)
    return found


def trip_count(
    comps: Dict[str, Computation], cond: str, while_line: str = ""
) -> int:
    """Recover the while trip count.

    Preferred source: XLA's own ``backend_config={"known_trip_count":{"n":N}}``
    annotation on the while op.  Fallback: jax scans compare the induction
    var (starting at 0, step 1) LT an s32 constant — take the max positive
    s32 constant reachable from the condition computation.
    """
    tm = _TRIP_RE.search(while_line)
    if tm:
        return int(tm.group(1))
    candidates: List[int] = []

    def scan_comp(name: str, depth: int = 0):
        if name not in comps or depth > 3:
            return
        for ins in comps[name].instructions:
            if ins.op == "constant":
                cm = re.search(r"constant\((-?\d+)\)", ins.line)
                if cm and ins.type_str.strip().startswith("s32"):
                    candidates.append(int(cm.group(1)))
            for c in ins.called:
                scan_comp(c, depth + 1)

    scan_comp(cond)
    pos = [c for c in candidates if c > 0]
    if not pos:
        return 1
    return max(pos)


# --------------------------------------------------------------------------
# collective wire bytes
# --------------------------------------------------------------------------
def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        first = m.group(1)
        return len([x for x in first.split(",") if x.strip() != ""])
    return n_devices


def _collective_wire_bytes(ins: Instruction, n_devices: int) -> Tuple[str, float]:
    gs = _group_size(ins.line, n_devices)
    rb = _shape_bytes(ins.type_str)
    frac = (gs - 1) / gs if gs > 1 else 0.0
    if ins.op.startswith("all-gather"):
        return "all-gather", rb * frac
    if ins.op.startswith("all-reduce"):
        # The CPU backend promotes bf16 all-reduces to f32 (reduction
        # computation renamed *_promoted).  A real TPU reduces in bf16 on
        # the wire, so halve the counted bytes for promoted reductions.
        if "_promo" in ins.line:
            rb *= 0.5
        return "all-reduce", 2.0 * rb * frac
    if ins.op.startswith("reduce-scatter"):
        return "reduce-scatter", rb * (gs - 1)
    if ins.op.startswith("all-to-all"):
        return "all-to-all", rb * frac
    if ins.op.startswith("collective-permute"):
        return "collective-permute", float(rb)
    return ins.op, 0.0


# --------------------------------------------------------------------------
# the recursive analyzer
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    transcendentals: float = 0.0
    wire_bytes: float = 0.0
    wire_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __add__(self, o: "Cost") -> "Cost":
        kinds = dict(self.wire_by_kind)
        for k, v in o.wire_by_kind.items():
            kinds[k] = kinds.get(k, 0.0) + v
        return Cost(
            self.flops + o.flops,
            self.bytes_accessed + o.bytes_accessed,
            self.transcendentals + o.transcendentals,
            self.wire_bytes + o.wire_bytes,
            kinds,
        )

    def scaled(self, f: float) -> "Cost":
        return Cost(
            self.flops * f,
            self.bytes_accessed * f,
            self.transcendentals * f,
            self.wire_bytes * f,
            {k: v * f for k, v in self.wire_by_kind.items()},
        )


class HloAnalyzer:
    def __init__(self, hlo_text: str, n_devices: int):
        try:  # jaxlib >= 0.5 renamed the extension module
            from jax._src.lib import _jax as _jaxlib
        except ImportError:
            from jax._src.lib import xla_extension as _jaxlib

        import jax

        self._jaxlib = _jaxlib
        self._client = jax.devices()[0].client
        self.n_devices = n_devices
        self.comps = parse_computations(hlo_text)
        self.entry = _entry_name(self.comps)
        self._xla_cache: Dict[str, Cost] = {}
        self._total_cache: Dict[str, Cost] = {}

    # -- XLA cost of a computation subtree (whiles counted once) ---------
    def _xla_cost(self, comp: str) -> Cost:
        if comp in self._xla_cache:
            return self._xla_cache[comp]
        mod_txt = extract_module_text(self.comps, comp)
        m = self._jaxlib.hlo_module_from_text(mod_txt)
        props = self._jaxlib.hlo_module_cost_analysis(self._client, m)
        wire = self._direct_wire(comp, set())
        cost = Cost(
            flops=float(props.get("flops", 0.0)),
            bytes_accessed=float(props.get("bytes accessed", 0.0)),
            transcendentals=float(props.get("transcendentals", 0.0)),
            wire_bytes=sum(wire.values()),
            wire_by_kind=wire,
        )
        self._xla_cache[comp] = cost
        return cost

    def _direct_wire(self, comp: str, visited: set) -> Dict[str, float]:
        """Collective bytes reachable without weighting (incl. through-while
        ONCE — matching what _xla_cost's module extraction contains)."""
        if comp in visited:
            return {}
        visited.add(comp)
        out: Dict[str, float] = {}
        for ins in self.comps[comp].instructions:
            if any(ins.op.startswith(c) for c in _COLLECTIVES):
                kind, b = _collective_wire_bytes(ins, self.n_devices)
                out[kind] = out.get(kind, 0.0) + b
            for c in ins.called:
                if c in self.comps:
                    sub = self._direct_wire(c, visited)
                    for k, v in sub.items():
                        out[k] = out.get(k, 0.0) + v
        return out

    # -- trip-corrected total ------------------------------------------------
    def total_cost(self, comp: Optional[str] = None) -> Cost:
        comp = comp or self.entry
        if comp in self._total_cache:
            return self._total_cache[comp]
        cost = self._xla_cost(comp)
        for cond, body, line in _while_ops(self.comps, comp):
            trips = trip_count(self.comps, cond, line)
            body_total = self.total_cost(body)
            body_once = self._xla_cost(body)
            cost = cost + body_total.scaled(trips) + body_once.scaled(-1.0)
        self._total_cache[comp] = cost
        return cost

    def while_summary(self) -> List[Tuple[str, int]]:
        out = []
        for cond, body, line in self._all_whiles():
            out.append((body, trip_count(self.comps, cond, line)))
        return out

    # -- trip-weighted per-op output-byte breakdown ----------------------
    _OP_BYTES_SKIP = frozenset(
        ("parameter", "constant", "tuple", "get-tuple-element", "bitcast")
    )

    def op_bytes(self, comp: Optional[str] = None, mult: float = 1.0) -> Dict[str, float]:
        """Output bytes produced per op kind, while bodies weighted by
        their trip counts.  A cheap cost-share proxy (which ops move the
        data) for the perf-diagnosis layer: fusions count as one 'fusion'
        instruction rather than their internals, matching how a profiler
        attributes time to fused kernels."""
        comp = comp or self.entry
        out: Dict[str, float] = {}

        def merge(sub: Dict[str, float]) -> None:
            for k, v in sub.items():
                out[k] = out.get(k, 0.0) + v

        for ins in self.comps[comp].instructions:
            if ins.op in self._OP_BYTES_SKIP:
                continue
            if ins.op == "while":
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                if cm and bm and bm.group(1) in self.comps:
                    trips = trip_count(self.comps, cm.group(1), ins.line)
                    merge(self.op_bytes(bm.group(1), mult * trips))
                continue
            out[ins.op] = out.get(ins.op, 0.0) + mult * _shape_bytes(ins.type_str)
        return out

    def _all_whiles(self):
        found = []
        for c in self.comps.values():
            for ins in c.instructions:
                if ins.op == "while":
                    cm = re.search(r"condition=%?([\w\.\-]+)", ins.line)
                    bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
                    if cm and bm:
                        found.append((cm.group(1), bm.group(1), ins.line))
        return found


def analyze_compiled(compiled, n_devices: int) -> Dict[str, float]:
    """Full corrected analysis of a jax Compiled object.

    Returns per-DEVICE totals (post-SPMD HLO shapes are per-device).
    """
    txt = compiled.as_text()
    analyzer = HloAnalyzer(txt, n_devices)
    cost = analyzer.total_cost()
    raw = compiled.cost_analysis()
    if isinstance(raw, (list, tuple)):  # jax < 0.5 returns one dict per program
        raw = raw[0] if raw else {}
    if raw is None:  # CPU backends / older jax may report no cost analysis
        raw = {}
    return {
        "flops": cost.flops,
        "bytes_accessed": cost.bytes_accessed,
        "transcendentals": cost.transcendentals,
        "wire_bytes": cost.wire_bytes,
        "wire_by_kind": cost.wire_by_kind,
        "uncorrected_flops": float(raw.get("flops", 0.0)),
        "uncorrected_bytes": float(raw.get("bytes accessed", 0.0)),
        "while_trips": analyzer.while_summary(),
        "op_bytes": analyzer.op_bytes(),
    }
