"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
train_step / prefill_step / serve_step against these.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import config as C
from repro.models.transformer import cache_specs


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_input_specs(cfg: C.ModelConfig, shape: C.ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    text_s = s - cfg.num_prefix_embeds  # seq cells count the total sequence
    tok_shape = (b, text_s) if cfg.num_codebooks == 1 else (b, text_s, cfg.num_codebooks)
    specs = {
        "tokens": sds(tok_shape, jnp.int32),
        "targets": sds(tok_shape, jnp.int32),
    }
    if cfg.num_prefix_embeds > 0:
        specs["image_embeds"] = sds(
            (b, cfg.num_prefix_embeds, cfg.d_model), jnp.float32
        )
    return specs


def prefill_input_specs(cfg: C.ModelConfig, shape: C.ShapeConfig) -> Dict[str, Any]:
    specs = train_input_specs(cfg, shape)
    del specs["targets"]
    return specs


def decode_input_specs(cfg: C.ModelConfig, shape: C.ShapeConfig) -> Dict[str, Any]:
    """One new token against a cache of seq_len."""
    b, s = shape.global_batch, shape.seq_len
    tok_shape = (b, 1) if cfg.num_codebooks == 1 else (b, 1, cfg.num_codebooks)
    cache = jax.eval_shape(lambda: cache_specs(cfg, b, s))
    return {
        "tokens": sds(tok_shape, jnp.int32),
        "cache": cache,
        "pos": sds((), jnp.int32),
    }


def input_specs(cfg: C.ModelConfig, shape: C.ShapeConfig) -> Dict[str, Any]:
    if shape.mode == "train":
        return train_input_specs(cfg, shape)
    if shape.mode == "prefill":
        return prefill_input_specs(cfg, shape)
    if shape.mode == "decode":
        return decode_input_specs(cfg, shape)
    raise ValueError(shape.mode)
