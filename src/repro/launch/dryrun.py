import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the very first two lines, before any jax-importing module:
# jax locks the device count at first init, and the dry-run needs 512
# placeholder host devices to build the production meshes.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  * builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  * lowers the right step function (train_step for train cells, prefill /
    serve_step for inference cells) against ShapeDtypeStruct inputs with the
    sharding rules from parallel/sharding.py,
  * compiles, records memory_analysis() + trip-corrected cost analysis
    (launch/hlo_analysis.py) + collective wire bytes,
  * writes one JSON per cell under --out (benchmarks/roofline.py and
    EXPERIMENTS.md consume these).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b \
      --shape train_4k --mesh multi                           # one cell
"""

import argparse
import dataclasses
import json
import math
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ALIASES, ARCHS, get_config, get_shape
from repro.launch import mesh as mesh_lib
from repro.launch.specs import input_specs
from repro.launch.hlo_analysis import analyze_compiled
from repro.models import config as C
from repro.models.transformer import forward, param_specs
from repro.parallel import sharding as sh
from repro.serve.engine import make_decode_step
from repro.train.optim import adamw
from repro.train.steps import TrainState, init_train_state, make_train_step

from jax.sharding import PartitionSpec as P


def _opt_state_specs(param_spec_tree):
    return {"mu": param_spec_tree, "nu": param_spec_tree}


def count_params(cfg: C.ModelConfig) -> Dict[str, float]:
    """Total and active parameter counts (active < total only for MoE)."""
    specs = param_specs(cfg)
    total = sum(int(np_prod(l.shape)) for l in jax.tree.leaves(specs))
    active = total
    if cfg.moe is not None:
        flat, _ = jax.tree_util.tree_flatten_with_path(specs)
        routed = 0
        for keypath, leaf in flat:
            path = "/".join(sh._key_str(k) for k in keypath)
            if ("mlp/w_gate" in path or "mlp/w_up" in path or "mlp/w_down" in path) and (
                "shared" not in path
            ) and leaf.ndim >= 3:
                routed += int(np_prod(leaf.shape))
        active = total - routed + int(routed * cfg.moe.top_k / cfg.moe.num_experts)
    return {"total": float(total), "active": float(active)}


def np_prod(shape):
    out = 1
    for s in shape:
        out *= int(s)
    return out


def model_flops(cfg: C.ModelConfig, shape: C.ShapeConfig, counts) -> float:
    """6*N*D for training, 2*N*D for prefill, 2*N*B for decode (one token)."""
    n = counts["active"]
    if shape.mode == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.mode == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch


# --------------------------------------------------------------------------
# Step builders (lower targets)
# --------------------------------------------------------------------------
def _bf16_params(p_specs):
    """Serving weights are bf16 (production checkpoints); fp32 stays for
    small norm scales where it matters numerically -- here we cast all."""
    import jax.numpy as _jnp

    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, _jnp.bfloat16)
        if s.dtype == _jnp.float32
        else s,
        p_specs,
    )


def build_train_target(cfg: C.ModelConfig, shape: C.ShapeConfig, mesh, microbatches: int = 1):
    specs = input_specs(cfg, shape)
    p_specs = param_specs(cfg)
    p_shard = sh.param_sharding(mesh, p_specs)
    opt = adamw(1e-4)
    step_fn = make_train_step(cfg, opt, microbatches=microbatches)

    state_specs = jax.eval_shape(
        lambda p: init_train_state(p, opt), p_specs
    )
    state_shard = TrainState(
        params=p_shard, opt_state=_opt_state_specs(p_shard), step=P()
    )
    batch_shard = sh.activation_specs(mesh, specs)

    in_shardings = (
        TrainState(p_shard, _opt_state_specs(p_shard), P()),
        batch_shard,
    )
    out_shardings = (
        TrainState(p_shard, _opt_state_specs(p_shard), P()),
        None,  # metrics: let the compiler place scalars
    )
    args = (state_specs, specs)
    return step_fn, args, in_shardings, out_shardings


def build_prefill_target(cfg: C.ModelConfig, shape: C.ShapeConfig, mesh):
    specs = input_specs(cfg, shape)
    p_specs = _bf16_params(param_specs(cfg))
    p_shard = sh.param_sharding(mesh, p_specs)
    batch_shard = sh.activation_specs(mesh, specs)

    # inference: no remat needed
    infer_cfg = dataclasses.replace(cfg, remat="none")

    def prefill_fn(params, batch):
        logits, _, cache = forward(
            infer_cfg, params, batch["tokens"],
            image_embeds=batch.get("image_embeds"), return_cache=True,
            last_only=True,
        )
        return logits[:, -1], cache

    out_shape = jax.eval_shape(lambda p, b: prefill_fn(p, b), p_specs, specs)
    logits_shape, cache_shape = out_shape
    cache_shard = sh.cache_specs_sharding(mesh, cache_shape)
    logits_rule = (sh.DP,) + (None,) * (len(logits_shape.shape) - 2) + (sh.TP,)
    logits_spec = sh._fit(mesh, logits_shape.shape, logits_rule)
    in_shardings = (p_shard, batch_shard)
    out_shardings = (logits_spec, cache_shard)
    args = (p_specs, specs)
    return prefill_fn, args, in_shardings, out_shardings


def build_decode_target(cfg: C.ModelConfig, shape: C.ShapeConfig, mesh):
    specs = input_specs(cfg, shape)
    p_specs = _bf16_params(param_specs(cfg))
    p_shard = sh.param_sharding(mesh, p_specs)
    infer_cfg = dataclasses.replace(cfg, remat="none")
    decode_fn = make_decode_step(infer_cfg)

    cache_shard = sh.cache_specs_sharding(mesh, specs["cache"])
    tok_shard = sh.activation_specs(mesh, {"tokens": specs["tokens"]})["tokens"]

    def step(params, cache, tokens, pos):
        return decode_fn(params, cache, tokens, pos)

    logits_shape = jax.eval_shape(
        step, p_specs, specs["cache"], specs["tokens"], specs["pos"]
    )[0]
    logits_rule = (sh.DP,) + (None,) * (len(logits_shape.shape) - 2) + (sh.TP,)
    logits_spec = sh._fit(mesh, logits_shape.shape, logits_rule)
    in_shardings = (p_shard, cache_shard, tok_shard, P())
    out_shardings = (logits_spec, cache_shard)
    args = (p_specs, specs["cache"], specs["tokens"], specs["pos"])
    return step, args, in_shardings, out_shardings


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    n_dev = np_prod(mesh.devices.shape)
    mesh_name = "multi" if multi_pod else "single"
    cell_id = f"{arch}_{shape_name}_{mesh_name}"
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "n_devices": n_dev, "mode": shape.mode,
    }

    if shape.name == "long_500k" and not cfg.is_sub_quadratic():
        rec["status"] = "skip"
        rec["reason"] = "full-attention architecture; O(L^2) at 524k (DESIGN.md)"
        _write(out_dir, cell_id, rec)
        return rec

    try:
        from repro.parallel.act_sharding import activation_sharding

        # HBM-fit escalation: when the per-device peak exceeds the v5e
        # budget on a train cell, raise the gradient-accumulation
        # microbatch count (the standard production lever) and recompile.
        microbatches = 1
        seq_parallel = False
        counts_total = count_params(cfg)["total"]
        attempts = []
        while True:
            if shape.mode == "train":
                fn, args, in_sh, out_sh = build_train_target(
                    cfg, shape, mesh, microbatches=microbatches
                )
            elif shape.mode == "prefill":
                fn, args, in_sh, out_sh = build_prefill_target(cfg, shape, mesh)
            else:
                fn, args, in_sh, out_sh = build_decode_target(cfg, shape, mesh)

            donate = (
                (0,) if shape.mode == "train" else ((1,) if shape.mode == "decode" else ())
            )
            t0 = time.time()
            with jax.set_mesh(mesh), activation_sharding(
                mesh, seq_parallel=seq_parallel
            ):
                lowered = jax.jit(
                    fn,
                    in_shardings=in_sh,
                    out_shardings=out_sh,
                    donate_argnums=donate,
                ).lower(*args)
                t1 = time.time()
                compiled = lowered.compile()
            t2 = time.time()

            ma0 = compiled.memory_analysis()
            peak = (
                ma0.argument_size_in_bytes
                + ma0.output_size_in_bytes
                + ma0.temp_size_in_bytes
                - getattr(ma0, "alias_size_in_bytes", 0)  # donated buffers
            ) / 2**30
            attempts.append(
                {
                    "microbatches": microbatches,
                    "seq_parallel": seq_parallel,
                    "peak_gib": peak,
                }
            )
            local_batch = shape.global_batch * mesh.shape["model"] // n_dev
            mb_maxed = microbatches >= min(local_batch, 64)
            if shape.mode != "train" or peak <= 15.0:
                break
            # ZeRO-3 weight-gather traffic scales with the microbatch count,
            # so sequence-parallel residuals (cheap per-layer collectives)
            # engage BEFORE pushing microbatches past 8 (§Perf iteration 5)
            # Measured trade (EXPERIMENTS.md §Perf iters 5/7): seq-parallel
            # residuals beat extra grad-accum for mid-size models (qwen:
            # coll 42.8->30.4s) but trigger pathological SPMD resharding at
            # deepseek-67b scale (coll 78->452s).  Heuristic: sp-first only
            # under 40B params.
            sp_first = counts_total <= 4e10
            mb_cap = (
                8 if (sp_first and not seq_parallel) else min(local_batch, 64)
            )
            if sp_first and microbatches >= 8 and not seq_parallel:
                seq_parallel = True
            elif microbatches < mb_cap:
                factor = max(2, 2 ** math.ceil(math.log2(peak / 12.0)))
                microbatches = min(microbatches * factor, mb_cap)
            elif not seq_parallel:
                seq_parallel = True  # last resort for the big models
            else:
                break
        rec["microbatches"] = microbatches
        rec["seq_parallel"] = seq_parallel
        rec["hbm_fit_attempts"] = attempts

        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_gib": ma.argument_size_in_bytes / 2**30,
            "output_gib": ma.output_size_in_bytes / 2**30,
            "temp_gib": ma.temp_size_in_bytes / 2**30,
            "alias_gib": getattr(ma, "alias_size_in_bytes", 0) / 2**30,
            "code_gib": ma.generated_code_size_in_bytes / 2**30,
            "peak_gib": (
                ma.argument_size_in_bytes
                + ma.output_size_in_bytes
                + ma.temp_size_in_bytes
                - getattr(ma, "alias_size_in_bytes", 0)
            ) / 2**30,
        }
        rec["fits_hbm16"] = rec["memory"]["peak_gib"] <= 16.0
        rec["timings"] = {"lower_s": t1 - t0, "compile_s": t2 - t1}

        analysis = analyze_compiled(compiled, n_dev)
        rec["cost"] = analysis

        counts = count_params(cfg)
        rec["params"] = counts
        mf = model_flops(cfg, shape, counts)
        rec["model_flops"] = mf

        # roofline terms (per device; HLO costs are already per-device)
        flops_t = analysis["flops"] / mesh_lib.PEAK_FLOPS_BF16
        mem_t = analysis["bytes_accessed"] / mesh_lib.HBM_BW
        coll_t = analysis["wire_bytes"] / mesh_lib.ICI_BW
        dominant = max(
            ("compute", flops_t), ("memory", mem_t), ("collective", coll_t),
            key=lambda kv: kv[1],
        )[0]
        rec["roofline"] = {
            "compute_s": flops_t,
            "memory_s": mem_t,
            "collective_s": coll_t,
            "dominant": dominant,
            "bound_s": max(flops_t, mem_t, coll_t),
            "model_vs_hlo_flops": mf / max(analysis["flops"] * n_dev, 1.0),
        }
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    _write(out_dir, cell_id, rec)
    return rec


def _write(out_dir: str, cell_id: str, rec: Dict[str, Any]) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell_id + ".json"), "w") as f:
        json.dump(rec, f, indent=2, default=str)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="assignment id or module name")
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = [ALIASES.get(args.arch, args.arch)] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else [s.name for s in C.ALL_SHAPES]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                mesh_name = "multi" if multi else "single"
                t0 = time.time()
                rec = run_cell(arch, shape_name, multi, args.out)
                dt = time.time() - t0
                status = rec["status"]
                n_ok += status == "ok"
                n_skip += status == "skip"
                n_err += status == "error"
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (
                        f"peak={rec['memory']['peak_gib']:.2f}GiB "
                        f"dom={r['dominant']} bound={r['bound_s']*1e3:.2f}ms"
                    )
                elif status == "error":
                    extra = rec["error"][:120]
                print(
                    f"[{status:5s}] {arch:22s} {shape_name:12s} {mesh_name:6s} "
                    f"({dt:5.1f}s) {extra}",
                    flush=True,
                )
    print(f"\nDRY-RUN SUMMARY: ok={n_ok} skip={n_skip} error={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
