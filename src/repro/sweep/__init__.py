"""Work-stealing distributed sweep driver for the table-4 grid.

The paper's main experiment is a ``task x method x seed`` grid (91 tasks,
6 methods, 3 seeds).  One process can own the whole sweep, but the grid is
embarrassingly parallel across units — this package turns it into a
manifest of work units that any number of concurrently running driver
processes lease, run and append to the shared JSONL results file:

* `manifest`  — the deterministic unit list (and its on-disk contract, so
  every driver agrees on the grid);
* `lease`     — lease files with TTL + heartbeat on shared storage.  All
  writes are full-content-to-temp-then-rename (no lock server); expired
  leases are reclaimed by any driver (work stealing);
* `driver`    — the `SweepDriver` loop plus `run_unit`, the single-unit
  runner shared with the serial `benchmarks/table4_overall.py` path so a
  distributed sweep is record-identical to a serial one;
* `merge`     — crash-tolerant JSONL reading (torn trailing lines from a
  killed appender are skipped and reported, duplicate records from
  stolen-but-still-running units are deduped last-write-wins by unit key)
  and the canonical merged view every summarizer reads.

Correctness does NOT depend on mutual exclusion: leases are a liveness
optimization (avoid duplicate work), while the determinism of the engine
guarantees that a duplicated unit produces an identical record and the
merge layer keeps exactly one.  CLI: ``python -m repro.sweep --results
results/table4.jsonl --heartbeat 30`` (see `__main__`).
"""

# Lazy attribute exports: `driver` (and through it the engine/evaluator/
# jax stack plus the task registry) must not load just because a
# summarizer imported `repro.sweep.merge` to parse a JSONL.
_EXPORTS = {
    "Lease": "repro.sweep.lease",
    "LeaseStore": "repro.sweep.lease",
    "SweepDriver": "repro.sweep.driver",
    "SweepManifest": "repro.sweep.manifest",
    "WorkUnit": "repro.sweep.manifest",
    "append_jsonl": "repro.sweep.merge",
    "append_record": "repro.sweep.merge",
    "build_manifest": "repro.sweep.manifest",
    "dedupe_last_wins": "repro.sweep.merge",
    "join_fleet": "repro.sweep.driver",
    "load_records": "repro.sweep.merge",
    "quick_subset": "repro.sweep.manifest",
    "read_jsonl": "repro.sweep.merge",
    "read_records": "repro.sweep.merge",
    "record_key": "repro.sweep.merge",
    "run_unit": "repro.sweep.driver",
}


def __getattr__(name):
    if name in _EXPORTS:
        import importlib

        return getattr(importlib.import_module(_EXPORTS[name]), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "Lease",
    "LeaseStore",
    "SweepDriver",
    "SweepManifest",
    "WorkUnit",
    "append_jsonl",
    "append_record",
    "build_manifest",
    "dedupe_last_wins",
    "join_fleet",
    "load_records",
    "quick_subset",
    "read_jsonl",
    "read_records",
    "record_key",
    "run_unit",
]
