"""Lease files with TTL + heartbeat on shared storage — no lock server.

One lease file per work unit, full JSON content, always written as
temp-file-then-rename so readers see either the old lease or the new one,
never a torn write.  Acquisition of a *free* unit uses ``os.link`` (which
fails if the lease exists, unlike rename) so two drivers racing on a free
unit get exactly one winner.  Stealing an *expired* lease uses
``os.replace`` followed by a read-back: the last writer's content wins,
and every stealer that doesn't read its own owner id back walks away.

There is a deliberate, documented hole: between a stealer's read-back and
a second stealer's replace, both can briefly believe they own the unit
(classic shared-filesystem TOCTOU).  Leases are therefore a *liveness*
mechanism — they keep N drivers from duplicating work in the common case
— not a correctness mechanism.  Correctness comes from the engine's
determinism (a duplicated unit yields a byte-identical record) plus
last-write-wins dedup by unit key at merge time (`repro.sweep.merge`).

Expiry is judged against the lease's own recorded TTL (so a mixed fleet
honors each writer's contract) using wall-clock time; shared-storage
fleets should keep TTL comfortably above host clock skew.

**Lost-ownership contract.**  ``heartbeat()`` returning False means the
lease is gone or owned by someone else — the unit was stolen while we
worked on it.  What the holder must do next depends on how its output is
merged:

* *Batch writers* (the sweep driver): finishing anyway is harmless.  The
  unit's single record is appended when done; the thief's duplicate is
  byte-identical (engine determinism) and dedups at merge.
* *Streaming writers* (the serving fleet, `repro.serve.fleet`): the
  holder must **stop emitting for this request immediately** — cancel the
  slot at the next sync point and write no further journal records for
  it, not even a terminal one.  The thief replays the stream from
  scratch; tokens the loser already journaled are a prefix of the
  replay and dedup by ``(uid, token_index)``.  Emitting past the loss
  would be benign only while the loser stays healthy — the reason its
  lease expired is usually that it is *not* (wedged sync, dying host),
  and a half-dead worker's late writes are exactly the ones that must
  not be able to extend a stream another worker now owns.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Dict, List, Optional

from repro.ioutil import tmp_suffix


@dataclasses.dataclass
class Lease:
    unit: str
    owner: str
    acquired_at: float
    heartbeat_at: float
    ttl: float
    stolen_from: Optional[str] = None

    def expired(self, now: Optional[float] = None) -> bool:
        return (now if now is not None else time.time()) - self.heartbeat_at > self.ttl

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


class LeaseStore:
    """Per-unit lease files under `root`, owned by `owner`."""

    def __init__(self, root: str, owner: str, ttl: float, create: bool = True):
        if ttl <= 0:
            raise ValueError("lease ttl must be positive")
        self.root = root
        self.owner = owner
        self.ttl = ttl
        if create:
            os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------
    def _path(self, slug: str) -> str:
        return os.path.join(self.root, f"{slug}.lease")

    def _write(self, path: str, lease: Lease, replace: bool) -> bool:
        """Atomically publish `lease`; with replace=False, lose (return
        False) if the file already exists."""
        tmp = path + tmp_suffix()
        with open(tmp, "w") as f:
            json.dump(lease.to_dict(), f)
        try:
            if replace:
                os.replace(tmp, path)
                return True
            try:
                os.link(tmp, path)
                return True
            except FileExistsError:
                return False
        finally:
            try:
                os.unlink(tmp)
            except FileNotFoundError:
                pass  # consumed by os.replace

    def read(self, slug: str) -> Optional[Lease]:
        """The current lease, or None if free.  An unparseable lease file
        (should not happen — writes are atomic — but shared storage is
        shared storage) is treated as a live lease aged by file mtime, so
        it is stealable only once stale."""
        path = self._path(slug)
        try:
            with open(path) as f:
                data = json.load(f)
            return Lease(
                unit=data["unit"],
                owner=data["owner"],
                acquired_at=float(data["acquired_at"]),
                heartbeat_at=float(data["heartbeat_at"]),
                ttl=float(data["ttl"]),
                stolen_from=data.get("stolen_from"),
            )
        except FileNotFoundError:
            return None
        except (OSError, ValueError, KeyError, TypeError):
            try:
                mtime = os.stat(path).st_mtime
            except OSError:
                return None
            return Lease(
                unit=slug, owner="<unreadable>", acquired_at=mtime,
                heartbeat_at=mtime, ttl=self.ttl,
            )

    # ------------------------------------------------------------------
    def try_acquire(self, slug: str) -> bool:
        """Acquire the unit's lease: free units via atomic create, expired
        leases via steal + read-back confirmation.  False means a live
        owner holds it (or we lost a race) — callers move on and repoll.
        """
        now = time.time()
        path = self._path(slug)
        current = self.read(slug)
        fresh = Lease(
            unit=slug, owner=self.owner, acquired_at=now,
            heartbeat_at=now, ttl=self.ttl,
        )
        if current is None:
            return self._write(path, fresh, replace=False)
        if current.owner == self.owner and not current.expired(now):
            return True  # already ours (e.g. retry after a crash-restart)
        if not current.expired(now):
            return False
        # work stealing: replace the expired lease, then confirm we are
        # the last writer (concurrent stealers: exactly the read-back
        # winner proceeds; see the module docstring for the residual race)
        fresh.stolen_from = current.owner
        self._write(path, fresh, replace=True)
        confirmed = self.read(slug)
        return confirmed is not None and confirmed.owner == self.owner

    def heartbeat(self, slug: str) -> bool:
        """Bump our lease's heartbeat.  False when the lease is gone or
        owned by someone else — i.e. it expired and was stolen — in which
        case the caller has lost the unit.  Batch callers may finish
        anyway (the duplicate record dedups at merge); streaming callers
        must stop emitting immediately — see the module docstring's
        lost-ownership contract."""
        current = self.read(slug)
        if current is None or current.owner != self.owner:
            return False
        current.heartbeat_at = time.time()
        return self._write(self._path(slug), current, replace=True)

    def release(self, slug: str) -> None:
        """Drop our lease (no-op if it was stolen meanwhile)."""
        current = self.read(slug)
        if current is not None and current.owner == self.owner:
            try:
                os.unlink(self._path(slug))
            except FileNotFoundError:
                pass

    # ------------------------------------------------------------------
    def all_leases(self) -> List[Lease]:
        out = []
        try:
            names = sorted(os.listdir(self.root))
        except FileNotFoundError:
            return out  # no sweep state yet (read-only status views)
        for name in names:
            if name.endswith(".lease"):
                lease = self.read(name[: -len(".lease")])
                if lease is not None:
                    out.append(lease)
        return out
