"""The work-stealing sweep driver loop.

Any number of `SweepDriver` processes point at the same results file (on
shared storage) and the same manifest; each repeatedly:

1. re-reads the merged results to learn which units are done,
2. walks the manifest in order and tries to lease the first unleased
   not-done unit (expired leases are stolen — see `repro.sweep.lease`),
3. runs the unit through the exact single-unit runner the serial harness
   uses (`run_unit`), heartbeating the lease from a background thread,
4. appends the record (`repro.sweep.merge.append_record`) and releases.

A driver exits when every unit has a record.  When live peers hold the
remaining leases it polls, so the fleet as a whole finishes even if a
peer dies mid-unit: its lease expires and someone steals the unit,
resuming from the unit-scoped engine checkpoint when one exists.

Determinism contract (the whole point): with ``timing_mode="simulated"``,
N racing drivers — including kills, steals and duplicated units — produce
a merged view record-identical to one driver running the grid serially,
because every unit's trajectory depends only on ``(task, method, seed)``
and the engine checkpoints replay exactly (tested in
tests/test_sweep_driver.py).  Wall-clock timing mode keeps exactly-once
units but records carry real (host-dependent) runtimes, as in the serial
sweep.
"""

from __future__ import annotations

import os
import shutil
import socket
import threading
import time
from typing import Dict, List, Optional

from repro.core.engine import EvolutionEngine
from repro.core.methods import MethodConfig, get_method
from repro.evaluation import EvalConfig, Evaluator, ParallelEvaluator
from repro.sweep import merge
from repro.sweep.lease import LeaseStore
from repro.sweep.manifest import SweepManifest, WorkUnit
from repro.tasks import get_task
from repro.tasks.base import KernelTask


def run_unit(
    task: KernelTask,
    method: MethodConfig,
    seed: int,
    evaluator: Evaluator,
    trials: int,
    rag_pool: List,
    batch_size: int = 1,
    checkpoint_dir: Optional[str] = None,
) -> Dict:
    """Run one grid cell and shape its JSONL record.  Shared by the serial
    table-4 harness and the distributed driver, so both paths emit
    byte-identical records for the same ``(task, method, seed)``.

    With a `checkpoint_dir` (unit-scoped under the sweep state dir, so
    concurrent units never collide on disk) the engine checkpoints every
    few trials and resumes a predecessor's progress — how a stolen unit
    continues a dead worker's run to the identical trajectory."""
    eng = EvolutionEngine(
        task,
        method,
        evaluator=evaluator,
        seed=seed,
        rag_pool=[r for r in rag_pool if r[0] != task.name],
        batch_size=batch_size,
        checkpoint_dir=checkpoint_dir,
    )
    if checkpoint_dir:
        eng.resume()
    res = eng.run(max_trials=trials)
    rec = res.to_dict()
    rec["category"] = task.category
    rec["speedups_all"] = [s.speedup for s in res.history if s.valid and s.speedup]
    return rec


def join_fleet(manifest: SweepManifest, results: str, **driver_kw) -> "SweepDriver":
    """Publish (or adopt) the fleet's manifest beside `results` and build
    a driver — the one join path shared by ``python -m repro.sweep`` and
    ``python -m benchmarks.run --distributed``, so they cannot drift."""
    from repro.sweep.manifest import create_or_load

    sweep_dir = f"{results}.sweep"
    os.makedirs(sweep_dir, exist_ok=True)
    man = create_or_load(os.path.join(sweep_dir, "manifest.json"), manifest)
    return SweepDriver(man, results, sweep_dir=sweep_dir, **driver_kw)


class _Heartbeat(threading.Thread):
    """Bumps one lease every `interval` seconds until stopped; flips
    `lost` and exits if the lease was stolen (the driver still finishes
    the unit — the duplicate record dedups at merge)."""

    def __init__(self, store: LeaseStore, slug: str, interval: float):
        super().__init__(daemon=True, name=f"heartbeat-{slug}")
        self.store = store
        self.slug = slug
        self.interval = interval
        self.lost = False
        # NB: not named _stop — Thread itself has a private _stop method
        # that join() calls internally; shadowing it breaks join()
        self._halt = threading.Event()

    def run(self):
        while not self._halt.wait(self.interval):
            try:
                alive = self.store.heartbeat(self.slug)
            except OSError:
                continue  # transient shared-storage hiccup: retry next beat
            if not alive:
                self.lost = True
                return

    def stop(self):
        self._halt.set()
        self.join(timeout=5.0)


class SweepDriver:
    def __init__(
        self,
        manifest: SweepManifest,
        results: str,
        sweep_dir: Optional[str] = None,
        owner: Optional[str] = None,
        heartbeat: float = 30.0,
        ttl: Optional[float] = None,
        poll: Optional[float] = None,
        workers: int = 0,
        max_units: Optional[int] = None,
        progress: bool = False,
    ):
        self.manifest = manifest
        self.results = results
        self.sweep_dir = sweep_dir or f"{results}.sweep"
        self.owner = owner or f"{socket.gethostname()}-{os.getpid()}"
        self.heartbeat = heartbeat
        # a lease survives two missed heartbeats before it is stealable
        self.ttl = ttl if ttl is not None else 3.0 * heartbeat
        self.poll = poll if poll is not None else max(0.2, min(heartbeat, 5.0))
        self.max_units = max_units
        self.progress = progress
        self.leases = LeaseStore(
            os.path.join(self.sweep_dir, "leases"), self.owner, self.ttl
        )
        cfg = EvalConfig(
            timing_runs=manifest.timing_runs, timing_mode=manifest.timing_mode
        )
        cache_dir = os.path.join(self.sweep_dir, "eval_cache")
        if workers > 1:
            self.evaluator: Evaluator = ParallelEvaluator(
                cfg, workers=workers, cache_dir=cache_dir
            )
        else:
            self.evaluator = Evaluator(cfg, cache_dir=cache_dir)
        self.stats = {"completed": 0, "stolen": 0, "lost_leases": 0}

    # ------------------------------------------------------------------
    def _log(self, msg: str) -> None:
        if self.progress:
            print(f"[sweep:{self.owner}] {msg}", flush=True)

    def _checkpoint_dir(self, unit: WorkUnit) -> str:
        return os.path.join(self.sweep_dir, "checkpoints", unit.slug)

    def _run_leased_unit(self, unit: WorkUnit) -> None:
        hb = _Heartbeat(self.leases, unit.slug, self.heartbeat / 2.0)
        hb.start()
        try:
            rec = run_unit(
                get_task(unit.task),
                get_method(unit.method_key),
                unit.seed,
                evaluator=self.evaluator,
                trials=self.manifest.trials,
                rag_pool=self.manifest.rag_pool(),
                batch_size=self.manifest.batch_size,
                checkpoint_dir=self._checkpoint_dir(unit),
            )
            merge.append_record(self.results, rec)
            self.stats["completed"] += 1
            if hb.lost:
                # stolen mid-run; our record is a benign duplicate
                self.stats["lost_leases"] += 1
            else:
                # the unit-scoped checkpoint only matters while the unit is
                # in flight (steal-resume); drop it once the record landed.
                # Skipped when our lease was stolen — the thief may be
                # resuming from this very directory right now (its engine
                # tolerates the dir vanishing, but keeping it is kinder).
                shutil.rmtree(self._checkpoint_dir(unit), ignore_errors=True)
            self._log(
                f"done {unit.key} spd={rec['best_speedup']:.2f} "
                f"val={rec['validity_rate']:.2f}"
            )
        finally:
            hb.stop()
            try:
                self.leases.release(unit.slug)
            except OSError:
                pass  # expires on its own; the record already landed

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, int]:
        """Drive until every manifest unit has a record (or `max_units`
        of our own completions, for tests/graceful draining)."""
        units = self.manifest.units
        try:
            while True:
                done = merge.completed_keys(self.results)
                pending = [u for u in units if u.key not in done]
                if not pending:
                    break
                claimed = None
                for unit in pending:
                    try:
                        existing = self.leases.read(unit.slug)
                        stealing = existing is not None and existing.expired()
                        acquired = self.leases.try_acquire(unit.slug)
                    except OSError:
                        # transient shared-storage hiccup: same policy as
                        # the heartbeat thread — skip, retry next scan
                        continue
                    if not acquired:
                        continue
                    # the unit may have finished between our done-scan and
                    # the acquire (completion and lease release are not one
                    # atomic step) — recheck before burning a run on it
                    if unit.key in merge.completed_keys(self.results):
                        try:
                            self.leases.release(unit.slug)
                        except OSError:
                            pass
                        continue
                    claimed = unit
                    if stealing:
                        self.stats["stolen"] += 1
                        self._log(f"stole expired lease for {unit.key}")
                    break
                if claimed is None:
                    # everything pending is leased by live peers: wait for
                    # their records (or their leases to expire)
                    time.sleep(self.poll)
                    continue
                self._run_leased_unit(claimed)
                if self.max_units and self.stats["completed"] >= self.max_units:
                    break
        finally:
            if isinstance(self.evaluator, ParallelEvaluator):
                self.evaluator.close()
        return dict(self.stats)
