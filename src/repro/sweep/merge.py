"""Crash-tolerant JSONL results: append, read, dedupe, merge.

The results file is the existing table-4 resume protocol — one JSON
record per ``task x method x seed`` unit, appended by any number of
concurrent writers on shared storage.  This module owns the two failure
modes a distributed sweep adds:

* **Torn trailing lines.**  A SIGKILLed appender can leave a partial
  final line.  `append_record` writes each record as a single
  ``O_APPEND`` write *and* prepends a newline when the file doesn't end
  in one, so a torn tail never swallows the next good record; readers
  skip-and-count unparseable lines instead of crashing the summary.
* **Duplicate records.**  Work stealing plus the lease layer's documented
  TOCTOU window means a unit can legitimately be run twice.  The engine
  is deterministic, so duplicates are identical in content; `load_records`
  dedupes last-write-wins by unit key regardless.

Every summarizer reads through `load_records`, so the "merged view" needs
no separate file — but ``python -m repro.sweep merge`` can materialize a
clean, canonically-sorted copy for archival.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Dict, List, Optional, Tuple

from repro.ioutil import atomic_write

KEY_FIELDS = ("task", "method", "seed")


def record_key(rec) -> Optional[Tuple[str, str, int]]:
    """The unit key of a record, or None for malformed records."""
    if not isinstance(rec, dict):
        return None
    try:
        return (rec["task"], rec["method"], rec["seed"])
    except (KeyError, TypeError):
        return None


def _ends_with_newline(path: str) -> bool:
    try:
        with open(path, "rb") as f:
            f.seek(0, os.SEEK_END)
            if f.tell() == 0:
                return True  # empty file: no healing needed
            f.seek(-1, os.SEEK_END)
            return f.read(1) == b"\n"
    except OSError:
        return True


def append_record(path: str, rec: Dict) -> None:
    """Append one record as a single O_APPEND write, healing a torn tail
    left by a killed writer with a leading newline.  (The heal check races
    with concurrent appenders in the worst case into an extra blank line,
    which readers skip.)"""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    data = (json.dumps(rec) + "\n").encode()
    if not _ends_with_newline(path):
        data = b"\n" + data
    fd = os.open(path, os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
    try:
        os.write(fd, data)
    finally:
        os.close(fd)


def read_records(path: str) -> Tuple[List[Dict], int]:
    """All parseable records in file order plus the count of skipped
    partial/corrupt lines.  Missing file reads as empty."""
    records: List[Dict] = []
    partial = 0
    try:
        f = open(path, "rb")
    except OSError:
        return records, partial
    with f:
        for raw in f:
            line = raw.decode("utf-8", errors="replace").strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                partial += 1
                continue
            if record_key(rec) is None:
                partial += 1
                continue
            records.append(rec)
    return records, partial


def load_records(path: str, warn: bool = True) -> List[Dict]:
    """The merged view: parseable records deduped last-write-wins by unit
    key, in first-appearance order.  With `warn`, skipped partial lines
    are reported to stderr (never fatal — a torn tail from a killed
    appender must not crash a summary)."""
    records, partial = read_records(path)
    if partial and warn:
        sys.stderr.write(
            f"[sweep] {path}: skipped {partial} partial/corrupt line(s) "
            "(torn append from a killed writer?)\n"
        )
    merged: Dict[Tuple[str, str, int], Dict] = {}
    order: List[Tuple[str, str, int]] = []
    for rec in records:
        key = record_key(rec)
        if key not in merged:
            order.append(key)
        merged[key] = rec
    return [merged[k] for k in order]


def completed_keys(path: str) -> set:
    """Unit keys (manifest `WorkUnit.key` strings) with a finished record."""
    return {
        f"{r['task']}|{r['method']}|{r['seed']}" for r in load_records(path, warn=False)
    }


def write_merged(path: str, out: str) -> int:
    """Materialize the canonical merged file: deduped, sorted by unit key,
    written atomically.  Returns the record count."""
    records = load_records(path)
    records.sort(key=lambda r: (r["task"], r["method"], r["seed"]))
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    atomic_write(
        out,
        lambda f: f.writelines(json.dumps(r) + "\n" for r in records),
        mode="w",
    )
    return len(records)
