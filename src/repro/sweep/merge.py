"""Crash-tolerant JSONL journals: append, read, dedupe, merge.

The generic layer (`append_jsonl` / `read_jsonl` / `dedupe_last_wins`) is
the crash-safety discipline shared by every concurrent JSONL writer on
shared storage — the table-4 sweep results below, and the serving fleet's
per-worker token journals (`repro.serve.fleet`).  It owns the two failure
modes a distributed appender adds:

* **Torn trailing lines.**  A SIGKILLed appender can leave a partial
  final line.  `append_jsonl` writes each record as a single
  ``O_APPEND`` write *and* prepends a newline when the file doesn't end
  in one, so a torn tail never swallows the next good record; readers
  skip-and-count unparseable lines instead of crashing the summary.
* **Duplicate records.**  Work stealing plus the lease layer's documented
  TOCTOU window means a unit can legitimately be run twice.  The engines
  are deterministic, so duplicates are identical in content;
  `dedupe_last_wins` keeps exactly one per key regardless.

The table-4 layer (`append_record` / `load_records` / …) specializes this
to one JSON record per ``task x method x seed`` unit.  Every summarizer
reads through `load_records`, so the "merged view" needs no separate
file — but ``python -m repro.sweep merge`` can materialize a clean,
canonically-sorted copy for archival.
"""

from __future__ import annotations

import json
import os
import sys
from typing import Callable, Dict, List, Optional, Tuple

from repro.ioutil import atomic_write

KEY_FIELDS = ("task", "method", "seed")


def record_key(rec) -> Optional[Tuple[str, str, int]]:
    """The unit key of a record, or None for malformed records."""
    if not isinstance(rec, dict):
        return None
    try:
        return (rec["task"], rec["method"], rec["seed"])
    except (KeyError, TypeError):
        return None


def _ends_with_newline(path: str) -> bool:
    try:
        with open(path, "rb") as f:
            f.seek(0, os.SEEK_END)
            if f.tell() == 0:
                return True  # empty file: no healing needed
            f.seek(-1, os.SEEK_END)
            return f.read(1) == b"\n"
    except OSError:
        return True


def append_jsonl(path: str, rec: Dict) -> None:
    """Append one record as a single O_APPEND write, healing a torn tail
    left by a killed writer with a leading newline.  (The heal check races
    with concurrent appenders in the worst case into an extra blank line,
    which readers skip.)"""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    data = (json.dumps(rec) + "\n").encode()
    if not _ends_with_newline(path):
        data = b"\n" + data
    fd = os.open(path, os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
    try:
        os.write(fd, data)
    finally:
        os.close(fd)


# the table-4 results file uses the generic journal discipline verbatim
append_record = append_jsonl


def read_jsonl(path: str) -> Tuple[List[Dict], int]:
    """All parseable JSON lines in file order plus the count of skipped
    partial/corrupt lines.  Missing file reads as empty.  Schema-agnostic:
    any parseable JSON value counts as a record."""
    records: List[Dict] = []
    partial = 0
    try:
        f = open(path, "rb")
    except OSError:
        return records, partial
    with f:
        for raw in f:
            line = raw.decode("utf-8", errors="replace").strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError:
                partial += 1
    return records, partial


def dedupe_last_wins(records: List[Dict], key_fn: Callable) -> List[Dict]:
    """Dedupe by ``key_fn(rec)`` last-write-wins, in first-appearance
    order; records whose key is None are dropped.  Safe whenever writers
    are deterministic — duplicates are then identical in content and
    which one survives is immaterial."""
    merged: Dict = {}
    order: List = []
    for rec in records:
        key = key_fn(rec)
        if key is None:
            continue
        if key not in merged:
            order.append(key)
        merged[key] = rec
    return [merged[k] for k in order]


def read_records(path: str) -> Tuple[List[Dict], int]:
    """All parseable *unit* records in file order plus the count of
    skipped partial/corrupt/keyless lines.  Missing file reads as empty."""
    raw, partial = read_jsonl(path)
    records = [r for r in raw if record_key(r) is not None]
    return records, partial + (len(raw) - len(records))


def load_records(path: str, warn: bool = True) -> List[Dict]:
    """The merged view: parseable records deduped last-write-wins by unit
    key, in first-appearance order.  With `warn`, skipped partial lines
    are reported to stderr (never fatal — a torn tail from a killed
    appender must not crash a summary)."""
    records, partial = read_records(path)
    if partial and warn:
        sys.stderr.write(
            f"[sweep] {path}: skipped {partial} partial/corrupt line(s) "
            "(torn append from a killed writer?)\n"
        )
    return dedupe_last_wins(records, record_key)


def completed_keys(path: str) -> set:
    """Unit keys (manifest `WorkUnit.key` strings) with a finished record."""
    return {
        f"{r['task']}|{r['method']}|{r['seed']}" for r in load_records(path, warn=False)
    }


def write_merged(path: str, out: str) -> int:
    """Materialize the canonical merged file: deduped, sorted by unit key,
    written atomically.  Returns the record count."""
    records = load_records(path)
    records.sort(key=lambda r: (r["task"], r["method"], r["seed"]))
    os.makedirs(os.path.dirname(out) or ".", exist_ok=True)
    atomic_write(
        out,
        lambda f: f.writelines(json.dumps(r) + "\n" for r in records),
        mode="w",
    )
    return len(records)
