"""Sweep manifest: the deterministic work-unit list for one grid.

A manifest is the single source of truth a fleet of drivers shares: the
ordered task list, the method keys, the seed count and the evaluation
config knobs that affect records (trials, timing_runs, timing_mode,
batch_size).  Units enumerate in the same nesting order as the serial
``table4_overall.run`` loop (``task -> seed -> method``), so a serial
sweep and a distributed sweep walk the identical grid.

The manifest persists as JSON beside the results file; the first driver
writes it atomically (temp file + ``os.link``, which fails rather than
overwrites if another driver won the race) and every driver — including
the writer — then reads the file back, so a fleet started with divergent
flags fails loudly instead of silently splitting the grid.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.core.methods import DISPLAY_ORDER, get_method
from repro.ioutil import tmp_suffix
from repro.tasks import benchmark_tasks, get_task
from repro.tasks.base import CATEGORIES

MANIFEST_VERSION = 1

# RAG pool size for AI CUDA Engineer's Compose stage (matches the serial
# sweep: naive sources of the grid's first tasks stand in for the
# cross-kernel archive retrieval)
RAG_POOL_TASKS = 8

_SLUG_RE = re.compile(r"[^A-Za-z0-9_.-]+")


def _slug(text: str) -> str:
    return _SLUG_RE.sub("-", text)


def quick_subset(tasks, per_category: int = 2):
    """The quick-mode grid: the first `per_category` tasks per category,
    in category order (moved here from benchmarks/table4_overall.py so the
    serial harness and the distributed driver share one definition)."""
    by_cat = defaultdict(list)
    for t in tasks:
        by_cat[t.category].append(t)
    out = []
    for c in CATEGORIES:
        out += by_cat[c][:per_category]
    return out


@dataclasses.dataclass(frozen=True)
class WorkUnit:
    """One cell of the grid.  `method` is the display name (what records
    carry); `method_key` is the registry key (what CLIs take)."""

    task: str
    method_key: str
    method: str
    seed: int

    @property
    def key(self) -> str:
        """The dedup/completion key — matches `merge.record_key` on the
        records the unit produces."""
        return f"{self.task}|{self.method}|{self.seed}"

    @property
    def slug(self) -> str:
        """Filesystem-safe name for lease files and checkpoint dirs."""
        return _slug(f"{self.task}__{self.method_key}__s{self.seed}")


@dataclasses.dataclass
class SweepManifest:
    tasks: List[str]
    methods: List[str]  # method registry keys, in schedule order
    seeds: int
    trials: int = 45
    timing_runs: int = 11
    timing_mode: str = "wall"
    batch_size: int = 1
    version: int = MANIFEST_VERSION

    def __post_init__(self):
        for key in self.methods:
            get_method(key)  # raises KeyError on an unknown method
        for name in self.tasks:
            get_task(name)  # raises KeyError on an unknown task

    # ------------------------------------------------------------------
    @property
    def units(self) -> List[WorkUnit]:
        out = []
        for task in self.tasks:
            for seed in range(self.seeds):
                for mkey in self.methods:
                    out.append(
                        WorkUnit(
                            task=task,
                            method_key=mkey,
                            method=get_method(mkey).name,
                            seed=seed,
                        )
                    )
        return out

    def rag_pool(self) -> List[Tuple[str, str]]:
        """Naive sources of the grid's first tasks (see RAG_POOL_TASKS) —
        identical to the pool the serial table4 harness builds."""
        return [
            (name, get_task(name).initial_source)
            for name in self.tasks[:RAG_POOL_TASKS]
        ]

    # ------------------------------------------------------------------
    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "SweepManifest":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def build_manifest(
    mode: str = "quick",
    seeds: Optional[int] = None,
    trials: int = 45,
    timing_runs: int = 11,
    timing_mode: str = "wall",
    batch_size: int = 1,
    tasks: Optional[List[str]] = None,
    methods: Optional[List[str]] = None,
) -> SweepManifest:
    """Build the grid.  `tasks`/`methods` override the benchmark set (used
    by the fault-injection harness to sweep calibration tasks); otherwise
    `mode` selects the paper's quick (12-task, 1-seed) or full grid."""
    if tasks is None:
        ts = benchmark_tasks()
        if mode == "quick":
            ts = quick_subset(ts)
        tasks = [t.name for t in ts]
    if seeds is None:
        seeds = 1 if mode == "quick" else 3
    return SweepManifest(
        tasks=list(tasks),
        methods=list(methods or DISPLAY_ORDER),
        seeds=seeds,
        trials=trials,
        timing_runs=timing_runs,
        timing_mode=timing_mode,
        batch_size=batch_size,
    )


def create_or_load(path: str, manifest: Optional[SweepManifest] = None) -> SweepManifest:
    """Publish `manifest` at `path` if absent (atomic create: temp +
    ``os.link`` never overwrites a concurrent winner), then load whatever
    the file holds.  A mismatch between the loaded grid and the one this
    driver was asked to run raises — a fleet must agree on the manifest.
    """
    if manifest is not None and not os.path.exists(path):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + tmp_suffix()
        with open(tmp, "w") as f:
            json.dump(manifest.to_dict(), f, indent=2, sort_keys=True)
            f.write("\n")
        try:
            os.link(tmp, path)
        except FileExistsError:
            pass  # another driver published first; defer to its copy
        finally:
            os.unlink(tmp)
    with open(path) as f:
        loaded = SweepManifest.from_dict(json.load(f))
    if manifest is not None and loaded.to_dict() != manifest.to_dict():
        raise ValueError(
            f"manifest at {path} does not match this driver's grid — "
            "the fleet must be started with identical sweep flags "
            f"(existing: {loaded.to_dict()!r})"
        )
    return loaded
