"""CLI for the distributed sweep.

  # run (default subcommand): join/start a fleet over the quick grid
  PYTHONPATH=src python -m repro.sweep --results results/table4.jsonl --heartbeat 30

  # the full paper grid, from as many hosts as you like (shared storage):
  PYTHONPATH=src python -m repro.sweep run --results /shared/table4.jsonl --mode full

  # a serial reference run (no leases; manifest order — the `--workers 0`
  # baseline the fault-injection suite compares fleets against):
  PYTHONPATH=src python -m repro.sweep run --results out.jsonl --serial

  # operational views:
  PYTHONPATH=src python -m repro.sweep status --results results/table4.jsonl
  PYTHONPATH=src python -m repro.sweep merge  --results results/table4.jsonl
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import warnings

warnings.filterwarnings("ignore")


def _manifest_path(results: str) -> str:
    return f"{results}.sweep/manifest.json"


def _csv(text):
    return [s for s in (text or "").split(",") if s]


def cmd_run(args) -> int:
    from repro.sweep import driver as driver_mod
    from repro.sweep import manifest as manifest_mod
    from repro.sweep import merge
    from repro.tasks import get_task

    built = manifest_mod.build_manifest(
        mode=args.mode,
        seeds=args.seeds,
        trials=args.trials,
        timing_runs=args.timing_runs,
        timing_mode=args.timing_mode,
        batch_size=args.batch_size,
        tasks=_csv(args.tasks) or None,
        methods=_csv(args.methods) or None,
    )
    if args.serial:
        os.makedirs(f"{args.results}.sweep", exist_ok=True)
        man = manifest_mod.create_or_load(_manifest_path(args.results), built)
        # the clean single-process reference: manifest order, no leases
        from repro.core.methods import get_method
        from repro.evaluation import EvalConfig, Evaluator

        cfg = EvalConfig(timing_runs=man.timing_runs, timing_mode=man.timing_mode)
        ev = Evaluator(cfg, cache_dir=f"{args.results}.sweep/eval_cache")
        done = merge.completed_keys(args.results)
        rag = man.rag_pool()
        for unit in man.units:
            if unit.key in done:
                continue
            rec = driver_mod.run_unit(
                get_task(unit.task), get_method(unit.method_key), unit.seed,
                evaluator=ev, trials=man.trials, rag_pool=rag,
                batch_size=man.batch_size,
            )
            merge.append_record(args.results, rec)
        print(f"serial sweep complete: {len(man.units)} units in {args.results}")
        return 0

    drv = driver_mod.join_fleet(
        built,
        args.results,
        owner=args.owner,
        heartbeat=args.heartbeat,
        ttl=args.ttl,
        poll=args.poll,
        workers=args.workers,
        max_units=args.max_units,
        progress=not args.quiet,
    )
    t0 = time.time()
    stats = drv.run()
    print(
        f"driver {drv.owner} exiting after {time.time() - t0:.1f}s: "
        f"{stats['completed']} unit(s) completed, {stats['stolen']} stolen, "
        f"{stats['lost_leases']} lease(s) lost mid-run"
    )
    return 0


def cmd_merge(args) -> int:
    from repro.sweep import merge

    out = args.out or f"{os.path.splitext(args.results)[0]}.merged.jsonl"
    n = merge.write_merged(args.results, out)
    print(f"merged {n} unique record(s) -> {out}")
    return 0


def cmd_status(args) -> int:
    from repro.sweep import manifest as manifest_mod
    from repro.sweep import merge
    from repro.sweep.lease import LeaseStore

    path = _manifest_path(args.results)
    if not os.path.exists(path):
        print(f"no manifest at {path} — has a sweep started?")
        return 1
    man = manifest_mod.create_or_load(path)
    units = man.units
    done = merge.completed_keys(args.results)
    _, partial = merge.read_records(args.results)
    # read-only view: must not create sweep state (or need write access)
    store = LeaseStore(
        f"{args.results}.sweep/leases", owner="status", ttl=1.0, create=False
    )
    leases = {l.unit: l for l in store.all_leases()}
    live = stale = 0
    owners = {}
    now = time.time()
    for u in units:
        lease = leases.get(u.slug)
        if lease is None or u.key in done:
            continue
        if lease.expired(now):
            stale += 1
        else:
            live += 1
            owners[lease.owner] = owners.get(lease.owner, 0) + 1
    pending = sum(1 for u in units if u.key not in done)
    print(f"grid:      {len(units)} units "
          f"({len(man.tasks)} tasks x {len(man.methods)} methods x {man.seeds} seeds)")
    print(f"done:      {len(units) - pending}")
    print(f"pending:   {pending} ({live} leased live, {stale} stale-leased, "
          f"{pending - live - stale} unclaimed)")
    if partial:
        print(f"warning:   {partial} partial/corrupt result line(s) will be "
              "skipped at merge")
    for owner, n in sorted(owners.items()):
        print(f"  live lease(s) held by {owner}: {n}")
    if args.json:
        print(json.dumps({
            "units": len(units), "done": len(units) - pending,
            "pending": pending, "live_leases": live, "stale_leases": stale,
            "partial_lines": partial,
        }))
    return 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    # `python -m repro.sweep --results ...` defaults to the run subcommand
    if not argv or argv[0].startswith("-"):
        argv = ["run"] + argv

    ap = argparse.ArgumentParser(prog="python -m repro.sweep", description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("run", help="join/start a driver fleet (default)")
    rp.add_argument("--results", default="results/table4.jsonl")
    rp.add_argument("--mode", choices=["quick", "full"], default="quick")
    rp.add_argument("--tasks", default="",
                    help="comma-separated task-name override (e.g. calibration grids)")
    rp.add_argument("--methods", default="",
                    help="comma-separated method-key override")
    rp.add_argument("--seeds", type=int, default=None,
                    help="seeds per task x method (default: 1 quick, 3 full)")
    rp.add_argument("--trials", type=int, default=45)
    rp.add_argument("--timing-runs", type=int, default=11)
    rp.add_argument("--timing-mode", choices=["wall", "simulated"], default="wall")
    rp.add_argument("--batch-size", type=int, default=1)
    rp.add_argument("--workers", type=int, default=0,
                    help=">1 evaluates candidates in a worker-process pool")
    rp.add_argument("--heartbeat", type=float, default=30.0,
                    help="seconds between lease heartbeats")
    rp.add_argument("--ttl", type=float, default=None,
                    help="lease expiry (default 3x heartbeat)")
    rp.add_argument("--poll", type=float, default=None,
                    help="idle re-scan interval when peers hold all leases")
    rp.add_argument("--owner", default=None,
                    help="lease owner id (default host-pid)")
    rp.add_argument("--max-units", type=int, default=None,
                    help="exit after completing this many units (drain)")
    rp.add_argument("--serial", action="store_true",
                    help="single-process reference run: manifest order, no leases")
    rp.add_argument("--quiet", action="store_true")
    rp.set_defaults(fn=cmd_run)

    mp = sub.add_parser("merge", help="materialize the deduped merged view")
    mp.add_argument("--results", default="results/table4.jsonl")
    mp.add_argument("--out", default=None)
    mp.set_defaults(fn=cmd_merge)

    sp = sub.add_parser("status", help="grid/lease/results status")
    sp.add_argument("--results", default="results/table4.jsonl")
    sp.add_argument("--json", action="store_true")
    sp.set_defaults(fn=cmd_status)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
