"""Pattern-block transformer covering all 10 assigned architectures.

The model is a repeating *pattern* of (mixer, mlp) units (cfg.pattern).  The
forward pass scans over `n_blocks = num_layers // len(pattern)` stacked
pattern-blocks (small HLO, exact cost accounting via the while-trip
correction in launch/hlo_analysis.py) and applies the
`num_layers % len(pattern)` remainder units unstacked.

Three entry points:
    forward()       train/prefill logits (+ aux loss, + cache when asked —
                    cache entries are emitted as scan outputs of the same
                    pass, no duplicated mixer compute)
    decode_step()   one token against a cache (serve_step for decode cells)
    init_cache()    per-unit cache pytree (ring-buffer for local attention)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import config as C
from repro.models import layers as L
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import recurrent as rec
from repro.models.flash import flash_attention
from repro.parallel.act_sharding import constrain

# Sequence length at or below which plain materialized attention is used
# (smoke tests / tiny models); above it the flash path kicks in.
_FULL_ATTN_MAX_SEQ = 1024


# ==========================================================================
# Parameter init
# ==========================================================================
def _unit_init(key: jax.Array, cfg: C.ModelConfig, mixer: str, mlp: str) -> dict:
    k_mix, k_mlp, k_norm = jax.random.split(key, 3)
    p: Dict[str, Any] = {
        "norm_mix": L.rmsnorm_init(cfg.d_model),
        "norm_mlp": L.rmsnorm_init(cfg.d_model),
    }
    if cfg.use_post_norms:
        p["post_norm_mix"] = L.rmsnorm_init(cfg.d_model)
        p["post_norm_mlp"] = L.rmsnorm_init(cfg.d_model)

    if mixer in (C.GLOBAL_ATTN, C.LOCAL_ATTN):
        p["mixer"] = attn.attention_init(
            k_mix,
            cfg.d_model,
            cfg.num_heads,
            cfg.num_kv_heads,
            cfg.head_dim,
            bias=cfg.attn_bias,
            qk_norm=cfg.use_qk_norm,
        )
    elif mixer == C.MLA_ATTN:
        p["mixer"] = mla_mod.mla_init(k_mix, cfg.d_model, cfg.num_heads, cfg.mla)
    elif mixer == C.RGLRU:
        p["mixer"] = rec.rglru_init(k_mix, cfg.d_model, cfg.recurrent, cfg.lru_width)
    elif mixer == C.RWKV6:
        p["mixer"] = rec.rwkv6_init(k_mix, cfg.d_model, cfg.recurrent)
    else:
        raise ValueError(mixer)

    if mlp == C.DENSE_MLP:
        p["mlp"] = L.dense_mlp_init(k_mlp, cfg.d_model, cfg.d_ff)
    elif mlp == C.MOE_MLP:
        p["mlp"] = moe_mod.moe_init(k_mlp, cfg.d_model, cfg.moe)
    elif mlp == C.RWKV_CHANNEL_MIX:
        p["mlp"] = L.rwkv_cmix_init(k_mlp, cfg.d_model, cfg.d_ff)
    else:
        raise ValueError(mlp)
    return p


def _block_init(key: jax.Array, cfg: C.ModelConfig) -> dict:
    keys = jax.random.split(key, len(cfg.pattern))
    return {
        f"u{i}": _unit_init(keys[i], cfg, mixer, mlp)
        for i, (mixer, mlp) in enumerate(cfg.pattern)
    }


def init_params(key: jax.Array, cfg: C.ModelConfig) -> dict:
    cfg.validate()
    k_emb, k_blocks, k_rem, k_head = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": L.embed_init(k_emb, cfg.padded_vocab, cfg.d_model, cfg.num_codebooks),
        "final_norm": L.rmsnorm_init(cfg.d_model),
    }
    if cfg.n_blocks > 0:
        block_keys = jax.random.split(k_blocks, cfg.n_blocks)
        params["blocks"] = jax.vmap(lambda k: _block_init(k, cfg))(block_keys)
    if cfg.n_remainder > 0:
        rem_keys = jax.random.split(k_rem, max(cfg.n_remainder, 2))
        params["rem"] = {
            f"r{i}": _unit_init(rem_keys[i], cfg, *cfg.pattern[i])
            for i in range(cfg.n_remainder)
        }
    if not cfg.tie_embeddings or cfg.num_codebooks > 1:
        params["lm_head"] = L.lm_head_init(
            k_head, cfg.padded_vocab, cfg.d_model, cfg.num_codebooks
        )
    return params


def param_specs(cfg: C.ModelConfig) -> dict:
    """ShapeDtypeStruct pytree of the parameters (no allocation)."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def _dtype(cfg: C.ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


# ==========================================================================
# Unit application (train / prefill).  `collect` asks mixers to also return
# their cache entry (K/V, latents, recurrent state) from the same compute.
# ==========================================================================
def _mixer_apply(
    cfg: C.ModelConfig,
    mixer: str,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    collect: bool,
) -> Tuple[jax.Array, Dict[str, Any]]:
    dtype = _dtype(cfg)
    rope_args = (cfg.rope_theta, cfg.rope_scaling)
    s = x.shape[1]
    uc: Dict[str, Any] = {}
    if mixer in (C.GLOBAL_ATTN, C.LOCAL_ATTN):
        q, k, v = attn.project_qkv(
            p, x, dtype=dtype, rope_args=rope_args, positions=positions
        )
        window = cfg.window if mixer == C.LOCAL_ATTN else None
        if collect:
            if window is not None and window < s:
                # ring-buffer fill: token p lands at slot p % window for the
                # last `window` tokens — matches the decode write convention
                # for any prompt length
                idx = (jnp.arange(window) - s) % window + (s - window)
                uc["k"] = constrain(k[:, idx], "cache_kv")
                uc["v"] = constrain(v[:, idx], "cache_kv")
            else:
                uc["k"] = constrain(k, "cache_kv")
                uc["v"] = constrain(v, "cache_kv")
        if window is not None and window < s and s % window == 0:
            # banded blocking beats windowed flash on HBM bytes here
            # (hypothesis tested and REFUTED in §Perf iteration 6): cost
            # 2*S*W exactly, no full-S logit rows
            o = attn.local_attention(
                q, k, v, window=window, logit_cap=cfg.attn_logit_softcap
            )
        elif s <= _FULL_ATTN_MAX_SEQ:
            o = attn.full_attention(
                q, k, v, causal=True, window=window, logit_cap=cfg.attn_logit_softcap
            )
        else:
            o = flash_attention(
                q, k, v, logit_cap=cfg.attn_logit_softcap, window=window
            )
        return attn.attention_out(p, o, dtype=dtype), uc
    if mixer == C.MLA_ATTN:
        if collect:
            ckv, kr = mla_mod.mla_new_token_latents(
                p, x, cfg.mla, dtype=dtype, positions=positions,
                rope_theta=cfg.rope_theta, rope_scaling=cfg.rope_scaling,
            )
            uc["ckv"] = constrain(ckv, "cache_latent")
            uc["kr"] = constrain(kr, "cache_latent")
        out = mla_mod.mla_attention_train(
            p, x, cfg.mla, dtype=dtype, positions=positions,
            rope_theta=cfg.rope_theta, rope_scaling=cfg.rope_scaling,
        )
        return out, uc
    if mixer == C.RGLRU:
        out, (conv_c, h_last) = rec.rglru_block(p, x, dtype=dtype)
        if collect:
            uc["conv"], uc["h"] = conv_c, h_last
        return out, uc
    if mixer == C.RWKV6:
        out, (state, shift) = rec.rwkv6_block(p, x, cfg.recurrent, dtype=dtype)
        if collect:
            uc["state"] = constrain(state, "cache_state")
            uc["shift"] = shift.astype(dtype)
        return out, uc
    raise ValueError(mixer)


def _mlp_apply(
    cfg: C.ModelConfig, mlp: str, p: dict, x: jax.Array, *, decode: bool = False
) -> Tuple[jax.Array, jax.Array]:
    dtype = _dtype(cfg)
    if mlp == C.DENSE_MLP:
        return L.dense_mlp(p, x, act=cfg.act, dtype=dtype), jnp.zeros((), jnp.float32)
    if mlp == C.MOE_MLP:
        from repro.parallel.act_sharding import current_mesh

        mesh = current_mesh()
        if mesh is not None:
            return moe_mod.moe_mlp_expert_parallel(
                p, x, cfg.moe, act=cfg.act, dtype=dtype, mesh=mesh
            )
        if decode:
            # the batched dispatch couples rows (capacity competition +
            # scatter-add summation order), which would make a request's
            # decode stream depend on its batch neighbours — serving and
            # speculative verification need row-independent logits
            return moe_mod.moe_mlp_decode(p, x, cfg.moe, act=cfg.act, dtype=dtype)
        return moe_mod.moe_mlp(p, x, cfg.moe, act=cfg.act, dtype=dtype)
    if mlp == C.RWKV_CHANNEL_MIX:
        return L.rwkv_cmix(p, x, dtype=dtype), jnp.zeros((), jnp.float32)
    raise ValueError(mlp)


def _unit_apply(
    cfg: C.ModelConfig,
    unit: Tuple[str, str],
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    collect: bool = False,
) -> Tuple[jax.Array, jax.Array, Dict[str, Any]]:
    mixer, mlp = unit
    h = L.rmsnorm(p["norm_mix"], x, eps=cfg.norm_eps)
    h, uc = _mixer_apply(cfg, mixer, p["mixer"], h, positions, collect)
    if cfg.use_post_norms:
        h = L.rmsnorm(p["post_norm_mix"], h, eps=cfg.norm_eps)
    x = x + h
    h = L.rmsnorm(p["norm_mlp"], x, eps=cfg.norm_eps)
    if collect and mlp == C.RWKV_CHANNEL_MIX:
        uc["cmix_shift"] = h[:, -1, :].astype(_dtype(cfg))
    h, aux = _mlp_apply(cfg, mlp, p["mlp"], h)
    if cfg.use_post_norms:
        h = L.rmsnorm(p["post_norm_mlp"], h, eps=cfg.norm_eps)
    return x + h, aux, uc


def _remat_groups(cfg: C.ModelConfig) -> int:
    """Number of outer remat groups: the smallest divisor of n_blocks at or
    above sqrt(n_blocks) (1 = flat single-level remat for small models)."""
    n = cfg.n_blocks
    if n < 16:
        return 1
    root = n**0.5
    for d in range(int(root), n):
        if d > 1 and n % d == 0 and d >= root:
            return d
    return 1


def _remat_wrap(cfg: C.ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_saveable)
    raise ValueError(cfg.remat)


# ==========================================================================
# Forward (train / prefill)
# ==========================================================================
def forward(
    cfg: C.ModelConfig,
    params: dict,
    tokens: jax.Array,
    *,
    image_embeds: Optional[jax.Array] = None,
    return_cache: bool = False,
    last_only: bool = False,
) -> Tuple[jax.Array, jax.Array, Optional[dict]]:
    """Returns (logits, aux_loss, cache-or-None).

    tokens: (B, S) int32, or (B, S, C) for multi-codebook audio.
    image_embeds: (B, P, d_model) prepended when cfg.num_prefix_embeds > 0.
    The returned cache (prefill mode) covers exactly the input length; the
    serving layer pads it to its decode horizon.
    """
    dtype = _dtype(cfg)
    x = L.embed_lookup(params["embed"], tokens, dtype=dtype, scale=cfg.scale_embeddings)
    if cfg.num_prefix_embeds > 0:
        assert image_embeds is not None
        x = jnp.concatenate([image_embeds.astype(dtype), x], axis=1)
    x = constrain(x, "btd")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def block_fn(carry, block_params):
        h, aux = carry
        h = constrain(h, "btd")
        bc = {}
        for i, unit in enumerate(cfg.pattern):
            h, a, bc[f"u{i}"] = _unit_apply(
                cfg, unit, block_params[f"u{i}"], h, positions, collect=return_cache
            )
            aux = aux + a
        return (h, aux), bc

    cache: Optional[Dict[str, Any]] = {} if return_cache else None
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_blocks > 0:
        groups = _remat_groups(cfg) if (cfg.remat != "none" and not return_cache) else 1
        if groups > 1:
            # two-level (sqrt) remat: checkpoint at the GROUP level so the
            # outer scan saves only `groups` carries instead of n_blocks;
            # the inner scan's per-block residuals are transient within one
            # group's backward.  Same single extra forward as flat remat.
            inner = cfg.n_blocks // groups
            gp = jax.tree.map(
                lambda a: a.reshape((groups, inner) + a.shape[1:]),
                params["blocks"],
            )

            def group_fn(carry, gparams):
                out, _ = jax.lax.scan(block_fn, carry, gparams)
                return out, None

            wrapped = _remat_wrap(cfg, group_fn)
            (x, aux), _ = jax.lax.scan(wrapped, (x, aux), gp)
        else:
            wrapped = _remat_wrap(cfg, block_fn)
            (x, aux), block_caches = jax.lax.scan(wrapped, (x, aux), params["blocks"])
            if return_cache:
                cache["blocks"] = block_caches
    if cfg.n_remainder > 0:
        rem_caches = {}
        for i in range(cfg.n_remainder):
            unit_fn = _remat_wrap(
                cfg,
                lambda h, p, u=cfg.pattern[i]: _unit_apply(
                    cfg, u, p, h, positions, collect=return_cache
                ),
            )
            x, a, rem_caches[f"r{i}"] = unit_fn(x, params["rem"][f"r{i}"])
            aux = aux + a
        if return_cache:
            cache["rem"] = rem_caches

    if last_only:
        # serving prefill: only the last position's logits are needed —
        # slicing BEFORE the unembed keeps the (B, S, V) tensor out of the
        # program entirely (it dominated prefill memory otherwise)
        x = x[:, -1:]
    x = L.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = L.unembed(
        params["embed"],
        x,
        dtype=dtype,
        num_codebooks=cfg.num_codebooks,
        head=params.get("lm_head"),
    )
    logits = constrain(L.softcap(logits, cfg.final_logit_softcap), "logits")
    return logits, aux, cache


# ==========================================================================
# KV / state caches
# ==========================================================================
def _unit_cache_spec(
    cfg: C.ModelConfig,
    mixer: str,
    mlp: str,
    batch: int,
    max_len: int,
    layout: str = "dense",
    num_pages: Optional[int] = None,
    page_size: Optional[int] = None,
) -> dict:
    dtype = _dtype(cfg)
    spec: Dict[str, Any] = {}
    if mixer == C.GLOBAL_ATTN and layout == "paged":
        # shared page pool + per-sequence block tables instead of a dense
        # (batch, max_len) slab: (KV, P, page_size, D), contiguous per
        # (kv head, page) so the flash-decode kernel fetches a page with
        # one simple DMA.  The same page ids index every layer's pool.
        spec["k_pages"] = jnp.zeros(
            (cfg.num_kv_heads, num_pages, page_size, cfg.head_dim), dtype
        )
        spec["v_pages"] = jnp.zeros(
            (cfg.num_kv_heads, num_pages, page_size, cfg.head_dim), dtype
        )
    elif mixer in (C.GLOBAL_ATTN, C.LOCAL_ATTN):
        # local attention keeps its per-slot ring buffer in both layouts —
        # the window already bounds it, paging would buy nothing
        s_cache = max_len if mixer == C.GLOBAL_ATTN else min(max_len, cfg.window)
        spec["k"] = jnp.zeros((batch, s_cache, cfg.num_kv_heads, cfg.head_dim), dtype)
        spec["v"] = jnp.zeros((batch, s_cache, cfg.num_kv_heads, cfg.head_dim), dtype)
    elif mixer == C.MLA_ATTN:
        spec["ckv"] = jnp.zeros((batch, max_len, cfg.mla.kv_lora_rank), dtype)
        spec["kr"] = jnp.zeros((batch, max_len, cfg.mla.qk_rope_head_dim), dtype)
    elif mixer == C.RGLRU:
        rc = cfg.recurrent
        spec["conv"] = jnp.zeros((batch, rc.conv_width - 1, cfg.lru_width), dtype)
        spec["h"] = jnp.zeros((batch, cfg.lru_width), jnp.float32)
    elif mixer == C.RWKV6:
        rc = cfg.recurrent
        hd = rc.rwkv_head_dim
        spec["state"] = jnp.zeros((batch, cfg.d_model // hd, hd, hd), jnp.float32)
        spec["shift"] = jnp.zeros((batch, cfg.d_model), dtype)
    if mlp == C.RWKV_CHANNEL_MIX:
        spec["cmix_shift"] = jnp.zeros((batch, cfg.d_model), dtype)
    return spec


def init_cache(
    cfg: C.ModelConfig,
    batch: int,
    max_len: int,
    *,
    layout: str = "dense",
    num_pages: Optional[int] = None,
    page_size: Optional[int] = None,
) -> dict:
    """Zero cache pytree.  Stacked (n_blocks, ...) leading dim for scan.

    ``layout="paged"`` swaps every global-attention unit's dense
    (batch, max_len) K/V slab for a shared page pool addressed through
    per-sequence block tables (see `repro.serve.paged_cache`); all other
    cache kinds are unchanged.  The dense layout is byte-identical to the
    historical cache.
    """
    if layout not in ("dense", "paged"):
        raise ValueError(layout)
    if layout == "paged" and (num_pages is None or page_size is None):
        raise ValueError("paged cache needs num_pages and page_size")
    cache: Dict[str, Any] = {}
    if cfg.n_blocks > 0:
        def one_block(_):
            return {
                f"u{i}": _unit_cache_spec(
                    cfg, mixer, mlp, batch, max_len,
                    layout, num_pages, page_size,
                )
                for i, (mixer, mlp) in enumerate(cfg.pattern)
            }
        cache["blocks"] = jax.vmap(one_block)(jnp.arange(cfg.n_blocks))
    if cfg.n_remainder > 0:
        cache["rem"] = {
            f"r{i}": _unit_cache_spec(
                cfg, *cfg.pattern[i], batch, max_len,
                layout, num_pages, page_size,
            )
            for i in range(cfg.n_remainder)
        }
    return cache


def cache_specs(
    cfg: C.ModelConfig,
    batch: int,
    max_len: int,
    *,
    layout: str = "dense",
    num_pages: Optional[int] = None,
    page_size: Optional[int] = None,
) -> dict:
    return jax.eval_shape(
        lambda: init_cache(
            cfg, batch, max_len,
            layout=layout, num_pages=num_pages, page_size=page_size,
        )
    )


# ==========================================================================
# Decode step
# ==========================================================================
def _paged_write_page(
    block_tables: jax.Array, pos: jax.Array, ps: int
) -> jax.Array:
    """Page id for a token write at `pos` ((B,) or (B, K); result matches),
    routed to the null page (0, permanently garbage by convention) when
    `pos` lies beyond the block-table horizon — a done-but-unretired slot
    parked at the `max_len` boundary, or a speculative lookahead past the
    allocated window, must never clamp into a real (possibly shared) page."""
    mp = block_tables.shape[1]
    qidx = pos // ps
    clipped = jnp.clip(qidx, 0, mp - 1)
    if pos.ndim == 1:
        page = block_tables[jnp.arange(pos.shape[0]), clipped]
    else:
        page = jnp.take_along_axis(block_tables, clipped, axis=1)
    return jnp.where(qidx < mp, page, jnp.int32(0))


def _unit_decode(
    cfg: C.ModelConfig,
    unit: Tuple[str, str],
    p: dict,
    ucache: dict,
    x: jax.Array,
    pos: jax.Array,
    block_tables: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """x: (B, 1, D); pos: scalar int32 position of the new token, or a
    (B,) vector of per-sequence positions (continuous batching — each
    slot may be at a different decode offset).  ``block_tables`` (B, MP)
    routes paged global-attention caches; dense caches ignore it."""
    mixer, mlp = unit
    dtype = _dtype(cfg)
    rope_args = (cfg.rope_theta, cfg.rope_scaling)
    b = x.shape[0]
    new_cache = dict(ucache)
    ragged = getattr(pos, "ndim", 0) == 1
    if ragged:
        positions = pos[:, None]
        rows = jnp.arange(b)
    else:
        positions = jnp.broadcast_to(pos[None, None], (b, 1))

    h = L.rmsnorm(p["norm_mix"], x, eps=cfg.norm_eps)
    if mixer in (C.GLOBAL_ATTN, C.LOCAL_ATTN):
        q, k, v = attn.project_qkv(
            p["mixer"], h, dtype=dtype, rope_args=rope_args, positions=positions
        )
        if "k_pages" in ucache:
            # paged pool: alloc-on-write happened host-side (the block
            # table already names a page for `pos`); scatter the token
            # into (page, offset) and attend through the block table
            assert ragged and block_tables is not None
            ps = ucache["k_pages"].shape[2]
            page_id = _paged_write_page(block_tables, pos, ps)
            off = pos % ps
            k_pages = ucache["k_pages"].at[:, page_id, off].set(
                k[:, 0].transpose(1, 0, 2).astype(ucache["k_pages"].dtype)
            )
            v_pages = ucache["v_pages"].at[:, page_id, off].set(
                v[:, 0].transpose(1, 0, 2).astype(ucache["v_pages"].dtype)
            )
            from repro.kernels import ops as kops

            o = kops.flash_decode(
                q, k_pages, v_pages, block_tables, pos + 1,
                logit_cap=cfg.attn_logit_softcap, backend=cfg.kernel_backend,
            )
            mo = attn.attention_out(p["mixer"], o, dtype=dtype)
            new_cache["k_pages"], new_cache["v_pages"] = k_pages, v_pages
        else:
            s_cache = ucache["k"].shape[1]
            slot = pos % s_cache if mixer == C.LOCAL_ATTN else pos
            if ragged:
                # mode="drop": a done-but-unretired slot parked at the slab
                # boundary (pos == max_len) must not clamp into the last
                # real position
                k_cache = ucache["k"].at[rows, slot].set(
                    k[:, 0].astype(ucache["k"].dtype), mode="drop"
                )
                v_cache = ucache["v"].at[rows, slot].set(
                    v[:, 0].astype(ucache["v"].dtype), mode="drop"
                )
            else:
                k_cache = jax.lax.dynamic_update_slice(
                    ucache["k"], k.astype(ucache["k"].dtype), (0, slot, 0, 0)
                )
                v_cache = jax.lax.dynamic_update_slice(
                    ucache["v"], v.astype(ucache["v"].dtype), (0, slot, 0, 0)
                )
            lengths = jnp.minimum(pos + 1, s_cache)
            o = attn.decode_attention(
                q, k_cache, v_cache,
                lengths=jnp.broadcast_to(lengths, (b,)),
                logit_cap=cfg.attn_logit_softcap,
            )
            mo = attn.attention_out(p["mixer"], o, dtype=dtype)
            new_cache["k"], new_cache["v"] = k_cache, v_cache
    elif mixer == C.MLA_ATTN:
        ckv_new, kr_new = mla_mod.mla_new_token_latents(
            p["mixer"], h, cfg.mla, dtype=dtype, positions=positions,
            rope_theta=cfg.rope_theta, rope_scaling=cfg.rope_scaling,
        )
        if ragged:
            ckv = ucache["ckv"].at[rows, pos].set(
                ckv_new[:, 0].astype(ucache["ckv"].dtype), mode="drop"
            )
            kr = ucache["kr"].at[rows, pos].set(
                kr_new[:, 0].astype(ucache["kr"].dtype), mode="drop"
            )
        else:
            ckv = jax.lax.dynamic_update_slice(
                ucache["ckv"], ckv_new.astype(ucache["ckv"].dtype), (0, pos, 0)
            )
            kr = jax.lax.dynamic_update_slice(
                ucache["kr"], kr_new.astype(ucache["kr"].dtype), (0, pos, 0)
            )
        mo = mla_mod.mla_decode(
            p["mixer"], h, ckv, kr, cfg.mla, dtype=dtype,
            lengths=jnp.broadcast_to(pos + 1, (b,)),
            rope_theta=cfg.rope_theta, rope_scaling=cfg.rope_scaling,
        )
        new_cache["ckv"], new_cache["kr"] = ckv, kr
    elif mixer == C.RGLRU:
        mo, (conv_c, h_c) = rec.rglru_block(
            p["mixer"], h, dtype=dtype,
            conv_carry=ucache["conv"], h_prev=ucache["h"], decode=True,
        )
        new_cache["conv"] = conv_c.astype(ucache["conv"].dtype)
        new_cache["h"] = h_c
    elif mixer == C.RWKV6:
        mo, (state, shift) = rec.rwkv6_block(
            p["mixer"], h, cfg.recurrent, dtype=dtype,
            state=ucache["state"], shift_carry=ucache["shift"], decode=True,
        )
        new_cache["state"] = state
        new_cache["shift"] = shift.astype(ucache["shift"].dtype)
    else:
        raise ValueError(mixer)
    if cfg.use_post_norms:
        mo = L.rmsnorm(p["post_norm_mix"], mo, eps=cfg.norm_eps)
    x = x + mo

    h = L.rmsnorm(p["norm_mlp"], x, eps=cfg.norm_eps)
    if mlp == C.RWKV_CHANNEL_MIX:
        shifted = L.token_shift(h, last=ucache["cmix_shift"])
        mo = L.rwkv_cmix(p["mlp"], h, dtype=dtype, shifted=shifted)
        new_cache["cmix_shift"] = h[:, -1, :].astype(ucache["cmix_shift"].dtype)
    else:
        mo, _ = _mlp_apply(cfg, mlp, p["mlp"], h, decode=True)
    if cfg.use_post_norms:
        mo = L.rmsnorm(p["post_norm_mlp"], mo, eps=cfg.norm_eps)
    return x + mo, new_cache


def decode_step(
    cfg: C.ModelConfig,
    params: dict,
    cache: dict,
    tokens: jax.Array,
    pos: jax.Array,
    *,
    block_tables: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """One decode step.  tokens: (B, 1) (or (B, 1, C)); pos: scalar int32
    for the classic lock-step batch, or a (B,) int32 vector of
    per-sequence positions for continuous batching (paged or dense).
    ``block_tables`` (B, max_pages) is required iff `cache` was built
    with ``layout="paged"``.

    Returns (logits (B, 1, V) or (B, 1, C, V), new_cache).
    """
    dtype = _dtype(cfg)
    x = L.embed_lookup(params["embed"], tokens, dtype=dtype, scale=cfg.scale_embeddings)
    new_cache: Dict[str, Any] = {}

    if cfg.n_blocks > 0:
        # cache travels as scan CARRY with per-layer dynamic slice/update —
        # one buffer, updated in place (xs/ys stacking would double-buffer
        # the whole KV cache)
        def block_fn(carry, inp):
            h, blocks_cache = carry
            li, bp = inp
            bc = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, li, 0, keepdims=False),
                blocks_cache,
            )
            nbc = {}
            for i, unit in enumerate(cfg.pattern):
                h, nbc[f"u{i}"] = _unit_decode(
                    cfg, unit, bp[f"u{i}"], bc[f"u{i}"], h, pos, block_tables
                )
            blocks_cache = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), li, 0
                ),
                blocks_cache,
                nbc,
            )
            return (h, blocks_cache), None

        (x, new_cache["blocks"]), _ = jax.lax.scan(
            block_fn,
            (x, cache["blocks"]),
            (jnp.arange(cfg.n_blocks), params["blocks"]),
        )
    if cfg.n_remainder > 0:
        new_cache["rem"] = {}
        for i in range(cfg.n_remainder):
            x, nc = _unit_decode(
                cfg, cfg.pattern[i], params["rem"][f"r{i}"], cache["rem"][f"r{i}"],
                x, pos, block_tables,
            )
            new_cache["rem"][f"r{i}"] = nc

    x = L.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = L.unembed(
        params["embed"], x, dtype=dtype,
        num_codebooks=cfg.num_codebooks, head=params.get("lm_head"),
    )
    logits = L.softcap(logits, cfg.final_logit_softcap)
    return logits, new_cache


# ==========================================================================
# Multi-token (speculative) decode.  `decode_multi` processes K = 1 + k
# tokens per slot in one call: the committed current token plus k
# unverified drafts.  Paged global-attention units score all K queries in
# ONE flash_decode pass (the K-query tile in kernels/flash_decode.py);
# every other cache family runs an inner jax.lax.scan whose per-step body
# is exactly `_unit_decode`, so per-token math matches K sequential
# decode_step calls by construction.  The scan stages per-step carries so
# `commit_multi` can rewind state written by rejected draft tokens.
# ==========================================================================
_REWIND_KEYS = ("conv", "h", "state", "shift", "cmix_shift")


def _unit_decode_paged_multi(
    cfg: C.ModelConfig,
    unit: Tuple[str, str],
    p: dict,
    ucache: dict,
    x: jax.Array,
    pos: jax.Array,
    block_tables: jax.Array,
) -> Tuple[jax.Array, dict]:
    """Paged global-attention unit over K tokens in one pass: scatter all
    K tokens into the page pools (beyond-horizon writes null-routed), then
    one width-K flash_decode where query row t sees length pos+1+t."""
    mixer, mlp = unit
    dtype = _dtype(cfg)
    rope_args = (cfg.rope_theta, cfg.rope_scaling)
    b, kk, _ = x.shape
    positions = pos[:, None] + jnp.arange(kk)[None, :]  # (B, K)
    new_cache = dict(ucache)

    h = L.rmsnorm(p["norm_mix"], x, eps=cfg.norm_eps)
    q, k, v = attn.project_qkv(
        p["mixer"], h, dtype=dtype, rope_args=rope_args, positions=positions
    )
    ps = ucache["k_pages"].shape[2]
    page_id = _paged_write_page(block_tables, positions, ps)  # (B, K)
    off = positions % ps
    k_pages = ucache["k_pages"].at[:, page_id, off].set(
        k.transpose(2, 0, 1, 3).astype(ucache["k_pages"].dtype)
    )
    v_pages = ucache["v_pages"].at[:, page_id, off].set(
        v.transpose(2, 0, 1, 3).astype(ucache["v_pages"].dtype)
    )
    from repro.kernels import ops as kops

    o = kops.flash_decode(
        q, k_pages, v_pages, block_tables, pos + 1,
        logit_cap=cfg.attn_logit_softcap, backend=cfg.kernel_backend,
    )
    mo = attn.attention_out(p["mixer"], o, dtype=dtype)
    new_cache["k_pages"], new_cache["v_pages"] = k_pages, v_pages
    if cfg.use_post_norms:
        mo = L.rmsnorm(p["post_norm_mix"], mo, eps=cfg.norm_eps)
    x = x + mo

    h = L.rmsnorm(p["norm_mlp"], x, eps=cfg.norm_eps)
    mo, _ = _mlp_apply(cfg, mlp, p["mlp"], h, decode=True)
    if cfg.use_post_norms:
        mo = L.rmsnorm(p["post_norm_mlp"], mo, eps=cfg.norm_eps)
    return x + mo, new_cache


def _unit_decode_multi(
    cfg: C.ModelConfig,
    unit: Tuple[str, str],
    p: dict,
    ucache: dict,
    x: jax.Array,
    pos: jax.Array,
    block_tables: Optional[jax.Array],
) -> Tuple[jax.Array, dict, dict]:
    """x: (B, K, D); pos: (B,) position of x[:, 0].  Returns
    (y (B, K, D), new_ucache, staged) where `staged` holds rollback state:
    recurrent/shift carries after each of the K steps ((K, B, ...)), and
    for local-attention rings the pre-write contents of the K written
    slots ((K, B, kv, d))."""
    mixer, mlp = unit
    b, kk, _ = x.shape
    if (
        mixer == C.GLOBAL_ATTN
        and "k_pages" in ucache
        and mlp != C.RWKV_CHANNEL_MIX
    ):
        y, nuc = _unit_decode_paged_multi(
            cfg, unit, p, ucache, x, pos, block_tables
        )
        return y, nuc, {}
    if mixer == C.LOCAL_ATTN and kk > ucache["k"].shape[1]:
        raise ValueError(
            f"speculative width {kk} exceeds the local-attention ring size "
            f"{ucache['k'].shape[1]}: ring slots would collide and rollback "
            "could not restore rejected writes"
        )
    rows = jnp.arange(b)

    def step(uc, xt, pt):
        st = {}
        if mixer == C.LOCAL_ATTN:
            slot = pt % uc["k"].shape[1]
            st["k_old"] = uc["k"][rows, slot]
            st["v_old"] = uc["v"][rows, slot]
        y, nuc = _unit_decode(cfg, unit, p, uc, xt, pt, block_tables)
        for name in _REWIND_KEYS:
            if name in nuc:
                st[name] = nuc[name]
        return y, nuc, st

    # Per-token sequencing strategy is chosen per mixer so each step
    # compiles bit-identically to the inlined single-step path (the
    # stream-identity contract): XLA's fusion choices differ between a
    # scanned body and an unrolled one by ulps, and which variant matches
    # the plain `decode_step` compile differs by family — the recurrent
    # mixers match under lax.scan, the attention/MLA mixers under a
    # static unroll.  K (the speculation width) is small either way.
    if mixer in (C.RGLRU, C.RWKV6):
        def scan_step(uc, inp):
            xt, pt = inp
            y, nuc, st = step(uc, xt[:, None], pt)
            return nuc, (y[:, 0], st)

        steps_pos = pos[None, :] + jnp.arange(kk)[:, None]  # (K, B)
        nuc, (ys, staged) = jax.lax.scan(
            scan_step, ucache, (x.transpose(1, 0, 2), steps_pos)
        )
        return ys.transpose(1, 0, 2), nuc, staged

    uc = ucache
    ys = []
    staged_steps = []
    for t in range(kk):
        y, uc, st = step(uc, x[:, t:t + 1], pos + t)
        ys.append(y)
        staged_steps.append(st)
    staged = (
        jax.tree.map(lambda *ls: jnp.stack(ls, axis=0), *staged_steps)
        if staged_steps[0]
        else {}
    )
    return jnp.concatenate(ys, axis=1), uc, staged


def decode_multi(
    cfg: C.ModelConfig,
    params: dict,
    cache: dict,
    tokens: jax.Array,
    pos: jax.Array,
    *,
    block_tables: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict, dict]:
    """Speculative decode over K tokens per slot.

    tokens: (B, K) int32 — the committed current token followed by K-1
    unverified drafts; pos: (B,) int32 position of tokens[:, 0].  Returns
    (logits (B, K, V), new_cache, staged): new_cache holds all K token
    writes including the rejected ones — pass `staged` plus the per-slot
    accepted count to `commit_multi` to rewind.  logits[:, t] matches the
    t-th of K sequential `decode_step` calls on the same tokens
    bit-for-bit (CI-gated).  Text-only (num_codebooks == 1).
    """
    if cfg.num_codebooks != 1:
        raise ValueError("decode_multi is text-only (num_codebooks == 1)")
    dtype = _dtype(cfg)
    x = L.embed_lookup(
        params["embed"], tokens, dtype=dtype, scale=cfg.scale_embeddings
    )
    new_cache: Dict[str, Any] = {}
    staged: Dict[str, Any] = {}

    if cfg.n_blocks > 0:
        def block_fn(carry, inp):
            h, blocks_cache = carry
            li, bp = inp
            bc = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, li, 0, keepdims=False),
                blocks_cache,
            )
            nbc = {}
            st = {}
            for i, unit in enumerate(cfg.pattern):
                h, nbc[f"u{i}"], st[f"u{i}"] = _unit_decode_multi(
                    cfg, unit, bp[f"u{i}"], bc[f"u{i}"], h, pos, block_tables
                )
            blocks_cache = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), li, 0
                ),
                blocks_cache,
                nbc,
            )
            return (h, blocks_cache), st

        (x, new_cache["blocks"]), staged["blocks"] = jax.lax.scan(
            block_fn,
            (x, cache["blocks"]),
            (jnp.arange(cfg.n_blocks), params["blocks"]),
        )
    if cfg.n_remainder > 0:
        new_cache["rem"] = {}
        staged["rem"] = {}
        for i in range(cfg.n_remainder):
            x, nc, st = _unit_decode_multi(
                cfg, cfg.pattern[i], params["rem"][f"r{i}"], cache["rem"][f"r{i}"],
                x, pos, block_tables,
            )
            new_cache["rem"][f"r{i}"] = nc
            staged["rem"][f"r{i}"] = st

    x = L.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = L.unembed(
        params["embed"], x, dtype=dtype,
        num_codebooks=cfg.num_codebooks, head=params.get("lm_head"),
    )
    logits = L.softcap(logits, cfg.final_logit_softcap)
    return logits, new_cache, staged


def _commit_unit(
    uc: dict, st: dict, sel: jax.Array, keep: jax.Array, pos: jax.Array,
    stacked: bool,
) -> dict:
    if not st:
        return uc
    nuc = dict(uc)
    ax = 1 if stacked else 0  # staged leaves: (NB, K, B, ...) or (K, B, ...)
    for name in _REWIND_KEYS:
        if name in st:
            leaf = st[name]
            idx_shape = [1] * leaf.ndim
            idx_shape[ax + 1] = sel.shape[0]
            idx = jnp.clip(sel, 0, leaf.shape[ax] - 1).reshape(idx_shape)
            picked = jnp.take_along_axis(leaf, idx, axis=ax)
            nuc[name] = jnp.squeeze(picked, axis=ax).astype(uc[name].dtype)
    if "k_old" in st:
        ko, vo = st["k_old"], st["v_old"]
        if stacked:
            ko = ko.transpose(0, 2, 1, 3, 4)  # (NB, B, K, kv, d)
            vo = vo.transpose(0, 2, 1, 3, 4)
        else:
            ko = ko.transpose(1, 0, 2, 3)  # (B, K, kv, d)
            vo = vo.transpose(1, 0, 2, 3)
        kk = ko.shape[-3]
        s_cache = uc["k"].shape[-3]
        b = sel.shape[0]
        slots = (pos[:, None] + jnp.arange(kk)[None, :]) % s_cache  # (B, K)
        rej = jnp.arange(kk)[None, :] >= keep[:, None]  # (B, K)
        brows = jnp.arange(b)[:, None]
        if stacked:
            m = rej[None, :, :, None, None]
            nuc["k"] = nuc["k"].at[:, brows, slots].set(
                jnp.where(m, ko, nuc["k"][:, brows, slots])
            )
            nuc["v"] = nuc["v"].at[:, brows, slots].set(
                jnp.where(m, vo, nuc["v"][:, brows, slots])
            )
        else:
            m = rej[:, :, None, None]
            nuc["k"] = nuc["k"].at[brows, slots].set(
                jnp.where(m, ko, nuc["k"][brows, slots])
            )
            nuc["v"] = nuc["v"].at[brows, slots].set(
                jnp.where(m, vo, nuc["v"][brows, slots])
            )
    return nuc


def commit_multi(
    cfg: C.ModelConfig,
    cache: dict,
    staged: dict,
    keep: jax.Array,
    pos: jax.Array,
) -> dict:
    """Rewind a `decode_multi` cache to `keep` committed tokens per slot.

    keep: (B,) int32 in [1, K]; pos: (B,) position of the first token of
    the speculative window.  Slab and paged leaves need no rewind — writes
    beyond the committed position sit past every future read's length mask
    and are overwritten before they become visible.  Recurrent and
    token-shift carries are re-selected at step keep-1; local-attention
    ring slots written by rejected steps are restored from the staged
    pre-write values (a ring write at pos+t lands in a slot still inside
    the live window, so a plain pos rewind would leave it corrupted).
    """
    sel = keep - 1
    new_cache = dict(cache)
    if staged.get("blocks"):
        blocks = dict(cache["blocks"])
        for uk, st in staged["blocks"].items():
            blocks[uk] = _commit_unit(
                cache["blocks"][uk], st, sel, keep, pos, stacked=True
            )
        new_cache["blocks"] = blocks
    if staged.get("rem"):
        rem = dict(cache["rem"])
        for rk, st in staged["rem"].items():
            rem[rk] = _commit_unit(
                cache["rem"][rk], st, sel, keep, pos, stacked=False
            )
        new_cache["rem"] = rem
    return new_cache


# ==========================================================================
# Chunked prefill.  A prompt is prefilled C tokens at a time against a
# dense "prefill carry" (one jit shape regardless of prompt length, so a
# long admission never stalls in-flight decode and never retraces).  The
# carry is layout-agnostic: the paged serving layer scatters each chunk's
# global-attention K/V into its page pool separately, and the carry doubles
# as the prefix-cache snapshot payload (callers must NOT donate it).
# ==========================================================================
def prefill_cap(max_len: int, chunk: int) -> int:
    """Carry slab length: max_len rounded up to a chunk multiple so every
    fixed-size chunk slice stays in bounds (dynamic_slice must never clamp,
    or the final chunk's page scatter would read misaligned positions)."""
    return ((max_len + chunk - 1) // chunk) * chunk


def _unit_prefill_spec(
    cfg: C.ModelConfig, mixer: str, mlp: str, batch: int, cap: int
) -> dict:
    dtype = _dtype(cfg)
    spec: Dict[str, Any] = {}
    if mixer in (C.GLOBAL_ATTN, C.LOCAL_ATTN):
        # both attention kinds carry a FULL cap-length slab during prefill —
        # chunk attention needs arbitrary lookback within the prompt; the
        # local ring conversion happens once in finish_prefill_carry
        spec["k"] = jnp.zeros((batch, cap, cfg.num_kv_heads, cfg.head_dim), dtype)
        spec["v"] = jnp.zeros((batch, cap, cfg.num_kv_heads, cfg.head_dim), dtype)
    elif mixer == C.MLA_ATTN:
        spec["ckv"] = jnp.zeros((batch, cap, cfg.mla.kv_lora_rank), dtype)
        spec["kr"] = jnp.zeros((batch, cap, cfg.mla.qk_rope_head_dim), dtype)
    elif mixer == C.RGLRU:
        rc = cfg.recurrent
        spec["conv"] = jnp.zeros((batch, rc.conv_width - 1, cfg.lru_width), dtype)
        spec["h"] = jnp.zeros((batch, cfg.lru_width), jnp.float32)
    elif mixer == C.RWKV6:
        hd = cfg.recurrent.rwkv_head_dim
        spec["state"] = jnp.zeros((batch, cfg.d_model // hd, hd, hd), jnp.float32)
        spec["shift"] = jnp.zeros((batch, cfg.d_model), dtype)
    if mlp == C.RWKV_CHANNEL_MIX:
        spec["cmix_shift"] = jnp.zeros((batch, cfg.d_model), dtype)
    return spec


def init_prefill_carry(cfg: C.ModelConfig, batch: int, cap: int) -> dict:
    """Zero prefill carry (same block/rem structure as the decode cache)."""
    carry: Dict[str, Any] = {}
    if cfg.n_blocks > 0:
        def one_block(_):
            return {
                f"u{i}": _unit_prefill_spec(cfg, mixer, mlp, batch, cap)
                for i, (mixer, mlp) in enumerate(cfg.pattern)
            }
        carry["blocks"] = jax.vmap(one_block)(jnp.arange(cfg.n_blocks))
    if cfg.n_remainder > 0:
        carry["rem"] = {
            f"r{i}": _unit_prefill_spec(cfg, *cfg.pattern[i], batch, cap)
            for i in range(cfg.n_remainder)
        }
    return carry


def _unit_prefill_chunk(
    cfg: C.ModelConfig,
    unit: Tuple[str, str],
    p: dict,
    ucache: dict,
    x: jax.Array,
    start: jax.Array,
    valid_len: jax.Array,
) -> Tuple[jax.Array, dict]:
    """x: (B, C, D); start: (B,) absolute offset of the chunk; valid_len:
    (B,) real tokens in it (== C everywhere but a padded final chunk).

    Attention families need no valid_len: padded queries produce garbage
    outputs (discarded) and garbage K/V beyond the prompt, which causal
    masking keeps at exactly 0 probability for every real query.  The
    recurrent families and the cmix shift take their carries at
    valid_len - 1 so padding is a state no-op.
    """
    mixer, mlp = unit
    dtype = _dtype(cfg)
    rope_args = (cfg.rope_theta, cfg.rope_scaling)
    b, c, _ = x.shape
    positions = start[:, None] + jnp.arange(c)[None, :]  # (B, C)
    rows = jnp.arange(b)[:, None]
    new_cache = dict(ucache)

    h = L.rmsnorm(p["norm_mix"], x, eps=cfg.norm_eps)
    if mixer in (C.GLOBAL_ATTN, C.LOCAL_ATTN):
        q, k, v = attn.project_qkv(
            p["mixer"], h, dtype=dtype, rope_args=rope_args, positions=positions
        )
        k_cache = ucache["k"].at[rows, positions].set(k.astype(ucache["k"].dtype))
        v_cache = ucache["v"].at[rows, positions].set(v.astype(ucache["v"].dtype))
        o = attn.chunk_decode_attention(
            q, k_cache, v_cache, start=start,
            window=cfg.window if mixer == C.LOCAL_ATTN else None,
            logit_cap=cfg.attn_logit_softcap,
        )
        mo = attn.attention_out(p["mixer"], o, dtype=dtype)
        new_cache["k"], new_cache["v"] = k_cache, v_cache
    elif mixer == C.MLA_ATTN:
        ckv_new, kr_new = mla_mod.mla_new_token_latents(
            p["mixer"], h, cfg.mla, dtype=dtype, positions=positions,
            rope_theta=cfg.rope_theta, rope_scaling=cfg.rope_scaling,
        )
        ckv = ucache["ckv"].at[rows, positions].set(ckv_new.astype(ucache["ckv"].dtype))
        kr = ucache["kr"].at[rows, positions].set(kr_new.astype(ucache["kr"].dtype))
        mo = mla_mod.mla_chunk_decode(
            p["mixer"], h, ckv, kr, cfg.mla, dtype=dtype, positions=positions,
            rope_theta=cfg.rope_theta, rope_scaling=cfg.rope_scaling,
        )
        new_cache["ckv"], new_cache["kr"] = ckv, kr
    elif mixer == C.RGLRU:
        mo, (conv_c, h_c) = rec.rglru_block(
            p["mixer"], h, dtype=dtype,
            conv_carry=ucache["conv"], h_prev=ucache["h"], valid_len=valid_len,
        )
        new_cache["conv"] = conv_c.astype(ucache["conv"].dtype)
        new_cache["h"] = h_c
    elif mixer == C.RWKV6:
        mo, (state, shift) = rec.rwkv6_block(
            p["mixer"], h, cfg.recurrent, dtype=dtype,
            state=ucache["state"], shift_carry=ucache["shift"], valid_len=valid_len,
        )
        new_cache["state"] = state
        new_cache["shift"] = shift.astype(ucache["shift"].dtype)
    else:
        raise ValueError(mixer)
    if cfg.use_post_norms:
        mo = L.rmsnorm(p["post_norm_mix"], mo, eps=cfg.norm_eps)
    x = x + mo

    h = L.rmsnorm(p["norm_mlp"], x, eps=cfg.norm_eps)
    if mlp == C.RWKV_CHANNEL_MIX:
        shifted = L.token_shift(h, last=ucache["cmix_shift"])
        mo = L.rwkv_cmix(p["mlp"], h, dtype=dtype, shifted=shifted)
        new_cache["cmix_shift"] = jnp.take_along_axis(
            h, (valid_len - 1)[:, None, None], axis=1
        )[:, 0].astype(ucache["cmix_shift"].dtype)
    else:
        mo, _ = _mlp_apply(cfg, mlp, p["mlp"], h)
    if cfg.use_post_norms:
        mo = L.rmsnorm(p["post_norm_mlp"], mo, eps=cfg.norm_eps)
    return x + mo, new_cache


def prefill_chunk(
    cfg: C.ModelConfig,
    params: dict,
    carry: dict,
    tokens: jax.Array,
    start: jax.Array,
    length: jax.Array,
) -> Tuple[jax.Array, dict]:
    """One fixed-size prefill step.  tokens: (B, C) int32 (right-padded past
    ``length``); start: (B,) absolute offset of the chunk; length: (B,)
    valid tokens in it.  Returns (logits (B, C, V), new_carry).

    Callers must NOT donate the carry: prefix-cache snapshots hold
    zero-copy references to the returned arrays.
    """
    dtype = _dtype(cfg)
    x = L.embed_lookup(params["embed"], tokens, dtype=dtype, scale=cfg.scale_embeddings)
    new_carry: Dict[str, Any] = {}
    if cfg.n_blocks > 0:
        def block_fn(carry_, inp):
            h, blocks_cache = carry_
            li, bp = inp
            bc = jax.tree.map(
                lambda c: jax.lax.dynamic_index_in_dim(c, li, 0, keepdims=False),
                blocks_cache,
            )
            nbc = {}
            for i, unit in enumerate(cfg.pattern):
                h, nbc[f"u{i}"] = _unit_prefill_chunk(
                    cfg, unit, bp[f"u{i}"], bc[f"u{i}"], h, start, length
                )
            blocks_cache = jax.tree.map(
                lambda c, n: jax.lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), li, 0
                ),
                blocks_cache,
                nbc,
            )
            return (h, blocks_cache), None

        (x, new_carry["blocks"]), _ = jax.lax.scan(
            block_fn,
            (x, carry["blocks"]),
            (jnp.arange(cfg.n_blocks), params["blocks"]),
        )
    if cfg.n_remainder > 0:
        new_carry["rem"] = {}
        for i in range(cfg.n_remainder):
            x, nc = _unit_prefill_chunk(
                cfg, cfg.pattern[i], params["rem"][f"r{i}"],
                carry["rem"][f"r{i}"], x, start, length,
            )
            new_carry["rem"][f"r{i}"] = nc

    x = L.rmsnorm(params["final_norm"], x, eps=cfg.norm_eps)
    logits = L.unembed(
        params["embed"], x, dtype=dtype,
        num_codebooks=cfg.num_codebooks, head=params.get("lm_head"),
    )
    logits = L.softcap(logits, cfg.final_logit_softcap)
    return logits, new_carry


def finish_prefill_carry(
    cfg: C.ModelConfig, carry: dict, length: jax.Array, max_len: int
) -> dict:
    """Fold a finished prefill carry into the shape the decode-cache insert
    expects: global/MLA slabs statically sliced to max_len, local-attention
    slabs gathered into the decode ring convention (ring slot j holds the
    newest token with position % s_cache == j), recurrent state passed
    through.  length: (B,) prompt lengths."""

    def unit_fix(mixer: str, uc: dict) -> dict:
        out = dict(uc)
        if mixer == C.LOCAL_ATTN:
            s_cache = min(max_len, cfg.window)
            idx = (jnp.arange(s_cache)[None, :] - length[:, None]) % s_cache + (
                length[:, None] - s_cache
            )
            # slots not yet reached by short prompts hold arbitrary values;
            # decode writes each before its first attend (lengths mask)
            idx = jnp.maximum(idx, 0)
            out["k"] = jnp.take_along_axis(uc["k"], idx[:, :, None, None], axis=1)
            out["v"] = jnp.take_along_axis(uc["v"], idx[:, :, None, None], axis=1)
        elif mixer == C.GLOBAL_ATTN:
            out["k"] = uc["k"][:, :max_len]
            out["v"] = uc["v"][:, :max_len]
        elif mixer == C.MLA_ATTN:
            out["ckv"] = uc["ckv"][:, :max_len]
            out["kr"] = uc["kr"][:, :max_len]
        return out

    fixed: Dict[str, Any] = {}
    if cfg.n_blocks > 0:
        fixed["blocks"] = {
            f"u{i}": jax.vmap(lambda uc, m=mixer: unit_fix(m, uc))(
                carry["blocks"][f"u{i}"]
            )
            for i, (mixer, _mlp) in enumerate(cfg.pattern)
        }
    if cfg.n_remainder > 0:
        fixed["rem"] = {
            f"r{i}": unit_fix(cfg.pattern[i][0], carry["rem"][f"r{i}"])
            for i in range(cfg.n_remainder)
        }
    return fixed


# ==========================================================================
# Namespace object
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class Transformer:
    """Config-bound convenience wrapper."""

    cfg: C.ModelConfig

    def init(self, key):
        return init_params(key, self.cfg)

    def param_specs(self):
        return param_specs(self.cfg)

    def __call__(self, params, tokens, **kw):
        return forward(self.cfg, params, tokens, **kw)

    def decode(self, params, cache, tokens, pos, *, block_tables=None):
        return decode_step(
            self.cfg, params, cache, tokens, pos, block_tables=block_tables
        )

    def init_cache(self, batch, max_len, **kw):
        return init_cache(self.cfg, batch, max_len, **kw)
