"""Attention mixers: full / chunked-causal / banded-local, GQA, decode.

Three execution strategies, all numerically equivalent:

* ``full_attention``     — materializes (B, H, Sq, Sk) scores.  Used for short
                           sequences and as the oracle in tests.
* ``chunked_attention``  — flash-style online-softmax over KV chunks via
                           lax.scan; memory O(S * chunk).  Used for global
                           layers at long sequence length (XLA path; the
                           Pallas flash kernel implements the same math).
* ``local_attention``    — banded blocking for sliding-window layers: each Q
                           block of size W attends to (previous, own) blocks,
                           exact for window <= W and cost 2*S*W instead of S².

Decode (single query token against a cache) goes through ``decode_attention``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import softcap
from repro.parallel.act_sharding import constrain

NEG_INF = -2.0e38  # fp32-safe mask value


def _gqa_expand(q: jax.Array, num_kv: int) -> jax.Array:
    """(B, S, H, D) -> (B, S, KV, G, D)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


def _scale(head_dim: int) -> float:
    return head_dim**-0.5


# --------------------------------------------------------------------------
# Full attention (oracle / short sequences / remainder layers)
# --------------------------------------------------------------------------
def full_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
    q_offset: int = 0,
) -> jax.Array:
    """q: (B, Sq, H, Dq), k: (B, Sk, KV, Dq), v: (B, Sk, KV, Dv).

    Returns (B, Sq, H, Dv).  ``q_offset`` is the absolute position of q[0]
    relative to k[0] (used for decode / chunked evaluation).
    """
    b, sq, h, dq = q.shape
    sk, kv = k.shape[1], k.shape[2]
    qg = _gqa_expand(q, kv)  # (B, Sq, KV, G, Dq)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    logits *= _scale(dq)
    logits = softcap(logits, logit_cap)

    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, v.shape[-1])


# --------------------------------------------------------------------------
# Chunked (flash-style) causal attention — pure-XLA path
# --------------------------------------------------------------------------
def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    logit_cap: Optional[float] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Causal attention with online softmax, O(q_chunk * kv_chunk) memory.

    Shapes as in full_attention.  Requires Sq % q_chunk == Sk % kv_chunk == 0.
    """
    b, s, h, dq = q.shape
    kvh = k.shape[2]
    dv = v.shape[-1]
    assert s % q_chunk == 0 and s % kv_chunk == 0, (s, q_chunk, kv_chunk)
    nq, nk = s // q_chunk, s // kv_chunk
    g = h // kvh
    scale = _scale(dq)

    # (nq, B, C, KV, G, D)
    qs = q.reshape(b, nq, q_chunk, kvh, g, dq).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(b, nk, kv_chunk, kvh, dq).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nk, kv_chunk, kvh, dv).transpose(1, 0, 2, 3, 4)

    q_pos_in_chunk = jnp.arange(q_chunk)
    k_pos_in_chunk = jnp.arange(kv_chunk)

    def one_q_chunk(qi, qc):
        # qc: (B, C, KV, G, D)
        def kv_step(carry, inputs):
            m, l, acc = carry
            ki, kc, vc = inputs
            logits = jnp.einsum("bqkgd,bskd->bkgqs", qc, kc).astype(jnp.float32)
            logits *= scale
            logits = softcap(logits, logit_cap)
            q_abs = qi * q_chunk + q_pos_in_chunk
            k_abs = ki * kv_chunk + k_pos_in_chunk
            mask = q_abs[:, None] >= k_abs[None, :]
            logits = jnp.where(mask, logits, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vc.dtype), vc)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_chunk), jnp.float32)
        acc0 = jnp.zeros((b, kvh, g, q_chunk, dv), jnp.float32)
        # Only kv chunks <= qi contribute under causality; we scan all chunks
        # for a static trip count but mask — see local_attention for the
        # banded variant that avoids the waste for windowed layers.
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, acc0), (jnp.arange(nk), ks, vs)
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (B, KV, G, C, Dv) -> (B, C, KV*G, Dv)
        return out.transpose(0, 3, 1, 2, 4).reshape(b, q_chunk, h, dv)

    outs = jax.lax.map(lambda args: one_q_chunk(*args), (jnp.arange(nq), qs))
    # (nq, B, C, H, Dv) -> (B, S, H, Dv)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv).astype(v.dtype)


# --------------------------------------------------------------------------
# Banded local attention (sliding window) — cost 2*S*W
# --------------------------------------------------------------------------
def local_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    logit_cap: Optional[float] = None,
) -> jax.Array:
    """Causal sliding-window attention, exact, via banded blocking.

    Each Q block of size W attends to the previous and its own K/V block.
    Requires S % window == 0 (configs guarantee it; pad upstream otherwise).
    """
    b, s, h, dq = q.shape
    kvh = k.shape[2]
    dv = v.shape[-1]
    w = window
    assert s % w == 0, (s, w)
    nb = s // w
    g = h // kvh
    scale = _scale(dq)

    qb = q.reshape(b, nb, w, kvh, g, dq)
    kb = k.reshape(b, nb, w, kvh, dq)
    vb = v.reshape(b, nb, w, kvh, dv)
    # previous block (zeros before block 0)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)  # (B, nb, 2W, KV, Dq)
    v2 = jnp.concatenate([v_prev, vb], axis=2)

    logits = jnp.einsum("bnqkgd,bnskd->bnkgqs", qb, k2).astype(jnp.float32)
    logits *= scale
    logits = softcap(logits, logit_cap)

    q_pos = w + jnp.arange(w)  # position within the 2W strip
    k_pos = jnp.arange(2 * w)
    mask = (q_pos[:, None] >= k_pos[None, :]) & (q_pos[:, None] - k_pos[None, :] < w)
    # block 0 has no previous block; mask its first W kv slots
    block0_mask = mask & (k_pos[None, :] >= w)
    full_mask = jnp.broadcast_to(mask, (nb, w, 2 * w))
    full_mask = full_mask.at[0].set(block0_mask)
    logits = jnp.where(full_mask[None, :, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bnkgqs,bnskd->bnqkgd", probs, v2)
    return out.reshape(b, s, h, dv)


# --------------------------------------------------------------------------
# Decode attention (one new token vs. a cache)
# --------------------------------------------------------------------------
def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    *,
    lengths: jax.Array,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
) -> jax.Array:
    """q: (B, 1, H, D); caches: (B, S, KV, D); lengths: (B,) valid entries.

    Returns (B, 1, H, Dv).
    """
    b, _, h, dq = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    qg = _gqa_expand(q, kvh)[:, 0]  # (B, KV, G, D)
    logits = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache).astype(jnp.float32)
    logits *= _scale(dq)
    logits = softcap(logits, logit_cap)
    pos = jnp.arange(s)[None, :]  # (1, S)
    valid = pos < lengths[:, None]
    if window is not None:
        valid &= pos >= (lengths[:, None] - window)
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache)
    return out.reshape(b, 1, h, v_cache.shape[-1])


# --------------------------------------------------------------------------
# Chunk-prefill attention (C new tokens vs. a cache slab)
# --------------------------------------------------------------------------
def chunk_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    *,
    start: jax.Array,
    window: Optional[int] = None,
    logit_cap: Optional[float] = None,
) -> jax.Array:
    """Attention for a chunk of C prompt tokens against a cache slab that
    already contains them (the multi-query generalization of
    ``decode_attention`` — chunked prefill's inner op).

    q: (B, C, H, D); caches: (B, S, KV, D); start: (B,) absolute position
    of q[:, 0].  Query i sits at position start + i and attends causally
    to cache positions <= start + i (optionally windowed).  Cache entries
    past the chunk (stale/garbage) are masked exactly — they contribute
    0 probability regardless of value.
    """
    b, c, h, dq = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]
    qg = _gqa_expand(q, kvh)  # (B, C, KV, G, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(jnp.float32)
    logits *= _scale(dq)
    logits = softcap(logits, logit_cap)
    q_pos = start[:, None] + jnp.arange(c)[None, :]  # (B, C)
    k_pos = jnp.arange(s)[None, None, :]  # (1, 1, S)
    valid = k_pos <= q_pos[:, :, None]
    if window is not None:
        valid &= q_pos[:, :, None] - k_pos < window
    logits = jnp.where(valid[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
    return out.reshape(b, c, h, v_cache.shape[-1])


# --------------------------------------------------------------------------
# Parameter init + module-level wrapper
# --------------------------------------------------------------------------
def attention_init(
    key: jax.Array,
    d_model: int,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    *,
    bias: bool = False,
    qk_norm: bool = False,
) -> dict:
    ks = jax.random.split(key, 4)
    sc = d_model**-0.5
    params = {
        "w_q": jax.random.normal(ks[0], (d_model, num_heads, head_dim), jnp.float32) * sc,
        "w_k": jax.random.normal(ks[1], (d_model, num_kv_heads, head_dim), jnp.float32) * sc,
        "w_v": jax.random.normal(ks[2], (d_model, num_kv_heads, head_dim), jnp.float32) * sc,
        "w_o": jax.random.normal(ks[3], (num_heads, head_dim, d_model), jnp.float32)
        * (num_heads * head_dim) ** -0.5,
    }
    if bias:
        params["b_q"] = jnp.zeros((num_heads, head_dim), jnp.float32)
        params["b_k"] = jnp.zeros((num_kv_heads, head_dim), jnp.float32)
        params["b_v"] = jnp.zeros((num_kv_heads, head_dim), jnp.float32)
    if qk_norm:
        params["q_norm"] = {"scale": jnp.zeros((head_dim,), jnp.float32)}
        params["k_norm"] = {"scale": jnp.zeros((head_dim,), jnp.float32)}
    return params


def project_qkv(params: dict, x: jax.Array, *, dtype, rope_args, positions):
    """Shared Q/K/V projection (+bias, +qk-norm, +rope)."""
    from repro.models.layers import rmsnorm  # local import to avoid cycle

    xc = x.astype(dtype)
    q = jnp.einsum("bsd,dhk->bshk", xc, params["w_q"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bshk", xc, params["w_k"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bshk", xc, params["w_v"].astype(dtype))
    if "b_q" in params:
        q = q + params["b_q"].astype(dtype)
        k = k + params["b_k"].astype(dtype)
        v = v + params["b_v"].astype(dtype)
    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    q = apply_rope_wrap(q, positions, rope_args)
    k = apply_rope_wrap(k, positions, rope_args)
    q = constrain(q, "bshd")
    k = constrain(k, "bshd")
    v = constrain(v, "bshd")
    return q, k, v


def apply_rope_wrap(x, positions, rope_args):
    from repro.models.layers import apply_rope

    return apply_rope(x, positions, theta=rope_args[0], scaling=rope_args[1])


def attention_out(params: dict, attn: jax.Array, *, dtype) -> jax.Array:
    out = jnp.einsum("bshk,hkd->bsd", attn.astype(dtype), params["w_o"].astype(dtype))
    return constrain(out, "btd")
