"""Flash attention (chunked online-softmax) with a hand-written VJP.

Pure-XLA implementation, v2 (§Perf iteration 3): the KV dimension is
scanned in chunks (memory O(S * kv_chunk)) while the query dimension stays
a VECTORIZED tensor axis — no q-chunk loop.  That keeps the query/sequence
axis intact for GSPMD, so attention shards over ANY mesh axis assigned to
S or heads; in particular architectures whose head count does not divide
the tensor-parallel degree (qwen2.5's 40 heads on TP=16) shard S instead
of replicating heads (16x compute/bytes saving measured in the dry-run —
see EXPERIMENTS.md §Perf).

Backward recomputes per-chunk probabilities from saved (q, k, v, out,
logsumexp) — flash's standard memory/compute trade, with the correct tanh'
factor for gemma2-style logit soft-capping.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def _kv_chunks(x: jax.Array, n: int, c: int) -> jax.Array:
    """(B, S, KV, D) -> (n, B, c, KV, D)."""
    b, s, kv, d = x.shape
    return x.reshape(b, n, c, kv, d).swapaxes(0, 1)


def _logits(qg, kc, scale, cap):
    """qg: (B,S,KV,G,D), kc: (B,Ck,KV,D) -> fp32 (B,KV,G,S,Ck)."""
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, kc).astype(jnp.float32) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    return s


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _flash(q, k, v, cap, kv_chunk, window):
    out, _ = _flash_fwd_impl(q, k, v, cap, kv_chunk, window)
    return out


def _flash_fwd_impl(q, k, v, cap, kv_chunk, window=None):
    b, s, h, d = q.shape
    kvh = k.shape[2]
    dv = v.shape[-1]
    g = h // kvh
    scale = d**-0.5
    nk = s // kv_chunk
    qg = q.reshape(b, s, kvh, g, d)
    ks = _kv_chunks(k, nk, kv_chunk)
    vs = _kv_chunks(v, nk, kv_chunk)
    q_pos = jnp.arange(s)

    def step(carry, inp):
        m, l, acc = carry
        ki, kc, vc = inp
        sij = _logits(qg, kc, scale, cap)  # (B,KV,G,S,Ck)
        k_abs = ki * kv_chunk + jnp.arange(kv_chunk)
        mask = q_pos[:, None] >= k_abs[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_abs[None, :] < window
        sij = jnp.where(mask, sij, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sij, axis=-1))
        p = jnp.exp(sij - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vc.dtype), vc)
        acc_new = acc * corr[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, s, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (jnp.arange(nk), ks, vs))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    lse = m + jnp.log(jnp.maximum(l, 1e-30))  # (B,KV,G,S)
    out = o.transpose(0, 3, 1, 2, 4).reshape(b, s, h, dv).astype(v.dtype)
    return out, lse


def _flash_fwd(q, k, v, cap, kv_chunk, window):
    out, lse = _flash_fwd_impl(q, k, v, cap, kv_chunk, window)
    return out, (q, k, v, out, lse)


def _flash_bwd(cap, kv_chunk, window, res, dout):
    q, k, v, out, lse = res
    b, s, h, d = q.shape
    kvh = k.shape[2]
    dv = v.shape[-1]
    g = h // kvh
    scale = d**-0.5
    nk = s // kv_chunk

    qg = q.reshape(b, s, kvh, g, d)
    dog = dout.reshape(b, s, kvh, g, dv)
    # D = rowsum(dO * O) per query: (B,KV,G,S)
    dvec = jnp.sum(
        (dout * out).astype(jnp.float32).reshape(b, s, kvh, g, dv), axis=-1
    ).transpose(0, 2, 3, 1)
    ks = _kv_chunks(k, nk, kv_chunk)
    vs = _kv_chunks(v, nk, kv_chunk)
    q_pos = jnp.arange(s)

    def step(dq_acc, inp):
        ki, kc, vc = inp
        sij = _logits(qg, kc, scale, cap)
        k_abs = ki * kv_chunk + jnp.arange(kv_chunk)
        mask = q_pos[:, None] >= k_abs[None, :]
        if window is not None:
            mask &= q_pos[:, None] - k_abs[None, :] < window
        p = jnp.where(mask, jnp.exp(jnp.where(mask, sij, NEG_INF) - lse[..., None]), 0.0)
        dvj = jnp.einsum("bkgqs,bqkgd->bskd", p, dog.astype(jnp.float32))
        dp = jnp.einsum("bqkgd,bskd->bkgqs", dog.astype(jnp.float32), vc.astype(jnp.float32))
        ds = p * (dp - dvec[..., None])
        if cap is not None:
            ds = ds * (1.0 - jnp.square(sij / cap))
        dq_c = jnp.einsum("bkgqs,bskd->bqkgd", ds, kc.astype(jnp.float32)) * scale
        dkj = jnp.einsum("bkgqs,bqkgd->bskd", ds, qg.astype(jnp.float32)) * scale
        return dq_acc + dq_c, (dkj, dvj)

    dq0 = jnp.zeros((b, s, kvh, g, d), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0, (jnp.arange(nk), ks, vs))
    dk = dks.swapaxes(0, 1).reshape(b, s, kvh, d).astype(k.dtype)
    dv_ = dvs.swapaxes(0, 1).reshape(b, s, kvh, dv).astype(v.dtype)
    return dq.reshape(b, s, h, d).astype(q.dtype), dk, dv_


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    logit_cap: Optional[float] = None,
    window: Optional[int] = None,
    kv_chunk: int = 512,
    q_chunk: Optional[int] = None,  # kept for API compat; unused in v2
) -> jax.Array:
    """Causal flash attention (optionally sliding-window masked).

    q: (B,S,H,D); k/v: (B,S,KV,D).  S must be divisible by kv_chunk
    (shrunk automatically when S is small).
    """
    s = q.shape[1]
    kv_chunk = min(kv_chunk, s)
    assert s % kv_chunk == 0, (s, kv_chunk)
    return _flash(q, k, v, logit_cap, kv_chunk, window)
