"""Token-choice top-k Mixture of Experts with shared experts.

Dispatch is sort-free: token->slot assignment is computed with a stable
argsort over expert ids (the standard dropping implementation), then experts
run as one batched einsum over an (E, C, D) tensor.  Tokens beyond an
expert's capacity are dropped (their combine weight contribution is zero),
matching capacity-factor semantics of Switch/DeepSeek training.

Sharding intent: the expert dimension E lives on the "model" mesh axis
(expert parallelism); the token dimension stays on ("pod", "data").  XLA
inserts the dispatch all-to-all from the scatter/gather pair.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import MoEConfig
from repro.models.layers import activation
from repro.parallel.act_sharding import constrain


def moe_init(key: jax.Array, d_model: int, cfg: MoEConfig) -> dict:
    ks = jax.random.split(key, 6)
    e, f = cfg.num_experts, cfg.expert_d_ff
    sc_in, sc_out = d_model**-0.5, f**-0.5
    params = {
        "router": jax.random.normal(ks[0], (d_model, e), jnp.float32) * sc_in,
        "w_gate": jax.random.normal(ks[1], (e, d_model, f), jnp.float32) * sc_in,
        "w_up": jax.random.normal(ks[2], (e, d_model, f), jnp.float32) * sc_in,
        "w_down": jax.random.normal(ks[3], (e, f, d_model), jnp.float32) * sc_out,
    }
    if cfg.num_shared_experts > 0:
        fs = f * cfg.num_shared_experts
        params["shared"] = {
            "w_gate": jax.random.normal(ks[4], (d_model, fs), jnp.float32) * sc_in,
            "w_up": jax.random.normal(ks[5], (d_model, fs), jnp.float32) * sc_in,
            "w_down": jax.random.normal(
                jax.random.fold_in(ks[5], 1), (fs, d_model), jnp.float32
            )
            * fs**-0.5,
        }
    return params


def router_probs(params: dict, x: jax.Array, cfg: MoEConfig) -> jax.Array:
    """Softmax router over experts; fp32.  x: (..., D) -> (..., E)."""
    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    return jax.nn.softmax(logits, axis=-1)


def capacity(num_tokens: int, cfg: MoEConfig) -> int:
    c = int(num_tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    c = max(c, cfg.top_k)
    if c >= 128:  # round up for capacity-axis shardability
        c = -(-c // 128) * 128
    return c


def moe_mlp(
    params: dict,
    x: jax.Array,
    cfg: MoEConfig,
    *,
    act: str,
    dtype,
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    cap = capacity(t, cfg)
    xf = x.reshape(t, d)

    probs = router_probs(params, x, cfg).reshape(t, e)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # ---- slot assignment (dropping) ---------------------------------------
    flat_e = top_e.reshape(t * k)
    order = jnp.argsort(flat_e, stable=True)  # group (token,choice) by expert
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(t * k) - starts[sorted_e]
    keep = pos_in_expert < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_expert, e * cap)  # drop -> OOB
    src_token = order // k

    # dispatch: (E*C, D); OOB writes fall off the end (mode="drop")
    gathered_tokens = constrain(xf[src_token], "td")
    dispatched = jnp.zeros((e * cap, d), x.dtype).at[slot].set(
        gathered_tokens, mode="drop"
    )
    de = constrain(dispatched.reshape(e, cap, d).astype(dtype), "ecd")

    # ---- expert computation ------------------------------------------------
    gate = jnp.einsum("ecd,edf->ecf", de, params["w_gate"].astype(dtype))
    up = jnp.einsum("ecd,edf->ecf", de, params["w_up"].astype(dtype))
    hidden = constrain(activation(act)(gate) * up, "ecd")
    expert_out = jnp.einsum("ecf,efd->ecd", hidden, params["w_down"].astype(dtype))
    expert_out = constrain(expert_out, "ecd").reshape(e * cap, d)

    # ---- combine -----------------------------------------------------------
    gathered = constrain(
        jnp.where(
            keep[:, None],
            expert_out.at[slot, :].get(mode="fill", fill_value=0.0),
            0.0,
        ),
        "td",
    )
    weight = top_p.reshape(t * k)[order][:, None].astype(x.dtype)
    combined = jnp.zeros((t, d), x.dtype).at[src_token].add(gathered * weight)
    out = constrain(combined.reshape(b, s, d), "btd")

    # ---- shared experts ----------------------------------------------------
    if "shared" in params:
        sh = params["shared"]
        xc = x.astype(dtype)
        g = xc @ sh["w_gate"].astype(dtype)
        u = xc @ sh["w_up"].astype(dtype)
        out = out + (activation(act)(g) * u) @ sh["w_down"].astype(dtype)

    # ---- load-balancing aux loss (Switch-style) ----------------------------
    # scatter-add histogram instead of a (T*k, E) one-hot — O(T*k) memory
    counts_f = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0)
    density = counts_f / (t * k)
    mean_probs = jnp.mean(probs, axis=0)
    aux = cfg.aux_loss_weight * e * jnp.sum(density * mean_probs)
    return out, aux


def moe_mlp_decode(
    params: dict,
    x: jax.Array,
    cfg: MoEConfig,
    *,
    act: str,
    dtype,
) -> Tuple[jax.Array, jax.Array]:
    """Per-token MoE for the decode path: x (B, S, D) -> (out, 0.0).

    The batched `moe_mlp` routes every token of the flattened batch
    through one global stable argsort + scatter-add, which couples rows
    two ways: tokens compete for expert capacity slots (drops depend on
    batch neighbours), and a token's k expert contributions are summed in
    slot order, so even without drops the float summation *order* — and
    therefore the output at the ULP level — depends on what the other
    rows routed.  At decode time that breaks the serving invariant that a
    request's logits are independent of which requests share the batch,
    and it breaks speculative decoding outright: accepted prefixes
    desynchronise rows, changing neighbours' hidden states and flipping
    argmaxes.  Here each token gathers its own top-k expert weights and
    sums contributions in top-k order — deterministic, row-independent,
    and drop-free (capacity is a training-throughput concession that has
    no business dropping tokens at inference).  Decode batches are tiny,
    so the per-token weight gather is cheap."""
    b, s, d = x.shape
    t = b * s
    k = cfg.top_k
    xf = x.reshape(t, d)

    probs = router_probs(params, x, cfg).reshape(t, cfg.num_experts)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    wg = params["w_gate"].astype(dtype)[top_e]  # (T, k, D, F)
    wu = params["w_up"].astype(dtype)[top_e]
    wd = params["w_down"].astype(dtype)[top_e]  # (T, k, F, D)
    xe = xf.astype(dtype)
    gate = jnp.einsum("td,tkdf->tkf", xe, wg)
    up = jnp.einsum("td,tkdf->tkf", xe, wu)
    hidden = activation(act)(gate) * up
    eo = jnp.einsum("tkf,tkfd->tkd", hidden, wd)
    out = jnp.einsum("tkd,tk->td", eo, top_p.astype(dtype))

    if "shared" in params:
        sh = params["shared"]
        g = xe @ sh["w_gate"].astype(dtype)
        u = xe @ sh["w_up"].astype(dtype)
        out = out + (activation(act)(g) * u) @ sh["w_down"].astype(dtype)
    return out.reshape(b, s, d).astype(x.dtype), jnp.zeros((), jnp.float32)


# ==========================================================================
# Expert-parallel MoE via shard_map (the production path)
#
# Tokens are sharded over (pod, data) and replicated over "model"; experts
# are sharded over "model".  Every device therefore already holds the tokens
# of its data shard and the weights of its expert shard: dispatch is local,
# and the only communication is one (B,S,D) psum over "model" to combine
# expert outputs — the Megatron-style MoE schedule.  This replaces the
# global-argsort dispatch (which SPMD cannot shard) whenever a mesh is
# active; the pure-jnp path above remains the single-device reference.
# ==========================================================================
def _moe_local(params_local, x_loc, cfg: MoEConfig, *, act: str, dtype, e_loc: int, j):
    """Per-device body.  x_loc: (B_loc, S, D); expert weights: (e_loc, D, F)."""
    b, s, d = x_loc.shape
    t = b * s
    e, k = cfg.num_experts, cfg.top_k
    xf = x_loc.reshape(t, d)

    logits = xf.astype(jnp.float32) @ params_local["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    cap = capacity(t, cfg)
    flat_e = top_e.reshape(t * k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_expert = jnp.arange(t * k) - starts[sorted_e]
    local_id = sorted_e - j * e_loc
    mine = (local_id >= 0) & (local_id < e_loc) & (pos_in_expert < cap)
    slot = jnp.where(mine, local_id * cap + pos_in_expert, e_loc * cap)
    src_token = order // k

    dispatched = jnp.zeros((e_loc * cap, d), x_loc.dtype).at[slot].set(
        xf[src_token], mode="drop"
    )
    de = dispatched.reshape(e_loc, cap, d).astype(dtype)
    # ZeRO-3: expert weights arrive D-sharded over "data"; gather per use in
    # the compute dtype (half the wire of fp32), freeing 1/dp of the weight
    # residency.  The transpose of the gather is the reduce-scatter that
    # keeps gradient memory sharded too.
    wg = jax.lax.all_gather(params_local["w_gate"].astype(dtype), "data", axis=1, tiled=True)
    wu = jax.lax.all_gather(params_local["w_up"].astype(dtype), "data", axis=1, tiled=True)
    wd = jax.lax.all_gather(params_local["w_down"].astype(dtype), "data", axis=2, tiled=True)
    gate = jnp.einsum("ecd,edf->ecf", de, wg)
    up = jnp.einsum("ecd,edf->ecf", de, wu)
    hidden = activation(act)(gate) * up
    expert_out = jnp.einsum("ecf,efd->ecd", hidden, wd).reshape(e_loc * cap, d)

    gathered = jnp.where(
        mine[:, None], expert_out.at[slot, :].get(mode="fill", fill_value=0.0), 0.0
    )
    weight = top_p.reshape(t * k)[order][:, None].astype(x_loc.dtype)
    partial = jnp.zeros((t, d), x_loc.dtype).at[src_token].add(gathered * weight)
    out = jax.lax.psum(partial, "model").reshape(b, s, d)

    counts_f = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0)
    density = counts_f / (t * k)
    mean_probs = jnp.mean(probs, axis=0)
    aux = cfg.aux_loss_weight * e * jnp.sum(density * mean_probs)
    return out, aux


def moe_mlp_expert_parallel(params: dict, x: jax.Array, cfg: MoEConfig, *, act: str, dtype, mesh):
    """shard_map'd expert-parallel MoE.  Falls back to moe_mlp when the
    model axis does not divide the expert count."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    tp = mesh.shape.get("model", 1)
    if tp == 1 or cfg.num_experts % tp != 0:
        out, aux = moe_mlp(params, x, cfg, act=act, dtype=dtype)
        return out, aux
    e_loc = cfg.num_experts // tp
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)

    routed = {
        "router": params["router"],
        "w_gate": params["w_gate"],
        "w_up": params["w_up"],
        "w_down": params["w_down"],
    }
    in_specs = (
        {
            "router": P(None, None),
            "w_gate": P("model", "data", None),
            "w_up": P("model", "data", None),
            "w_down": P("model", None, "data"),
        },
        P(batch_axes, None, None),
    )
    out_specs = (P(batch_axes, None, None), P())

    def body(pl, x_loc):
        j = jax.lax.axis_index("model")
        out, aux = _moe_local(pl, x_loc, cfg, act=act, dtype=dtype, e_loc=e_loc, j=j)
        # aux identical across model shards after psum-free local calc:
        # average across batch shards for a global estimate
        aux = jax.lax.pmean(aux, batch_axes) if batch_axes else aux
        aux = jax.lax.pmean(aux, "model")
        return out, aux

    out, aux = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )(routed, x)

    # shared experts: plain tensor-parallel dense path outside the shard_map
    if "shared" in params:
        sh = params["shared"]
        xc = x.astype(dtype)
        g = xc @ sh["w_gate"].astype(dtype)
        u = xc @ sh["w_up"].astype(dtype)
        out = out + (activation(act)(g) * u) @ sh["w_down"].astype(dtype)
    return out, aux
