"""Model definitions for the 10 assigned architectures.

A single pattern-block transformer (`transformer.py`) covers every family:
mixers (global/local attention, MLA, RG-LRU, RWKV6) and MLPs (dense gated,
MoE, RWKV channel-mix) are selected per pattern-unit from the ModelConfig.
"""

from repro.models.config import (
    MLAConfig,
    MoEConfig,
    ModelConfig,
    RecurrentConfig,
)
from repro.models.transformer import (
    Transformer,
    init_params,
    param_specs,
)

__all__ = [
    "MLAConfig",
    "MoEConfig",
    "ModelConfig",
    "RecurrentConfig",
    "Transformer",
    "init_params",
    "param_specs",
]
