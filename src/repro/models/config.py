"""Model configuration dataclasses.

Every assigned architecture is expressed as a ModelConfig.  The transformer is
built from a repeating *pattern* of units (e.g. 5 local-attention layers
followed by 1 global-attention layer for gemma3); the model scans over
`num_layers // len(pattern)` stacked pattern-blocks and applies the remainder
`num_layers % len(pattern)` units unstacked.  This keeps the HLO small (one
block body) without lax.switch branching, so `cost_analysis()` FLOPs are exact.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Mixer kinds usable inside a pattern.
GLOBAL_ATTN = "global_attn"
LOCAL_ATTN = "local_attn"
MLA_ATTN = "mla_attn"
RGLRU = "rglru"
RWKV6 = "rwkv6"

MIXER_KINDS = (GLOBAL_ATTN, LOCAL_ATTN, MLA_ATTN, RGLRU, RWKV6)

# MLP kinds.
DENSE_MLP = "dense"
MOE_MLP = "moe"
RWKV_CHANNEL_MIX = "rwkv_cmix"

MLP_KINDS = (DENSE_MLP, MOE_MLP, RWKV_CHANNEL_MIX)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2)."""

    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Token-choice top-k mixture of experts with shared experts."""

    num_experts: int = 64
    num_shared_experts: int = 2
    top_k: int = 6
    capacity_factor: float = 1.25
    # d_ff of each routed expert (shared experts use the same width scaled by
    # num_shared_experts, matching DeepSeek's layout).
    expert_d_ff: int = 1408
    router_noise: float = 0.0
    aux_loss_weight: float = 0.001


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:
    """RG-LRU (Griffin/RecurrentGemma) and RWKV6 hyperparameters."""

    lru_width: Optional[int] = None  # defaults to d_model when None
    conv_width: int = 4
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_gate_lora: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # Repeating pattern of (mixer, mlp) units; cycled to cover num_layers.
    pattern: Tuple[Tuple[str, str], ...] = ((GLOBAL_ATTN, DENSE_MLP),)

    # Attention details.
    attn_bias: bool = False  # qwen2.5-style QKV bias
    attn_logit_softcap: Optional[float] = None  # gemma2
    final_logit_softcap: Optional[float] = None  # gemma2
    window: Optional[int] = None  # sliding window for local_attn units
    rope_theta: float = 10_000.0
    rope_scaling: float = 1.0

    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    recurrent: Optional[RecurrentConfig] = None

    # Modality stubs.  num_prefix_embeds > 0 prepends precomputed embeddings
    # (ViT patches for VLM).  num_codebooks > 1 sums codebook embeddings and
    # emits one logit head per codebook (EnCodec tokens for audio).
    num_prefix_embeds: int = 0
    num_codebooks: int = 1

    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"  # silu | gelu
    # Scale token embeddings by sqrt(d_model) (gemma family convention).
    scale_embeddings: bool = False
    # Post-attention/post-mlp extra norms (gemma2/3 use sandwich norms).
    use_post_norms: bool = False
    # qk-norm (gemma3).
    use_qk_norm: bool = False

    # Compute dtype for matmuls; params are kept fp32.
    compute_dtype: str = "bfloat16"

    # Remat policy for training: "none" | "full" | "dots".
    remat: str = "full"

    # Kernel backend: "xla" (default, used for dry-run/compile) or
    # "pallas_interpret" (routes hot-spots through the Pallas kernels in
    # interpret mode; used by integration tests on CPU).
    kernel_backend: str = "xla"

    # ---- derived helpers -------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the vocab dimension of
        the embedding / logits shards over any mesh axis up to 256 — the
        standard production trick for odd tokenizer sizes (e.g. 92553).
        Logits over padded ids are masked to -inf in the loss/serving."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def lru_width(self) -> int:
        rec = self.recurrent or RecurrentConfig()
        return rec.lru_width or self.d_model

    def unit_kinds(self) -> Tuple[Tuple[str, str], ...]:
        """The full per-layer (mixer, mlp) list, pattern cycled."""
        reps = -(-self.num_layers // len(self.pattern))
        return (self.pattern * reps)[: self.num_layers]

    @property
    def n_blocks(self) -> int:
        return self.num_layers // len(self.pattern)

    @property
    def n_remainder(self) -> int:
        return self.num_layers % len(self.pattern)

    def validate(self) -> None:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name
        for mixer, mlp in self.pattern:
            assert mixer in MIXER_KINDS, mixer
            assert mlp in MLP_KINDS, mlp
            if mixer == MLA_ATTN:
                assert self.mla is not None
            if mlp == MOE_MLP:
                assert self.moe is not None
            if mixer in (RGLRU, RWKV6):
                assert self.recurrent is not None
        if any(m == LOCAL_ATTN for m, _ in self.pattern):
            assert self.window is not None, f"{self.name}: local attn needs window"

    def is_sub_quadratic(self) -> bool:
        """True when no pattern unit uses unbounded (global/MLA) attention."""
        return all(m in (LOCAL_ATTN, RGLRU, RWKV6) for m, _ in self.pattern)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}
