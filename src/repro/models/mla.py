"""Multi-head Latent Attention (DeepSeek-V2) — prefill/train and absorbed decode.

The KV cache stores only the compressed latent ``c_kv`` (rank 512) plus the
shared rope key (64 dims) per token — the memory win that defines MLA.  Decode
uses the *absorbed* formulation: query projected through W_UK into latent
space so attention runs directly against the compressed cache, and the
attention output is expanded through W_UV afterwards.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import NEG_INF, full_attention
from repro.models.flash import flash_attention
from repro.models.config import MLAConfig
from repro.models.layers import apply_rope
from repro.parallel.act_sharding import constrain


def mla_init(key: jax.Array, d_model: int, num_heads: int, cfg: MLAConfig) -> dict:
    ks = jax.random.split(key, 6)
    r = cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    sc = d_model**-0.5
    scr = r**-0.5
    return {
        # queries (V2-Lite has no q-lora): d_model -> heads x (nope + rope)
        "w_q": jax.random.normal(ks[0], (d_model, num_heads, dn + dr), jnp.float32) * sc,
        # compressed kv latent
        "w_dkv": jax.random.normal(ks[1], (d_model, r), jnp.float32) * sc,
        "kv_norm": {"scale": jnp.zeros((r,), jnp.float32)},
        # up-projections from the latent
        "w_uk": jax.random.normal(ks[2], (r, num_heads, dn), jnp.float32) * scr,
        "w_uv": jax.random.normal(ks[3], (r, num_heads, dv), jnp.float32) * scr,
        # shared (per-token, head-agnostic) rope key
        "w_kr": jax.random.normal(ks[4], (d_model, dr), jnp.float32) * sc,
        "w_o": jax.random.normal(ks[5], (num_heads, dv, d_model), jnp.float32)
        * (num_heads * dv) ** -0.5,
    }


def mla_project(
    params: dict,
    x: jax.Array,
    cfg: MLAConfig,
    *,
    dtype,
    positions: jax.Array,
    rope_theta: float,
    rope_scaling: float,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Returns (q_nope, q_rope, c_kv, k_rope).

    q_nope: (B,S,H,dn)  q_rope: (B,S,H,dr)  c_kv: (B,S,r)  k_rope: (B,S,dr).
    """
    from repro.models.layers import rmsnorm

    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    xc = x.astype(dtype)
    q = constrain(jnp.einsum("bsd,dhk->bshk", xc, params["w_q"].astype(dtype)), "bshd")
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, theta=rope_theta, scaling=rope_scaling)
    c_kv = xc @ params["w_dkv"].astype(dtype)
    c_kv = rmsnorm(params["kv_norm"], c_kv)
    k_rope = xc @ params["w_kr"].astype(dtype)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, theta=rope_theta, scaling=rope_scaling)[
        :, :, 0, :
    ]
    return q_nope, q_rope, c_kv, k_rope


def mla_attention_train(
    params: dict,
    x: jax.Array,
    cfg: MLAConfig,
    *,
    dtype,
    positions: jax.Array,
    rope_theta: float,
    rope_scaling: float,
) -> jax.Array:
    """Training/prefill path: decompress K/V and run standard attention."""
    b, s, _ = x.shape
    h = params["w_uk"].shape[1]
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = mla_project(
        params, x, cfg, dtype=dtype, positions=positions,
        rope_theta=rope_theta, rope_scaling=rope_scaling,
    )
    k_nope = constrain(
        jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"].astype(dtype)), "bshd"
    )
    v = constrain(
        jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"].astype(dtype)), "bshd"
    )
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (b, s, h, dr))], axis=-1)
    if s <= 1024:
        attn = full_attention(q, k, v, causal=True)
    else:
        attn = flash_attention(q, k, v)
    out = jnp.einsum("bshk,hkd->bsd", attn.astype(dtype), params["w_o"].astype(dtype))
    return constrain(out, "btd")


def mla_decode(
    params: dict,
    x: jax.Array,
    cache_ckv: jax.Array,
    cache_krope: jax.Array,
    cfg: MLAConfig,
    *,
    dtype,
    lengths: jax.Array,
    rope_theta: float,
    rope_scaling: float,
) -> jax.Array:
    """Absorbed decode.  x: (B, 1, D); caches: (B, S, r) and (B, S, dr).

    The new token's (c_kv, k_rope) must already be written into the caches at
    position ``lengths - 1`` by the caller.
    """
    b = x.shape[0]
    positions = (lengths - 1)[:, None]  # (B, 1)
    q_nope, q_rope, _, _ = mla_project(
        params, x, cfg, dtype=dtype, positions=positions,
        rope_theta=rope_theta, rope_scaling=rope_scaling,
    )
    # absorb W_UK into the query: (B,1,H,dn) @ (r,H,dn) -> (B,1,H,r)
    q_latent = jnp.einsum("bqhk,rhk->bqhr", q_nope, params["w_uk"].astype(dtype))
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    logits = (
        jnp.einsum("bqhr,bsr->bhqs", q_latent, cache_ckv)
        + jnp.einsum("bqhk,bsk->bhqs", q_rope, cache_krope)
    ).astype(jnp.float32) * scale
    s = cache_ckv.shape[1]
    valid = jnp.arange(s)[None, :] < lengths[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(dtype)
    # attention in latent space, then expand through W_UV
    o_latent = jnp.einsum("bhqs,bsr->bqhr", probs, cache_ckv)
    o = jnp.einsum("bqhr,rhk->bqhk", o_latent, params["w_uv"].astype(dtype))
    return jnp.einsum("bqhk,hkd->bqd", o, params["w_o"].astype(dtype))


def mla_chunk_decode(
    params: dict,
    x: jax.Array,
    cache_ckv: jax.Array,
    cache_krope: jax.Array,
    cfg: MLAConfig,
    *,
    dtype,
    positions: jax.Array,
    rope_theta: float,
    rope_scaling: float,
) -> jax.Array:
    """Absorbed attention for a chunk of C prompt tokens (the multi-query
    generalization of `mla_decode` — chunked prefill's MLA op).

    x: (B, C, D); caches: (B, S, r) / (B, S, dr) with the chunk's own
    latents already written at ``positions``; positions: (B, C) absolute.
    Query i attends causally to cache positions <= positions[:, i].
    """
    q_nope, q_rope, _, _ = mla_project(
        params, x, cfg, dtype=dtype, positions=positions,
        rope_theta=rope_theta, rope_scaling=rope_scaling,
    )
    q_latent = jnp.einsum("bqhk,rhk->bqhr", q_nope, params["w_uk"].astype(dtype))
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    logits = (
        jnp.einsum("bqhr,bsr->bhqs", q_latent, cache_ckv)
        + jnp.einsum("bqhk,bsk->bhqs", q_rope, cache_krope)
    ).astype(jnp.float32) * scale
    s = cache_ckv.shape[1]
    valid = jnp.arange(s)[None, None, :] <= positions[:, :, None]  # (B, C, S)
    logits = jnp.where(valid[:, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(dtype)
    o_latent = jnp.einsum("bhqs,bsr->bqhr", probs, cache_ckv)
    o = jnp.einsum("bqhr,rhk->bqhk", o_latent, params["w_uv"].astype(dtype))
    return jnp.einsum("bqhk,hkd->bqd", o, params["w_o"].astype(dtype))


def mla_new_token_latents(
    params: dict,
    x: jax.Array,
    cfg: MLAConfig,
    *,
    dtype,
    positions: jax.Array,
    rope_theta: float,
    rope_scaling: float,
) -> Tuple[jax.Array, jax.Array]:
    """(c_kv, k_rope) for new tokens — what gets appended to the cache."""
    _, _, c_kv, k_rope = mla_project(
        params, x, cfg, dtype=dtype, positions=positions,
        rope_theta=rope_theta, rope_scaling=rope_scaling,
    )
    return c_kv, k_rope
