"""Shared layers: norms, rotary embeddings, activations, dense MLPs.

All parameters are plain pytrees (nested dicts of jnp arrays).  Params stay
fp32; matmul inputs are cast to the config compute dtype at the call site.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.act_sharding import constrain


def _cast(x: jax.Array, dtype) -> jax.Array:
    return x.astype(dtype) if x.dtype != dtype else x


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rmsnorm_init(dim: int) -> dict:
    return {"scale": jnp.zeros((dim,), jnp.float32)}


def rmsnorm(params: dict, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with (1 + scale) parameterization (gemma convention).

    Normalization happens in fp32 regardless of input dtype.
    """
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    out = normed * (1.0 + params["scale"])
    return out.astype(dtype)


# --------------------------------------------------------------------------
# Rotary position embeddings
# --------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)


def apply_rope(
    x: jax.Array,
    positions: jax.Array,
    *,
    theta: float = 10_000.0,
    scaling: float = 1.0,
) -> jax.Array:
    """Rotate the last dim of ``x``.

    x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq).
    Uses the split-halves convention (llama/gemma).
    """
    head_dim = x.shape[-1]
    inv_freq = rope_frequencies(head_dim, theta)
    pos = positions.astype(jnp.float32) / scaling
    angles = pos[..., None] * inv_freq  # (..., seq, head_dim//2)
    angles = angles[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    rotated = jnp.concatenate((x1 * cos - x2 * sin, x2 * cos + x1 * sin), axis=-1)
    return rotated.astype(x.dtype)


# --------------------------------------------------------------------------
# Activations
# --------------------------------------------------------------------------
def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        # gemma uses tanh-approximated gelu
        return lambda x: jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu
    raise ValueError(f"unknown activation {name!r}")


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Logit soft-capping: cap * tanh(x / cap).  No-op when cap is None."""
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# --------------------------------------------------------------------------
# Dense (gated) MLP
# --------------------------------------------------------------------------
def dense_mlp_init(key: jax.Array, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d_model**-0.5
    scale_out = d_ff**-0.5
    return {
        "w_gate": jax.random.normal(k1, (d_model, d_ff), jnp.float32) * scale_in,
        "w_up": jax.random.normal(k2, (d_model, d_ff), jnp.float32) * scale_in,
        "w_down": jax.random.normal(k3, (d_ff, d_model), jnp.float32) * scale_out,
    }


def dense_mlp(params: dict, x: jax.Array, *, act: str, dtype) -> jax.Array:
    """SwiGLU / GeGLU MLP.  x: (..., d_model)."""
    xc = _cast(x, dtype)
    gate = xc @ _cast(params["w_gate"], dtype)
    up = xc @ _cast(params["w_up"], dtype)
    hidden = constrain(activation(act)(gate) * up, "bsf")
    return constrain(hidden @ _cast(params["w_down"], dtype), "btd")


# --------------------------------------------------------------------------
# RWKV channel mix (the FFN used by rwkv6 blocks)
# --------------------------------------------------------------------------
def rwkv_cmix_init(key: jax.Array, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_r": jnp.full((d_model,), 0.5, jnp.float32),
        "w_k": jax.random.normal(k1, (d_model, d_ff), jnp.float32) * d_model**-0.5,
        "w_v": jax.random.normal(k2, (d_ff, d_model), jnp.float32) * d_ff**-0.5,
        "w_r": jax.random.normal(k3, (d_model, d_model), jnp.float32) * d_model**-0.5,
    }


def token_shift(x: jax.Array, last: Optional[jax.Array] = None) -> jax.Array:
    """RWKV token shift: x_{t-1} (zeros / `last` carry for t=0).

    x: (B, S, D).  `last`: (B, D) carry from the previous chunk, or None.
    """
    if last is None:
        last = jnp.zeros_like(x[:, :1, :])
    else:
        last = last[:, None, :]
    return jnp.concatenate([last.astype(x.dtype), x[:, :-1, :]], axis=1)


def rwkv_cmix(
    params: dict,
    x: jax.Array,
    *,
    dtype,
    shifted: Optional[jax.Array] = None,
) -> jax.Array:
    """RWKV channel mix.  x: (B, S, D); shifted defaults to token_shift(x)."""
    if shifted is None:
        shifted = token_shift(x)
    xc, sc = _cast(x, dtype), _cast(shifted, dtype)
    mu_k, mu_r = _cast(params["mu_k"], dtype), _cast(params["mu_r"], dtype)
    xk = xc + mu_k * (sc - xc)
    xr = xc + mu_r * (sc - xc)
    k = constrain(jnp.square(jax.nn.relu(xk @ _cast(params["w_k"], dtype))), "bsf")
    r = jax.nn.sigmoid(xr @ _cast(params["w_r"], dtype))
    return constrain(r * (k @ _cast(params["w_v"], dtype)), "btd")


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------
def embed_init(key: jax.Array, vocab: int, d_model: int, num_codebooks: int = 1) -> dict:
    shape = (vocab, d_model) if num_codebooks == 1 else (num_codebooks, vocab, d_model)
    return {"table": jax.random.normal(key, shape, jnp.float32) * d_model**-0.5}


def embed_lookup(params: dict, tokens: jax.Array, *, dtype, scale: bool) -> jax.Array:
    """tokens: (B, S) int32 or (B, S, C) for multi-codebook."""
    table = params["table"]
    if table.ndim == 2:
        out = jnp.take(table, tokens, axis=0)
    else:
        # (C, V, D) table, (B, S, C) tokens -> sum over codebooks.
        per_cb = jax.vmap(
            lambda tab, tok: jnp.take(tab, tok, axis=0), in_axes=(0, 2), out_axes=0
        )(table, tokens)
        out = jnp.sum(per_cb, axis=0)
    out = out.astype(dtype)
    if scale:
        d_model = table.shape[-1]
        out = out * jnp.asarray(d_model**0.5, dtype)
    return out


def unembed(
    params: dict,
    x: jax.Array,
    *,
    dtype,
    num_codebooks: int = 1,
    head: Optional[dict] = None,
) -> jax.Array:
    """Project hidden states to logits.

    Tied embeddings: uses embed table transpose.  Multi-codebook: one head per
    codebook, output (..., C, V).
    """
    xc = _cast(x, dtype)
    if head is not None:
        w = head["w"]
        if num_codebooks == 1:
            return xc @ _cast(w, dtype)
        return jnp.einsum("...d,cdv->...cv", xc, _cast(w, dtype))
    table = params["table"]
    if table.ndim == 2:
        return xc @ _cast(table, dtype).T
    return jnp.einsum("...d,cvd->...cv", xc, _cast(table, dtype))


def lm_head_init(key: jax.Array, vocab: int, d_model: int, num_codebooks: int = 1) -> dict:
    shape = (d_model, vocab) if num_codebooks == 1 else (num_codebooks, d_model, vocab)
    return {"w": jax.random.normal(key, shape, jnp.float32) * d_model**-0.5}
