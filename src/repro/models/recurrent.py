"""Recurrent mixers: RG-LRU (Griffin / RecurrentGemma) and RWKV6 (Finch).

Both are O(S) in sequence length with O(1) decode state — they carry the
``long_500k`` cells that full attention cannot serve.

RG-LRU trains with ``jax.lax.associative_scan`` (parallel prefix over the
linear recurrence h_t = a_t * h_{t-1} + b_t).  RWKV6 trains with the chunked
formulation (intra-chunk attention-like matrix + inter-chunk state), scanned
over chunks; ratios of cumulative decays are computed in log space.  A Pallas
kernel (kernels/wkv6.py) implements the same chunk math for TPU.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import RecurrentConfig
from repro.models.layers import token_shift
from repro.parallel.act_sharding import constrain

_RGLRU_C = 8.0  # the fixed exponent scale from the Griffin paper


# ==========================================================================
# RG-LRU
# ==========================================================================
def rglru_init(key: jax.Array, d_model: int, cfg: RecurrentConfig, lru_width: int) -> dict:
    ks = jax.random.split(key, 7)
    w = lru_width
    sc = d_model**-0.5
    scw = w**-0.5
    # Λ init so that a^c spans (0.9, 0.999) roughly — standard Griffin init.
    lam = jax.random.uniform(ks[0], (w,), jnp.float32, 0.4, 0.8)
    return {
        "w_y": jax.random.normal(ks[1], (d_model, w), jnp.float32) * sc,  # gate branch
        "w_x": jax.random.normal(ks[2], (d_model, w), jnp.float32) * sc,  # main branch
        "conv_w": jax.random.normal(ks[3], (cfg.conv_width, w), jnp.float32) * 0.1,
        "conv_b": jnp.zeros((w,), jnp.float32),
        "w_a": jax.random.normal(ks[4], (w, w), jnp.float32) * scw,  # recurrence gate
        "w_i": jax.random.normal(ks[5], (w, w), jnp.float32) * scw,  # input gate
        "lambda": lam,
        "w_out": jax.random.normal(ks[6], (w, d_model), jnp.float32) * scw,
    }


def causal_conv1d(
    x: jax.Array, w: jax.Array, b: jax.Array, *, carry: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal temporal conv.  x: (B,S,W); w: (K,W); carry: (B,K-1,W).

    Returns (out, new_carry) where new_carry holds the last K-1 inputs.
    """
    k = w.shape[0]
    if carry is None:
        carry = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([carry.astype(x.dtype), x], axis=1)  # (B, S+K-1, W)
    s = x.shape[1]
    out = jnp.zeros_like(x)
    for tap in range(k):
        out = out + xp[:, tap : tap + s, :] * w[tap].astype(x.dtype)
    out = out + b.astype(x.dtype)
    new_carry = xp[:, -(k - 1) :, :]
    return out, new_carry


def _rglru_gates(params: dict, xw: jax.Array, dtype):
    """Per-token log-decay and gated input.  xw: (B,S,W) post-conv."""
    r = jax.nn.sigmoid(xw @ params["w_a"].astype(dtype)).astype(jnp.float32)
    i = jax.nn.sigmoid(xw @ params["w_i"].astype(dtype)).astype(jnp.float32)
    log_a = -_RGLRU_C * jax.nn.softplus(params["lambda"]) * r  # (B,S,W) fp32
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) via expm1 for stability near a ~ 1
    mult = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    b = mult * (i * xw.astype(jnp.float32))
    return a, b


def rglru_scan(
    params: dict, xw: jax.Array, *, dtype, h_init: Optional[jax.Array] = None
) -> jax.Array:
    """Parallel RG-LRU over a full sequence.  xw: (B,S,W) -> (B,S,W).

    ``h_init`` (B,W) fp32 resumes the recurrence from a carried state
    (chunked prefill): folding ``a_0 * h_init`` into the first step's b
    term makes the associative scan compute h_t for the continued
    sequence exactly."""
    a, b = _rglru_gates(params, xw, dtype)
    if h_init is not None:
        b = b.at[:, 0].add(a[:, 0] * h_init.astype(jnp.float32))

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(xw.dtype)


def rglru_step(
    params: dict, xw: jax.Array, h_prev: jax.Array, *, dtype
) -> Tuple[jax.Array, jax.Array]:
    """Single decode step.  xw: (B,1,W); h_prev: (B,W) fp32."""
    a, b = _rglru_gates(params, xw, dtype)
    h = a[:, 0] * h_prev + b[:, 0]
    return h.astype(xw.dtype)[:, None, :], h


def rglru_block(
    params: dict,
    x: jax.Array,
    *,
    dtype,
    conv_carry: Optional[jax.Array] = None,
    h_prev: Optional[jax.Array] = None,
    decode: bool = False,
    valid_len: Optional[jax.Array] = None,
):
    """Full Griffin recurrent block.

    Train/prefill: returns (out, (conv_carry, h_last)).
    Decode: requires conv_carry + h_prev, returns (out, (conv_carry, h)).
    Chunked prefill: non-decode with ``h_prev`` resumes the recurrence;
    ``valid_len`` (B,) marks how many of the chunk's tokens are real —
    the returned carries are taken at position valid_len-1 so a padded
    final chunk leaves the same state as an exact-length prefill.
    """
    xc = x.astype(dtype)
    gate = constrain(jax.nn.gelu(xc @ params["w_y"].astype(dtype), approximate=True), "bsf")
    main_in = constrain(xc @ params["w_x"].astype(dtype), "bsf")
    pre_conv_carry = conv_carry
    main, new_conv_carry = causal_conv1d(
        main_in, params["conv_w"], params["conv_b"], carry=conv_carry
    )
    if decode:
        h_seq, h_last = rglru_step(params, main, h_prev, dtype=dtype)
    else:
        h_seq = rglru_scan(params, main, dtype=dtype, h_init=h_prev)
        h_last = h_seq[:, -1, :].astype(jnp.float32)
        if valid_len is not None:
            assert pre_conv_carry is not None, "valid_len needs a conv carry"
            h_last = jnp.take_along_axis(
                h_seq, (valid_len - 1)[:, None, None], axis=1
            )[:, 0].astype(jnp.float32)
            # conv carry = the last conv_width-1 *valid* inputs: position p
            # of the continued stream sits at index p - start + (K-1) of the
            # padded input, so positions valid_len-(K-1) .. valid_len-1 are
            # indices valid_len .. valid_len+K-2
            k = params["conv_w"].shape[0]
            xp = jnp.concatenate(
                [pre_conv_carry.astype(main_in.dtype), main_in], axis=1
            )
            idx = valid_len[:, None] + jnp.arange(k - 1)[None, :]
            new_conv_carry = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    out = constrain(
        (gate * h_seq.astype(dtype)) @ params["w_out"].astype(dtype), "btd"
    )
    return out, (new_conv_carry, h_last)


# ==========================================================================
# RWKV6 (Finch)
# ==========================================================================
def rwkv6_init(key: jax.Array, d_model: int, cfg: RecurrentConfig) -> dict:
    ks = jax.random.split(key, 10)
    d = d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    sc = d**-0.5
    return {
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_v": jnp.full((d,), 0.5, jnp.float32),
        "mu_w": jnp.full((d,), 0.5, jnp.float32),
        "mu_g": jnp.full((d,), 0.5, jnp.float32),
        "w_r": jax.random.normal(ks[0], (d, d), jnp.float32) * sc,
        "w_k": jax.random.normal(ks[1], (d, d), jnp.float32) * sc,
        "w_v": jax.random.normal(ks[2], (d, d), jnp.float32) * sc,
        "w_g": jax.random.normal(ks[3], (d, d), jnp.float32) * sc,
        "w_o": jax.random.normal(ks[4], (d, d), jnp.float32) * sc,
        # data-dependent decay: w = exp(-exp(w0 + tanh(x A) B))
        "decay_w0": jnp.full((d,), -6.0, jnp.float32) + jax.random.uniform(ks[5], (d,)) * 2.0,
        "decay_a": jax.random.normal(ks[6], (d, cfg.rwkv_decay_lora), jnp.float32) * sc,
        "decay_b": jax.random.normal(
            ks[7], (cfg.rwkv_decay_lora, d), jnp.float32
        ) * cfg.rwkv_decay_lora**-0.5,
        "bonus_u": jax.random.normal(ks[8], (h, hd), jnp.float32) * 0.1,
        # per-head output group-norm
        "gn_scale": jnp.ones((d,), jnp.float32),
        "gn_bias": jnp.zeros((d,), jnp.float32),
    }


def _rwkv6_projections(params: dict, x: jax.Array, *, dtype, shifted=None):
    """Token-shift mixing + projections.  x: (B,S,D)."""
    if shifted is None:
        shifted = token_shift(x)
    xc = x.astype(dtype)
    sc = shifted.astype(dtype)

    def mix(mu):
        # compute the lerp in the compute dtype: keeps cotangents (and the
        # per-layer tensor-parallel all-reduces) in bf16, not fp32
        return xc + mu.astype(dtype) * (sc - xc)

    r = constrain(mix(params["mu_r"]) @ params["w_r"].astype(dtype), "bsf")
    k = constrain(mix(params["mu_k"]) @ params["w_k"].astype(dtype), "bsf")
    v = constrain(mix(params["mu_v"]) @ params["w_v"].astype(dtype), "bsf")
    g = constrain(jax.nn.silu(mix(params["mu_g"]) @ params["w_g"].astype(dtype)), "bsf")
    xw = mix(params["mu_w"]).astype(jnp.float32)
    log_w = -jnp.exp(  # decay path stays fp32 (exp-of-exp sensitivity)
        params["decay_w0"]
        + jnp.tanh(xw @ params["decay_a"].astype(jnp.float32))
        @ params["decay_b"].astype(jnp.float32)
    )  # (B,S,D), <= 0
    return r, k, v, g, log_w


def _split_heads(x: jax.Array, head_dim: int) -> jax.Array:
    b, s, d = x.shape
    return x.reshape(b, s, d // head_dim, head_dim)


def wkv6_chunked(
    r: jax.Array,
    k: jax.Array,
    v: jax.Array,
    log_w: jax.Array,
    u: jax.Array,
    *,
    state: Optional[jax.Array] = None,
    chunk: int = 64,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked WKV6.  r,k,v,log_w: (B,S,H,K); u: (H,K).

    Returns (out (B,S,H,K) fp32, final state (B,H,K,K) fp32).
    state[b,h,i,j]: sum over past s of  prod(decay)_{s+1..t} k_s[i] v_s[j].
    """
    b, s, h, dk = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    f32 = jnp.float32
    rc = r.astype(f32).reshape(b, n, chunk, h, dk).transpose(1, 0, 3, 2, 4)
    kc = k.astype(f32).reshape(b, n, chunk, h, dk).transpose(1, 0, 3, 2, 4)
    vc = v.astype(f32).reshape(b, n, chunk, h, dk).transpose(1, 0, 3, 2, 4)
    lw = log_w.astype(f32).reshape(b, n, chunk, h, dk).transpose(1, 0, 3, 2, 4)
    # shapes now (n, B, H, C, K)

    if state is None:
        state = jnp.zeros((b, h, dk, dk), f32)

    uu = u.astype(f32)  # (H, K)

    def chunk_step(s_in, inputs):
        rc_, kc_, vc_, lw_ = inputs  # (B,H,C,K)
        cum = jnp.cumsum(lw_, axis=2)  # inclusive cumulative log decay
        cum_excl = cum - lw_  # exclusive: prod of decays before position i
        total = cum[:, :, -1:, :]  # (B,H,1,K)
        # inter-chunk: o_i += (r_i * exp(cum_excl_i)) @ S_in
        r_dec = rc_ * jnp.exp(cum_excl)
        o_inter = jnp.einsum("bhck,bhkv->bhcv", r_dec, s_in)
        # intra-chunk: M[i,s] = sum_c r_i,c k_s,c exp(cum_excl_i - cum_s)  (s<i)
        #              M[i,i] = sum_c r_i,c k_i,c u_c
        # exp(cum_excl_i - cum_s) factored as exp(cum_excl_i) * exp(-cum_s);
        # -cum_s >= 0 so clamp at 30 against fp32 overflow under extreme decay
        # (inactive for the chunk=64 default; standard chunked-WKV practice).
        k_dec = kc_ * jnp.exp(jnp.minimum(-cum, 30.0))
        m = jnp.einsum("bhck,bhsk->bhcs", r_dec, k_dec)
        idx = jnp.arange(rc_.shape[2])
        strict = idx[:, None] > idx[None, :]
        m = jnp.where(strict, m, 0.0)
        diag = jnp.einsum("bhck,hk,bhck->bhc", rc_, uu, kc_)
        o_intra = jnp.einsum("bhcs,bhsv->bhcv", m, vc_) + diag[..., None] * vc_
        # state update: S_out = diag(exp(total)) S_in + sum_s exp(total-cum_s) k_s^T v_s
        k_for_state = kc_ * jnp.exp(total - cum)
        s_out = jnp.exp(total).transpose(0, 1, 3, 2) * s_in + jnp.einsum(
            "bhsk,bhsv->bhkv", k_for_state, vc_
        )
        return s_out, o_inter + o_intra

    # checkpoint the chunk body: backward recomputes intra-chunk tensors
    # from (state, chunk inputs) instead of saving them per chunk
    final_state, outs = jax.lax.scan(
        jax.checkpoint(chunk_step), state, (rc, kc, vc, lw)
    )
    # (n, B, H, C, K) -> (B, S, H, K)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(b, s, h, dk)
    return out, final_state


def wkv6_step(
    r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array, u: jax.Array, state: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence.  r,k,v,log_w: (B,1,H,K); state: (B,H,K,K)."""
    f32 = jnp.float32
    r1, k1, v1, lw1 = (t.astype(f32)[:, 0] for t in (r, k, v, log_w))  # (B,H,K)
    kv = jnp.einsum("bhk,bhv->bhkv", k1, v1)
    out = jnp.einsum("bhk,bhkv->bhv", r1, state + u.astype(f32)[None, :, :, None] * kv)
    new_state = jnp.exp(lw1)[..., None] * state + kv
    return out[:, None], new_state


def rwkv6_block(
    params: dict,
    x: jax.Array,
    cfg: RecurrentConfig,
    *,
    dtype,
    norm_eps: float = 1e-5,
    state: Optional[jax.Array] = None,
    shift_carry: Optional[jax.Array] = None,
    decode: bool = False,
    chunk: int = 64,
    valid_len: Optional[jax.Array] = None,
):
    """Full RWKV6 time-mix block.  x: (B,S,D).

    Returns (out, (new_state, new_shift_carry)).

    ``valid_len`` (B,) — chunked prefill with a padded final chunk:
    positions >= valid_len are made state no-ops (k -> 0, log_w -> 0, so
    the wkv recurrence neither accumulates nor decays past the last real
    token) and the shift carry is taken at valid_len-1, leaving exactly
    the state an exact-length prefill would.
    """
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    shifted = None
    if decode or shift_carry is not None:
        shifted = token_shift(x, last=shift_carry)
    r, k, v, g, log_w = _rwkv6_projections(params, x, dtype=dtype, shifted=shifted)
    if valid_len is not None and not decode:
        vmask = jnp.arange(s)[None, :, None] < valid_len[:, None, None]
        k = jnp.where(vmask, k, 0)
        log_w = jnp.where(vmask, log_w, 0.0)
    rh, kh, vh, lwh = (_split_heads(t, hd) for t in (r, k, v, log_w))
    if decode:
        out_h, new_state = wkv6_step(rh, kh, vh, lwh, params["bonus_u"], state)
        out_h = out_h.reshape(b, 1, h, hd)
    else:
        out_h, new_state = wkv6_chunked(
            rh, kh, vh, lwh, params["bonus_u"], state=state, chunk=chunk
        )
    # per-head group norm
    mean = jnp.mean(out_h, axis=-1, keepdims=True)
    var = jnp.var(out_h, axis=-1, keepdims=True)
    normed = (out_h - mean) * jax.lax.rsqrt(var + norm_eps)
    flat = normed.reshape(b, -1, d).astype(dtype)
    flat = flat * params["gn_scale"].astype(dtype) + params["gn_bias"].astype(dtype)
    out = constrain((flat * g) @ params["w_o"].astype(dtype), "btd")
    if valid_len is not None and not decode:
        new_shift = jnp.take_along_axis(x, (valid_len - 1)[:, None, None], axis=1)[:, 0]
    else:
        new_shift = x[:, -1, :]
    return out, (new_state, new_shift)
