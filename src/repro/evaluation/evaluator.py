"""Candidate-kernel evaluator (paper §4.3, two-stage + timing).

Stage 1 (compile check): ``compile()`` + exec of the source in a fresh
namespace, then a jit trace against the task's input shapes.  This is the
TPU-stack analogue of an nvcc compile: Python syntax errors, missing
symbols, shape/dtype errors and Pallas BlockSpec violations all surface
here.

Stage 2 (functional test): 5 seeded inputs, compared against the pure-jnp
oracle with per-task tolerances — the paper's protocol verbatim.  Oracle
outputs are cached by ``(task, input_seed)`` so ``task.ref(...)`` runs once
per task/seed pair instead of once per candidate; with a ``cache_dir`` the
cache persists to disk and is shared across processes and re-runs.

Performance: median wall-clock of the jitted candidate over ``timing_runs``
repeats after warmup (the paper averages 100 GPU runs; the knob is
configurable and recorded).  ``timing_mode="simulated"`` replaces the
wall-clock with a deterministic pseudo-runtime derived from the source
hash — bit-identical across runs, processes and serial/parallel
evaluation, which is what the determinism tests and throughput benches
compare against.  A per-candidate deadline (SIGALRM) provides straggler
mitigation: a hanging candidate is failed, not waited on.  (SIGALRM only
arms on a main thread; `ParallelEvaluator` workers guarantee one and add a
hard process-kill deadline on top.)

Results are cached by source hash — populations re-evaluate nothing.
Baselines (the naive implementation's runtime) are cached in memory and,
with ``cache_dir``, in ``baseline_us.json`` keyed by task + timing config
so benchmark re-runs skip re-timing the naive kernels.
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
import re
import signal
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.ioutil import atomic_write, read_json, update_json
from repro.tasks.base import KernelTask


@dataclasses.dataclass
class EvalConfig:
    n_correctness: int = 5
    timing_runs: int = 15
    warmup_runs: int = 2
    timeout_s: float = 30.0
    input_seed_base: int = 10_000
    # "wall": median wall-clock of the jitted candidate (default).
    # "simulated": deterministic pseudo-runtime from the source hash —
    # compile + correctness stages still run for real; only the timing
    # stage is replaced.  Used by tests/benches that need bit-identical
    # results across serial and parallel evaluation.
    timing_mode: str = "wall"


@dataclasses.dataclass
class EvalResult:
    compile_ok: bool = False
    correct: bool = False
    runtime_us: Optional[float] = None
    error: Optional[str] = None
    stage: str = "compile"

    @property
    def valid(self) -> bool:
        return self.compile_ok and self.correct


def source_key(task_name: str, source: str) -> Tuple[str, str]:
    """The result-cache key: (task, sha1 of source).  Shared by the serial
    evaluator, the parallel pool and the engine's bookkeeping."""
    return (task_name, hashlib.sha1(source.encode()).hexdigest())


def _pseudo_runtime_us(task_name: str, sha: str) -> float:
    """Deterministic stand-in runtime in [50, 1050) us for timing_mode="simulated"."""
    h = int(hashlib.sha1(f"{task_name}:{sha}".encode()).hexdigest()[:12], 16)
    return 50.0 + (h % 1_000_000) / 1000.0


def _errmsg(e: BaseException, limit: int = 500) -> str:
    """Candidate-fault message, deterministic across processes: object reprs
    in exception text carry memory addresses (`<function ... at 0x7f...>`)
    that differ between the parent and a worker, which would break the
    serial==parallel bit-identity contract — scrub them."""
    msg = re.sub(r"0x[0-9a-fA-F]+", "0x<addr>", str(e)[:limit])
    return f"{type(e).__name__}: {msg}"


def _task_fingerprint(task: KernelTask) -> str:
    """Version stamp for the disk caches: if a task's renderer (and hence
    its naive source) changes across PRs, stale oracle/baseline entries
    must miss rather than silently corrupt verdicts.  The naive source
    hashes the renderer's output; ref() changes usually accompany it."""
    return hashlib.sha1(task.initial_source.encode()).hexdigest()[:10]


class _Deadline:
    """SIGALRM-based per-candidate timeout (main thread only)."""

    def __init__(self, seconds: float):
        self.seconds = seconds
        self.active = False

    def __enter__(self):
        if self.seconds and self.seconds > 0:
            try:
                signal.signal(signal.SIGALRM, self._raise)
                signal.setitimer(signal.ITIMER_REAL, self.seconds)
                self.active = True
            except ValueError:
                self.active = False  # not in main thread; run unguarded
        return self

    def _raise(self, *a):
        raise TimeoutError(f"candidate exceeded {self.seconds}s deadline")

    def __exit__(self, *a):
        if self.active:
            signal.setitimer(signal.ITIMER_REAL, 0.0)
        return False


class Evaluator:
    def __init__(self, config: Optional[EvalConfig] = None, cache_dir: Optional[str] = None):
        self.config = config or EvalConfig()
        self._cache: Dict[Tuple[str, str], EvalResult] = {}
        self._baseline_us: Dict[str, float] = {}
        self._oracle_cache: Dict[Tuple[str, int], np.ndarray] = {}
        self.cache_hits = 0
        self.oracle_hits = 0
        self.oracle_misses = 0
        self.cache_dir: Optional[str] = None
        if cache_dir:
            self.set_cache_dir(cache_dir)

    # ------------------------------------------------------------------
    def set_cache_dir(self, cache_dir: str) -> None:
        """Enable the on-disk layer (oracle outputs + baseline timings)."""
        self.cache_dir = cache_dir
        os.makedirs(os.path.join(cache_dir, "oracle"), exist_ok=True)

    def stats_snapshot(self) -> Dict[str, int]:
        return {
            "cache_hits": self.cache_hits,
            "oracle_hits": self.oracle_hits,
            "oracle_misses": self.oracle_misses,
            "evaluated": len(self._cache),
        }

    # ------------------------------------------------------------------
    def evaluate(self, task: KernelTask, source: str) -> EvalResult:
        key = source_key(task.name, source)
        if key in self._cache:
            self.cache_hits += 1
            return self._cache[key]
        with _Deadline(self.config.timeout_s):
            try:
                result = self._evaluate_uncached(task, source, key[1])
            except TimeoutError as e:
                result = EvalResult(error=str(e), stage="timeout")
            except Exception as e:  # noqa: BLE001 — candidate faults are data
                result = EvalResult(error=_errmsg(e), stage="unexpected")
        self._cache[key] = result
        return result

    def evaluate_batch(self, task: KernelTask, sources: List[str]) -> List[EvalResult]:
        """Evaluate a population batch; duplicates hit the result cache.

        The serial reference implementation of the interface
        `ParallelEvaluator` fans out to worker processes.
        """
        return [self.evaluate(task, s) for s in sources]

    def _evaluate_uncached(self, task: KernelTask, source: str, sha: str) -> EvalResult:
        # Candidates may legitimately choose float64 (a real 2x cost on this
        # host, mirroring fp64 CUDA kernels); jax disables x64 by default so
        # the evaluator enables it locally for candidate + oracle execution.
        with jax.experimental.enable_x64():
            return self._evaluate_x64(task, source, sha)

    def _evaluate_x64(self, task: KernelTask, source: str, sha: str) -> EvalResult:
        cfg = self.config
        # ---- stage 1: compile check ----------------------------------
        try:
            code = compile(source, f"<candidate:{task.name}>", "exec")
            ns: Dict[str, Any] = {}
            exec(code, ns)  # noqa: S102 — sandboxed candidate execution
            fn = ns.get("kernel")
            if fn is None:
                return EvalResult(error="no `kernel` function defined", stage="compile")
            jfn = jax.jit(fn)
            inputs0 = task.make_inputs(cfg.input_seed_base)
            jfn.lower(*inputs0)  # trace: shape/dtype/primitive errors
        except TimeoutError:
            raise  # the deadline, not a candidate fault: stage "timeout"
        except Exception as e:  # noqa: BLE001
            return EvalResult(error=_errmsg(e), stage="compile")

        # ---- stage 2: functional test (5 cases vs oracle) -------------
        try:
            for i in range(cfg.n_correctness):
                seed = cfg.input_seed_base + i
                inputs = task.make_inputs(seed)
                got = np.asarray(jfn(*inputs))
                want = self._oracle(task, seed)
                if got.shape != want.shape:
                    return EvalResult(
                        compile_ok=True,
                        error=f"shape mismatch {got.shape} vs {want.shape}",
                        stage="correctness",
                    )
                if not np.allclose(got, want, rtol=task.rtol, atol=task.atol):
                    max_err = float(np.max(np.abs(got.astype(np.float64) - want.astype(np.float64))))
                    return EvalResult(
                        compile_ok=True,
                        error=f"value mismatch (max abs err {max_err:.3e})",
                        stage="correctness",
                    )
        except TimeoutError:
            raise  # the deadline, not a candidate fault: stage "timeout"
        except Exception as e:  # noqa: BLE001
            return EvalResult(
                compile_ok=True, error=_errmsg(e), stage="correctness"
            )

        # ---- performance ------------------------------------------------
        if cfg.timing_mode == "simulated":
            return EvalResult(
                compile_ok=True, correct=True,
                runtime_us=_pseudo_runtime_us(task.name, sha), stage="done",
            )
        inputs = task.make_inputs(cfg.input_seed_base)
        for _ in range(cfg.warmup_runs):
            jax.block_until_ready(jfn(*inputs))
        times = []
        for _ in range(cfg.timing_runs):
            t0 = time.perf_counter()
            jax.block_until_ready(jfn(*inputs))
            times.append(time.perf_counter() - t0)
        runtime_us = float(np.median(times) * 1e6)
        return EvalResult(
            compile_ok=True, correct=True, runtime_us=runtime_us, stage="done"
        )

    # ------------------------------------------------------------------
    # oracle-output cache: task.ref(...) runs once per (task, seed)
    # ------------------------------------------------------------------
    def _oracle_path(self, task: KernelTask, seed: int) -> Optional[str]:
        if not self.cache_dir:
            return None
        return os.path.join(
            self.cache_dir, "oracle",
            f"{task.name}_{_task_fingerprint(task)}_{seed}.npy",
        )

    def _oracle(self, task: KernelTask, seed: int) -> np.ndarray:
        key = (task.name, seed)
        cached = self._oracle_cache.get(key)
        if cached is not None:
            self.oracle_hits += 1
            return cached
        path = self._oracle_path(task, seed)
        if path and os.path.exists(path):
            try:
                want = np.load(path)
                self.oracle_hits += 1
                self._oracle_cache[key] = want
                return want
            except (OSError, ValueError):
                pass  # corrupt/partial file: recompute below
        self.oracle_misses += 1
        want = np.asarray(task.ref(*task.make_inputs(seed)))
        self._oracle_cache[key] = want
        if path:
            try:
                atomic_write(path, lambda f: np.save(f, want))
            except OSError:
                pass  # disk layer is best-effort
        return want

    # ------------------------------------------------------------------
    # baseline runtimes (memory -> disk -> measure)
    # ------------------------------------------------------------------
    def _baseline_key(self, task: KernelTask) -> str:
        c = self.config
        key = (
            f"{task.name}@{_task_fingerprint(task)}"
            f"|r{c.timing_runs}w{c.warmup_runs}|{c.timing_mode}"
        )
        if c.timing_mode == "wall":
            # wall-clock baselines are hardware-specific: never reuse them
            # across hosts when eval_cache lives on shared storage
            import platform

            key += f"|{platform.node()}x{os.cpu_count()}"
        return key

    def _baseline_file(self) -> Optional[str]:
        if not self.cache_dir:
            return None
        return os.path.join(self.cache_dir, "baseline_us.json")

    def baseline_us(self, task: KernelTask) -> float:
        """Runtime of the task's initial (naive) implementation, cached in
        memory and (with cache_dir) on disk beside the checkpoints."""
        key = self._baseline_key(task)
        if key in self._baseline_us:
            return self._baseline_us[key]
        path = self._baseline_file()
        if path and os.path.exists(path):
            data = read_json(path)
            if key in data:
                self._baseline_us[key] = float(data[key])
                return self._baseline_us[key]
        res = self.evaluate(task, task.initial_source)
        if not res.valid:
            raise RuntimeError(
                f"naive implementation of {task.name} failed: {res.error}"
            )
        self._baseline_us[key] = res.runtime_us
        if path:
            try:
                update_json(path, {key: res.runtime_us})
            except OSError:
                pass  # disk layer is best-effort
        return self._baseline_us[key]

    def speedup(self, task: KernelTask, result: EvalResult) -> Optional[float]:
        if not result.valid or not result.runtime_us:
            return None
        return self.baseline_us(task) / result.runtime_us
