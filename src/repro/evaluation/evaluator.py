"""Candidate-kernel evaluator (paper §4.3, two-stage + timing).

Stage 1 (compile check): ``compile()`` + exec of the source in a fresh
namespace, then a jit trace against the task's input shapes.  This is the
TPU-stack analogue of an nvcc compile: Python syntax errors, missing
symbols, shape/dtype errors and Pallas BlockSpec violations all surface
here.

Stage 2 (functional test): 5 seeded inputs, compared against the pure-jnp
oracle with per-task tolerances — the paper's protocol verbatim.

Performance: median wall-clock of the jitted candidate over ``timing_runs``
repeats after warmup (the paper averages 100 GPU runs; the knob is
configurable and recorded).  A per-candidate deadline (SIGALRM) provides
straggler mitigation: a hanging candidate is failed, not waited on.

Results are cached by source hash — populations re-evaluate nothing.
"""

from __future__ import annotations

import dataclasses
import hashlib
import signal
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

from repro.tasks.base import KernelTask


@dataclasses.dataclass
class EvalConfig:
    n_correctness: int = 5
    timing_runs: int = 15
    warmup_runs: int = 2
    timeout_s: float = 30.0
    input_seed_base: int = 10_000


@dataclasses.dataclass
class EvalResult:
    compile_ok: bool = False
    correct: bool = False
    runtime_us: Optional[float] = None
    error: Optional[str] = None
    stage: str = "compile"

    @property
    def valid(self) -> bool:
        return self.compile_ok and self.correct


class _Deadline:
    """SIGALRM-based per-candidate timeout (main thread only)."""

    def __init__(self, seconds: float):
        self.seconds = seconds
        self.active = False

    def __enter__(self):
        if self.seconds and self.seconds > 0:
            try:
                signal.signal(signal.SIGALRM, self._raise)
                signal.setitimer(signal.ITIMER_REAL, self.seconds)
                self.active = True
            except ValueError:
                self.active = False  # not in main thread; run unguarded
        return self

    def _raise(self, *a):
        raise TimeoutError(f"candidate exceeded {self.seconds}s deadline")

    def __exit__(self, *a):
        if self.active:
            signal.setitimer(signal.ITIMER_REAL, 0.0)
        return False


class Evaluator:
    def __init__(self, config: Optional[EvalConfig] = None):
        self.config = config or EvalConfig()
        self._cache: Dict[Tuple[str, str], EvalResult] = {}
        self._baseline_us: Dict[str, float] = {}

    # ------------------------------------------------------------------
    def evaluate(self, task: KernelTask, source: str) -> EvalResult:
        key = (task.name, hashlib.sha1(source.encode()).hexdigest())
        if key in self._cache:
            return self._cache[key]
        with _Deadline(self.config.timeout_s):
            try:
                result = self._evaluate_uncached(task, source)
            except TimeoutError as e:
                result = EvalResult(error=str(e), stage="timeout")
            except Exception as e:  # noqa: BLE001 — candidate faults are data
                result = EvalResult(
                    error=f"{type(e).__name__}: {e}", stage="unexpected"
                )
        self._cache[key] = result
        return result

    def _evaluate_uncached(self, task: KernelTask, source: str) -> EvalResult:
        # Candidates may legitimately choose float64 (a real 2x cost on this
        # host, mirroring fp64 CUDA kernels); jax disables x64 by default so
        # the evaluator enables it locally for candidate + oracle execution.
        with jax.experimental.enable_x64():
            return self._evaluate_x64(task, source)

    def _evaluate_x64(self, task: KernelTask, source: str) -> EvalResult:
        cfg = self.config
        # ---- stage 1: compile check ----------------------------------
        try:
            code = compile(source, f"<candidate:{task.name}>", "exec")
            ns: Dict[str, Any] = {}
            exec(code, ns)  # noqa: S102 — sandboxed candidate execution
            fn = ns.get("kernel")
            if fn is None:
                return EvalResult(error="no `kernel` function defined", stage="compile")
            jfn = jax.jit(fn)
            inputs0 = task.make_inputs(cfg.input_seed_base)
            jfn.lower(*inputs0)  # trace: shape/dtype/primitive errors
        except Exception as e:  # noqa: BLE001
            return EvalResult(
                error=f"{type(e).__name__}: {str(e)[:500]}", stage="compile"
            )

        # ---- stage 2: functional test (5 cases vs oracle) -------------
        try:
            for i in range(cfg.n_correctness):
                inputs = task.make_inputs(cfg.input_seed_base + i)
                got = np.asarray(jfn(*inputs))
                want = np.asarray(task.ref(*inputs))
                if got.shape != want.shape:
                    return EvalResult(
                        compile_ok=True,
                        error=f"shape mismatch {got.shape} vs {want.shape}",
                        stage="correctness",
                    )
                if not np.allclose(got, want, rtol=task.rtol, atol=task.atol):
                    max_err = float(np.max(np.abs(got.astype(np.float64) - want.astype(np.float64))))
                    return EvalResult(
                        compile_ok=True,
                        error=f"value mismatch (max abs err {max_err:.3e})",
                        stage="correctness",
                    )
        except Exception as e:  # noqa: BLE001
            return EvalResult(
                compile_ok=True,
                error=f"{type(e).__name__}: {str(e)[:500]}",
                stage="correctness",
            )

        # ---- performance ------------------------------------------------
        inputs = task.make_inputs(cfg.input_seed_base)
        for _ in range(cfg.warmup_runs):
            jax.block_until_ready(jfn(*inputs))
        times = []
        for _ in range(cfg.timing_runs):
            t0 = time.perf_counter()
            jax.block_until_ready(jfn(*inputs))
            times.append(time.perf_counter() - t0)
        runtime_us = float(np.median(times) * 1e6)
        return EvalResult(
            compile_ok=True, correct=True, runtime_us=runtime_us, stage="done"
        )

    # ------------------------------------------------------------------
    def baseline_us(self, task: KernelTask) -> float:
        """Runtime of the task's initial (naive) implementation, cached."""
        if task.name not in self._baseline_us:
            res = self.evaluate(task, task.initial_source)
            if not res.valid:
                raise RuntimeError(
                    f"naive implementation of {task.name} failed: {res.error}"
                )
            self._baseline_us[task.name] = res.runtime_us
        return self._baseline_us[task.name]

    def speedup(self, task: KernelTask, result: EvalResult) -> Optional[float]:
        if not result.valid or not result.runtime_us:
            return None
        return self.baseline_us(task) / result.runtime_us
