"""Candidate-kernel evaluator (paper §4.3, two-stage + timing).

Stage 1 (compile check): ``compile()`` + exec of the source in a fresh
namespace, then a jit trace against the task's input shapes.  This is the
TPU-stack analogue of an nvcc compile: Python syntax errors, missing
symbols, shape/dtype errors and Pallas BlockSpec violations all surface
here.

Stage 2 (functional test): 5 seeded inputs, compared against the pure-jnp
oracle with per-task tolerances — the paper's protocol verbatim.  Oracle
outputs are cached by ``(task, input_seed)`` so ``task.ref(...)`` runs once
per task/seed pair instead of once per candidate; with a ``cache_dir`` the
cache persists to disk and is shared across processes and re-runs.

Performance: delegated to the shared timing subsystem
(`repro.evaluation.timing`).  ``timing_mode="wall"`` measures the jitted
candidate through `WallClockTiming` — warmup, IQR outlier rejection,
median of the kept repeats, and a noise-floor estimate recorded on the
result (`EvalResult.noise_floor_us`) so downstream consumers can tell a
real speedup from measurement noise.  ``timing_mode="simulated"``
resolves through `SimulatedTiming`, byte-identical to the historical
pseudo-runtime path — bit-identical across runs, processes and
serial/parallel evaluation, which is what the determinism tests and
throughput benches compare against.  A per-candidate deadline (SIGALRM)
provides straggler
mitigation: a hanging candidate is failed, not waited on.  (SIGALRM only
arms on a main thread; `ParallelEvaluator` workers guarantee one and add a
hard process-kill deadline on top.)

Results are cached by source hash — populations re-evaluate nothing.
Baselines (the naive implementation's runtime) are cached in memory and,
with ``cache_dir``, in ``baseline_us.json`` keyed by task + timing config
so benchmark re-runs skip re-timing the naive kernels.
"""

from __future__ import annotations

import dataclasses
import hashlib
import math
import os
import re
import secrets
import signal
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import numpy as np

from repro.evaluation.timing import (
    Measurement,
    TimingProvider,
    TimingRequest,
    provider_from_config,
    pseudo_runtime_us,
)
from repro.ioutil import atomic_write, read_json, update_json
from repro.tasks.base import KernelTask
from repro.verify import VerificationPolicy, VerificationReport, error_stats


@dataclasses.dataclass
class EvalConfig:
    n_correctness: int = 5
    timing_runs: int = 15
    warmup_runs: int = 2
    timeout_s: float = 30.0
    input_seed_base: int = 10_000
    # "wall": statistically hardened wall-clock of the jitted candidate
    # (default; see repro.evaluation.timing.WallClockTiming).
    # "simulated": deterministic pseudo-runtime from the source hash —
    # compile + correctness stages still run for real; only the timing
    # stage is replaced.  Used by tests/benches that need bit-identical
    # results across serial and parallel evaluation.
    timing_mode: str = "wall"
    # produce a PerfDiagnosis (repro.diagnosis) for every candidate that
    # passes stage 1.  Diagnosis is read-only feedback: it never changes
    # a verdict, and it degrades to a partial record rather than failing
    # when compilation/cost analysis is unavailable.
    diagnosis: bool = True
    # default verification mode: "off" is the legacy two-stage gate,
    # byte-identical to the pre-verification engine; "strict" runs the
    # full tier ladder (repro.verify).  Per-call `evaluate(..., verify=)`
    # overrides this, so one evaluator (and its caches) can serve both
    # strict and legacy methods in the same sweep grid.
    verify: str = "off"
    # pin the strict-mode run nonce for exact replay of a rejection; None
    # draws a fresh nonce per evaluator (recorded on every report)
    verify_nonce: Optional[str] = None


@dataclasses.dataclass
class EvalResult:
    compile_ok: bool = False
    correct: bool = False
    runtime_us: Optional[float] = None
    error: Optional[str] = None
    stage: str = "compile"
    # measurement resolution in µs (WallClockTiming's kept-sample IQR;
    # exactly 0.0 for simulated timing): runtime differences below this
    # are noise, not signal
    noise_floor_us: Optional[float] = None
    # serialized PerfDiagnosis (repro.diagnosis.record schema) when
    # EvalConfig.diagnosis is on and the candidate passed stage 1; plain
    # dict so it crosses the ParallelEvaluator worker pipe untouched
    diagnosis: Optional[Dict[str, Any]] = None
    # elementwise error statistics of the failing oracle comparison
    # (max-abs, max-rel, argmax index) — populated in both verify modes;
    # the legacy error *message* stays byte-identical in off mode
    err_max_abs: Optional[float] = None
    err_max_rel: Optional[float] = None
    err_argmax: Optional[List[int]] = None
    # serialized VerificationReport (repro.verify.report schema) in
    # strict mode; always None in off mode
    verification: Optional[Dict[str, Any]] = None

    @property
    def valid(self) -> bool:
        return self.compile_ok and self.correct

    @property
    def ok(self) -> bool:
        """Valid AND carrying a usable runtime: non-finite or zero
        runtime_us must never enter speedup accounting (a 0µs "infinite
        speedup" would silently win every comparison)."""
        return (
            self.valid
            and self.runtime_us is not None
            and math.isfinite(self.runtime_us)
            and self.runtime_us > 0
        )


def source_key(task_name: str, source: str) -> Tuple[str, str]:
    """The result-cache key: (task, sha1 of source).  Shared by the serial
    evaluator, the parallel pool and the engine's bookkeeping."""
    return (task_name, hashlib.sha1(source.encode()).hexdigest())


def _pseudo_runtime_us(task_name: str, sha: str) -> float:
    """Back-compat alias for `repro.evaluation.timing.pseudo_runtime_us`."""
    return pseudo_runtime_us(f"{task_name}:{sha}")


def _errmsg(e: BaseException, limit: int = 500) -> str:
    """Candidate-fault message, deterministic across processes: object reprs
    in exception text carry memory addresses (`<function ... at 0x7f...>`)
    that differ between the parent and a worker, which would break the
    serial==parallel bit-identity contract — scrub them."""
    msg = re.sub(r"0x[0-9a-fA-F]+", "0x<addr>", str(e)[:limit])
    return f"{type(e).__name__}: {msg}"


def _task_fingerprint(task: KernelTask) -> str:
    """Version stamp for the disk caches: if a task's renderer (and hence
    its naive source) changes across PRs, stale oracle/baseline entries
    must miss rather than silently corrupt verdicts.  The naive source
    hashes the renderer's output; ref() changes usually accompany it."""
    return hashlib.sha1(task.initial_source.encode()).hexdigest()[:10]


class _Deadline:
    """SIGALRM-based per-candidate timeout (main thread only)."""

    def __init__(self, seconds: float):
        self.seconds = seconds
        self.active = False

    def __enter__(self):
        if self.seconds and self.seconds > 0:
            try:
                signal.signal(signal.SIGALRM, self._raise)
                signal.setitimer(signal.ITIMER_REAL, self.seconds)
                self.active = True
            except ValueError:
                self.active = False  # not in main thread; run unguarded
        return self

    def _raise(self, *a):
        raise TimeoutError(f"candidate exceeded {self.seconds}s deadline")

    def __exit__(self, *a):
        if self.active:
            signal.setitimer(signal.ITIMER_REAL, 0.0)
        return False


class Evaluator:
    def __init__(
        self,
        config: Optional[EvalConfig] = None,
        cache_dir: Optional[str] = None,
        timing: Optional[TimingProvider] = None,
    ):
        self.config = config or EvalConfig()
        # the single timing path: every runtime_us this evaluator reports
        # comes from one TimingProvider (injectable for tests)
        self.timing: TimingProvider = timing or provider_from_config(self.config)
        if self.timing.mode not in ("wall", "simulated"):
            # roofline scores (kernel, genome) pairs, not candidate sources —
            # it belongs to the autotuner, not candidate evaluation
            raise ValueError(
                f"Evaluator cannot time candidates with a "
                f"{self.timing.mode!r} provider (use wall or simulated)"
            )
        # strict-mode run nonce: every tier-2/3 input this evaluator draws
        # derives from it (pin via EvalConfig.verify_nonce to replay)
        self.verify_nonce: str = self.config.verify_nonce or secrets.token_hex(8)
        self._policies: Dict[str, VerificationPolicy] = {}
        self._warmed: Set[Tuple[str, str, bool]] = set()
        self._warm_free: Set[Tuple[str, int]] = set()
        self._cache: Dict[Tuple[str, str, str], EvalResult] = {}
        self._baseline_us: Dict[str, float] = {}
        self._oracle_cache: Dict[Tuple[str, int], np.ndarray] = {}
        self.cache_hits = 0
        self.oracle_hits = 0
        self.oracle_misses = 0
        self.cache_dir: Optional[str] = None
        if cache_dir:
            self.set_cache_dir(cache_dir)

    # ------------------------------------------------------------------
    def set_cache_dir(self, cache_dir: str) -> None:
        """Enable the on-disk layer (oracle outputs + baseline timings)."""
        self.cache_dir = cache_dir
        os.makedirs(os.path.join(cache_dir, "oracle"), exist_ok=True)

    def stats_snapshot(self) -> Dict[str, int]:
        return {
            "cache_hits": self.cache_hits,
            "oracle_hits": self.oracle_hits,
            "oracle_misses": self.oracle_misses,
            "evaluated": len(self._cache),
        }

    # ------------------------------------------------------------------
    def _policy(self, task: KernelTask) -> VerificationPolicy:
        p = self._policies.get(task.name)
        if p is None or p.nonce != self.verify_nonce:
            p = VerificationPolicy(task, self.verify_nonce)
            self._policies[task.name] = p
        return p

    def _warm_refs(self, task: KernelTask, strict: bool) -> None:
        """Build every reference output the evaluation will compare
        against *before* the candidate deadline arms.  Oracle
        construction used to run inside the candidate's `_Deadline`, so
        the first candidate on a cold cache could be charged a spurious
        ``stage="timeout"`` for time the evaluator itself spent — a
        verdict that then stuck in the result cache."""
        key = (task.name, _task_fingerprint(task), strict)
        if key in self._warmed:
            return
        try:
            with jax.experimental.enable_x64():
                for i in range(self.config.n_correctness):
                    self._oracle(task, self.config.input_seed_base + i)
                    # the correctness gate re-reads this key immediately;
                    # that is the same logical access warming just paid
                    # for, so exempt it from hit accounting once
                    self._warm_free.add((task.name, self.config.input_seed_base + i))
                if strict:
                    self._policy(task).warm()
        except Exception:  # noqa: BLE001 — an oracle that cannot be built
            # fails *inside* the evaluation proper with the legacy
            # per-candidate attribution, not here
            return
        self._warmed.add(key)

    # ------------------------------------------------------------------
    def evaluate(
        self, task: KernelTask, source: str, verify: Optional[str] = None
    ) -> EvalResult:
        mode = verify or self.config.verify
        key = source_key(task.name, source) + (mode,)
        if key in self._cache:
            self.cache_hits += 1
            return self._cache[key]
        self._warm_refs(task, strict=(mode == "strict"))
        with _Deadline(self.config.timeout_s):
            try:
                result = self._evaluate_uncached(task, source, key[1], mode)
            except TimeoutError as e:
                result = EvalResult(error=str(e), stage="timeout")
            except Exception as e:  # noqa: BLE001 — candidate faults are data
                result = EvalResult(error=_errmsg(e), stage="unexpected")
        self._cache[key] = result
        return result

    def evaluate_batch(
        self, task: KernelTask, sources: List[str], verify: Optional[str] = None
    ) -> List[EvalResult]:
        """Evaluate a population batch; duplicates hit the result cache.

        The serial reference implementation of the interface
        `ParallelEvaluator` fans out to worker processes.
        """
        return [self.evaluate(task, s, verify=verify) for s in sources]

    def _evaluate_uncached(
        self, task: KernelTask, source: str, sha: str, mode: str = "off"
    ) -> EvalResult:
        # Candidates may legitimately choose float64 (a real 2x cost on this
        # host, mirroring fp64 CUDA kernels); jax disables x64 by default so
        # the evaluator enables it locally for candidate + oracle execution.
        with jax.experimental.enable_x64():
            return self._evaluate_x64(task, source, sha, mode)

    @staticmethod
    def _rep(report: Optional[VerificationReport]) -> Optional[Dict[str, Any]]:
        return report.finalize().to_dict() if report is not None else None

    def _evaluate_x64(
        self, task: KernelTask, source: str, sha: str, mode: str = "off"
    ) -> EvalResult:
        cfg = self.config
        strict = mode == "strict"
        report: Optional[VerificationReport] = None
        if strict:
            policy = self._policy(task)
            report = VerificationReport(mode="strict", nonce=self.verify_nonce)
            # ---- tier 0: static guard (before any candidate code runs)
            violations = policy.static_check(source)
            if violations:
                detail = "; ".join(violations[:3])
                report.record(0, False, detail)
                return EvalResult(
                    error=f"static guard: {detail}",
                    stage="verify",
                    diagnosis=self._diagnose(task, None),
                    verification=self._rep(report),
                )
            report.record(0, True, "source clean")

        # ---- stage 1 / tier 1: compile check -------------------------
        try:
            code = compile(source, f"<candidate:{task.name}>", "exec")
            ns: Dict[str, Any] = {}
            exec(code, ns)  # noqa: S102 — sandboxed candidate execution
            fn = ns.get("kernel")
            if fn is None:
                if report:
                    report.record(1, False, "no `kernel` function defined")
                return EvalResult(
                    error="no `kernel` function defined",
                    stage="compile",
                    diagnosis=self._diagnose(task, None),
                    verification=self._rep(report),
                )
            jfn = jax.jit(fn)
            inputs0 = task.make_inputs(cfg.input_seed_base)
            jfn.lower(*inputs0)  # trace: shape/dtype/primitive errors
        except TimeoutError:
            raise  # the deadline, not a candidate fault: stage "timeout"
        except Exception as e:  # noqa: BLE001
            if report:
                report.record(1, False, _errmsg(e))
            return EvalResult(
                error=_errmsg(e), stage="compile",
                diagnosis=self._diagnose(task, None),
                verification=self._rep(report),
            )
        if report:
            report.record(1, True, "compiled and traced")

        # ---- tiers 2+3 (strict only): fuzz + property invariants -----
        if strict:
            if not policy.run_functional(jfn, report):
                tr = report.tiers[-1]
                return EvalResult(
                    compile_ok=True,
                    error=f"verification failed at tier 2 (fuzz): {tr.detail}",
                    stage="correctness",
                    diagnosis=self._diagnose(task, jfn),
                    err_max_abs=report.max_abs_err,
                    err_max_rel=report.max_rel_err,
                    err_argmax=report.err_argmax,
                    verification=self._rep(report),
                )
            if not policy.run_properties(jfn, report):
                tr = report.tiers[-1]
                return EvalResult(
                    compile_ok=True,
                    error=f"verification failed at tier 3 (property): {tr.detail}",
                    stage="correctness",
                    diagnosis=self._diagnose(task, jfn),
                    verification=self._rep(report),
                )

        # ---- stage 2 / tier 4: functional test vs oracle --------------
        try:
            for i in range(cfg.n_correctness):
                seed = cfg.input_seed_base + i
                inputs = task.make_inputs(seed)
                got = np.asarray(jfn(*inputs))
                want = self._oracle(task, seed)
                if got.shape != want.shape:
                    if report:
                        report.record(
                            4, False, f"shape {got.shape} vs {want.shape}"
                        )
                    return EvalResult(
                        compile_ok=True,
                        error=f"shape mismatch {got.shape} vs {want.shape}",
                        stage="correctness",
                        diagnosis=self._diagnose(task, jfn),
                        verification=self._rep(report),
                    )
                if not np.allclose(got, want, rtol=task.rtol, atol=task.atol):
                    max_err = float(np.max(np.abs(got.astype(np.float64) - want.astype(np.float64))))
                    max_abs, max_rel, idx = error_stats(got, want)
                    if strict:
                        report.max_abs_err = max_abs
                        report.max_rel_err = max_rel
                        report.err_argmax = idx
                        report.record(
                            4, False,
                            f"seed {i}: max abs err {max_abs:.3e}, "
                            f"max rel err {max_rel:.3e}",
                        )
                        error = (
                            f"value mismatch (max abs err {max_abs:.3e}, "
                            f"max rel err {max_rel:.3e}, at {tuple(idx)})"
                        )
                    else:
                        # byte-locked legacy message (strict-off golden)
                        error = f"value mismatch (max abs err {max_err:.3e})"
                    return EvalResult(
                        compile_ok=True,
                        error=error,
                        stage="correctness",
                        diagnosis=self._diagnose(task, jfn),
                        err_max_abs=max_abs,
                        err_max_rel=max_rel,
                        err_argmax=idx,
                        verification=self._rep(report),
                    )
        except TimeoutError:
            raise  # the deadline, not a candidate fault: stage "timeout"
        except Exception as e:  # noqa: BLE001
            if report:
                report.record(4, False, _errmsg(e))
            return EvalResult(
                compile_ok=True, error=_errmsg(e), stage="correctness",
                diagnosis=self._diagnose(task, jfn),
                verification=self._rep(report),
            )
        if report:
            report.record(4, True, f"{cfg.n_correctness} seeds within tolerance")

        # ---- performance (via the shared timing subsystem) ---------------
        m = self._measure(task, jfn, sha)
        if (
            m.runtime_us is None
            or not math.isfinite(m.runtime_us)
            or m.runtime_us <= 0
        ):
            # a degenerate measurement must not mint an unbeatable
            # "infinite speedup" candidate (see EvalResult.ok)
            return EvalResult(
                compile_ok=True, correct=True,
                error=f"unusable runtime measurement ({m.runtime_us!r})",
                stage="timing",
                diagnosis=self._diagnose(task, jfn),
                verification=self._rep(report),
            )
        return EvalResult(
            compile_ok=True, correct=True, runtime_us=m.runtime_us,
            stage="done", noise_floor_us=m.noise_floor_us,
            diagnosis=self._diagnose(task, jfn, m),
            verification=self._rep(report),
        )

    def _diagnose(
        self, task: KernelTask, jfn, m: Optional[Measurement] = None
    ) -> Optional[Dict[str, Any]]:
        """Serialized PerfDiagnosis for the candidate (None with diagnosis
        off).  Stage-1 failures get an 'empty' stub; candidates that traced
        get HLO costs; timed candidates get the full roofline fusion.
        Diagnosis is advisory — any failure degrades to None rather than
        propagating into the verdict."""
        if not self.config.diagnosis:
            return None
        from repro.diagnosis import diagnose, diagnose_jitted

        try:
            if jfn is None:
                return diagnose(
                    notes=["stage-1 failure: no compiled artifact"]
                ).to_dict()
            return diagnose_jitted(
                task,
                jfn,
                runtime_us=m.runtime_us if m else None,
                timing_mode=self.timing.mode if m else "",
                noise_floor_us=m.noise_floor_us if m else None,
                input_seed=self.config.input_seed_base,
            ).to_dict()
        except Exception:  # noqa: BLE001 — never fail a candidate over feedback
            return None

    def _measure(self, task: KernelTask, jfn, sha: str) -> Measurement:
        """One Measurement for the (already warm-traced) jitted candidate.
        Simulated timing never builds inputs or runs the candidate —
        exactly the historical cost profile of that mode."""
        if self.timing.mode == "simulated":
            return self.timing.measure(TimingRequest(key=f"{task.name}:{sha}"))
        inputs = task.make_inputs(self.config.input_seed_base)
        return self.timing.measure(
            TimingRequest(thunk=lambda: jax.block_until_ready(jfn(*inputs)))
        )

    # ------------------------------------------------------------------
    # oracle-output cache: task.ref(...) runs once per (task, seed)
    # ------------------------------------------------------------------
    def _oracle_path(self, task: KernelTask, seed: int) -> Optional[str]:
        if not self.cache_dir:
            return None
        return os.path.join(
            self.cache_dir, "oracle",
            f"{task.name}_{_task_fingerprint(task)}_{seed}.npy",
        )

    def _oracle(self, task: KernelTask, seed: int) -> np.ndarray:
        key = (task.name, seed)
        cached = self._oracle_cache.get(key)
        if cached is not None:
            if key in self._warm_free:
                self._warm_free.discard(key)
            else:
                self.oracle_hits += 1
            return cached
        path = self._oracle_path(task, seed)
        if path and os.path.exists(path):
            try:
                want = np.load(path)
                self.oracle_hits += 1
                self._oracle_cache[key] = want
                return want
            except (OSError, ValueError):
                pass  # corrupt/partial file: recompute below
        self.oracle_misses += 1
        want = np.asarray(task.ref(*task.make_inputs(seed)))
        self._oracle_cache[key] = want
        if path:
            try:
                atomic_write(path, lambda f: np.save(f, want))
            except OSError:
                pass  # disk layer is best-effort
        return want

    # ------------------------------------------------------------------
    # baseline runtimes (memory -> disk -> measure)
    # ------------------------------------------------------------------
    def _baseline_key(self, task: KernelTask) -> str:
        # keyed by the provider actually measuring (an injected provider
        # may disagree with config.timing_mode — its numbers must never
        # land under another mode's cache key), falling back to the config
        # knobs when the provider doesn't carry its own
        c = self.config
        mode = self.timing.mode
        runs = getattr(self.timing, "timing_runs", c.timing_runs)
        warmup = getattr(self.timing, "warmup_runs", c.warmup_runs)
        key = f"{task.name}@{_task_fingerprint(task)}|r{runs}w{warmup}|{mode}"
        if mode == "wall":
            # wall-clock baselines are hardware-specific: never reuse them
            # across hosts when eval_cache lives on shared storage.  "iqr1"
            # stamps the measurement methodology (WallClockTiming's outlier
            # rejection): baselines recorded by the pre-hardening median
            # loop must miss rather than pair a stale unhardened baseline
            # with hardened candidate timings
            import platform

            key += f"|iqr1|{platform.node()}x{os.cpu_count()}"
        return key

    def _baseline_file(self) -> Optional[str]:
        if not self.cache_dir:
            return None
        return os.path.join(self.cache_dir, "baseline_us.json")

    def baseline_us(self, task: KernelTask) -> float:
        """Runtime of the task's initial (naive) implementation, cached in
        memory and (with cache_dir) on disk beside the checkpoints."""
        key = self._baseline_key(task)
        if key in self._baseline_us:
            return self._baseline_us[key]
        path = self._baseline_file()
        if path and os.path.exists(path):
            data = read_json(path)
            if key in data:
                self._baseline_us[key] = float(data[key])
                return self._baseline_us[key]
        res = self.evaluate(task, task.initial_source)
        if not res.valid:
            raise RuntimeError(
                f"naive implementation of {task.name} failed: {res.error}"
            )
        self._baseline_us[key] = res.runtime_us
        if path:
            try:
                update_json(path, {key: res.runtime_us})
            except OSError:
                pass  # disk layer is best-effort
        return self._baseline_us[key]

    def speedup(self, task: KernelTask, result: EvalResult) -> Optional[float]:
        if not result.ok:  # also rejects non-finite / zero runtimes
            return None
        return self.baseline_us(task) / result.runtime_us
