"""Process-parallel candidate evaluation: the pipelined population engine.

The serial evaluator is wall-clock-bound by the slowest candidate and its
SIGALRM deadline only arms on the main thread.  `ParallelEvaluator` keeps
the exact `Evaluator` interface (``evaluate`` / ``evaluate_batch`` /
``baseline_us`` / ``speedup``) but fans each batch out to a pool of
spawned worker processes, giving real per-candidate isolation: a candidate
that hangs in native code is killed with its worker, not waited on.

Worker protocol
---------------
Each worker is a fresh interpreter launched via ``subprocess`` — spawn
semantics (no forked JAX state) without re-importing the parent's
``__main__``, so the pool works from scripts, pytest and the REPL alike.
The parent passes one end of a ``multiprocessing.Pipe`` as an inherited
file descriptor (``REPRO_EVAL_WORKER_FD``) and sends
``("init", eval_config, cache_dir, extra_task_modules)`` as the first
message.  The worker then imports ``repro.tasks`` (populating the task
registry, plus any ``extra_task_modules``), builds a process-local
`Evaluator`, and sends ``("ready",)``.  Then, in a loop:

    parent -> worker   ("eval", job_id, task_name, source, verify_mode)
    worker -> parent   ("result", job_id, eval_result_dict, stats_dict)
    parent -> worker   None                      # shutdown request

The init config ships the parent's *resolved* strict-verification nonce
(``EvalConfig.verify_nonce`` is pinned to ``Evaluator.verify_nonce``
before the send), so every worker draws the identical tier-2/3 inputs
the parent would — parallel strict evaluation stays bit-identical to
serial, and one recorded nonce replays the whole pool's rejections.

Timeouts are layered.  Inside the worker the per-candidate SIGALRM
deadline (``EvalConfig.timeout_s``) fires on the worker's main thread —
which, unlike the engine's old in-process evaluation, is guaranteed to BE
a main thread.  Hard hangs that never return to the Python interpreter
are handled by the parent: after ``worker_deadline_s`` the worker is
SIGKILLed and respawned, and the candidate fails with stage ``timeout``.

Cache keys
----------
* results: ``(task_name, sha1(source))`` held in the parent and shared
  across workers — a source evaluated once anywhere is never resubmitted,
  and duplicate sources within one batch collapse to a single job.
* oracle outputs: ``(task_name, input_seed)`` in each worker's memory;
  with ``cache_dir`` they are shared across workers/processes/runs via
  ``<cache_dir>/oracle/<task>_<seed>.npy`` (atomic-rename writes).
* baselines: ``<cache_dir>/baseline_us.json`` keyed by task + timing
  config (see `Evaluator.baseline_us`).

Timing: each worker builds its own `TimingProvider` from the `EvalConfig`
it received at spawn (`repro.evaluation.timing.provider_from_config`), so
parent and workers share one timing definition without shipping provider
objects across the pipe.  Custom provider *instances* therefore cannot be
injected into a pool — construct workers' behavior through the config.

Determinism: compile and correctness outcomes are pure functions of the
source, so parallel evaluation returns bit-identical `EvalResult`s to the
serial evaluator; with ``timing_mode="simulated"`` the runtimes are too —
`SimulatedTiming` is a pure function of the source hash (tested in
tests/test_parallel_eval.py and regression-locked in tests/test_timing.py).
"""

from __future__ import annotations

import dataclasses
import os
import subprocess
import sys
import time
from multiprocessing import Pipe, connection
from typing import Dict, List, Optional, Tuple

from repro.evaluation.evaluator import (
    EvalConfig,
    EvalResult,
    Evaluator,
    _errmsg,
    source_key,
)
from repro.tasks.base import KernelTask

_WORKER_CMD = "from repro.evaluation.parallel import _worker_entry; _worker_entry()"


def _worker_entry():
    """Subprocess entry: rebuild the pipe from the inherited fd, read the
    init message, serve jobs (see module docstring for the protocol)."""
    from multiprocessing.connection import Connection

    conn = Connection(int(os.environ["REPRO_EVAL_WORKER_FD"]))
    _, config, cache_dir, extra_task_modules = conn.recv()
    _worker_main(conn, config, cache_dir, extra_task_modules)


def _worker_main(conn, config: EvalConfig, cache_dir: Optional[str], extra_task_modules):
    import importlib
    import warnings

    warnings.filterwarnings("ignore")
    import repro.tasks as tasks_mod

    for mod in extra_task_modules or ():
        importlib.import_module(mod)
    ev = Evaluator(config, cache_dir=cache_dir)
    conn.send(("ready",))
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):  # parent went away
            break
        if msg is None:
            break
        _, job_id, task_name, source, verify = msg
        try:
            task = tasks_mod.get_task(task_name)
            payload = dataclasses.asdict(ev.evaluate(task, source, verify=verify))
        except BaseException as e:  # noqa: BLE001 — a worker never dies on a job
            payload = dataclasses.asdict(
                EvalResult(error=_errmsg(e), stage="unexpected")
            )
        conn.send(("result", job_id, payload, ev.stats_snapshot()))
    conn.close()


class _Worker:
    __slots__ = ("proc", "conn", "state", "job_id", "started", "uid")

    def __init__(self, proc, conn, uid: int):
        self.proc = proc
        self.conn = conn
        self.uid = uid
        self.state = "starting"  # starting -> idle <-> busy
        self.job_id: Optional[str] = None
        self.started = 0.0


class ParallelEvaluator(Evaluator):
    """Drop-in `Evaluator` that evaluates population batches in a pool of
    spawned worker processes.

    Workers start lazily on the first evaluation and persist across
    batches (their jit caches stay warm).  Use as a context manager or
    call ``close()`` to reap them.
    """

    def __init__(
        self,
        config: Optional[EvalConfig] = None,
        workers: Optional[int] = None,
        cache_dir: Optional[str] = None,
        worker_deadline_s: Optional[float] = None,
        extra_task_modules: Tuple[str, ...] = (),
        timing=None,
    ):
        if timing is not None:
            raise ValueError(
                "ParallelEvaluator cannot take a timing provider instance: "
                "workers rebuild their provider from EvalConfig at spawn "
                "(set EvalConfig.timing_mode/timing_runs/warmup_runs instead)"
            )
        super().__init__(config, cache_dir=cache_dir)
        self.workers = max(1, workers or min(4, os.cpu_count() or 1))
        if worker_deadline_s is None and self.config.timeout_s:
            # grace over the in-worker SIGALRM: only hard (native) hangs
            # should ever reach the kill path
            worker_deadline_s = self.config.timeout_s * 1.5 + 30.0
        self.worker_deadline_s = worker_deadline_s
        self.extra_task_modules = tuple(extra_task_modules)
        self.workers_killed = 0
        self._pool: List[_Worker] = []
        self._uid_seq = 0
        self._worker_stats: Dict[int, Dict[str, int]] = {}

    # ------------------------------------------------------------------
    def set_cache_dir(self, cache_dir: str) -> None:
        # workers receive cache_dir at spawn; changing it under a live pool
        # would desynchronize parent and workers, so it only applies before
        # the first evaluation
        if getattr(self, "_pool", None):  # guard: also called from super().__init__
            import warnings

            warnings.warn(
                f"ParallelEvaluator.set_cache_dir({cache_dir!r}) ignored: the "
                "worker pool is already running with "
                f"cache_dir={self.cache_dir!r}; construct the evaluator with "
                "cache_dir (or set it before the first evaluation) to persist "
                "oracle/baseline caches",
                RuntimeWarning,
                stacklevel=2,
            )
            return
        super().set_cache_dir(cache_dir)

    # ------------------------------------------------------------------
    # pool management
    # ------------------------------------------------------------------
    def _spawn(self) -> _Worker:
        parent_conn, child_conn = Pipe()
        env = dict(os.environ)
        src_root = os.path.dirname(
            os.path.dirname(os.path.abspath(sys.modules["repro"].__file__))
        )
        prev = env.get("PYTHONPATH")
        env["PYTHONPATH"] = src_root + (os.pathsep + prev if prev else "")
        env["REPRO_EVAL_WORKER_FD"] = str(child_conn.fileno())
        proc = subprocess.Popen(
            [sys.executable, "-c", _WORKER_CMD],
            env=env,
            pass_fds=(child_conn.fileno(),),
            close_fds=True,
            stdout=subprocess.DEVNULL,  # candidate prints are not results
            stderr=subprocess.DEVNULL,
        )
        child_conn.close()
        # pin the parent's resolved nonce so every worker draws identical
        # strict-verification inputs (see module docstring)
        cfg = dataclasses.replace(self.config, verify_nonce=self.verify_nonce)
        parent_conn.send(("init", cfg, self.cache_dir, self.extra_task_modules))
        self._uid_seq += 1
        w = _Worker(proc, parent_conn, self._uid_seq)
        self._pool.append(w)
        return w

    def _ensure_pool(self, n: int) -> None:
        while len(self._pool) < min(n, self.workers):
            self._spawn()

    def _reap(self, w: _Worker, kill: bool = False) -> None:
        if kill and w.proc.poll() is None:
            w.proc.kill()
            self.workers_killed += 1
        try:
            w.proc.wait(timeout=5.0)
        except subprocess.TimeoutExpired:
            w.proc.kill()
            try:
                w.proc.wait(timeout=2.0)
            except subprocess.TimeoutExpired:
                pass
        try:
            w.conn.close()
        except OSError:
            pass
        if w in self._pool:
            self._pool.remove(w)

    def close(self) -> None:
        """Shut the pool down; idle workers exit cleanly, stuck ones are reaped."""
        for w in self._pool:
            try:
                w.conn.send(None)
            except (OSError, ValueError, BrokenPipeError):
                pass
        for w in list(self._pool):
            self._reap(w)
        self._pool.clear()

    def __enter__(self) -> "ParallelEvaluator":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # best-effort; close() is the real API
        try:
            self.close()
        except Exception:  # noqa: BLE001
            pass

    # ------------------------------------------------------------------
    # evaluation
    # ------------------------------------------------------------------
    def evaluate(
        self, task: KernelTask, source: str, verify: Optional[str] = None
    ) -> EvalResult:
        return self.evaluate_batch(task, [source], verify=verify)[0]

    def evaluate_batch(
        self, task: KernelTask, sources: List[str], verify: Optional[str] = None
    ) -> List[EvalResult]:
        mode = verify or self.config.verify
        results: List[Optional[EvalResult]] = [None] * len(sources)
        pending: Dict[Tuple[str, str, str], List[int]] = {}
        queue: List[Tuple[str, str]] = []  # (sha, source), submission order
        for i, src in enumerate(sources):
            key = source_key(task.name, src) + (mode,)
            if key in self._cache:
                self.cache_hits += 1
                results[i] = self._cache[key]
            elif key in pending:
                pending[key].append(i)
            else:
                pending[key] = [i]
                queue.append((key[1], src))
        if pending:
            # spawn the full pool up front: workers warm (JAX import, ~s)
            # concurrently instead of trickling in behind the first batch
            self._ensure_pool(self.workers)
            self._run_jobs(task, queue, pending, results, mode)
        return results  # type: ignore[return-value]

    def _finish(
        self,
        task_name: str,
        sha: str,
        mode: str,
        res: EvalResult,
        pending: Dict[Tuple[str, str, str], List[int]],
        results: List[Optional[EvalResult]],
    ) -> None:
        key = (task_name, sha, mode)
        self._cache[key] = res
        for i in pending.pop(key):
            results[i] = res

    def _run_jobs(self, task, queue, pending, results, mode) -> None:
        todo = list(reversed(queue))  # pop() from the end = submission order
        sources = {sha: src for sha, src in queue}
        n_outstanding = len(todo)
        retried: set = set()
        consecutive_crashes = 0
        while n_outstanding:
            if consecutive_crashes > max(4, 2 * self.workers):
                raise RuntimeError(
                    "evaluation workers keep dying before serving a job — "
                    "the spawned interpreter cannot re-import the parent "
                    "__main__/environment (see repro/evaluation/parallel.py)"
                )
            # dispatch to idle workers
            for w in self._pool:
                if not todo:
                    break
                if w.state == "idle":
                    sha, src = todo.pop()
                    w.conn.send(("eval", sha, task.name, src, mode))
                    w.state = "busy"
                    w.job_id = sha
                    w.started = time.monotonic()
            # collect results / readiness; wait() wakes immediately on any
            # message, so the timeout only bounds how late a hard-deadline
            # kill can fire — no busy-polling between events
            wait_s = 0.2
            if self.worker_deadline_s:
                now = time.monotonic()
                for w in self._pool:
                    if w.state == "busy":
                        remaining = w.started + self.worker_deadline_s - now
                        wait_s = max(0.0, min(wait_s, remaining))
            ready = connection.wait([w.conn for w in self._pool], timeout=wait_s)
            for c in ready:
                w = next((x for x in self._pool if x.conn is c), None)
                if w is None:  # reaped earlier in this iteration
                    continue
                try:
                    msg = c.recv()
                except (EOFError, OSError):
                    # worker died underneath us (e.g. OOM-killed); retry its
                    # job once on another worker before failing it, so a
                    # transient kill can't change an otherwise-deterministic
                    # batch result
                    consecutive_crashes += 1
                    if w.state == "busy":
                        if w.job_id not in retried:
                            retried.add(w.job_id)
                            todo.append((w.job_id, sources[w.job_id]))
                        else:
                            self._finish(
                                task.name, w.job_id, mode,
                                EvalResult(error="evaluation worker crashed", stage="unexpected"),
                                pending, results,
                            )
                            n_outstanding -= 1
                    self._reap(w)
                    continue
                if msg[0] == "ready":
                    w.state = "idle"
                    consecutive_crashes = 0
                elif msg[0] == "result":
                    _, job_id, payload, stats = msg
                    self._worker_stats[w.uid] = stats
                    self._finish(
                        task.name, job_id, mode, EvalResult(**payload), pending, results
                    )
                    n_outstanding -= 1
                    w.state = "idle"
                    w.job_id = None
            # hard-deadline kills (stuck in native code; SIGALRM never fired)
            if self.worker_deadline_s:
                now = time.monotonic()
                for w in list(self._pool):
                    if w.state == "busy" and now - w.started > self.worker_deadline_s:
                        self._finish(
                            task.name, w.job_id, mode,
                            EvalResult(
                                error=(
                                    f"candidate exceeded {self.worker_deadline_s}s "
                                    "hard deadline; worker killed"
                                ),
                                stage="timeout",
                            ),
                            pending, results,
                        )
                        n_outstanding -= 1
                        self._reap(w, kill=True)
            # keep the pool at strength for remaining work
            deficit = min(self.workers, len(todo) + sum(
                1 for w in self._pool if w.state == "busy"
            )) - len(self._pool)
            for _ in range(max(0, deficit)):
                self._spawn()

    # ------------------------------------------------------------------
    def stats_snapshot(self) -> Dict[str, int]:
        agg = {
            "cache_hits": self.cache_hits,
            "oracle_hits": 0,
            "oracle_misses": 0,
            "evaluated": len(self._cache),
            "workers_killed": self.workers_killed,
        }
        for s in self._worker_stats.values():
            agg["oracle_hits"] += s.get("oracle_hits", 0)
            agg["oracle_misses"] += s.get("oracle_misses", 0)
        return agg
