"""Two-stage evaluation: compile check -> functional test -> performance.

`Evaluator` runs candidates in-process and serially; `ParallelEvaluator`
keeps the same interface but pipelines population batches through a pool
of spawned worker processes with hard per-candidate timeouts (see
repro/evaluation/parallel.py for the worker protocol and cache keys).
Both share the source-hash result cache format, the `(task, seed)`
oracle-output cache and the on-disk baseline/oracle layer.
"""

from repro.evaluation.evaluator import EvalConfig, EvalResult, Evaluator, source_key
from repro.evaluation.parallel import ParallelEvaluator

__all__ = ["EvalConfig", "EvalResult", "Evaluator", "ParallelEvaluator", "source_key"]
