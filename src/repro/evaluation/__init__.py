"""Two-stage evaluation: compile check -> functional test -> performance.

`Evaluator` runs candidates in-process and serially; `ParallelEvaluator`
keeps the same interface but pipelines population batches through a pool
of spawned worker processes with hard per-candidate timeouts (see
repro/evaluation/parallel.py for the worker protocol and cache keys).
Both share the source-hash result cache format, the `(task, seed)`
oracle-output cache and the on-disk baseline/oracle layer.

All runtime numbers flow through the unified timing subsystem
(`repro.evaluation.timing`): `WallClockTiming` (measured, statistically
hardened), `SimulatedTiming` (deterministic pseudo-runtimes) and
`RooflineTiming` (analytic v5e models, used by the autotuner's offline
fallback) behind one `TimingProvider` protocol.
"""

from repro.evaluation.evaluator import EvalConfig, EvalResult, Evaluator, source_key
from repro.evaluation.parallel import ParallelEvaluator
from repro.evaluation.timing import (
    Measurement,
    RooflineTiming,
    SimulatedTiming,
    TimingProvider,
    TimingRequest,
    WallClockTiming,
    device_kind,
    provider_for,
    provider_from_config,
    resolve_timing_mode,
)

__all__ = [
    "EvalConfig",
    "EvalResult",
    "Evaluator",
    "Measurement",
    "ParallelEvaluator",
    "RooflineTiming",
    "SimulatedTiming",
    "TimingProvider",
    "TimingRequest",
    "WallClockTiming",
    "device_kind",
    "provider_for",
    "provider_from_config",
    "resolve_timing_mode",
    "source_key",
]
