"""Two-stage evaluation: compile check -> functional test -> performance."""

from repro.evaluation.evaluator import EvalConfig, EvalResult, Evaluator

__all__ = ["EvalConfig", "EvalResult", "Evaluator"]
