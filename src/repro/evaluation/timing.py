"""Unified timing subsystem: one measurement layer for evaluator + autotuner.

Every runtime number this repo ranks candidates by — the evolution
engine's candidate wall-clocks, the autotuner's genome scores, the
benchmark harnesses — flows through a `TimingProvider`, so the statistics
(warmup, outlier rejection, drift cancellation, noise floor) are defined
once instead of re-hand-rolled per call site.  "Towards Robust Agentic
CUDA Kernel Benchmarking" (Lange et al., 2025) identifies naive
single-shot timing as the dominant source of bogus speedups in LLM kernel
evolution; this module is the hardening layer that claim asks for.

Three providers implement the protocol:

* `WallClockTiming` — measured on-hardware timing: ``warmup_runs``
  untimed warmups (jit compile + caches), ``timing_runs`` timed repeats,
  Tukey-fence IQR outlier rejection (a GC pause or a noisy neighbor
  cannot become the reported runtime), median of the kept samples, and a
  noise-floor estimate (the IQR of the kept samples, in µs) recorded
  alongside every measurement — two candidates whose medians differ by
  less than the noise floor are indistinguishable, and downstream
  consumers can say so instead of shipping a fake ranking.  When a
  ``baseline_thunk`` is supplied, baseline and candidate are measured
  *interleaved* (B,C,B,C,...) so slow clock drift (thermal throttling,
  background load ramping) hits both series equally and cancels in the
  ratio.  The clock is injectable for deterministic tests.
* `SimulatedTiming` — the deterministic pseudo-runtime derived from the
  source hash, byte-identical to the historical
  ``timing_mode="simulated"`` path (regression-locked in
  tests/test_timing.py against a committed fixture).  This is what keeps
  serial/parallel/distributed runs bit-comparable.
* `RooflineTiming` — the analytic v5e roofline models that used to be
  inlined in `launch/autotune.py`: modeled kernel time (compute vs HBM
  term, MXU-underfill penalty) with the VMEM-fit constraint as the
  feasibility gate.  The offline fallback when no accelerator is
  attached.

Providers consume a `TimingRequest` and return a `Measurement` (or
``None`` when the request is infeasible — e.g. a genome that does not
tile the shape or busts the VMEM budget).  Each provider reads only the
request fields it needs: wall uses the thunks, simulated the key,
roofline the (kernel, genome) pair.
"""

from __future__ import annotations

import dataclasses
import hashlib
import re
import time
from typing import Any, Callable, Dict, List, Optional, Protocol, Tuple

import numpy as np

# --------------------------------------------------------------------------
# request / result records
# --------------------------------------------------------------------------


@dataclasses.dataclass
class TimingRequest:
    """What to time.  Fields are provider-specific (see module docstring)."""

    thunk: Optional[Callable[[], Any]] = None  # wall: run + block until done
    baseline_thunk: Optional[Callable[[], Any]] = None  # wall: interleave vs this
    key: Optional[str] = None  # simulated: "task:sha"
    kernel: Optional[str] = None  # roofline: model name
    genome: Optional[Dict[str, Any]] = None  # roofline: knob assignment


@dataclasses.dataclass
class Measurement:
    """One timing verdict plus the statistics that produced it."""

    runtime_us: float
    mode: str  # "wall" | "simulated" | "roofline"
    runs: int = 1  # samples collected
    kept: int = 1  # samples surviving outlier rejection
    outliers: int = 0
    noise_floor_us: float = 0.0
    baseline_us: Optional[float] = None  # interleaved companion median
    vmem_bytes: Optional[int] = None  # roofline: modeled VMEM footprint

    @property
    def rank(self) -> float:
        """Drift-cancelled ranking key: the candidate/baseline ratio when an
        interleaved baseline was measured, the raw runtime otherwise."""
        if self.baseline_us:
            return self.runtime_us / self.baseline_us
        return self.runtime_us

    def provenance(self) -> Dict[str, Any]:
        """The ``_meta`` payload persisted beside a tuned genome."""
        out: Dict[str, Any] = {
            "source": "measured" if self.mode == "wall" else "modeled",
            "timing": self.mode,
            "runs": self.runs,
            "kept": self.kept,
            "outliers": self.outliers,
            "noise_floor_us": round(self.noise_floor_us, 3),
        }
        if self.baseline_us is not None:
            out["baseline_us"] = round(self.baseline_us, 3)
        return out


class TimingProvider(Protocol):
    mode: str

    def measure(self, request: TimingRequest) -> Optional[Measurement]: ...


# --------------------------------------------------------------------------
# wall clock
# --------------------------------------------------------------------------


def _iqr_keep(samples: List[float]) -> Tuple[List[float], float]:
    """Tukey fences: keep samples within [q1 - 1.5·IQR, q3 + 1.5·IQR].
    Returns (kept, iqr_of_kept)."""
    arr = np.asarray(samples, dtype=np.float64)
    q1, q3 = np.percentile(arr, [25.0, 75.0])
    iqr = q3 - q1
    # relative slack so a zero-IQR series (all samples equal) doesn't
    # reject neighbors that differ only in float rounding
    slack = 1e-9 * max(abs(q1), abs(q3))
    lo, hi = q1 - 1.5 * iqr - slack, q3 + 1.5 * iqr + slack
    kept = [s for s in samples if lo <= s <= hi]
    if not kept:  # degenerate (can't happen: the median is always in-fence)
        kept = list(samples)
    kq1, kq3 = np.percentile(np.asarray(kept, dtype=np.float64), [25.0, 75.0])
    return kept, float(kq3 - kq1)


class WallClockTiming:
    """Measured on-hardware timing with statistical hardening.

    ``clock`` defaults to ``time.perf_counter`` and is injectable so the
    statistics are testable without real hardware (tests/test_timing.py
    drives it with a scripted fake clock).
    """

    mode = "wall"

    def __init__(
        self,
        timing_runs: int = 15,
        warmup_runs: int = 2,
        clock: Optional[Callable[[], float]] = None,
    ):
        if timing_runs < 1:
            raise ValueError(f"timing_runs must be >= 1, got {timing_runs}")
        self.timing_runs = timing_runs
        self.warmup_runs = max(0, warmup_runs)
        self.clock = clock or time.perf_counter

    def _series(self, thunk: Callable[[], Any]) -> float:
        t0 = self.clock()
        thunk()
        return self.clock() - t0

    def measure(self, request: TimingRequest) -> Optional[Measurement]:
        thunk = request.thunk
        if thunk is None:
            raise ValueError("WallClockTiming requires TimingRequest.thunk")
        baseline = request.baseline_thunk
        # warmup: untimed, interleaved when a baseline rides along so both
        # arrive at the timed section equally warm
        for _ in range(self.warmup_runs):
            if baseline is not None:
                baseline()
            thunk()
        cand: List[float] = []
        base: List[float] = []
        # interleaved B,C,B,C,... — drift (thermal, background load) moves
        # both series together and cancels in the ratio
        for _ in range(self.timing_runs):
            if baseline is not None:
                base.append(self._series(baseline))
            cand.append(self._series(thunk))
        kept, iqr = _iqr_keep(cand)
        m = Measurement(
            runtime_us=float(np.median(kept) * 1e6),
            mode=self.mode,
            runs=self.timing_runs,
            kept=len(kept),
            outliers=self.timing_runs - len(kept),
            noise_floor_us=float(iqr * 1e6),
        )
        if base:
            bkept, _ = _iqr_keep(base)
            m.baseline_us = float(np.median(bkept) * 1e6)
        return m


# --------------------------------------------------------------------------
# simulated (deterministic pseudo-runtime)
# --------------------------------------------------------------------------


def pseudo_runtime_us(key: str) -> float:
    """Deterministic stand-in runtime in [50, 1050) µs for a ``task:sha``
    key.  The exact historical ``timing_mode="simulated"`` formula — any
    change here breaks bit-comparability with every recorded run, which is
    why tests/test_timing.py locks it against a committed fixture."""
    h = int(hashlib.sha1(key.encode()).hexdigest()[:12], 16)
    return 50.0 + (h % 1_000_000) / 1000.0


class SimulatedTiming:
    """Byte-identical to the historical simulated path: runtime is a pure
    function of the ``task:sha`` key, noise floor is exactly zero."""

    mode = "simulated"

    def measure(self, request: TimingRequest) -> Optional[Measurement]:
        if request.key is None:
            raise ValueError("SimulatedTiming requires TimingRequest.key")
        return Measurement(
            runtime_us=pseudo_runtime_us(request.key),
            mode=self.mode,
            runs=1,
            kept=1,
            outliers=0,
            noise_floor_us=0.0,
        )


# --------------------------------------------------------------------------
# roofline (analytic v5e models, moved verbatim from launch/autotune.py)
# --------------------------------------------------------------------------

VMEM_BYTES = 128 * 2**20  # v5e VMEM per core (we budget half for double-buffering)
VMEM_BUDGET = VMEM_BYTES // 2


def _peaks() -> Tuple[float, float]:
    from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

    return PEAK_FLOPS_BF16, HBM_BW


def model_flash(g, *, s=8192, h=32, d=128, b=1):
    peak, bw = _peaks()
    bq, bk = g["block_q"], g["block_k"]
    if s % bq or s % bk:
        return None
    n_tiles = (s // bq) * (s // bk) * h * b
    flops_tile = 2 * bq * bk * d * 2  # qk^T and pv
    bytes_tile = (bq * d + 2 * bk * d) * 2  # q stays resident per q row
    # causal: ~half the tiles contribute
    t_compute = 0.5 * n_tiles * flops_tile / peak
    t_memory = 0.5 * n_tiles * bytes_tile / bw
    # MXU alignment penalty: dims below 128 underfill the systolic array
    util = min(bq, 128) / 128 * min(bk, 128) / 128
    t_compute /= max(util, 1e-3)
    vmem = (bq * d + bk * d * 2) * 2 + bq * (d + 2) * 4
    return max(t_compute, t_memory), vmem


def model_matmul(g, *, m=8192, n=8192, k=8192):
    peak, bw = _peaks()
    bm, bn, bk = g["block_m"], g["block_n"], g["block_k"]
    if m % bm or n % bn or k % bk:
        return None
    tiles = (m // bm) * (n // bn) * (k // bk)
    t_compute = 2 * m * n * k / peak
    bytes_total = tiles * (bm * bk + bk * bn) * 2 + (m // bm) * (n // bn) * bm * bn * 2
    t_memory = bytes_total / bw
    util = min(bm, 128) / 128 * min(bn, 128) / 128 * min(bk, 128) / 128
    vmem = (bm * bk + bk * bn) * 2 + bm * bn * 4
    return max(t_compute / max(util, 1e-3), t_memory), vmem


def model_wkv6(g, *, s=8192, h=32, kd=64, b=8):
    peak, bw = _peaks()
    c = g["chunk"]
    if s % c:
        return None
    n_chunks = (s // c) * h * b
    flops = n_chunks * (2 * c * kd * kd * 3 + 2 * c * c * kd * 2)
    bytes_ = n_chunks * (4 * c * kd * 2 + c * kd * 4)
    vmem = 5 * c * kd * 4 + kd * kd * 4
    # small chunks underfill the MXU on the (c x c) intra matmul
    util = min(c, 128) / 128
    return max(flops / peak / max(util, 1e-3), bytes_ / bw), vmem


def model_flash_decode(g, *, b=32, s=8192, h=32, kvh=8, d=128):
    """Paged flash-decode at the serving shape: one query token per
    sequence against an s-token paged KV history.  Decode attention is
    HBM-bound, so the model is a bandwidth term plus two overheads the
    genome actually trades off: per-page DMA issue cost (small pages ->
    more descriptors) and per-grid-step cost (small tiles -> longer
    sequential split-K sweep), with a tail-waste factor for the
    partially-filled last tile of each sequence."""
    peak, bw = _peaks()
    ps, bp = g["page_size"], g["block_pages"]
    if s % ps or (s // ps) % bp:
        return None
    tile = ps * bp
    grp = h // kvh
    # K+V bf16 traffic, read once per kv head (the GQA-grouped grid)
    t_memory = b * kvh * (2 * s * d * 2) * (1.0 + tile / (2.0 * s)) / bw
    flops = b * h * s * d * 2 * 2
    util = min(tile, 128) / 128 * min(grp, 128) / 128
    t_compute = flops / peak / max(util, 1e-3)
    n_tiles = b * kvh * (s // tile)
    n_pages = b * kvh * (s // ps)
    t_overhead = n_tiles * 150e-9 + n_pages * 2 * 30e-9
    # gather buffers (pool dtype) + fp32 softmax state per group
    vmem = 2 * tile * d * 2 + grp * (d + 2) * 4
    return max(t_compute, t_memory) + t_overhead, vmem


ROOFLINE_MODELS = {
    "flash": model_flash,
    "flash_decode": model_flash_decode,
    "matmul": model_matmul,
    "wkv6": model_wkv6,
}


class RooflineTiming:
    """Analytic genome scoring: modeled seconds from the v5e roofline,
    ``None`` when the genome does not tile the benchmark shape or its
    working set busts the VMEM budget (the g(p) != 0 constraint)."""

    mode = "roofline"

    def __init__(self, vmem_budget: int = VMEM_BUDGET):
        self.vmem_budget = vmem_budget

    def measure(self, request: TimingRequest) -> Optional[Measurement]:
        if request.kernel is None or request.genome is None:
            raise ValueError("RooflineTiming requires TimingRequest.kernel + genome")
        model = ROOFLINE_MODELS.get(request.kernel)
        if model is None:
            raise KeyError(f"no roofline model for kernel {request.kernel!r}")
        out = model(request.genome)
        if out is None:
            return None
        t, vmem = out
        if vmem > self.vmem_budget:
            return None
        return Measurement(
            runtime_us=t * 1e6,
            mode=self.mode,
            runs=1,
            kept=1,
            outliers=0,
            noise_floor_us=0.0,
            vmem_bytes=int(vmem),
        )


# --------------------------------------------------------------------------
# backend detection + factories
# --------------------------------------------------------------------------

_device_kind_cache: Optional[str] = None


def normalize_device_kind(kind: str) -> str:
    """Registry-key form of a jax ``device_kind`` string: lowercase,
    non-alphanumerics collapsed to ``_`` ("TPU v5e" -> "tpu_v5e")."""
    return re.sub(r"[^a-z0-9]+", "_", kind.lower()).strip("_") or "cpu"


def device_kind() -> str:
    """The attached backend's normalized device kind ("cpu" when jax is
    unavailable or uninitialized-safe detection fails).  Cached: a
    process's devices do not change."""
    global _device_kind_cache
    if _device_kind_cache is None:
        try:
            import jax

            d = jax.devices()[0]
            _device_kind_cache = normalize_device_kind(
                getattr(d, "device_kind", None) or d.platform
            )
        except Exception:  # noqa: BLE001 — detection is best-effort
            _device_kind_cache = "cpu"
    return _device_kind_cache


def has_accelerator() -> bool:
    """True when jax sees a non-CPU backend (TPU/GPU)."""
    try:
        import jax

        return jax.devices()[0].platform != "cpu"
    except Exception:  # noqa: BLE001
        return False


def resolve_timing_mode(mode: str) -> str:
    """``auto`` -> measured wall-clock when a real accelerator is attached,
    the roofline model otherwise; explicit modes pass through."""
    if mode == "auto":
        return "wall" if has_accelerator() else "roofline"
    if mode not in ("wall", "roofline", "simulated"):
        raise ValueError(f"unknown timing mode {mode!r}")
    return mode


def provider_for(
    mode: str,
    *,
    timing_runs: int = 15,
    warmup_runs: int = 2,
    clock: Optional[Callable[[], float]] = None,
) -> TimingProvider:
    """Build the provider for a (resolved) timing mode."""
    mode = resolve_timing_mode(mode)
    if mode == "wall":
        return WallClockTiming(timing_runs=timing_runs, warmup_runs=warmup_runs, clock=clock)
    if mode == "simulated":
        return SimulatedTiming()
    return RooflineTiming()


def provider_from_config(config) -> TimingProvider:
    """The evaluator's provider: ``EvalConfig.timing_mode`` plus its
    run-count knobs (config is any object with timing_mode / timing_runs /
    warmup_runs attributes)."""
    return provider_for(
        config.timing_mode,
        timing_runs=config.timing_runs,
        warmup_runs=config.warmup_runs,
    )
