"""SyntheticLLM — the offline stand-in for the LLM code generator.

A seeded stochastic source-to-source engine over each task's genome space,
with an explicit FAULT MODEL so validity is a measurable outcome:

  * with p_syntax    : emit genuinely broken source (unbalanced paren,
                       missing name, bad indent) -> fails stage 1 for real;
  * with p_semantic  : emit compiling-but-wrong code (perturbed constant,
                       wrong axis, off-by-one slice) -> fails stage 2 for real;
  * otherwise        : a genome move — exploration (random genome) vs
                       exploitation (neighbor of a parent, biased toward
                       knob choices whose measured gains the insight store
                       recorded) at the method's `explore` rate.

The information regime modulates behavior exactly as the paper argues it
does for real LLMs: parents (I2) anchor proposals near known-good genomes;
insights (I3) steer knob choices; their absence means wide random search.
Every proposal also states a one-line insight (knob -> choice), the
"solution-insight pair" the paper's methods produce.
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.insights import InsightStore
from repro.core.traverse import GuidingConfig, InformationBundle
from repro.proposers.base import Proposal, Proposer
from repro.tasks.base import KernelTask


def _break_syntax(source: str, rng: np.random.Generator) -> str:
    """Introduce a real stage-1 fault."""
    mode = int(rng.integers(4))
    lines = source.splitlines()
    body_idx = [i for i, l in enumerate(lines) if l.startswith("    ") and l.strip()]
    if not body_idx:
        return source + "\n)"
    i = body_idx[int(rng.integers(len(body_idx)))]
    if mode == 0:  # unbalanced paren
        lines[i] = lines[i] + ")"
    elif mode == 1:  # undefined name
        lines[i] = re.sub(r"\bjnp\b", "jnp_undefined", lines[i], count=1)
    elif mode == 2:  # bad indent
        lines[i] = " " + lines[i]
    else:  # truncated response (the classic LLM failure)
        lines = lines[: max(3, len(lines) - int(rng.integers(1, 4)))]
    return "\n".join(lines)


def _break_semantics(source: str, rng: np.random.Generator) -> str:
    """Introduce a real stage-2 fault: compiles, wrong output."""
    candidates = [
        (r"axis=-1", "axis=0"),
        (r"jnp\.maximum", "jnp.minimum"),
        (r"\+ 1e-05", "+ 1e-01"),
        (r"(\W)0\.5(\W)", r"\g<1>0.55\g<2>"),
        (r"i:i\+step", "i:i+step-1"),
        (r"jnp\.exp", "jnp.expm1"),
        (r"jnp\.sum", "jnp.mean"),
        (r"jnp\.concatenate", "lambda a, axis=0: jnp.concatenate(a[::-1], axis=axis)"),
        (r" @ ", " + 0.001 + @ "),  # may also be a syntax break — still a fault
    ]
    order = rng.permutation(len(candidates))
    for j in order:
        pat, rep = candidates[int(j)]
        new, n = re.subn(pat, rep, source, count=1)
        if n:
            return new
    # fallback: scale the return value
    return source.replace("return out", "return out * 1.01")


def _reward_hack(task: KernelTask, source: str, rng: np.random.Generator) -> str:
    """Introduce a reward-hacking attempt: the kernel is wrapped so it is
    exactly right on the benchmark configuration and wrong (or
    NaN-laundering) everywhere else — it passes the legacy fixed-shape
    fixed-seed gate but is caught by the strict tier ladder (fuzz shapes /
    NaN probe).  Shape checks are static under jit, so both wrappers
    compile and trace cleanly."""
    shape = tuple(np.asarray(task.make_inputs(0)[0]).shape)
    hacked = re.sub(r"\bdef kernel\b", "def _inner", source, count=1)
    if int(rng.integers(2)) == 0:
        wrapper = (
            "\n\ndef kernel(*args):\n"
            "    # 'optimized' fast path: special-cases the benchmark shape\n"
            f"    if args[0].shape == {shape}:\n"
            "        return _inner(*args)\n"
            "    return _inner(*args) * 0.0\n"
        )
    else:
        wrapper = (
            "\n\ndef kernel(*args):\n"
            "    # 'numerical stabilization': launders NaN/Inf to zeros\n"
            "    return jnp.nan_to_num(_inner(*args))\n"
        )
    return hacked + wrapper


class SyntheticLLM(Proposer):
    name = "synthetic"

    def __init__(self, insight_store: Optional[InsightStore] = None):
        self.insight_store = insight_store

    # ------------------------------------------------------------------
    def propose(
        self,
        task: KernelTask,
        prompt: str,
        bundle: InformationBundle,
        guiding: GuidingConfig,
        fault,
        rng: np.random.Generator,
    ) -> Proposal:
        # the diagnosis regime of the lead parent (profiler-in-the-loop
        # feedback): with it, insight bias conditions on the bound regime —
        # mirroring how a real LLM would weigh "this helped while
        # memory-bound" differently once told the parent is compute-bound
        regime = None
        if guiding.use_diagnosis and bundle.diagnosis:
            bound = bundle.diagnosis.get("bound")
            if bound in ("compute", "memory"):
                regime = bound
        genome, knob, choice, parent_sid = self._pick_genome(
            task, bundle, guiding, fault, rng, regime
        )
        source = task.render(genome)
        insight = (
            f"set {knob}={choice}" if knob else f"try genome {genome}"
        )

        r = rng.random()
        if r < fault.p_syntax:
            source = _break_syntax(source, rng)
            insight = "(response was malformed)"
            genome = None
        elif r < fault.p_syntax + fault.p_semantic:
            source = _break_semantics(source, rng)
            insight = f"set {knob}={choice} (subtly wrong)"
            genome = None
        elif fault.p_hack and r < fault.p_syntax + fault.p_semantic + fault.p_hack:
            # reuses the single fault draw above: a zero p_hack (every
            # pre-existing method) consumes no extra RNG, keeping their
            # proposal streams bit-identical
            source = _reward_hack(task, source, rng)
            insight = f"set {knob}={choice} (tuned to the benchmark shape)"
            genome = None

        return Proposal(
            source=source,
            genome=genome,
            insight=insight,
            knob=knob,
            choice=choice,
            parent_sid=parent_sid,
            tokens_out=max(1, len(source) // 4 + len(insight) // 4),
        )

    # ------------------------------------------------------------------
    def _pick_genome(self, task, bundle, guiding, fault, rng, regime=None):
        parents = [s for s in bundle.historical if s.genome]
        explore = rng.random() < fault.explore or not parents

        if explore or bundle.operator in ("e1", "convert"):
            genome = task.random_genome(rng)
            # insights bias even exploration (I3): prefer knob choices with
            # positive measured gain
            genome = self._apply_insight_bias(task, genome, guiding, rng, regime=regime)
            return genome, None, None, None

        # exploitation: move near a parent
        if bundle.operator == "e2" and len(parents) >= 2:
            # crossover: per-knob uniform pick between two parents
            a, b = parents[0], parents[1]
            genome = {
                k: (a.genome if rng.random() < 0.5 else b.genome).get(
                    k, task.naive_genome[k]
                )
                for k in task.genome_space
            }
            return genome, None, None, a.sid
        parent = parents[int(rng.integers(len(parents)))]
        base = {k: parent.genome.get(k, task.naive_genome[k]) for k in task.genome_space}
        knob = self._pick_knob(task, guiding, rng, regime=regime)
        genome, knob, choice = task.neighbor_genome(base, rng, knob=knob)
        genome = self._apply_insight_bias(task, genome, guiding, rng, keep=knob, regime=regime)
        return genome, knob, genome[knob], parent.sid

    def _pick_knob(self, task, guiding, rng, regime=None) -> Optional[str]:
        """With insights, prefer knobs with the largest observed |gain|
        (restricted to the parent's bound regime when diagnosis gives one)."""
        if not (guiding.use_insights and self.insight_store):
            return None
        bias = self.insight_store.knob_bias(regime=regime)
        knobs = [k for k in task.genome_space if k in bias]
        if not knobs or rng.random() < 0.3:
            return None
        weights = np.array(
            [max(abs(g) for g in bias[k].values()) + 1e-3 for k in knobs]
        )
        weights = weights / weights.sum()
        return knobs[int(rng.choice(len(knobs), p=weights))]

    def _apply_insight_bias(self, task, genome, guiding, rng, keep=None, regime=None):
        if not (guiding.use_insights and self.insight_store):
            return genome
        bias = self.insight_store.knob_bias(regime=regime)
        g = dict(genome)
        for knob, choices in bias.items():
            if knob == keep or knob not in task.genome_space:
                continue
            best_choice, best_gain = max(choices.items(), key=lambda kv: kv[1])
            if best_gain > 0 and rng.random() < 0.6:
                # unhash tuples back to lists where needed
                for cand in task.genome_space[knob]:
                    if cand == best_choice or (
                        isinstance(best_choice, tuple) and list(best_choice) == cand
                    ):
                        g[knob] = cand
                        break
        return g
