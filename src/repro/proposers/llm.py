"""Real-LLM proposers, rebuilt on the provider-agnostic `LLMClient`
transport (EXPERIMENTS.md §Proposer batching documents the API; all
offline results still use the SyntheticLLM engine).

`LLMProposer` owns the protocol: render nothing itself (the Prompt
Engineering Layer's prompt arrives verbatim), request a single ``kernel``
function plus a one-line insight, extract the kernel-defining code block
from the response.  Transport concerns — retry/backoff, rate limiting,
token-budget backpressure — live in the client (`repro.proposers.client`).

``propose_batch`` issues up to ``concurrency`` requests at once on a
thread pool and returns proposals in submission order, which is what lets
`EvolutionEngine(pipeline=True)` overlap generation with evaluation.  The
proposer draws nothing from the engine RNG (``batchable = True``): retry
jitter is derived per ``(seed, request_id, attempt)`` inside the client,
so batched runs stay bit-identical to serial ones.

A request refused by the token-budget gate degrades to a *budget-exhausted
fallback*: the task's initial source with a marker insight, charged
nothing (``issued=False`` — no request went to the wire).  The trial still
happens (the evaluator's source-hash cache makes it nearly free) and the
run ends within budget instead of crashing mid-batch.
"""

from __future__ import annotations

import re
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import List, Optional, Sequence

import numpy as np

from repro.core.solution import count_tokens
from repro.core.traverse import GuidingConfig, InformationBundle
from repro.proposers.base import Proposal, ProposalRequest, Proposer
from repro.proposers.client import (
    AnthropicClient,
    CompletionRequest,
    LLMClient,
    TokenBudgetExceeded,
    OpenAIClient,
    TransportError,
)
from repro.tasks.base import KernelTask

_CODE_RE = re.compile(r"```(?:python)?\n(.*?)```", re.S)
_INSIGHT_RE = re.compile(r"(?:insight|rationale)\s*[:\-]\s*(.+)", re.I)
# the block we asked for defines (or assigns) `kernel`
_KERNEL_DEF_RE = re.compile(r"^\s*(?:def\s+kernel\b|kernel\s*=)", re.M)

BUDGET_EXHAUSTED_INSIGHT = "[budget-exhausted: request not issued]"
TRANSPORT_FAILED_INSIGHT = "[transport-failed: retries exhausted]"


def _extract(text: str) -> Proposal:
    """Parse a model response into a Proposal.

    Responses often contain several code blocks (scratch snippets, usage
    examples) before the actual answer — prefer the first block that
    defines ``kernel``, falling back to the first block, then to the raw
    text."""
    blocks = _CODE_RE.findall(text)
    source = text
    if blocks:
        source = next((b for b in blocks if _KERNEL_DEF_RE.search(b)), blocks[0])
    im = _INSIGHT_RE.search(text)
    insight = im.group(1).strip() if im else ""
    return Proposal(source=source, insight=insight, tokens_out=count_tokens(text))


class LLMProposer(Proposer):
    """Protocol layer over an `LLMClient`; concrete providers below just
    pick the default client."""

    name = "llm"
    batchable = True

    def __init__(self, client: LLMClient, max_tokens: int = 4096,
                 temperature: float = 0.8, concurrency: int = 8):
        self.client = client
        self.max_tokens = max_tokens
        self.temperature = temperature
        self.concurrency = max(1, concurrency)
        self._id_lock = threading.Lock()
        self._next_request_id = 0

    # ------------------------------------------------------------------
    def _take_request_id(self) -> int:
        with self._id_lock:
            rid = self._next_request_id
            self._next_request_id += 1
            return rid

    def _make_comp_request(self, request: ProposalRequest, request_id: int) -> CompletionRequest:
        return CompletionRequest(
            prompt=request.prompt,
            max_tokens=self.max_tokens,
            temperature=self.temperature,
            request_id=request_id,
        )

    def _fallback(self, request: ProposalRequest, insight: str) -> Proposal:
        """Degraded trial: the task's initial source (nearly free to
        evaluate — source-hash cache) with a marker insight, so the run
        keeps its schedule instead of dying mid-batch."""
        return Proposal(
            source=request.task.initial_source, insight=insight, tokens_out=0,
            issued=False,
        )

    def _complete_one(
        self,
        request: ProposalRequest,
        request_id: int,
        pre_reserved: bool = False,
        comp_req: Optional[CompletionRequest] = None,
    ) -> Proposal:
        if comp_req is None:
            comp_req = self._make_comp_request(request, request_id)
        try:
            comp = self.client.complete(comp_req, pre_reserved=pre_reserved)
        except TokenBudgetExceeded:
            return self._fallback(request, BUDGET_EXHAUSTED_INSIGHT)
        except TransportError:
            # retries exhausted on a transient fault: losing one proposal
            # beats losing the whole batch (non-retryable faults — auth,
            # malformed request — still raise)
            return self._fallback(request, TRANSPORT_FAILED_INSIGHT)
        proposal = _extract(comp.text)
        proposal.tokens_in = comp.tokens_in
        proposal.tokens_out = comp.tokens_out or proposal.tokens_out
        return proposal

    # ------------------------------------------------------------------
    def propose(self, task: KernelTask, prompt: str, bundle: InformationBundle,
                guiding: GuidingConfig, fault, rng: np.random.Generator) -> Proposal:
        request = ProposalRequest(
            task=task, prompt=prompt, bundle=bundle, guiding=guiding, fault=fault
        )
        return self._complete_one(request, self._take_request_id())

    def propose_batch(
        self, requests: Sequence[ProposalRequest], rng: np.random.Generator
    ) -> List[Proposal]:
        """Issue up to ``concurrency`` requests at once; results align with
        ``requests`` by index regardless of completion order.  Request ids
        are assigned in submission order before any worker runs, so retry
        jitter and rate-limit accounting are schedule-independent.

        Budget admission is decided up-front, in submission order, by
        reserving every admitted request's worst-case cost before any
        worker starts — which requests degrade to the budget fallback near
        exhaustion is therefore deterministic, not a thread race.  (This
        is more conservative than the serial loop, which returns each
        request's est-vs-actual headroom before the next reserve.)"""
        if not requests:
            return []
        rids = [self._take_request_id() for _ in requests]
        if len(requests) == 1:
            return [self._complete_one(requests[0], rids[0])]
        comp_reqs = [
            self._make_comp_request(r, rid) for r, rid in zip(requests, rids)
        ]
        admitted = [self.client.reserve(cr) for cr in comp_reqs]
        with ThreadPoolExecutor(
            max_workers=min(self.concurrency, len(requests))
        ) as pool:
            futures = [
                pool.submit(self._complete_one, r, rid, True, cr) if ok else None
                for r, rid, cr, ok in zip(requests, rids, comp_reqs, admitted)
            ]
            return [
                f.result() if f is not None else self._fallback(r, BUDGET_EXHAUSTED_INSIGHT)
                for f, r in zip(futures, requests)
            ]


class AnthropicProposer(LLMProposer):
    name = "anthropic"

    def __init__(self, model: str = "claude-sonnet-4-20250514",
                 api_key: Optional[str] = None, max_tokens: int = 4096,
                 temperature: float = 0.8, client: Optional[LLMClient] = None,
                 concurrency: int = 8):
        super().__init__(
            client or AnthropicClient(model=model, api_key=api_key),
            max_tokens=max_tokens, temperature=temperature, concurrency=concurrency,
        )


class OpenAIProposer(LLMProposer):
    name = "openai"

    def __init__(self, model: str = "gpt-4.1-2025-04-14",
                 api_key: Optional[str] = None, max_tokens: int = 4096,
                 temperature: float = 0.8, client: Optional[LLMClient] = None,
                 concurrency: int = 8):
        super().__init__(
            client or OpenAIClient(model=model, api_key=api_key),
            max_tokens=max_tokens, temperature=temperature, concurrency=concurrency,
        )
