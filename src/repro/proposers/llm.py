"""Real-LLM proposers over HTTPS (unexercised offline; implemented for
production use — EXPERIMENTS.md records that all offline results use the
SyntheticLLM engine instead).

Both clients render the prompt from the Prompt Engineering Layer verbatim,
request a single ``kernel`` function plus a one-line insight, and extract
the first python code block from the response.
"""

from __future__ import annotations

import json
import os
import re
import urllib.request
from typing import Optional

import numpy as np

from repro.core.traverse import GuidingConfig, InformationBundle
from repro.proposers.base import Proposal, Proposer
from repro.tasks.base import KernelTask

_CODE_RE = re.compile(r"```(?:python)?\n(.*?)```", re.S)
_INSIGHT_RE = re.compile(r"(?:insight|rationale)\s*[:\-]\s*(.+)", re.I)


def _extract(text: str) -> Proposal:
    m = _CODE_RE.search(text)
    source = m.group(1) if m else text
    im = _INSIGHT_RE.search(text)
    insight = im.group(1).strip() if im else ""
    return Proposal(
        source=source, insight=insight, tokens_out=max(1, len(text) // 4)
    )


class AnthropicProposer(Proposer):
    name = "anthropic"

    def __init__(self, model: str = "claude-sonnet-4-20250514", api_key: Optional[str] = None,
                 max_tokens: int = 4096, temperature: float = 0.8):
        self.model = model
        self.api_key = api_key or os.environ.get("ANTHROPIC_API_KEY", "")
        self.max_tokens = max_tokens
        self.temperature = temperature

    def propose(self, task: KernelTask, prompt: str, bundle: InformationBundle,
                guiding: GuidingConfig, fault, rng: np.random.Generator) -> Proposal:
        req = urllib.request.Request(
            "https://api.anthropic.com/v1/messages",
            data=json.dumps(
                {
                    "model": self.model,
                    "max_tokens": self.max_tokens,
                    "temperature": self.temperature,
                    "messages": [{"role": "user", "content": prompt}],
                }
            ).encode(),
            headers={
                "x-api-key": self.api_key,
                "anthropic-version": "2023-06-01",
                "content-type": "application/json",
            },
        )
        with urllib.request.urlopen(req, timeout=120) as resp:
            body = json.loads(resp.read())
        text = "".join(
            b.get("text", "") for b in body.get("content", []) if b.get("type") == "text"
        )
        return _extract(text)


class OpenAIProposer(Proposer):
    name = "openai"

    def __init__(self, model: str = "gpt-4.1-2025-04-14", api_key: Optional[str] = None,
                 max_tokens: int = 4096, temperature: float = 0.8):
        self.model = model
        self.api_key = api_key or os.environ.get("OPENAI_API_KEY", "")
        self.max_tokens = max_tokens
        self.temperature = temperature

    def propose(self, task: KernelTask, prompt: str, bundle: InformationBundle,
                guiding: GuidingConfig, fault, rng: np.random.Generator) -> Proposal:
        req = urllib.request.Request(
            "https://api.openai.com/v1/chat/completions",
            data=json.dumps(
                {
                    "model": self.model,
                    "max_tokens": self.max_tokens,
                    "temperature": self.temperature,
                    "messages": [{"role": "user", "content": prompt}],
                }
            ).encode(),
            headers={
                "Authorization": f"Bearer {self.api_key}",
                "content-type": "application/json",
            },
        )
        with urllib.request.urlopen(req, timeout=120) as resp:
            body = json.loads(resp.read())
        text = body["choices"][0]["message"]["content"]
        return _extract(text)
