"""Solution generation: synthetic mutation engine + real-LLM HTTP clients."""

from repro.proposers.base import Proposal, Proposer
from repro.proposers.synthetic import SyntheticLLM
from repro.proposers.llm import AnthropicProposer, OpenAIProposer

__all__ = [
    "AnthropicProposer",
    "OpenAIProposer",
    "Proposal",
    "Proposer",
    "SyntheticLLM",
]
