"""Solution generation: synthetic mutation engine + real-LLM proposers
over the provider-agnostic `LLMClient` transport."""

from repro.proposers.base import Proposal, ProposalRequest, Proposer
from repro.proposers.client import (
    AnthropicClient,
    Completion,
    CompletionRequest,
    LLMClient,
    MockClient,
    OpenAIClient,
    RateLimiter,
    RetryPolicy,
    SimulatedLatencyClient,
    TokenBudgetExceeded,
    TokenBudgetGate,
    TransportError,
)
from repro.proposers.llm import AnthropicProposer, LLMProposer, OpenAIProposer
from repro.proposers.synthetic import SyntheticLLM

__all__ = [
    "AnthropicClient",
    "AnthropicProposer",
    "Completion",
    "CompletionRequest",
    "LLMClient",
    "LLMProposer",
    "MockClient",
    "OpenAIClient",
    "OpenAIProposer",
    "Proposal",
    "ProposalRequest",
    "Proposer",
    "RateLimiter",
    "RetryPolicy",
    "SimulatedLatencyClient",
    "SyntheticLLM",
    "TokenBudgetExceeded",
    "TokenBudgetGate",
    "TransportError",
]
