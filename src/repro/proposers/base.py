"""Proposer interface: prompt (+ structured bundle) -> candidate source.

``propose_batch`` is the engine-facing primary interface: the engine
prepares one `ProposalRequest` per trial (consuming its seeded RNG in
trial order) and hands the whole batch over.  The base implementation
simply loops ``propose`` in submission order — so `SyntheticLLM`, whose
``propose`` draws from the engine RNG, keeps the exact serial draw order.
Proposers whose transport consumes *no* engine RNG (the `LLMClient`-backed
ones) set ``batchable = True`` and override ``propose_batch`` to issue the
requests concurrently, returning results in submission order.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core.traverse import GuidingConfig, InformationBundle
from repro.tasks.base import KernelTask


@dataclasses.dataclass
class Proposal:
    source: str
    genome: Optional[Dict[str, Any]] = None
    insight: str = ""
    # what changed relative to the parent (structured view for the insight
    # store; None for from-scratch proposals)
    knob: Optional[str] = None
    choice: Any = None
    parent_sid: Optional[str] = None
    # actual prompt tokens from the provider's usage field when available
    # (0 = unknown; the engine falls back to the count_tokens estimate)
    tokens_in: int = 0
    tokens_out: int = 0
    # False for degraded fallbacks whose request never went to the wire
    # (budget-exhausted / transport-failed) — the engine charges the token
    # ledger only for issued proposals
    issued: bool = True


@dataclasses.dataclass
class ProposalRequest:
    """One trial's fully-rendered generation request, prepared by the
    engine against the population/insight state at the batch start."""

    task: KernelTask
    prompt: str
    bundle: InformationBundle
    guiding: GuidingConfig
    fault: Any
    trial: int = -1


class Proposer:
    """One generation step.  Real-LLM proposers use only ``prompt``;
    the synthetic engine additionally reads the structured bundle."""

    name = "base"
    # True iff ``propose`` never draws from the engine RNG, making it safe
    # for the engine to prepare a whole batch of requests up-front and for
    # the proposer to complete them concurrently.  RNG-consuming proposers
    # (SyntheticLLM) must leave this False: their draw order is part of the
    # seeded-run contract.
    batchable = False

    def propose(
        self,
        task: KernelTask,
        prompt: str,
        bundle: InformationBundle,
        guiding: GuidingConfig,
        fault,
        rng: np.random.Generator,
    ) -> Proposal:
        raise NotImplementedError

    def propose_batch(
        self, requests: Sequence[ProposalRequest], rng: np.random.Generator
    ) -> List[Proposal]:
        """Complete a batch of prepared requests; results align with
        ``requests`` by index (submission order)."""
        return [
            self.propose(r.task, r.prompt, r.bundle, r.guiding, r.fault, rng)
            for r in requests
        ]
