"""Proposer interface: prompt (+ structured bundle) -> candidate source."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import numpy as np

from repro.core.traverse import GuidingConfig, InformationBundle
from repro.tasks.base import KernelTask


@dataclasses.dataclass
class Proposal:
    source: str
    genome: Optional[Dict[str, Any]] = None
    insight: str = ""
    # what changed relative to the parent (structured view for the insight
    # store; None for from-scratch proposals)
    knob: Optional[str] = None
    choice: Any = None
    parent_sid: Optional[str] = None
    tokens_out: int = 0


class Proposer:
    """One generation step.  Real-LLM proposers use only ``prompt``;
    the synthetic engine additionally reads the structured bundle."""

    name = "base"

    def propose(
        self,
        task: KernelTask,
        prompt: str,
        bundle: InformationBundle,
        guiding: GuidingConfig,
        fault,
        rng: np.random.Generator,
    ) -> Proposal:
        raise NotImplementedError
