"""Provider-agnostic LLM transport: the batched proposer API's bottom layer.

The proposal stack is split in two.  `LLMClient` owns *transport* — one
``complete(CompletionRequest) -> Completion`` call per generation, with
retry/backoff, rate limiting and token-budget backpressure handled here —
while `repro.proposers.llm.LLMProposer` owns *protocol* (prompt in, kernel
source + insight out).  Swapping providers, or swapping the network away
entirely for offline tests and benchmarks, changes only the client.

Concurrency contract: ``complete`` is thread-safe and is called from up to
``LLMProposer.concurrency`` worker threads at once.  Everything stochastic
is derived from ``(seed, request_id, attempt)`` — never from a shared RNG
cursor — so retry jitter is bit-identical no matter how threads interleave,
which is what keeps pipelined engine runs reproducible (see
EXPERIMENTS.md §Proposer batching).

Backpressure: a `TokenBudgetGate` wraps the run's `TokenLedger`.  Before a
request is issued the gate *reserves* its worst-case token cost
(prompt estimate + ``max_tokens``); a request that cannot reserve raises
`TokenBudgetExceeded` instead of going to the wire, and the reservation is
released once the call settles (the engine then charges actuals to the
ledger).  In-flight requests therefore count against the budget, so K
concurrent workers cannot collectively overshoot it.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
import urllib.error
import urllib.request
from typing import Any, Callable, Dict, List, Optional, Union

import numpy as np

from repro.core.solution import TokenLedger, count_tokens


# ---------------------------------------------------------------------------
# request / response records
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class CompletionRequest:
    prompt: str
    max_tokens: int = 4096
    temperature: float = 0.8
    # submission index within the run — drives deterministic retry jitter
    # and lets tests assert ordering; assigned by the proposer.
    request_id: int = 0


@dataclasses.dataclass
class Completion:
    text: str
    tokens_in: int = 0
    tokens_out: int = 0
    model: str = ""
    latency_s: float = 0.0
    attempts: int = 1


class TransportError(RuntimeError):
    """Retryable transport fault (network error, 408/429/529, 5xx).

    ``retry_after_s`` carries the server's ``Retry-After`` hint when one
    was present (429/529/503 responses typically set it); the retry loop
    honors it as a floor under its own backoff."""

    def __init__(self, message: str, retry_after_s: Optional[float] = None):
        super().__init__(message)
        self.retry_after_s = retry_after_s


class TokenBudgetExceeded(RuntimeError):
    """The TokenLedger budget cannot cover this request; it was not issued."""


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class RetryPolicy:
    """Exponential backoff with jitter derived from a seeded RNG.

    The jitter for attempt ``a`` of request ``r`` comes from
    ``default_rng((seed, r, a))`` — a pure function of the coordinates, so
    the delay schedule is reproducible across runs and independent of
    thread interleaving (a shared RNG cursor would not be).

    Two bounds keep a request from outliving its usefulness:
    ``total_deadline_s`` caps the *whole* retry loop (first byte of
    attempt 1 to the last backoff sleep) — once the next sleep would
    cross the deadline the loop gives up with the last error instead of
    sleeping through it; ``sleep_cap_s`` clamps any single sleep (after
    the server's ``Retry-After`` floor is applied), so a pathological
    hint can't park a worker thread for minutes.
    """

    max_attempts: int = 4
    base_delay_s: float = 0.5
    max_delay_s: float = 30.0
    jitter: float = 0.5  # uniform [0, jitter) * backoff added on top
    seed: int = 0
    total_deadline_s: Optional[float] = None  # None: attempts bound only
    sleep_cap_s: float = 60.0

    def delay_s(self, request_id: int, attempt: int,
                retry_after_s: Optional[float] = None) -> float:
        """Sleep before retry number ``attempt`` (1-based) of a request.
        A server ``Retry-After`` hint acts as a floor under the computed
        backoff; ``sleep_cap_s`` clamps the result either way."""
        backoff = min(self.max_delay_s, self.base_delay_s * (2.0 ** (attempt - 1)))
        rng = np.random.default_rng((self.seed, request_id, attempt))
        delay = backoff * (1.0 + self.jitter * float(rng.random()))
        if retry_after_s is not None:
            delay = max(delay, retry_after_s)
        return min(delay, self.sleep_cap_s)


class RateLimiter:
    """Thread-safe request-start spacing: at most ``requests_per_s`` starts
    per second, enforced as a minimum interval between consecutive starts
    (shared across all threads using this client)."""

    def __init__(self, requests_per_s: float):
        if requests_per_s <= 0:
            raise ValueError("requests_per_s must be positive")
        self.interval_s = 1.0 / requests_per_s
        self._lock = threading.Lock()
        self._next_start = 0.0
        self.waited_s = 0.0  # cumulative, for stats/tests

    def acquire(self) -> float:
        """Block until a request may start; returns the time waited."""
        with self._lock:
            now = time.monotonic()
            wait = max(0.0, self._next_start - now)
            self._next_start = max(now, self._next_start) + self.interval_s
            self.waited_s += wait
        if wait > 0:
            time.sleep(wait)
        return wait


class TokenBudgetGate:
    """Backpressure between a `TokenLedger` budget and in-flight requests.

    ``reserve(est)`` succeeds only while ``used + reserved + est`` fits the
    budget, where ``used`` is the larger of the ledger's charged total and
    the gate's own running total of *settled* request costs.  The second
    term matters because the engine charges the ledger only after a whole
    batch returns: between a request settling and that charge landing, the
    settled cost would otherwise be invisible and a sequential burst could
    overshoot the budget.  `LLMClient.complete` calls ``settle`` when the
    call finishes (success or failure), swapping the worst-case
    reservation for the actual cost.  A ``budget`` of None (on both gate
    and ledger) means unlimited.
    """

    def __init__(self, ledger: TokenLedger, budget: Optional[int] = None):
        self.ledger = ledger
        self._budget_override = budget
        self._lock = threading.Lock()
        self._reserved = 0
        self._settled = 0
        self.denied = 0  # requests refused at the gate, for stats/tests

    @property
    def budget(self) -> Optional[int]:
        """Read the ledger's budget live (unless explicitly overridden):
        `EvolutionEngine.resume()` restores ``ledger.budget`` from the
        checkpoint, and a gate built before that must enforce the restored
        value, not a constructor-time snapshot."""
        if self._budget_override is not None:
            return self._budget_override
        return self.ledger.budget

    def _used(self) -> int:
        # lock held by caller
        return max(self.ledger.total, self._settled)

    def remaining(self) -> Optional[int]:
        if self.budget is None:
            return None
        with self._lock:
            return max(0, self.budget - self._used() - self._reserved)

    def reserve(self, est_tokens: int) -> bool:
        if self.budget is None:
            return True
        with self._lock:
            if self._used() + self._reserved + est_tokens > self.budget:
                self.denied += 1
                return False
            self._reserved += est_tokens
            return True

    def settle(self, est_tokens: int, actual_tokens: int) -> None:
        """Replace a reservation with the request's actual token cost
        (0 for a request that ultimately failed)."""
        if self.budget is None:
            return
        with self._lock:
            self._reserved = max(0, self._reserved - est_tokens)
            self._settled += actual_tokens


# ---------------------------------------------------------------------------
# client base
# ---------------------------------------------------------------------------
class LLMClient:
    """Transport base: budget gate -> rate limit -> retrying ``_send``."""

    name = "base"

    def __init__(
        self,
        retry: Optional[RetryPolicy] = None,
        rate_limiter: Optional[RateLimiter] = None,
        budget_gate: Optional[TokenBudgetGate] = None,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ):
        self.retry = retry or RetryPolicy()
        self.rate_limiter = rate_limiter
        self.budget_gate = budget_gate
        # injectable for deterministic timeout tests (scripted clock)
        self._clock = clock
        self._sleep = sleep

    # -- overridden by concrete transports --------------------------------
    def _send(self, request: CompletionRequest) -> Completion:
        raise NotImplementedError

    # ---------------------------------------------------------------------
    def _estimate_cost(self, request: CompletionRequest) -> int:
        """Worst-case token cost reserved at the gate: the prompt estimate
        plus the full response allowance."""
        return count_tokens(request.prompt) + request.max_tokens

    def reserve(self, request: CompletionRequest) -> bool:
        """Reserve the request's worst-case budget cost without sending it;
        True when admitted (always, if no gate is configured).  Callers
        that reserve up-front MUST then issue the request with
        ``complete(request, pre_reserved=True)`` so the reservation is
        settled — `LLMProposer.propose_batch` uses this to decide batch
        admission in submission order before any worker thread starts."""
        if self.budget_gate is None:
            return True
        return self.budget_gate.reserve(self._estimate_cost(request))

    def complete(self, request: CompletionRequest, pre_reserved: bool = False) -> Completion:
        """Run the request through gate -> rate limit -> retrying _send.

        ``pre_reserved=True`` means the caller already holds this request's
        budget reservation (``budget_gate.reserve(_estimate_cost(req))``) —
        `LLMProposer.propose_batch` reserves for a whole batch up-front in
        submission order, so which requests are admitted near budget
        exhaustion is deterministic rather than a thread race.  The
        reservation is settled here either way."""
        est = self._estimate_cost(request)
        if (
            not pre_reserved
            and self.budget_gate is not None
            and not self.budget_gate.reserve(est)
        ):
            raise TokenBudgetExceeded(
                f"request {request.request_id} needs ~{est} tokens; "
                f"budget remaining {self.budget_gate.remaining()}"
            )
        comp: Optional[Completion] = None
        try:
            comp = self._complete_with_retry(request)
            return comp
        finally:
            if self.budget_gate is not None:
                # settle with what the engine will charge for this request
                # (prompt estimate + response tokens); 0 if it failed
                actual = (
                    count_tokens(request.prompt) + comp.tokens_out if comp else 0
                )
                self.budget_gate.settle(est, actual)

    def _complete_with_retry(self, request: CompletionRequest) -> Completion:
        t0 = self._clock()
        deadline = (
            None if self.retry.total_deadline_s is None
            else t0 + self.retry.total_deadline_s
        )
        last: Optional[TransportError] = None
        for attempt in range(1, self.retry.max_attempts + 1):
            if self.rate_limiter is not None:
                self.rate_limiter.acquire()
            try:
                comp = self._send(request)
            except TransportError as e:
                last = e
                if attempt < self.retry.max_attempts:
                    delay = self.retry.delay_s(
                        request.request_id, attempt,
                        retry_after_s=e.retry_after_s,
                    )
                    if deadline is not None and self._clock() + delay > deadline:
                        raise TransportError(
                            f"request {request.request_id} abandoned after "
                            f"{attempt} attempt(s): next retry would cross the "
                            f"{self.retry.total_deadline_s:.1f}s deadline "
                            f"(last error: {last})"
                        ) from last
                    self._sleep(delay)
                continue
            if not comp.tokens_in:
                comp.tokens_in = count_tokens(request.prompt)
            if not comp.tokens_out:
                comp.tokens_out = count_tokens(comp.text)
            comp.latency_s = self._clock() - t0
            comp.attempts = attempt
            return comp
        raise TransportError(
            f"request {request.request_id} failed after "
            f"{self.retry.max_attempts} attempts: {last}"
        )

    def close(self) -> None:  # symmetric with ParallelEvaluator.close()
        pass


# ---------------------------------------------------------------------------
# concrete transports
# ---------------------------------------------------------------------------
class AnthropicClient(LLMClient):
    name = "anthropic"
    url = "https://api.anthropic.com/v1/messages"

    def __init__(self, model: str = "claude-sonnet-4-20250514",
                 api_key: Optional[str] = None, timeout_s: float = 120.0, **kw):
        super().__init__(**kw)
        self.model = model
        self.api_key = api_key or os.environ.get("ANTHROPIC_API_KEY", "")
        self.timeout_s = timeout_s

    def _send(self, request: CompletionRequest) -> Completion:
        req = urllib.request.Request(
            self.url,
            data=json.dumps(
                {
                    "model": self.model,
                    "max_tokens": request.max_tokens,
                    "temperature": request.temperature,
                    "messages": [{"role": "user", "content": request.prompt}],
                }
            ).encode(),
            headers={
                "x-api-key": self.api_key,
                "anthropic-version": "2023-06-01",
                "content-type": "application/json",
            },
        )
        body = _http_json(req, self.timeout_s)
        text = "".join(
            b.get("text", "") for b in body.get("content", []) if b.get("type") == "text"
        )
        usage = body.get("usage", {})
        return Completion(
            text=text,
            tokens_in=int(usage.get("input_tokens", 0)),
            tokens_out=int(usage.get("output_tokens", 0)),
            model=body.get("model", self.model),
        )


class OpenAIClient(LLMClient):
    name = "openai"
    url = "https://api.openai.com/v1/chat/completions"

    def __init__(self, model: str = "gpt-4.1-2025-04-14",
                 api_key: Optional[str] = None, timeout_s: float = 120.0, **kw):
        super().__init__(**kw)
        self.model = model
        self.api_key = api_key or os.environ.get("OPENAI_API_KEY", "")
        self.timeout_s = timeout_s

    def _send(self, request: CompletionRequest) -> Completion:
        req = urllib.request.Request(
            self.url,
            data=json.dumps(
                {
                    "model": self.model,
                    "max_tokens": request.max_tokens,
                    "temperature": request.temperature,
                    "messages": [{"role": "user", "content": request.prompt}],
                }
            ).encode(),
            headers={
                "Authorization": f"Bearer {self.api_key}",
                "content-type": "application/json",
            },
        )
        body = _http_json(req, self.timeout_s)
        text = body["choices"][0]["message"]["content"]
        usage = body.get("usage", {})
        return Completion(
            text=text,
            tokens_in=int(usage.get("prompt_tokens", 0)),
            tokens_out=int(usage.get("completion_tokens", 0)),
            model=body.get("model", self.model),
        )


_RETRYABLE_HTTP = {408, 409, 429, 500, 502, 503, 504, 529}


def _retry_after_s(headers) -> Optional[float]:
    """Parse a ``Retry-After`` header's delay-seconds form (the HTTP-date
    form is rare on API endpoints and not worth a date parser; it reads
    as "no hint")."""
    if headers is None:
        return None
    raw = headers.get("Retry-After")
    if raw is None:
        return None
    try:
        return max(0.0, float(raw))
    except (TypeError, ValueError):
        return None


def _http_json(req: urllib.request.Request, timeout_s: float) -> Dict[str, Any]:
    """POST and decode, mapping transient failures to `TransportError`
    (including 408 timeouts and 529 overloads, carrying any ``Retry-After``
    hint for the retry loop)."""
    try:
        with urllib.request.urlopen(req, timeout=timeout_s) as resp:
            return json.loads(resp.read())
    except urllib.error.HTTPError as e:
        if e.code in _RETRYABLE_HTTP:
            raise TransportError(
                f"HTTP {e.code}", retry_after_s=_retry_after_s(e.headers)
            ) from e
        raise
    except (urllib.error.URLError, TimeoutError, OSError) as e:
        raise TransportError(str(e)) from e


# ---------------------------------------------------------------------------
# offline transports (tests + benchmarks)
# ---------------------------------------------------------------------------
_DEFAULT_REPLY = (
    "Insight: mock completion\n"
    "```python\n"
    "def kernel(x):\n"
    "    return x\n"
    "```\n"
)


class MockClient(LLMClient):
    """In-memory transport.  ``reply`` is the response text, a list cycled
    by request_id, or ``callable(request) -> str``.  ``failures`` maps
    request_id -> number of leading `TransportError`s before success, so
    retry behavior is scriptable per request.  Every wire-level attempt is
    recorded in ``calls`` as ``(request_id, attempt, monotonic_time)``.
    """

    name = "mock"

    def __init__(
        self,
        reply: Union[str, List[str], Callable[[CompletionRequest], str]] = _DEFAULT_REPLY,
        failures: Optional[Dict[int, int]] = None,
        latency_s: float = 0.0,
        **kw,
    ):
        super().__init__(**kw)
        self.reply = reply
        self.failures = dict(failures or {})
        self.latency_s = latency_s
        self.calls: List[Any] = []
        self._attempts: Dict[int, int] = {}
        self._lock = threading.Lock()

    def _latency_for(self, request: CompletionRequest) -> float:
        return self.latency_s

    def _send(self, request: CompletionRequest) -> Completion:
        with self._lock:
            attempt = self._attempts.get(request.request_id, 0) + 1
            self._attempts[request.request_id] = attempt
            self.calls.append((request.request_id, attempt, time.monotonic()))
            must_fail = attempt <= self.failures.get(request.request_id, 0)
        lat = self._latency_for(request)
        if lat > 0:
            time.sleep(lat)
        if must_fail:
            raise TransportError(
                f"scripted failure {attempt} for request {request.request_id}"
            )
        if callable(self.reply):
            text = self.reply(request)
        elif isinstance(self.reply, list):
            text = self.reply[request.request_id % len(self.reply)]
        else:
            text = self.reply
        return Completion(text=text, model=self.name)


class SimulatedLatencyClient(MockClient):
    """MockClient with a per-request service time — the offline stand-in
    for real API latency that the throughput benchmark measures against.
    ``latency_jitter`` adds a deterministic per-request component drawn
    from ``default_rng((seed, request_id))``, modelling provider variance
    without breaking reproducibility."""

    name = "simulated"

    def __init__(self, latency_s: float = 0.05, latency_jitter: float = 0.0,
                 seed: int = 0, **kw):
        super().__init__(latency_s=latency_s, **kw)
        self.latency_jitter = latency_jitter
        self.seed = seed

    def _latency_for(self, request: CompletionRequest) -> float:
        if not self.latency_jitter:
            return self.latency_s
        rng = np.random.default_rng((self.seed, request.request_id))
        return self.latency_s + self.latency_jitter * float(rng.random())
