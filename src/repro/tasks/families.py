"""Task-family builders: genome spaces + source renderers per category.

Every renderer emits a self-contained Python module defining
``kernel(*inputs)``.  Genomes span REAL implementation choices with REAL
wall-clock differences on the evaluation host (precision, algorithmic
formulation, loop vs vectorized structure, library primitives), so measured
speedups are genuine — the CPU analogue of the paper's CUDA optimization
headroom.  The naive genome mirrors the paper's deliberately-unoptimized
initial kernels.
"""

from __future__ import annotations

import textwrap
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.tasks.base import KernelTask, register
from repro.verify.properties import (
    homogeneous,
    permute_rows_equivariant,
    shift_invariant,
)

_HEADER = "import jax\nimport jax.numpy as jnp\nfrom functools import partial\n\n"


def _rng_inputs(shapes, seed, scale=1.0, positive=False, dtype=np.float32):
    rng = np.random.default_rng(seed)
    out = []
    for sh in shapes:
        a = rng.standard_normal(sh).astype(dtype) * scale
        if positive:
            a = np.abs(a) + 0.1
        out.append(a)
    return tuple(out)


def _fuzz_inputs(shape_tuples, seed, scale=1.0, positive=False):
    """Tier-2 fuzz cases: one input tuple per entry of ``shape_tuples``
    (each entry = the full shape list for one call), seeds offset per
    entry so no two cases share data.  Shapes are deliberately ragged /
    non-multiple-of-block / degenerate — a candidate special-cased to the
    benchmark configuration fails here."""
    return [
        _rng_inputs(list(shapes), seed + i, scale, positive)
        for i, shapes in enumerate(shape_tuples)
    ]


def _dtype_lines(genome) -> Tuple[str, str]:
    """(pre-cast line, post-cast expr) for the precision knob."""
    if genome.get("dtype", "float32") == "float64":
        return (
            "    args = [jnp.asarray(a, jnp.float64) for a in args]\n",
            ".astype(jnp.float32)",
        )
    return ("", "")


# ==========================================================================
# 1. Matrix multiplication (18)
# ==========================================================================
def _mm_render(spec):
    """Matmul source renderer.

    loop_rows / blocked always materialize transposed copies (the naive
    path); einsum / dot_general honor the pre_transpose knob (False folds
    the transpose into contraction dims — no copy).
    """

    def render(genome: Dict[str, Any]) -> str:
        pre, post = _dtype_lines(genome)
        impl = genome["impl"]
        ta, tb = spec["ta"], spec["tb"]
        batched = bool(spec.get("batched"))
        swap_a = "a = jnp.swapaxes(a, -1, -2)\n    " if ta else ""
        swap_b = "b = jnp.swapaxes(b, -1, -2)\n    " if tb else ""
        if impl == "loop_rows":
            nch = genome.get("chunks", 8)
            body = f"""
    {swap_a}{swap_b}chunks = []
    n = a.shape[{1 if batched else 0}]
    step = max(1, n // {nch})
    for i in range(0, n, step):
        chunks.append(a[{':, ' if batched else ''}i:i+step] @ b)
    out = jnp.concatenate(chunks, axis={1 if batched else 0})
"""
        elif impl == "blocked":
            blk = genome.get("block", 64)
            body = f"""
    {swap_a}{swap_b}k = a.shape[-1]
    acc = jnp.zeros(a.shape[:-1] + (b.shape[-1],), a.dtype)
    for ks in range(0, k, {blk}):
        acc = acc + a[..., ks:ks+{blk}] @ b[..., ks:ks+{blk}, :]
    out = acc
"""
        elif impl == "einsum":
            if genome.get("pre_transpose", True):
                sub_a, sub_b = "ik", "kj"
                prep = swap_a + swap_b
            else:
                sub_a = "ki" if ta else "ik"
                sub_b = "jk" if tb else "kj"
                prep = ""
            bpre = "b" if batched else ""
            body = f"    {prep}out = jnp.einsum('{bpre}{sub_a},{bpre}{sub_b}->{bpre}ij', a, b)\n"
        else:  # dot_general
            off = 1 if batched else 0
            if genome.get("pre_transpose", True):
                prep = swap_a + swap_b
                ca, cb = 1 + off, 0 + off
            else:
                prep = ""
                ca = (0 if ta else 1) + off
                cb = (1 if tb else 0) + off
            batch_dims = "((0,), (0,))" if batched else "((), ())"
            body = (
                f"    {prep}out = jax.lax.dot_general(a, b, "
                f"((({ca},), ({cb},)), {batch_dims}))\n"
            )
        return _HEADER + f"def kernel(a, b):\n    args = [a, b]\n{pre}    a, b = args\n{body}    return out{post}\n"

    return render


def _mm_ref(spec):
    def ref(a, b):
        a = jnp.asarray(a)
        b = jnp.asarray(b)
        if spec["ta"]:
            a = jnp.swapaxes(a, -1, -2)
        if spec["tb"]:
            b = jnp.swapaxes(b, -1, -2)
        return a @ b

    return ref


def _mm_fuzz(ta, tb, batched):
    """Ragged (m, k, n) triples re-laid-out under the task's transpose /
    batch convention: degenerate rows, inner dim 1, nothing a multiple of
    any tile size."""

    def shapes(m, k, n, b):
        a = (k, m) if ta else (m, k)
        bsh = (n, k) if tb else (k, n)
        if batched:
            return [(b,) + a, (b,) + bsh]
        return [a, bsh]

    cases = [shapes(7, 13, 5, 1), shapes(1, 9, 4, 2), shapes(6, 1, 3, 3)]
    return lambda seed: _fuzz_inputs(cases, seed, 0.5)


def make_matmul_task(name, desc, a_shape, b_shape, *, ta=False, tb=False, batched=False):
    spec = {"ta": ta, "tb": tb, "batched": batched}
    space = {
        "impl": ["loop_rows", "blocked", "einsum", "dot_general"],
        "dtype": ["float64", "float32"],
        "block": [8, 16, 32, 64, 128],
        "chunks": [4, 8, 16, 32, 64],
        "pre_transpose": [True, False],
    }
    naive = {
        "impl": "loop_rows",
        "dtype": "float32",
        "block": 8,
        "chunks": 64,
        "pre_transpose": True,
    }
    return register(
        KernelTask(
            name=name,
            category="matmul",
            description=desc,
            make_inputs=lambda seed: _rng_inputs([a_shape, b_shape], seed, 0.5),
            ref=_mm_ref(spec),
            genome_space=space,
            render=_mm_render(spec),
            naive_genome=naive,
            rtol=5e-3,
            atol=5e-3,
            fuzz_cases=_mm_fuzz(ta, tb, batched),
            # bilinear in each operand
            properties=(homogeneous(arg=0), homogeneous(arg=1)),
        )
    )


# ==========================================================================
# 2. Convolution (28)
# ==========================================================================
def _conv_dim_numbers(nd):
    return {1: ("NCH", "OIH", "NCH"), 2: ("NCHW", "OIHW", "NCHW"), 3: ("NCDHW", "OIDHW", "NCDHW")}[nd]


def _conv_ref(spec):
    nd = spec["nd"]
    dn = _conv_dim_numbers(nd)

    def ref(x, w):
        return jax.lax.conv_general_dilated(
            jnp.asarray(x),
            jnp.asarray(w),
            window_strides=spec["stride"],
            padding=spec["padding"],
            rhs_dilation=spec["dilation"],
            lhs_dilation=spec.get("lhs_dilation", (1,) * nd),
            feature_group_count=spec.get("groups", 1),
            dimension_numbers=dn,
        )

    return ref


def _conv_render(spec):
    nd = spec["nd"]
    dn = _conv_dim_numbers(nd)

    def render(genome):
        pre, post = _dtype_lines(genome)
        impl = genome["impl"]
        if impl == "lax_conv":
            body = f"""
    out = jax.lax.conv_general_dilated(
        x, w, window_strides={spec['stride']}, padding={spec['padding']!r},
        rhs_dilation={spec['dilation']}, lhs_dilation={spec.get('lhs_dilation', (1,)*nd)},
        feature_group_count={spec.get('groups', 1)},
        dimension_numbers={dn},
    )
"""
        elif impl == "taps_loop":
            body = f"""
    out = _taps_conv(x, w, {spec['stride']}, {spec['padding']!r}, {spec['dilation']},
                     {spec.get('lhs_dilation', (1,)*nd)}, {spec.get('groups', 1)})
"""
        else:  # im2col
            body = f"""
    out = _im2col_conv(x, w, {spec['stride']}, {spec['padding']!r},
                       {spec['dilation']}, {spec.get('lhs_dilation', (1,)*nd)},
                       {spec.get('groups', 1)})
"""
        single = f"def _single(x, w):\n{body}    return out\n"
        if genome.get("batch_loop", False):
            call = (
                "    out = jnp.concatenate(\n"
                "        [_single(x[i:i+1], w) for i in range(x.shape[0])], axis=0)\n"
            )
        else:
            call = "    out = _single(x, w)\n"
        return (
            _HEADER
            + _CONV_HELPERS
            + single
            + f"\ndef kernel(x, w):\n    args = [x, w]\n{pre}    x, w = args\n{call}    return out{post}\n"
        )

    return render


_CONV_HELPERS = textwrap.dedent(
    '''
    def _dilate(x, lhs_dilation):
        if all(d == 1 for d in lhs_dilation):
            return x
        sp = x.shape[2:]
        new = tuple((s - 1) * d + 1 for s, d in zip(sp, lhs_dilation))
        out = jnp.zeros(x.shape[:2] + new, x.dtype)
        idx = (slice(None), slice(None)) + tuple(
            slice(None, None, d) for d in lhs_dilation)
        return out.at[idx].set(x)

    def _pad_input(x, w, stride, padding, dilation):
        nd = x.ndim - 2
        if isinstance(padding, str):
            eff_k = tuple((w.shape[2 + i] - 1) * dilation[i] + 1 for i in range(nd))
            if padding == "SAME":
                pads = []
                for i in range(nd):
                    out_sz = -(-x.shape[2 + i] // stride[i])
                    total = max(0, (out_sz - 1) * stride[i] + eff_k[i] - x.shape[2 + i])
                    pads.append((total // 2, total - total // 2))
            else:
                pads = [(0, 0)] * nd
        else:
            pads = list(padding)
        cfg = [(0, 0), (0, 0)] + [(p[0], p[1]) for p in pads]
        return jnp.pad(x, cfg)

    def _taps_conv(x, w, stride, padding, dilation, lhs_dilation, groups):
        x = _dilate(x, lhs_dilation)
        xp = _pad_input(x, w, stride, padding if not isinstance(padding, str)
                        else padding, dilation)
        nd = x.ndim - 2
        co, ci_g = w.shape[0], w.shape[1]
        out = None
        ksizes = w.shape[2:]
        out_sp = tuple(
            (xp.shape[2 + i] - ((ksizes[i] - 1) * dilation[i] + 1)) // stride[i] + 1
            for i in range(nd))
        for g in range(groups):
            xg = xp[:, g * ci_g * groups // groups:, ...] if False else xp
            cig0 = g * (xp.shape[1] // groups)
            xg = xp[:, cig0:cig0 + xp.shape[1] // groups]
            og = None
            import itertools
            for taps in itertools.product(*[range(k) for k in ksizes]):
                sl = (slice(None), slice(None)) + tuple(
                    slice(t * dilation[i],
                          t * dilation[i] + out_sp[i] * stride[i], stride[i])
                    for i, t in enumerate(taps))
                patch = xg[sl]
                wt = w[g * (co // groups):(g + 1) * (co // groups),
                       (slice(None),) if False else slice(None)][
                    (slice(None), slice(None)) + tuple(slice(t, t + 1) for t in taps)]
                wt = wt.reshape(co // groups, xp.shape[1] // groups)
                contrib = jnp.tensordot(patch, wt, axes=((1,), (1,)))
                contrib = jnp.moveaxis(contrib, -1, 1)
                og = contrib if og is None else og + contrib
            out = og if out is None else jnp.concatenate([out, og], axis=1)
        return out

    def _im2col_conv(x, w, stride, padding, dilation, lhs_dilation, groups):
        x = _dilate(x, lhs_dilation)
        nd = x.ndim - 2
        pads = jax.lax.padtype_to_pads(x.shape[2:], tuple(
            (w.shape[2 + i] - 1) * dilation[i] + 1 for i in range(nd)),
            stride, padding) if isinstance(padding, str) else padding
        dn = {1: ("NCH", "OIH", "NCH"), 2: ("NCHW", "OIHW", "NCHW"),
              3: ("NCDHW", "OIDHW", "NCDHW")}[nd]
        patches = jax.lax.conv_general_dilated_patches(
            x, w.shape[2:], stride, pads, rhs_dilation=dilation,
            dimension_numbers=dn)
        n = x.shape[0]
        co = w.shape[0]
        wf = w.reshape(groups, co // groups, -1)
        pf = patches.reshape(n, groups, wf.shape[-1], -1)
        out = jnp.einsum('ngkp,ngok->ngop', pf, wf[None].repeat(n, 0)
                         if False else jnp.broadcast_to(wf, (n,) + wf.shape))
        return out.reshape((n, co) + patches.shape[2:])

    '''
)


def make_conv_task(
    name, desc, x_shape, w_shape, *, stride, padding, dilation,
    lhs_dilation=None, groups=1,
):
    nd = len(x_shape) - 2
    spec = {
        "nd": nd,
        "stride": stride,
        "padding": padding,
        "dilation": dilation,
        "groups": groups,
    }
    if lhs_dilation:
        spec["lhs_dilation"] = lhs_dilation
    # fuzz: keep channels/weights fixed (groups must divide), vary batch +
    # spatial dims; effective kernel extent lower-bounds VALID spatials
    eff = tuple((w_shape[2 + i] - 1) * dilation[i] + 1 for i in range(nd))
    fuzz_shapes = [
        [(1, x_shape[1]) + tuple(e + 4 for e in eff), w_shape],
        [(3, x_shape[1]) + tuple(e + 7 for e in eff), w_shape],
    ]
    impls = ["taps_loop", "im2col", "lax_conv"] if nd <= 2 else ["taps_loop", "lax_conv"]
    space = {
        "impl": impls,
        "dtype": ["float64", "float32"],
        "batch_loop": [True, False],
    }
    naive = {"impl": "taps_loop", "dtype": "float32", "batch_loop": True}
    return register(
        KernelTask(
            name=name,
            category="conv",
            description=desc,
            make_inputs=lambda seed: _rng_inputs([x_shape, w_shape], seed, 0.3),
            ref=_conv_ref(spec),
            genome_space=space,
            render=_conv_render(spec),
            naive_genome=naive,
            rtol=2e-3,
            atol=2e-3,
            fuzz_cases=lambda seed: _fuzz_inputs(fuzz_shapes, seed, 0.3),
            # bilinear in activations and weights
            properties=(homogeneous(arg=0), homogeneous(arg=1)),
        )
    )


# ==========================================================================
# 3. Activation & pooling (21)
# ==========================================================================
_ACT_EXPRS = {
    "relu": "jnp.maximum(x, 0)",
    "leaky_relu": "jnp.where(x >= 0, x, 0.01 * x)",
    "elu": "jnp.where(x >= 0, x, jnp.exp(x) - 1.0)",
    "selu": "1.0507 * jnp.where(x >= 0, x, 1.67326 * (jnp.exp(x) - 1.0))",
    "gelu": "0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))",
    "silu": "x * (1.0 / (1.0 + jnp.exp(-x)))",
    "mish": "x * jnp.tanh(jnp.logaddexp(x, 0.0))",
    "sigmoid": "1.0 / (1.0 + jnp.exp(-x))",
    "tanh": "jnp.tanh(x)",
    "hardtanh": "jnp.clip(x, -1.0, 1.0)",
    "softplus": "jnp.logaddexp(x, 0.0)",
    "softsign": "x / (1.0 + jnp.abs(x))",
}


def _act_render(op):
    def render(genome):
        pre, post = _dtype_lines(genome)
        expr = _ACT_EXPRS[op]
        if genome["impl"] == "chunked_loop":
            nch = genome.get("chunks", 16)
            body = f"""
    flat = x.reshape(-1)
    outs = []
    step = max(1, flat.shape[0] // {nch})
    for i in range(0, flat.shape[0], step):
        x = flat[i:i+step]
        outs.append({expr})
    out = jnp.concatenate(outs).reshape(args[0].shape)
"""
        else:
            body = f"    out = {expr}\n"
        return _HEADER + f"def kernel(x):\n    args = [x]\n{pre}    x, = args\n{body}    return out{post}\n"

    return render


def make_activation_task(name, op, shape):
    fns = {
        "relu": lambda x: jax.nn.relu(x),
        "leaky_relu": lambda x: jax.nn.leaky_relu(x, 0.01),
        "elu": lambda x: jax.nn.elu(x),
        "selu": lambda x: jax.nn.selu(x),
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "silu": lambda x: jax.nn.silu(x),
        "mish": lambda x: x * jnp.tanh(jax.nn.softplus(x)),
        "sigmoid": lambda x: jax.nn.sigmoid(x),
        "tanh": lambda x: jnp.tanh(x),
        "hardtanh": lambda x: jnp.clip(x, -1.0, 1.0),
        "softplus": lambda x: jax.nn.softplus(x),
        "softsign": lambda x: jax.nn.soft_sign(x),
    }
    return register(
        KernelTask(
            name=name,
            category="act_pool",
            description=f"Elementwise {op} activation.",
            make_inputs=lambda seed: _rng_inputs([shape], seed, 2.0),
            ref=fns[op],
            genome_space={
                "impl": ["chunked_loop", "vectorized"],
                "chunks": [8, 16, 32, 64],
                "dtype": ["float64", "float32"],
            },
            render=_act_render(op),
            naive_genome={"impl": "chunked_loop", "chunks": 64, "dtype": "float32"},
            fuzz_cases=lambda seed: _fuzz_inputs(
                [[(7, 33)], [(1, 5)], [(3, 1)]], seed, 2.0
            ),
            # elementwise: row order cannot matter
            properties=(permute_rows_equivariant(),),
        )
    )


def _softmax_render(log: bool):
    def render(genome):
        pre, post = _dtype_lines(genome)
        if genome["impl"] == "unstable":
            core = "e = jnp.exp(x); p = e / jnp.sum(e, axis=-1, keepdims=True)"
        else:
            core = (
                "m = jnp.max(x, axis=-1, keepdims=True); e = jnp.exp(x - m); "
                "p = e / jnp.sum(e, axis=-1, keepdims=True)"
            )
        out = "jnp.log(p)" if log else "p"
        nch = genome.get("rowloop", 0)
        if nch:
            body = f"""
    rows = []
    full = x
    step = max(1, full.shape[0] // {nch})
    for i in range(0, full.shape[0], step):
        x = full[i:i+step]
        {core}
        rows.append({out})
    out = jnp.concatenate(rows, axis=0)
"""
        else:
            body = f"    {core}\n    out = {out}\n"
        return _HEADER + f"def kernel(x):\n    args = [x]\n{pre}    x, = args\n{body}    return out{post}\n"

    return render


def make_softmax_task(name, shape, log=False):
    ref = (lambda x: jax.nn.log_softmax(x, axis=-1)) if log else (
        lambda x: jax.nn.softmax(x, axis=-1)
    )
    return register(
        KernelTask(
            name=name,
            category="act_pool",
            description=("Log-softmax" if log else "Softmax") + " over the last axis.",
            make_inputs=lambda seed: _rng_inputs([shape], seed, 2.0),
            ref=ref,
            genome_space={
                "impl": ["unstable", "stable"],
                "rowloop": [0, 16, 64],
                "dtype": ["float64", "float32"],
            },
            render=_softmax_render(log),
            naive_genome={"impl": "stable", "rowloop": 64, "dtype": "float32"},
            fuzz_cases=lambda seed: _fuzz_inputs(
                [[(7, 33)], [(1, 17)], [(5, 1)]], seed, 2.0
            ),
            # (log-)softmax's defining stability property plus row
            # independence
            properties=(shift_invariant(), permute_rows_equivariant()),
        )
    )


def _pool_render(spec):
    nd, op = spec["nd"], spec["op"]

    def render(genome):
        pre, post = _dtype_lines(genome)
        k, s = spec["k"], spec["s"]
        init = "-jnp.inf" if op == "max" else "0.0"
        comb = "jax.lax.max" if op == "max" else "jax.lax.add"
        wdims = (1, 1) + tuple(k)
        wstr = (1, 1) + tuple(s)
        if genome["impl"] == "stack_slices":
            body = f"""
    import itertools
    acc = None
    sp = x.shape[2:]
    out_sp = tuple((sp[i] - {k}[i]) // {s}[i] + 1 for i in range({nd}))
    for taps in itertools.product(*[range(kk) for kk in {k}]):
        sl = (slice(None), slice(None)) + tuple(
            slice(t, t + out_sp[i] * {s}[i], {s}[i]) for i, t in enumerate(taps))
        patch = x[sl]
        acc = patch if acc is None else ({'jnp.maximum(acc, patch)' if op == 'max' else 'acc + patch'})
    out = acc{' / ' + str(int(np.prod(k))) + '.0' if op == 'avg' else ''}
"""
        else:
            div = f" / {int(np.prod(k))}.0" if op == "avg" else ""
            body = f"""
    out = jax.lax.reduce_window(x, {init}, {comb}, {wdims}, {wstr}, 'VALID'){div}
"""
        single = f"def _single(x):\n{body}    return out\n"
        if genome.get("batch_loop", False):
            call = (
                "    out = jnp.concatenate(\n"
                "        [_single(x[i:i+1]) for i in range(x.shape[0])], axis=0)\n"
            )
        else:
            call = "    out = _single(x)\n"
        return (
            _HEADER
            + single
            + f"\ndef kernel(x):\n    args = [x]\n{pre}    x, = args\n{call}    return out{post}\n"
        )

    return render


def make_pool_task(name, desc, shape, *, k, s, op):
    nd = len(shape) - 2
    spec = {"nd": nd, "k": k, "s": s, "op": op}

    def ref(x):
        init = -jnp.inf if op == "max" else 0.0
        comb = jax.lax.max if op == "max" else jax.lax.add
        out = jax.lax.reduce_window(
            jnp.asarray(x), init, comb, (1, 1) + tuple(k), (1, 1) + tuple(s), "VALID"
        )
        if op == "avg":
            out = out / float(np.prod(k))
        return out

    return register(
        KernelTask(
            name=name,
            category="act_pool",
            description=desc,
            make_inputs=lambda seed: _rng_inputs([shape], seed, 1.0),
            ref=ref,
            genome_space={
                "impl": ["stack_slices", "reduce_window"],
                "batch_loop": [True, False],
                "dtype": ["float64", "float32"],
            },
            render=_pool_render(spec),
            naive_genome={"impl": "stack_slices", "batch_loop": True, "dtype": "float32"},
            fuzz_cases=lambda seed: _fuzz_inputs(
                [
                    [(2, 3) + tuple(2 * kk + 1 for kk in k)],
                    [(1, 2) + tuple(k)],
                ],
                seed,
                1.0,
            ),
            # positively homogeneous (holds for both max and avg)
            properties=(homogeneous(arg=0, scale=2.0),),
        )
    )
