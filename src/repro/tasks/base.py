"""Task definition + registry for KernelBench-JAX."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

CATEGORIES = (
    "matmul",
    "conv",
    "act_pool",
    "norm_reduce",
    "loss",
    "cumulative",
)

CATEGORY_LABELS = {
    "matmul": "Matrix Multiplication",
    "conv": "Convolution",
    "act_pool": "Activation & Pooling",
    "norm_reduce": "Normalization & Reduction",
    "loss": "Loss Functions",
    "cumulative": "Cumulative Operations",
    # outside the paper's six categories: evaluation-subsystem calibration
    # tasks (registered but excluded from all_tasks()/benchmark_tasks())
    "calibration": "Evaluation Calibration",
}


@dataclasses.dataclass
class KernelTask:
    name: str
    category: str
    description: str
    make_inputs: Callable[[int], Tuple[np.ndarray, ...]]
    ref: Callable[..., Any]  # pure-jnp oracle
    genome_space: Dict[str, List[Any]]
    render: Callable[[Dict[str, Any]], str]  # genome -> python source
    naive_genome: Dict[str, Any]  # the initial (deliberately slow) point
    rtol: float = 2e-4
    atol: float = 2e-4
    # ---- strict-verification declarations (repro.verify) -------------
    # extra input tuples at off-canonical shapes (ragged, non-multiple-of-
    # block, degenerate dims) for the tier-2 fuzz sweep; seeded by the run
    # nonce.  None = fuzz only the canonical shape at nonce seeds.
    fuzz_cases: Optional[Callable[[int], List[Tuple[np.ndarray, ...]]]] = None
    # tier-3 algebraic invariants (repro.verify.properties.PropertySpec)
    properties: Tuple[Any, ...] = ()
    # opt out of the tier-2 NaN-propagation probe for ops whose naive
    # implementation legitimately drops NaN (e.g. sort-based min/argmax)
    nan_probe: bool = True

    @property
    def initial_source(self) -> str:
        return self.render(self.naive_genome)

    def random_genome(self, rng: np.random.Generator) -> Dict[str, Any]:
        return {k: v[int(rng.integers(len(v)))] for k, v in self.genome_space.items()}

    def neighbor_genome(
        self, genome: Dict[str, Any], rng: np.random.Generator, knob: Optional[str] = None
    ) -> Tuple[Dict[str, Any], str, Any]:
        """Mutate one knob; returns (new_genome, knob, new_choice)."""
        knobs = list(self.genome_space)
        knob = knob or knobs[int(rng.integers(len(knobs)))]
        choices = [c for c in self.genome_space[knob] if c != genome.get(knob)]
        if not choices:
            return dict(genome), knob, genome.get(knob)
        choice = choices[int(rng.integers(len(choices)))]
        g = dict(genome)
        g[knob] = choice
        return g, knob, choice

    def task_context(self) -> str:
        """The I1 prompt section."""
        shapes = [tuple(a.shape) for a in self.make_inputs(0)]
        return (
            f"Operation: {self.name} ({CATEGORY_LABELS[self.category]})\n"
            f"{self.description}\n"
            f"Input shapes: {shapes}\n"
            "Target: single JAX function `kernel(*inputs)` matching the "
            "reference within tolerance; minimize wall-clock runtime."
        )


TASK_REGISTRY: Dict[str, KernelTask] = {}


def register(task: KernelTask) -> KernelTask:
    if task.name in TASK_REGISTRY:
        raise ValueError(f"duplicate task {task.name}")
    TASK_REGISTRY[task.name] = task
    return task


def get_task(name: str) -> KernelTask:
    return TASK_REGISTRY[name]


def all_tasks(category: Optional[str] = None) -> List[KernelTask]:
    ts = list(TASK_REGISTRY.values())
    if category:
        return [t for t in ts if t.category == category]
    # the dataset view: only the paper's six categories (calibration tasks
    # stay reachable via get_task / all_tasks("calibration"))
    return [t for t in ts if t.category in CATEGORIES]
