"""Calibration tasks for the evaluation subsystem itself.

These are NOT benchmark tasks (they are excluded from `all_tasks()` /
`benchmark_tasks()`): they exist so the parallel-evaluation pool, the
timeout kill path and the throughput benches can be exercised against a
workload with a *known* cost profile.  ``cal_sleep``'s rendered source
sleeps at module scope, so every evaluation of a distinct source costs
the genome's ``sleep_ms`` during the stage-1 exec — pure, GIL-releasing
wait, which makes pool speedups measurable even on tiny CI hosts.
"""

from __future__ import annotations

import numpy as np

from repro.tasks.base import KernelTask, register


def _cal_inputs(seed: int):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(64).astype(np.float32),)


def _cal_ref(x):
    return x * 2.0 + 1.0


def _render_sleep(genome):
    ms = genome.get("sleep_ms", 50)
    return (
        "import time\n"
        "import jax.numpy as jnp\n\n"
        f"time.sleep({ms} / 1000.0)  # simulated compile cost\n\n\n"
        "def kernel(x):\n"
        "    return x * 2.0 + 1.0\n"
    )


register(
    KernelTask(
        name="cal_quick",
        category="calibration",
        description=(
            "Calibration: cal_sleep's near-free sibling (0-3ms import "
            "cost) — lets multi-process sweep-driver tests run whole "
            "task x method x seed grids in seconds."
        ),
        make_inputs=_cal_inputs,
        ref=_cal_ref,
        genome_space={"sleep_ms": [0, 1, 2, 3]},
        render=_render_sleep,
        naive_genome={"sleep_ms": 1},
    )
)

register(
    KernelTask(
        name="cal_sleep",
        category="calibration",
        description=(
            "Calibration: trivial kernel whose source sleeps sleep_ms at "
            "import — a deterministic per-candidate evaluation cost."
        ),
        make_inputs=_cal_inputs,
        ref=_cal_ref,
        genome_space={"sleep_ms": [10, 25, 50, 100]},
        render=_render_sleep,
        naive_genome={"sleep_ms": 50},
    )
)
