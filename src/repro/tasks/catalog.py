"""The 91-task catalog — counts match the paper's Table 5 exactly.

Sizes are tuned so the naive implementation runs in roughly 0.5–10 ms on the
evaluation host: large enough to time reliably, small enough that a 45-trial
x 6-method x 3-seed sweep is tractable.
"""

from repro.tasks.families import (
    make_activation_task,
    make_conv_task,
    make_matmul_task,
    make_pool_task,
    make_softmax_task,
)
from repro.tasks.families2 import (
    make_cumulative_task,
    make_loss_task,
    make_norm_task,
    make_reduce_task,
)

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Matrix multiplication — 18
# ---------------------------------------------------------------------------
make_matmul_task("mm_square_s", "Square matmul 128x128x128.", (128, 128), (128, 128))
make_matmul_task("mm_square_m", "Square matmul 256x256x256.", (256, 256), (256, 256))
make_matmul_task("mm_square_l", "Square matmul 384x384x384.", (384, 384), (384, 384))
make_matmul_task("mm_tall", "Tall matmul 1024x128 @ 128x128.", (1024, 128), (128, 128))
make_matmul_task("mm_wide", "Wide matmul 128x128 @ 128x1024.", (128, 128), (128, 1024))
make_matmul_task("mm_small_k", "Inner-dim-poor matmul 512x32 @ 32x512.", (512, 32), (32, 512))
make_matmul_task("mm_large_k", "Inner-dim-rich matmul 128x1024 @ 1024x128.", (128, 1024), (1024, 128))
make_matmul_task("mm_at_b", "A^T B matmul.", (256, 192), (256, 160), ta=True)
make_matmul_task("mm_a_bt", "A B^T matmul.", (192, 256), (160, 256), tb=True)
make_matmul_task("mm_at_bt", "A^T B^T matmul.", (256, 192), (160, 256), ta=True, tb=True)
make_matmul_task("mm_gemv", "Matrix-vector product (GEMV as 1-row GEMM).", (1, 768), (768, 768))
make_matmul_task("mm_gevm", "Vector-matrix product.", (768, 768), (768, 1))
make_matmul_task("mm_sym", "Symmetric product A A^T.", (256, 256), (256, 256), tb=True)
make_matmul_task("mm_batched_s", "Batched matmul 8x(128^3).", (8, 128, 128), (8, 128, 128), batched=True)
make_matmul_task("mm_batched_m", "Batched matmul 16x(96x96x160).", (16, 96, 96), (16, 96, 160), batched=True)
make_matmul_task("mm_batched_heads", "Attention-shaped batched matmul 32 heads.", (32, 64, 64), (32, 64, 64), batched=True)
make_matmul_task("mm_batched_bt", "Batched A B^T (score matmul).", (16, 128, 64), (16, 128, 64), tb=True, batched=True)
make_matmul_task("mm_rect3", "Rectangular 320x256 @ 256x192.", (320, 256), (256, 192))

# ---------------------------------------------------------------------------
# Convolution — 28
# ---------------------------------------------------------------------------
# 1D (8)
make_conv_task("conv1d_k3", "1D conv k=3.", (8, 32, 512), (64, 32, 3), stride=(1,), padding="SAME", dilation=(1,))
make_conv_task("conv1d_k5", "1D conv k=5.", (8, 32, 512), (64, 32, 5), stride=(1,), padding="SAME", dilation=(1,))
make_conv_task("conv1d_k7", "1D conv k=7.", (8, 32, 512), (64, 32, 7), stride=(1,), padding="SAME", dilation=(1,))
make_conv_task("conv1d_stride2", "1D conv stride 2.", (8, 32, 512), (64, 32, 3), stride=(2,), padding="SAME", dilation=(1,))
make_conv_task("conv1d_dilated", "1D conv dilation 2.", (8, 32, 512), (64, 32, 3), stride=(1,), padding="SAME", dilation=(2,))
make_conv_task("conv1d_valid", "1D conv VALID padding.", (8, 32, 512), (64, 32, 5), stride=(1,), padding="VALID", dilation=(1,))
make_conv_task("conv1d_depthwise", "1D depthwise conv.", (8, 64, 512), (64, 1, 3), stride=(1,), padding="SAME", dilation=(1,), groups=64)
make_conv_task("conv1d_pointwise", "1D pointwise (1x1) conv.", (8, 64, 512), (128, 64, 1), stride=(1,), padding="VALID", dilation=(1,))
# 2D (14)
make_conv_task("conv2d_3x3", "2D conv 3x3.", (4, 16, 40, 40), (32, 16, 3, 3), stride=(1, 1), padding="SAME", dilation=(1, 1))
make_conv_task("conv2d_5x5", "2D conv 5x5.", (4, 16, 40, 40), (32, 16, 5, 5), stride=(1, 1), padding="SAME", dilation=(1, 1))
make_conv_task("conv2d_1x1", "2D pointwise conv.", (4, 64, 40, 40), (128, 64, 1, 1), stride=(1, 1), padding="VALID", dilation=(1, 1))
make_conv_task("conv2d_stride2", "2D conv stride 2.", (4, 16, 40, 40), (32, 16, 3, 3), stride=(2, 2), padding="SAME", dilation=(1, 1))
make_conv_task("conv2d_dilated2", "2D conv dilation 2.", (4, 16, 40, 40), (32, 16, 3, 3), stride=(1, 1), padding="SAME", dilation=(2, 2))
make_conv_task("conv2d_dilated3", "2D conv dilation 3.", (4, 16, 40, 40), (32, 16, 3, 3), stride=(1, 1), padding="SAME", dilation=(3, 3))
make_conv_task("conv2d_valid", "2D conv VALID.", (4, 16, 40, 40), (32, 16, 3, 3), stride=(1, 1), padding="VALID", dilation=(1, 1))
make_conv_task("conv2d_asym_1x7", "2D conv asymmetric 1x7.", (4, 16, 40, 40), (32, 16, 1, 7), stride=(1, 1), padding="SAME", dilation=(1, 1))
make_conv_task("conv2d_asym_7x1", "2D conv asymmetric 7x1.", (4, 16, 40, 40), (32, 16, 7, 1), stride=(1, 1), padding="SAME", dilation=(1, 1))
make_conv_task("conv2d_depthwise", "2D depthwise conv.", (4, 32, 40, 40), (32, 1, 3, 3), stride=(1, 1), padding="SAME", dilation=(1, 1), groups=32)
make_conv_task("conv2d_grouped4", "2D grouped conv (4 groups).", (4, 32, 40, 40), (64, 8, 3, 3), stride=(1, 1), padding="SAME", dilation=(1, 1), groups=4)
make_conv_task("conv2d_stride2_5x5", "2D conv 5x5 stride 2.", (4, 16, 40, 40), (32, 16, 5, 5), stride=(2, 2), padding="SAME", dilation=(1, 1))
make_conv_task("conv2d_transposed", "2D transposed conv (lhs dilation 2).", (4, 16, 24, 24), (32, 16, 3, 3), stride=(1, 1), padding=((1, 1), (1, 1)), dilation=(1, 1), lhs_dilation=(2, 2))
make_conv_task("conv2d_wide_ch", "2D conv wide channels.", (4, 64, 20, 20), (128, 64, 3, 3), stride=(1, 1), padding="SAME", dilation=(1, 1))
# 3D (6)
make_conv_task("conv3d_3x3x3", "3D conv 3^3.", (2, 8, 16, 16, 16), (16, 8, 3, 3, 3), stride=(1, 1, 1), padding="SAME", dilation=(1, 1, 1))
make_conv_task("conv3d_1x1x1", "3D pointwise conv.", (2, 16, 16, 16, 16), (32, 16, 1, 1, 1), stride=(1, 1, 1), padding="VALID", dilation=(1, 1, 1))
make_conv_task("conv3d_stride2", "3D conv stride 2.", (2, 8, 16, 16, 16), (16, 8, 3, 3, 3), stride=(2, 2, 2), padding="SAME", dilation=(1, 1, 1))
make_conv_task("conv3d_valid", "3D conv VALID.", (2, 8, 16, 16, 16), (16, 8, 3, 3, 3), stride=(1, 1, 1), padding="VALID", dilation=(1, 1, 1))
make_conv_task("conv3d_asym", "3D conv asymmetric 3x1x1.", (2, 8, 16, 16, 16), (16, 8, 3, 1, 1), stride=(1, 1, 1), padding="SAME", dilation=(1, 1, 1))
make_conv_task("conv3d_dilated", "3D conv dilation 2.", (2, 8, 16, 16, 16), (16, 8, 3, 3, 3), stride=(1, 1, 1), padding="SAME", dilation=(2, 2, 2))

# ---------------------------------------------------------------------------
# Activation & pooling — 21 (12 activations + 2 softmax + 7 pooling)
# ---------------------------------------------------------------------------
_ACT_SHAPE = (64, 4096)
for _op in (
    "relu", "leaky_relu", "elu", "selu", "gelu", "silu",
    "mish", "sigmoid", "tanh", "hardtanh", "softplus", "softsign",
):
    make_activation_task(f"act_{_op}", _op, _ACT_SHAPE)
make_softmax_task("act_softmax", (256, 1024))
make_softmax_task("act_log_softmax", (256, 1024), log=True)
make_pool_task("pool_max1d", "1D max-pool k=2 s=2.", (16, 32, 4096), k=(2,), s=(2,), op="max")
make_pool_task("pool_avg1d", "1D avg-pool k=2 s=2.", (16, 32, 4096), k=(2,), s=(2,), op="avg")
make_pool_task("pool_max2d", "2D max-pool 2x2.", (8, 32, 96, 96), k=(2, 2), s=(2, 2), op="max")
make_pool_task("pool_avg2d", "2D avg-pool 2x2.", (8, 32, 96, 96), k=(2, 2), s=(2, 2), op="avg")
make_pool_task("pool_max3d", "3D max-pool 2^3.", (4, 16, 24, 24, 24), k=(2, 2, 2), s=(2, 2, 2), op="max")
make_pool_task("pool_avg3d", "3D avg-pool 2^3.", (4, 16, 24, 24, 24), k=(2, 2, 2), s=(2, 2, 2), op="avg")
make_pool_task("pool_max2d_3x3", "2D max-pool 3x3 stride 2.", (8, 32, 96, 96), k=(3, 3), s=(2, 2), op="max")

# ---------------------------------------------------------------------------
# Normalization & reduction — 15 (6 norms + 9 reductions)
# ---------------------------------------------------------------------------
make_norm_task("norm_layer", "LayerNorm over last dim.", "layernorm", (128, 1024),
               lambda x: (x - jnp.mean(x, -1, keepdims=True)) / jnp.sqrt(jnp.var(x, -1, keepdims=True) + 1e-5))
make_norm_task("norm_rms", "RMSNorm over last dim.", "rmsnorm", (128, 1024),
               lambda x: x / jnp.sqrt(jnp.mean(x * x, -1, keepdims=True) + 1e-5))
make_norm_task("norm_batch", "BatchNorm (training stats) NCHW.", "batchnorm", (16, 32, 16, 16),
               lambda x: (x - jnp.mean(x, (0, 2, 3), keepdims=True)) / jnp.sqrt(jnp.var(x, (0, 2, 3), keepdims=True) + 1e-5))
make_norm_task("norm_group", "GroupNorm (8 groups) NCHW.", "groupnorm", (8, 32, 16, 16),
               lambda x: _groupnorm_ref(x, 8))
make_norm_task("norm_instance", "InstanceNorm NCHW.", "instancenorm", (8, 16, 32, 32),
               lambda x: (x - jnp.mean(x, (2, 3), keepdims=True)) / jnp.sqrt(jnp.var(x, (2, 3), keepdims=True) + 1e-5))
make_norm_task("norm_l2", "L2 normalize rows.", "l2norm", (256, 1024),
               lambda x: x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-5))


def _groupnorm_ref(x, g):
    x = jnp.asarray(x)
    n, c = x.shape[0], x.shape[1]
    xg = x.reshape(n, g, c // g, *x.shape[2:])
    axes = tuple(range(2, xg.ndim))
    m = jnp.mean(xg, axes, keepdims=True)
    v = jnp.var(xg, axes, keepdims=True)
    return ((xg - m) / jnp.sqrt(v + 1e-5)).reshape(x.shape)


make_reduce_task("reduce_sum", "Row sums.", "sum", (512, 2048), lambda x: jnp.sum(x, -1))
make_reduce_task("reduce_mean", "Row means.", "mean", (512, 2048), lambda x: jnp.mean(x, -1))
make_reduce_task("reduce_max", "Row max.", "max", (512, 512), lambda x: jnp.max(x, -1))
make_reduce_task("reduce_min", "Row min.", "min", (512, 512), lambda x: jnp.min(x, -1))
make_reduce_task("reduce_prod", "Row product.", "prod", (256, 256), lambda x: jnp.prod(x, -1))
make_reduce_task("reduce_std", "Row standard deviation.", "std", (512, 2048), lambda x: jnp.std(x, -1))
make_reduce_task("reduce_frobenius", "Frobenius norm.", "frobenius", (512, 2048), lambda x: jnp.sqrt(jnp.sum(x * x)))
make_reduce_task("reduce_logsumexp", "Row logsumexp.", "logsumexp", (512, 2048), lambda x: jax.nn.logsumexp(x, -1))
make_reduce_task("reduce_argmax", "Row argmax.", "argmax", (512, 512), lambda x: jnp.argmax(x, -1))

# ---------------------------------------------------------------------------
# Loss functions — 7
# ---------------------------------------------------------------------------
make_loss_task("loss_mse", "Mean squared error.", "mse", (256, 1024),
               lambda p, t: jnp.mean((p - t) ** 2))
make_loss_task("loss_mae", "Mean absolute error.", "mae", (256, 1024),
               lambda p, t: jnp.mean(jnp.abs(p - t)))
make_loss_task("loss_huber", "Huber loss (delta=1).", "huber", (256, 1024),
               lambda p, t: jnp.mean(jnp.where(jnp.abs(p - t) < 1.0, 0.5 * (p - t) ** 2, jnp.abs(p - t) - 0.5)))
make_loss_task("loss_hinge", "Hinge loss.", "hinge", (256, 1024),
               lambda p, t: jnp.mean(jnp.maximum(0.0, 1.0 - p * t)), target_kind="pm1")
make_loss_task("loss_bce", "Binary cross-entropy with logits.", "bce", (256, 1024),
               lambda p, t: -jnp.mean(t * jnp.log(jnp.clip(jax.nn.sigmoid(p), 1e-7, 1 - 1e-7)) + (1 - t) * jnp.log(jnp.clip(1 - jax.nn.sigmoid(p), 1e-7, 1 - 1e-7))),
               target_kind="binary")
make_loss_task("loss_ce", "Softmax cross-entropy (one-hot targets).", "ce", (256, 512),
               lambda p, t: -jnp.mean(jnp.sum(t * jax.nn.log_softmax(p, -1), -1)),
               target_kind="onehot")
make_loss_task("loss_kl", "KL divergence between distributions.", "kl", (256, 512),
               lambda p, t: jnp.mean(jnp.sum(t * (jnp.log(jnp.clip(t, 1e-9, None)) - jnp.log(jnp.clip(p, 1e-9, None))), -1)),
               target_kind="simplex")

# ---------------------------------------------------------------------------
# Cumulative operations — 5
# ---------------------------------------------------------------------------
make_cumulative_task("cum_sum", "Inclusive cumulative sum.", (64, 1024))
make_cumulative_task("cum_sum_rev", "Reverse cumulative sum.", (64, 1024), reverse=True)
make_cumulative_task("cum_sum_excl", "Exclusive cumulative sum.", (64, 1024), exclusive=True)
make_cumulative_task("cum_sum_masked", "Masked cumulative sum.", (64, 1024), masked=True)
make_cumulative_task("cum_prod", "Cumulative product.", (64, 1024), op="cumprod")
