"""KernelBench-JAX: 91 kernel-optimization tasks in the paper's 6 categories.

Category counts match the paper's Table 5 exactly:
    Matrix Multiplication   18 (19.8%)
    Convolution             28 (30.8%)
    Activation & Pooling    21 (23.1%)
    Normalization/Reduction 15 (16.5%)
    Loss Functions           7 (7.7%)
    Cumulative Operations    5 (5.5%)

Each task carries: a pure-jnp reference oracle, seeded input generators, a
deliberately-naive initial implementation (the optimization starting point,
mirroring the paper's initial CUDA kernels), and a genome-parameterized
implementation space that renders to real Python/JAX source text.
"""

from repro.tasks.base import KernelTask, TASK_REGISTRY, get_task, all_tasks
from repro.tasks import catalog  # noqa: F401  (populates the registry)
from repro.tasks import calibration  # noqa: F401  (eval-subsystem tasks)

# The paper's Table 5 per-category counts (18/28/21/15/7/5) sum to 94 while
# its headline says 91 kernels — an internal inconsistency of the paper
# (the percentages are consistent with /91).  We implement all 94 and define
# the 91-task benchmark set by excluding three supplementary tasks, keeping
# category proportions as close to Table 5 as possible (DESIGN.md §7).
SUPPLEMENTARY = ("conv1d_k7", "conv3d_asym", "act_softsign")


def benchmark_tasks():
    return [t for t in all_tasks() if t.name not in SUPPLEMENTARY]


__all__ = [
    "KernelTask",
    "TASK_REGISTRY",
    "SUPPLEMENTARY",
    "all_tasks",
    "benchmark_tasks",
    "get_task",
]
