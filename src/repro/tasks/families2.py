"""Task families (continued): normalization/reduction, loss, cumulative."""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.tasks.base import KernelTask, register
from repro.tasks.families import _HEADER, _dtype_lines, _fuzz_inputs, _rng_inputs
from repro.verify.properties import (
    homogeneous,
    permute_rows_invariant,
    scale_invariant,
    shift_equivariant,
    shift_invariant,
)


# ==========================================================================
# 4. Normalization & reduction (15)
# ==========================================================================
def _norm_render(op, axis_repr="-1", eps=1e-5):
    def render(genome):
        pre, post = _dtype_lines(genome)
        two_pass = genome.get("stats", "two_pass") == "two_pass"
        if op in ("layernorm", "rmsnorm", "groupnorm", "instancenorm"):
            if op == "rmsnorm":
                core = f"ms = jnp.mean(x * x, axis={axis_repr}, keepdims=True)\n    out = x / jnp.sqrt(ms + {eps})"
            elif two_pass:
                core = (
                    f"mean = jnp.mean(x, axis={axis_repr}, keepdims=True)\n"
                    f"    var = jnp.mean((x - mean) ** 2, axis={axis_repr}, keepdims=True)\n"
                    f"    out = (x - mean) / jnp.sqrt(var + {eps})"
                )
            else:
                core = (
                    f"mean = jnp.mean(x, axis={axis_repr}, keepdims=True)\n"
                    f"    var = jnp.mean(x * x, axis={axis_repr}, keepdims=True) - mean * mean\n"
                    f"    out = (x - mean) * jax.lax.rsqrt(var + {eps})"
                )
            if op == "groupnorm":
                core = (
                    "n, c = x.shape[0], x.shape[1]\n"
                    "    xg = x.reshape(n, 8, c // 8, *x.shape[2:])\n    x = xg\n    "
                    + core.replace(axis_repr, "tuple(range(2, x.ndim))")
                    + "\n    out = out.reshape(n, c, *args[0].shape[2:])"
                )
            if op == "instancenorm":
                core = core.replace(axis_repr, "(2, 3)")
        elif op == "batchnorm":
            core = (
                "mean = jnp.mean(x, axis=(0, 2, 3), keepdims=True)\n"
                "    var = jnp.var(x, axis=(0, 2, 3), keepdims=True)\n"
                f"    out = (x - mean) / jnp.sqrt(var + {eps})"
            )
        elif op == "l2norm":
            core = f"out = x / (jnp.linalg.norm(x, axis={axis_repr}, keepdims=True) + {eps})"
        else:
            raise ValueError(op)
        nch = genome.get("rowloop", 0)
        if nch:
            body = f"""
    rows = []
    step = max(1, x.shape[0] // {nch})
    full = x
    for i in range(0, full.shape[0], step):
        x = full[i:i+step]
        {core.replace(chr(10) + '    ', chr(10) + '        ')}
        rows.append(out)
    out = jnp.concatenate(rows, axis=0)
"""
        else:
            body = f"    {core}\n"
        return _HEADER + f"def kernel(x):\n    args = [x]\n{pre}    x, = args\n{body}    return out{post}\n"

    return render


def make_norm_task(name, desc, op, shape, ref, axis_repr="-1"):
    # batch-statistics norms must see the whole batch: row-chunking would
    # change semantics, so the knob collapses for them
    allow_rowloop = op not in ("batchnorm",)
    if op == "groupnorm":
        # the 8-group reshape hardcodes C % 8 == 0 in render and ref
        fuzz_shapes = [[(2, 16, 5, 3)], [(1, 8, 3, 2)]]
    elif op in ("batchnorm", "instancenorm"):
        fuzz_shapes = [[(2, 3, 5, 7)], [(3, 2, 4, 4)]]
    else:  # row-wise: layernorm / rmsnorm / l2norm
        fuzz_shapes = [[(7, 33)], [(1, 17)], [(5, 1)]]
    return register(
        KernelTask(
            name=name,
            category="norm_reduce",
            description=desc,
            make_inputs=lambda seed: _rng_inputs([shape], seed, 1.5),
            ref=ref,
            genome_space={
                "stats": ["two_pass", "fused"],
                "rowloop": [0, 16, 64] if allow_rowloop else [0],
                "dtype": ["float64", "float32"],
            },
            render=_norm_render(op, axis_repr),
            naive_genome={
                "stats": "two_pass",
                "rowloop": 64 if allow_rowloop else 0,
                "dtype": "float32",
            },
            rtol=1e-3,
            atol=1e-3,
            fuzz_cases=lambda seed: _fuzz_inputs(fuzz_shapes, seed, 1.5),
            # normalization is scale-free (up to eps; tol_factor absorbs it)
            properties=(scale_invariant(),),
        )
    )


def _reduce_render(op, axis_repr):
    expr = {
        "sum": f"jnp.sum(x, axis={axis_repr})",
        "mean": f"jnp.mean(x, axis={axis_repr})",
        "max": f"jnp.max(x, axis={axis_repr})",
        "min": f"jnp.min(x, axis={axis_repr})",
        "prod": f"jnp.prod(x, axis={axis_repr})",
        "std": f"jnp.std(x, axis={axis_repr})",
        "frobenius": "jnp.sqrt(jnp.sum(x * x))",
        "logsumexp": f"jax.nn.logsumexp(x, axis={axis_repr})",
        "argmax": f"jnp.argmax(x, axis={axis_repr})",
    }[op]
    pair = {
        "sum": ("a + b", "0.0"),
        "max": ("jnp.maximum(a, b)", "-jnp.inf"),
        "min": ("jnp.minimum(a, b)", "jnp.inf"),
        "prod": ("a * b", "1.0"),
    }

    sort_expr = {
        "max": "jnp.sort(x, axis=-1)[..., -1]",
        "min": "jnp.sort(x, axis=-1)[..., 0]",
        "argmax": "jnp.argsort(x, axis=-1)[..., -1]",
        "sum": "jnp.sum(jnp.sort(x, axis=-1), axis=-1)",  # 'numerically careful' naive
        "mean": "jnp.mean(jnp.sort(x, axis=-1), axis=-1)",
        "logsumexp": "jax.nn.logsumexp(jnp.sort(x, axis=-1), axis=-1)",
    }

    def render(genome):
        pre, post = _dtype_lines(genome)
        if op == "argmax":
            post = ""  # integer output
        impl = genome["impl"]
        if impl == "sort_based" and op in sort_expr:
            body = f"    out = {sort_expr[op]}\n"
        elif impl in ("chunk_loop", "sort_based") and op in pair:
            comb, init = pair[op]
            nch = genome.get("chunks", 16)
            body = f"""
    acc = None
    step = max(1, x.shape[-1] // {nch})
    for i in range(0, x.shape[-1], step):
        part = x[..., i:i+step]
        red = {expr.replace('(x', '(part')}
        if acc is None:
            acc = red
        else:
            a, b = acc, red
            acc = {comb}
    out = acc
"""
        else:
            body = f"    out = {expr}\n"
        return _HEADER + f"def kernel(x):\n    args = [x]\n{pre}    x, = args\n{body}    return out{post}\n"

    return render


_REDUCE_PROPS = {
    "sum": lambda: (homogeneous(),),
    "mean": lambda: (homogeneous(),),
    "max": lambda: (shift_equivariant(),),
    "min": lambda: (shift_equivariant(),),
    "logsumexp": lambda: (shift_equivariant(),),
    "std": lambda: (shift_invariant(),),
    "frobenius": lambda: (homogeneous(),),
    # argmax: a shift can flip float32 near-ties between the top two row
    # elements into a different (large-integer) answer — too flaky for a
    # hard gate.  prod: s^n overflows for any usable n.
    "argmax": lambda: (),
    "prod": lambda: (),
}


def make_reduce_task(name, desc, op, shape, ref, axis_repr="-1"):
    positive = op == "prod"
    scale = 0.05 if op == "prod" else 1.0
    return register(
        KernelTask(
            name=name,
            category="norm_reduce",
            description=desc,
            make_inputs=lambda seed: _rng_inputs(
                [shape], seed, 0.05 if op == "prod" else 1.0, positive=positive
            ),
            ref=ref,
            genome_space={
                "impl": ["sort_based", "chunk_loop", "vectorized"],
                "chunks": [16, 64],
                "dtype": ["float64", "float32"],
            },
            render=_reduce_render(op, axis_repr),
            naive_genome={
                "impl": "sort_based" if op in ("max", "min", "argmax", "sum", "mean", "logsumexp") else "chunk_loop",
                "chunks": 64,
                "dtype": "float32",
            },
            rtol=1e-3,
            atol=1e-3,
            fuzz_cases=lambda seed: _fuzz_inputs(
                [[(7, 33)], [(1, 17)], [(5, 1)]], seed, scale, positive
            ),
            properties=_REDUCE_PROPS[op](),
            # sort-based min drops NaN (sort orders NaN last, [..., 0]
            # misses it) — the legitimate naive implementation would fail
            # the probe
            nan_probe=op != "min",
        )
    )


# ==========================================================================
# 5. Loss functions (7)
# ==========================================================================
_LOSS_CORES = {
    "mse": "out = jnp.mean((pred - target) ** 2)",
    "mae": "out = jnp.mean(jnp.abs(pred - target))",
    "huber": (
        "d = jnp.abs(pred - target)\n"
        "    out = jnp.mean(jnp.where(d < 1.0, 0.5 * d * d, d - 0.5))"
    ),
    "hinge": "out = jnp.mean(jnp.maximum(0.0, 1.0 - pred * target))",
    "bce": (
        "p = jnp.clip(1.0 / (1.0 + jnp.exp(-pred)), 1e-7, 1 - 1e-7)\n"
        "    out = -jnp.mean(target * jnp.log(p) + (1 - target) * jnp.log(1 - p))"
    ),
    "ce": (
        "logp = pred - jax.nn.logsumexp(pred, axis=-1, keepdims=True)\n"
        "    out = -jnp.mean(jnp.sum(target * logp, axis=-1))"
    ),
    "kl": (
        "logp = jnp.log(jnp.clip(pred, 1e-9, None))\n"
        "    logq = jnp.log(jnp.clip(target, 1e-9, None))\n"
        "    out = jnp.mean(jnp.sum(target * (logq - logp), axis=-1))"
    ),
}


def _loss_render(op):
    def render(genome):
        pre, post = _dtype_lines(genome)
        core = _LOSS_CORES[op]
        if genome.get("two_pass", False):
            # materialize elementwise losses, reduce in a second pass
            core = core.replace("jnp.mean(", "jnp.mean(jnp.asarray(", 1).replace(
                ")", "))", 1
            ) if False else core
        nch = genome.get("rowloop", 0)
        if nch:
            body = f"""
    total = 0.0
    n = pred.shape[0]
    step = max(1, n // {nch})
    fullp, fullt = pred, target
    for i in range(0, n, step):
        pred, target = fullp[i:i+step], fullt[i:i+step]
        {core.replace(chr(10) + '    ', chr(10) + '        ')}
        total = total + out * pred.shape[0]
    out = total / n
"""
        else:
            body = f"    {core}\n"
        return (
            _HEADER
            + f"def kernel(pred, target):\n    args = [pred, target]\n{pre}    pred, target = args\n{body}    return out{post}\n"
        )

    return render


def make_loss_task(name, desc, op, shape, ref, *, target_kind="real"):
    def _inputs(seed, shp=shape):
        rng = np.random.default_rng(seed)
        pred = rng.standard_normal(shp).astype(np.float32)
        if target_kind == "real":
            target = rng.standard_normal(shp).astype(np.float32)
        elif target_kind == "binary":
            target = (rng.random(shp) > 0.5).astype(np.float32)
        elif target_kind == "pm1":
            target = np.sign(rng.standard_normal(shp)).astype(np.float32)
        elif target_kind == "simplex":
            t = np.abs(rng.standard_normal(shp)) + 1e-3
            target = (t / t.sum(-1, keepdims=True)).astype(np.float32)
            pred = np.abs(pred) + 1e-3
            pred = (pred / pred.sum(-1, keepdims=True)).astype(np.float32)
        elif target_kind == "onehot":
            idx = rng.integers(0, shp[-1], shp[:-1])
            target = np.eye(shp[-1], dtype=np.float32)[idx]
        return pred, target

    def make_inputs(seed):
        return _inputs(seed)

    def fuzz_cases(seed):
        return [
            _inputs(seed + i, shp)
            for i, shp in enumerate([(7, 33), (1, 16), (5, 2)])
        ]

    return register(
        KernelTask(
            name=name,
            category="loss",
            description=desc,
            make_inputs=make_inputs,
            ref=ref,
            genome_space={
                "rowloop": [0, 16, 64],
                "dtype": ["float64", "float32"],
            },
            render=_loss_render(op),
            naive_genome={"rowloop": 64, "dtype": "float32"},
            fuzz_cases=fuzz_cases,
            # batch-mean losses: example order cannot change the value
            properties=(permute_rows_invariant(),),
        )
    )


# ==========================================================================
# 6. Cumulative operations (5)
# ==========================================================================
def _cum_render(spec):
    op = spec["op"]

    def render(genome):
        pre, post = _dtype_lines(genome)
        impl = genome["impl"]
        if op == "cumsum":
            mat = "jnp.tril(jnp.ones((n, n), x.dtype))"
            if spec.get("exclusive"):
                mat = "jnp.tril(jnp.ones((n, n), x.dtype), k=-1)"
            if spec.get("reverse"):
                mat = mat.replace("tril", "triu")
                if spec.get("exclusive"):
                    mat = mat.replace("k=-1", "k=1")
            builtin = "jnp.cumsum(x, axis=-1)"
            if spec.get("reverse"):
                builtin = "jnp.flip(jnp.cumsum(jnp.flip(x, -1), axis=-1), -1)"
            if spec.get("exclusive"):
                builtin = (
                    "jnp.concatenate([jnp.zeros_like(x[..., :1]), "
                    "jnp.cumsum(x, axis=-1)[..., :-1]], axis=-1)"
                    if not spec.get("reverse")
                    else "jnp.concatenate([jnp.flip(jnp.cumsum(jnp.flip(x, -1), "
                    "axis=-1), -1)[..., 1:], jnp.zeros_like(x[..., :1])], axis=-1)"
                )
            if spec.get("masked"):
                prep = "    x = x * mask\n"
            else:
                prep = ""
            if impl == "matmul_tri":
                body = f"{prep}    n = x.shape[-1]\n    out = x @ {mat}.T\n"
            elif impl == "assoc_scan":
                core = "jax.lax.associative_scan(jnp.add, x, axis=-1)"
                if spec.get("reverse"):
                    core = "jnp.flip(jax.lax.associative_scan(jnp.add, jnp.flip(x, -1), axis=-1), -1)"
                if spec.get("exclusive"):
                    core = (
                        "jnp.concatenate([jnp.zeros_like(x[..., :1]), ("
                        + core
                        + ")[..., :-1]], axis=-1)"
                        if not spec.get("reverse")
                        else "jnp.concatenate([(" + core + ")[..., 1:], jnp.zeros_like(x[..., :1])], axis=-1)"
                    )
                body = f"{prep}    out = {core}\n"
            else:
                body = f"{prep}    out = {builtin}\n"
        else:  # cumprod
            if impl == "chunk_loop":
                body = """
    n = x.shape[-1]
    step = max(1, n // 16)
    outs = []
    carry = jnp.ones(x.shape[:-1] + (1,), x.dtype)
    for i in range(0, n, step):
        seg = jnp.cumprod(x[..., i:i+step], axis=-1) * carry
        outs.append(seg)
        carry = seg[..., -1:]
    out = jnp.concatenate(outs, axis=-1)
"""
            elif impl == "assoc_scan":
                body = "    out = jax.lax.associative_scan(jnp.multiply, x, axis=-1)\n"
            else:
                body = "    out = jnp.cumprod(x, axis=-1)\n"
        sig = "x, mask" if spec.get("masked") else "x"
        arglist = "[x, mask]" if spec.get("masked") else "[x]"
        unpack = "x, mask = args" if spec.get("masked") else "x, = args"
        return _HEADER + f"def kernel({sig}):\n    args = {arglist}\n{pre}    {unpack}\n{body}    return out{post}\n"

    return render


def make_cumulative_task(name, desc, shape, *, op="cumsum", **flags):
    spec = {"op": op, **flags}

    def ref(*arrays):
        x = jnp.asarray(arrays[0])
        if flags.get("masked"):
            x = x * jnp.asarray(arrays[1])
        if op == "cumprod":
            return jnp.cumprod(x, axis=-1)
        if flags.get("reverse"):
            out = jnp.flip(jnp.cumsum(jnp.flip(x, -1), axis=-1), -1)
        else:
            out = jnp.cumsum(x, axis=-1)
        if flags.get("exclusive"):
            if flags.get("reverse"):
                out = jnp.concatenate(
                    [out[..., 1:], jnp.zeros_like(x[..., :1])], axis=-1
                )
            else:
                out = jnp.concatenate(
                    [jnp.zeros_like(x[..., :1]), out[..., :-1]], axis=-1
                )
        return out

    def _inputs(seed, shp=shape):
        rng = np.random.default_rng(seed)
        x = rng.standard_normal(shp).astype(np.float32) * 0.1
        if op == "cumprod":
            x = 1.0 + x * 0.05
        if flags.get("masked"):
            mask = (rng.random(shp) > 0.3).astype(np.float32)
            return x, mask
        return (x,)

    def make_inputs(seed):
        return _inputs(seed)

    def fuzz_cases(seed):
        return [
            _inputs(seed + i, shp)
            for i, shp in enumerate([(7, 33), (1, 16), (3, 1)])
        ]

    impls = (
        ["matmul_tri", "assoc_scan", "builtin"]
        if op == "cumsum"
        else ["chunk_loop", "assoc_scan", "builtin"]
    )
    return register(
        KernelTask(
            name=name,
            category="cumulative",
            description=desc,
            make_inputs=make_inputs,
            ref=ref,
            genome_space={"impl": impls, "dtype": ["float64", "float32"]},
            render=_cum_render(spec),
            naive_genome={"impl": impls[0], "dtype": "float32"},
            rtol=1e-3,
            atol=1e-3,
            fuzz_cases=fuzz_cases,
            # cumsum (masked or not) is linear in x; cumprod is not
            properties=(homogeneous(arg=0),) if op == "cumsum" else (),
        )
    )
