"""Small shared I/O helpers: atomic writes for caches and registries.

Writers across the repo (oracle .npy cache, baseline_us.json, the tuned
genome registry) all need crash/concurrency-safe file updates: write to a
pid-suffixed temp file, then `os.replace` — readers see either the old or
the new content, never a torn write.  Concurrent updaters last-write-win
per whole file, which is acceptable for these append-mostly caches.
"""

from __future__ import annotations

import json
import os
import re
import socket
from typing import Any, Callable, Dict, Optional

# pid alone is not a unique writer id on *shared* storage — two hosts can
# run the same pid concurrently (the sweep driver's duplicate-unit window
# makes that real, not theoretical) and would interleave one temp file
_HOST = re.sub(r"[^A-Za-z0-9_.-]", "-", socket.gethostname()) or "host"


def tmp_suffix() -> str:
    """Per-writer temp-file suffix that is unique across hosts."""
    return f".tmp{_HOST}-{os.getpid()}"


def atomic_write(path: str, write_fn: Callable[[Any], None], mode: str = "wb") -> None:
    """Write via `write_fn(file_object)` to a temp file, then rename over `path`."""
    tmp = path + tmp_suffix()
    with open(tmp, mode) as f:
        write_fn(f)
    os.replace(tmp, path)


def read_json(path: str) -> Dict[str, Any]:
    """Best-effort JSON read: {} on missing/corrupt file."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return {}


def update_json(path: str, updates: Dict[str, Any]) -> Dict[str, Any]:
    """Read-merge-atomically-rewrite a JSON object file; returns the merge."""
    return merge_json(path, lambda data: {**data, **updates})


def merge_json(
    path: str, merge_fn: Callable[[Dict[str, Any]], Dict[str, Any]]
) -> Dict[str, Any]:
    """Read-transform-atomically-rewrite: `merge_fn` receives the freshly
    read file content and returns what to write.  Writers that build their
    update *from* the existing content (e.g. layered registry entries)
    must do the build inside `merge_fn` — reading the file separately and
    then calling `update_json` leaves a stale-snapshot window where a
    concurrent writer's keys are silently dropped."""
    data = merge_fn(read_json(path))
    atomic_write(
        path,
        lambda f: (json.dump(data, f, indent=2, sort_keys=True), f.write("\n")),
        mode="w",
    )
    return data
