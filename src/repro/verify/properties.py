"""Tier-3 property invariants: oracle-free algebraic checks.

A `PropertySpec` encodes one invariant of the *operation* as a transform
pair: perturb the inputs in a way whose effect on the true output is
known exactly, then require the candidate to be self-consistent —

    candidate(transform(inputs)) ≈ out_map(candidate(inputs))

No reference implementation appears on either side, so a candidate that
memorizes oracle outputs (or wraps the oracle itself) still has to
honor the operation's algebra on inputs it has never seen.  This is the
same idea as the shape/parameter draws in tests/test_kernel_properties.py
(hypothesis over non-multiple-of-block shapes), specialized to the
single-function candidate contract.

Transforms take and return numpy input tuples at the task's canonical
shapes/dtypes (so the candidate's existing jit trace is reused — tier 3
adds zero compiles), and must preserve dtype: a python-float scale like
``2.0`` keeps float32 arrays float32 under numpy's promotion rules.

Tolerances are deliberately loose (``tol_factor`` × the task tolerance,
default 10×): properties exist to kill structural cheats that are wrong
by orders of magnitude, not to re-litigate rounding.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Tuple

import numpy as np

# (inputs, rng) -> (transformed_inputs, out_map)
Transform = Callable[
    [Tuple[np.ndarray, ...], np.random.Generator],
    Tuple[Tuple[np.ndarray, ...], Callable[[np.ndarray], np.ndarray]],
]


@dataclasses.dataclass(frozen=True)
class PropertySpec:
    name: str
    transform: Transform
    tol_factor: float = 10.0


def _replace(inputs: Tuple[np.ndarray, ...], i: int, arr: np.ndarray):
    out = list(inputs)
    out[i] = arr
    return tuple(out)


# ---------------------------------------------------------------------------
# factories — declared on tasks via KernelTask.properties
# ---------------------------------------------------------------------------


def homogeneous(arg: int = 0, scale: float = 2.0, degree: float = 1.0) -> PropertySpec:
    """f(..., s·x_i, ...) = s^degree · f(..., x_i, ...) — linearity of
    matmul/conv/reductions in each operand (degree 1), squared losses in
    the residual (degree 2)."""

    def t(inputs, rng):
        new = _replace(inputs, arg, inputs[arg] * scale)
        return new, lambda y: y * (scale ** degree)

    return PropertySpec(f"homogeneous(arg{arg},s={scale:g},d={degree:g})", t)


def scale_invariant(arg: int = 0, scale: float = 2.0) -> PropertySpec:
    """f(s·x) = f(x) for s>0 — normalization layers (the eps in the
    denominator makes this approximate; tol_factor absorbs it)."""

    def t(inputs, rng):
        return _replace(inputs, arg, inputs[arg] * scale), lambda y: y

    return PropertySpec(f"scale_invariant(arg{arg},s={scale:g})", t)


def shift_invariant(arg: int = 0, shift: float = 1.5) -> PropertySpec:
    """f(x + c) = f(x) — softmax's defining stability property, argmax."""

    def t(inputs, rng):
        return _replace(inputs, arg, inputs[arg] + shift), lambda y: y

    return PropertySpec(f"shift_invariant(arg{arg},c={shift:g})", t)


def shift_equivariant(arg: int = 0, shift: float = 1.5) -> PropertySpec:
    """f(x + c) = f(x) + c — logsumexp, max/min reductions."""

    def t(inputs, rng):
        return _replace(inputs, arg, inputs[arg] + shift), lambda y: y + shift

    return PropertySpec(f"shift_equivariant(arg{arg},c={shift:g})", t)


def negate_equivariant(arg: int = 0) -> PropertySpec:
    """f(-x) = -f(x) — odd elementwise ops (tanh), linear ops."""

    def t(inputs, rng):
        return _replace(inputs, arg, -inputs[arg]), lambda y: -y

    return PropertySpec(f"negate_equivariant(arg{arg})", t)


def permute_rows_equivariant() -> PropertySpec:
    """f(x[π]) = f(x)[π] over the leading axis, one shared random
    permutation applied to *every* input — row-independent ops
    (elementwise activations, row softmax, per-row norms).  Kills
    position-special-cased candidates."""

    def t(inputs, rng):
        n = inputs[0].shape[0]
        perm = rng.permutation(n)
        new = tuple(a[perm] if a.ndim >= 1 and a.shape[0] == n else a for a in inputs)
        return new, lambda y: y[perm] if y.ndim >= 1 and y.shape[0] == n else y

    return PropertySpec("permute_rows_equivariant", t)


def permute_rows_invariant() -> PropertySpec:
    """f(x[π], y[π], ...) = f(x, y, ...) — scalar losses averaged over the
    batch: reordering examples cannot change the loss."""

    def t(inputs, rng):
        n = inputs[0].shape[0]
        perm = rng.permutation(n)
        new = tuple(a[perm] if a.ndim >= 1 and a.shape[0] == n else a for a in inputs)
        return new, lambda y: y

    return PropertySpec("permute_rows_invariant", t)


def check_property(
    spec: PropertySpec,
    fn: Callable[..., np.ndarray],
    inputs: Tuple[np.ndarray, ...],
    rng: np.random.Generator,
    rtol: float,
    atol: float,
) -> Tuple[bool, str]:
    """Run one spec against a candidate: (ok, detail)."""
    base = np.asarray(fn(*inputs))
    t_inputs, out_map = spec.transform(inputs, rng)
    got = np.asarray(fn(*t_inputs))
    want = np.asarray(out_map(base))
    if got.shape != want.shape:
        return False, f"{spec.name}: shape {got.shape} vs {want.shape}"
    r, a = rtol * spec.tol_factor, atol * spec.tol_factor
    if not np.allclose(got, want, rtol=r, atol=a, equal_nan=True):
        err = float(np.max(np.abs(got.astype(np.float64) - want.astype(np.float64))))
        return False, f"{spec.name}: violated (max abs dev {err:.3e})"
    return True, spec.name
