"""`VerificationPolicy`: the tiered gate a candidate must clear in strict
mode, one instance per (task, run-nonce).

The policy owns everything nonce-derived: the seed base is
``sha1(f"{nonce}:{task.name}")`` so every run draws fresh functional
inputs (killing seed memorization) while remaining exactly replayable by
pinning the nonce.  Reference outputs for the nonce/fuzz/NaN cases are
computed once per policy and memoized — `warm()` lets the evaluator pay
that cost *outside* the candidate deadline, so the first candidate on a
cold task is never charged for oracle construction (the same bug class
as the tier-4 disk-oracle warmup).

The policy never decides tier 1 (compile) or tier 4 (tolerance-vs-
oracle): those stay in the evaluator, byte-identical to the legacy path.
It contributes tier 0 (static guard), tier 2 (determinism + nonce seeds
+ fuzz shapes + NaN propagation) and tier 3 (property invariants), each
recorded on the caller's `VerificationReport`.
"""

from __future__ import annotations

import hashlib
import re
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from repro.tasks.base import KernelTask
from repro.verify.properties import check_property
from repro.verify.report import VerificationReport
from repro.verify.static_guard import static_violations

N_NONCE_SEEDS = 3


def derive_seed_base(nonce: str, task_name: str) -> int:
    """The per-(run, task) seed base: stable for a pinned nonce, fresh
    otherwise.  31-bit so seed_base + offsets stay well inside int64."""
    h = hashlib.sha1(f"{nonce}:{task_name}".encode()).hexdigest()
    return int(h[:8], 16) % (2**31)


def error_stats(got: np.ndarray, want: np.ndarray) -> Tuple[float, float, List[int]]:
    """(max_abs, max_rel, argmax_index) of the elementwise error.
    Non-finite differences (candidate NaN/Inf vs finite reference) are
    clamped to a large sentinel so the stats stay JSON-serializable."""
    g = np.asarray(got, dtype=np.float64)
    w = np.asarray(want, dtype=np.float64)
    diff = np.abs(g - w)
    diff = np.where(np.isfinite(diff), diff, 1e300)
    if diff.size == 0:
        return 0.0, 0.0, []
    flat = int(np.argmax(diff))
    max_abs = float(diff.reshape(-1)[flat])
    denom = np.maximum(np.abs(w), 1e-12)
    max_rel = float(np.max(diff / denom))
    idx = [int(i) for i in np.unravel_index(flat, diff.shape)]
    return max_abs, max_rel, idx


def _scrub(e: BaseException, limit: int = 300) -> str:
    """Deterministic candidate-fault message (same address scrubbing as
    the evaluator's _errmsg; duplicated to keep the import DAG acyclic —
    the evaluator imports this module)."""
    msg = re.sub(r"0x[0-9a-fA-F]+", "0x<addr>", str(e)[:limit])
    return f"{type(e).__name__}: {msg}"


class VerificationPolicy:
    """Tier 0/2/3 checks for one task under one run nonce."""

    def __init__(self, task: KernelTask, nonce: str):
        self.task = task
        self.nonce = nonce
        self.seed_base = derive_seed_base(nonce, task.name)
        # (label, inputs, want) — nonce-seeded paper-shape cases then fuzz
        self._cases: Optional[List[Tuple[str, Tuple[np.ndarray, ...], np.ndarray]]] = None
        # (inputs_with_nan, want) or None when the task opts out
        self._nan_case: Optional[Tuple[Tuple[np.ndarray, ...], np.ndarray]] = None
        self._nan_ready = False

    # ------------------------------------------------------------------
    # tier 0
    # ------------------------------------------------------------------
    def static_check(self, source: str) -> List[str]:
        return static_violations(source)

    # ------------------------------------------------------------------
    # case construction (reference runs; call under enable_x64)
    # ------------------------------------------------------------------
    def warm(self) -> None:
        """Compute and memoize every reference output this policy will
        compare against.  Idempotent; run it outside the candidate
        deadline so oracle construction is never billed to a candidate."""
        self.functional_cases()
        self.nan_case()

    def functional_cases(self):
        if self._cases is not None:
            return self._cases
        task = self.task
        cases = []
        for i in range(N_NONCE_SEEDS):
            inputs = task.make_inputs(self.seed_base + i)
            want = np.asarray(task.ref(*inputs))
            cases.append((f"nonce seed {i}", inputs, want))
        if task.fuzz_cases is not None:
            for j, inputs in enumerate(task.fuzz_cases(self.seed_base + 100)):
                inputs = tuple(inputs)
                want = np.asarray(task.ref(*inputs))
                shapes = tuple(tuple(a.shape) for a in inputs)
                cases.append((f"fuzz shape {shapes}", inputs, want))
        self._cases = cases
        return cases

    def nan_case(self):
        if self._nan_ready:
            return self._nan_case
        self._nan_ready = True
        task = self.task
        if not task.nan_probe:
            return None
        inputs = task.make_inputs(self.seed_base + 50)
        x = np.array(inputs[0], copy=True)
        if x.size == 0 or not np.issubdtype(x.dtype, np.floating):
            return None
        x.reshape(-1)[self.seed_base % x.size] = np.nan
        nan_inputs = (x,) + tuple(inputs[1:])
        want = np.asarray(task.ref(*nan_inputs))
        if not np.isnan(want).any():
            return None  # reference not NaN-sensitive here: nothing to probe
        self._nan_case = (nan_inputs, want)
        return self._nan_case

    # ------------------------------------------------------------------
    # tier 2: determinism + nonce seeds + fuzz shapes + NaN propagation
    # ------------------------------------------------------------------
    def run_functional(self, jfn: Callable[..., Any], report: VerificationReport) -> bool:
        task = self.task
        try:
            cases = self.functional_cases()
            # determinism: two calls at one fixed input must agree exactly
            _, inputs0, _ = cases[0]
            g1 = np.asarray(jfn(*inputs0))
            g2 = np.asarray(jfn(*inputs0))
            if g1.shape != g2.shape or not np.array_equal(g1, g2, equal_nan=True):
                report.record(2, False, "nondeterministic output at a fixed input")
                return False
            for label, inputs, want in cases:
                got = np.asarray(jfn(*inputs))
                if got.shape != want.shape:
                    report.record(
                        2, False, f"{label}: shape {got.shape} vs {want.shape}"
                    )
                    return False
                if not np.allclose(got, want, rtol=task.rtol, atol=task.atol):
                    max_abs, max_rel, idx = error_stats(got, want)
                    report.max_abs_err = max_abs
                    report.max_rel_err = max_rel
                    report.err_argmax = idx
                    report.record(
                        2, False,
                        f"{label}: max abs err {max_abs:.3e} "
                        f"(rel {max_rel:.3e})",
                    )
                    return False
            nan_detail = "nan probe skipped"
            nc = self.nan_case()
            if nc is not None:
                nan_inputs, want = nc
                got = np.asarray(jfn(*nan_inputs))
                if got.shape != want.shape:
                    report.record(
                        2, False, f"nan probe: shape {got.shape} vs {want.shape}"
                    )
                    return False
                hidden = np.isnan(want) & ~np.isnan(got)
                if hidden.any():
                    report.record(
                        2, False,
                        "nan probe: candidate hides NaN the reference propagates",
                    )
                    return False
                nan_detail = "nan probe ok"
        except Exception as e:  # noqa: BLE001 — candidate faults are data
            report.record(2, False, f"functional check raised: {_scrub(e)}")
            return False
        n_fuzz = len(cases) - N_NONCE_SEEDS
        report.record(
            2, True,
            f"{N_NONCE_SEEDS} nonce seeds, {n_fuzz} fuzz shapes, {nan_detail}",
        )
        return True

    # ------------------------------------------------------------------
    # tier 3: property invariants
    # ------------------------------------------------------------------
    def run_properties(self, jfn: Callable[..., Any], report: VerificationReport) -> bool:
        task = self.task
        specs = tuple(task.properties)
        if not specs:
            report.record(3, True, "no invariants declared")
            return True
        for j, spec in enumerate(specs):
            try:
                inputs = task.make_inputs(self.seed_base + 200 + j)
                rng = np.random.default_rng(self.seed_base + 500 + j)
                ok, detail = check_property(
                    spec, jfn, inputs, rng, task.rtol, task.atol
                )
            except Exception as e:  # noqa: BLE001
                ok, detail = False, f"{spec.name}: raised {_scrub(e)}"
            if not ok:
                report.record(3, False, detail)
                return False
        report.record(3, True, f"{len(specs)} invariants ok")
        return True
