"""Tier-0 static guard: AST screening of candidate sources for the
reward-hacking primitives a correctness gate cannot observe at runtime.

The threat model (arxiv 2509.14279): an evolved candidate can score as
"valid and fast" without computing anything by

* reading the evaluator's oracle cache from disk (``np.load`` of the
  ``oracle/`` ``.npy`` files, ``open()``), or
* monkeypatching the comparison machinery (``np.allclose = lambda...``)
  or numpy internals out from under the verifier, or
* escaping the exec namespace through introspection
  (``__builtins__``, ``f.__globals__``, ``object.__subclasses__``).

None of those appear in a legitimate jnp kernel, so the guard is a plain
allowlist/denylist over the parse tree — no execution, no sandboxing
claims.  A source that does not parse passes tier 0 untouched: tier 1's
``compile()`` owns syntax errors and must keep reporting them with the
same messages strict-off runs produce.

The guard is intentionally conservative-in-one-direction: it may let a
novel hack through to the dynamic tiers (fuzz/property/oracle), but it
must never reject the rendered sources of real tasks — every
``task.initial_source`` in the registry passes (audited in
tests/test_verify.py).
"""

from __future__ import annotations

import ast
from typing import List

# modules a candidate kernel may import (prefix match on dotted paths:
# "jax" admits "jax.numpy", "jax.lax", ...).  `time` is used by the
# calibration tasks' rendered sources.
ALLOWED_IMPORTS = frozenset(
    {"jax", "numpy", "functools", "itertools", "math", "time", "typing"}
)

# builtins whose *call* gives filesystem / namespace-escape powers
BANNED_CALLS = frozenset(
    {
        "open", "exec", "eval", "compile", "__import__", "input",
        "breakpoint", "getattr", "setattr", "delattr", "globals",
        "locals", "vars", "reload",
    }
)

# attribute calls that reach the filesystem regardless of receiver
# (np.load, np.save, jnp.load, arr.tofile, np.lib.format.open_memmap...)
BANNED_ATTR_CALLS = frozenset(
    {
        "load", "save", "savez", "savez_compressed", "loadtxt",
        "savetxt", "genfromtxt", "fromfile", "tofile", "memmap",
        "open_memmap", "open",
    }
)

# names/attributes that escape the exec namespace
BANNED_NAMES = frozenset({"__builtins__", "__import__", "__loader__", "__spec__"})


def _root_name(node: ast.AST) -> str:
    """The leftmost name of an attribute chain: np.testing.allclose -> np."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else ""


def _dotted(node: ast.Attribute) -> str:
    parts: List[str] = []
    cur: ast.AST = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
    return ".".join(reversed(parts))


def _import_allowed(module: str) -> bool:
    return module.split(".", 1)[0] in ALLOWED_IMPORTS


class _Guard(ast.NodeVisitor):
    def __init__(self) -> None:
        self.violations: List[str] = []
        # aliases bound to imported modules ("np" for `import numpy as np`):
        # assignment to any attribute under one is a monkeypatch
        self.module_aliases: set = set()

    def flag(self, msg: str) -> None:
        if msg not in self.violations:
            self.violations.append(msg)

    # -- imports -------------------------------------------------------
    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            if not _import_allowed(a.name):
                self.flag(f"forbidden import {a.name.split('.', 1)[0]!r}")
            else:
                self.module_aliases.add(a.asname or a.name.split(".", 1)[0])
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        mod = node.module or ""
        if node.level or not _import_allowed(mod):
            self.flag(f"forbidden import {(mod or '.').split('.', 1)[0]!r}")
        self.generic_visit(node)

    # -- calls ---------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        f = node.func
        if isinstance(f, ast.Name) and f.id in BANNED_CALLS:
            self.flag(f"forbidden call {f.id!r}")
        elif isinstance(f, ast.Attribute) and f.attr in BANNED_ATTR_CALLS:
            self.flag(f"forbidden file-access call {_dotted(f)!r}")
        self.generic_visit(node)

    # -- monkeypatching ------------------------------------------------
    def _check_patch_target(self, target: ast.AST) -> None:
        if isinstance(target, ast.Attribute):
            root = _root_name(target)
            if root in self.module_aliases:
                self.flag(f"monkeypatch of module attribute {_dotted(target)!r}")
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._check_patch_target(elt)
        elif isinstance(target, ast.Subscript):
            # np.__dict__["allclose"] = ... ; module.__dict__ access is also
            # caught below as a dunder attribute
            if isinstance(target.value, ast.Attribute):
                self._check_patch_target(target.value)

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._check_patch_target(t)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._check_patch_target(node.target)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            self._check_patch_target(t)
        self.generic_visit(node)

    # -- namespace escape ----------------------------------------------
    def visit_Attribute(self, node: ast.Attribute) -> None:
        if node.attr.startswith("__") and node.attr.endswith("__"):
            self.flag(f"forbidden dunder attribute {node.attr!r}")
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        if node.id in BANNED_NAMES:
            self.flag(f"forbidden name {node.id!r}")
        self.generic_visit(node)


def static_violations(source: str) -> List[str]:
    """All tier-0 violations in ``source`` (empty list = clean).

    Unparseable sources return no violations — the compile tier owns
    syntax errors and their (byte-locked) error messages.
    """
    try:
        tree = ast.parse(source)
    except SyntaxError:
        return []
    g = _Guard()
    g.visit(tree)
    return g.violations
