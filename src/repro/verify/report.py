"""The `VerificationReport` record: one structured pass/fail verdict per
candidate, tier by tier.

A report is a plain dataclass with a stable JSON form (`to_dict` /
`from_dict`, floats rounded so serialization is platform-stable), a
hand-rolled schema validator (no external jsonschema dependency — the
container must not grow new packages), and a *bounded* prompt rendering:
`render()` and `render_verification_section()` never exceed their
character budget, so a verification-augmented prompt cannot blow past
`LLMClient` token-budget estimates no matter how many checks a tier ran.

Tier numbering (the Sakana robust-verification ladder, arxiv 2509.14279):

  0  static    — AST guards: oracle-cache access, ``np.load``, forbidden
                 imports, monkeypatching of numpy/comparison machinery
  1  compile   — the existing compile + jit-trace stage
  2  fuzz      — nonce-randomized seeds at the paper shape (kills seed
                 memorization), per-family fuzz shapes (ragged,
                 non-multiple-of-block, degenerate dims), NaN propagation
  3  property  — per-family invariants (linearity, scale/shift
                 invariance, permutation equivariance) checked as
                 candidate self-consistency under input transforms
  4  oracle    — the tolerance-vs-oracle comparison at the fixed seeds,
                 with max-abs AND max-rel error recorded

Mirrors the PerfDiagnosis record (repro.diagnosis.record) deliberately:
same serialization discipline, same omit-None policy, same bounded
prompt section — the engine threads both through the identical
Solution/prompt plumbing.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

# Hard ceiling (characters) for the whole "Verification feedback" prompt
# section (~170 tokens under the 4-chars/token estimate).
VERIFY_PROMPT_BUDGET = 700

TIER_NAMES: Dict[int, str] = {
    0: "static",
    1: "compile",
    2: "fuzz",
    3: "property",
    4: "oracle",
}


@dataclasses.dataclass
class TierResult:
    """Outcome of one tier for one candidate."""

    tier: int
    name: str
    ok: bool
    # failure reason, or a short pass summary ("3 nonce seeds, 3 fuzz
    # shapes, NaN probe")
    detail: str = ""

    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"tier": self.tier, "name": self.name, "ok": self.ok}
        if self.detail:
            d["detail"] = self.detail
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TierResult":
        return cls(
            tier=int(d["tier"]),
            name=str(d["name"]),
            ok=bool(d["ok"]),
            detail=str(d.get("detail", "")),
        )


@dataclasses.dataclass
class VerificationReport:
    """What happened to a candidate on its way through the gate.

    ``nonce`` is the run nonce whose hash seeds every tier-2/3 input —
    recorded so a rejection is exactly reproducible later by pinning
    ``EvalConfig.verify_nonce`` to the same value.
    """

    mode: str = "strict"
    nonce: str = ""
    passed: bool = False
    failed_tier: Optional[int] = None
    tiers: List[TierResult] = dataclasses.field(default_factory=list)
    # mismatch statistics from the failing (or final oracle) comparison
    max_abs_err: Optional[float] = None
    max_rel_err: Optional[float] = None
    err_argmax: Optional[List[int]] = None

    # ------------------------------------------------------------------
    def record(self, tier: int, ok: bool, detail: str = "") -> TierResult:
        tr = TierResult(tier=tier, name=TIER_NAMES[tier], ok=ok, detail=detail)
        self.tiers.append(tr)
        if not ok and self.failed_tier is None:
            self.failed_tier = tier
        return tr

    def finalize(self) -> "VerificationReport":
        self.passed = self.failed_tier is None and bool(self.tiers)
        return self

    @property
    def failed_name(self) -> str:
        if self.failed_tier is None:
            return ""
        return TIER_NAMES.get(self.failed_tier, "?")

    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        """Stable JSON form: None fields omitted, floats rounded."""
        out: Dict[str, Any] = {
            "mode": self.mode,
            "nonce": self.nonce,
            "passed": self.passed,
            "tiers": [t.to_dict() for t in self.tiers],
        }
        if self.failed_tier is not None:
            out["failed_tier"] = self.failed_tier
        if self.max_abs_err is not None:
            out["max_abs_err"] = _round_err(self.max_abs_err)
        if self.max_rel_err is not None:
            out["max_rel_err"] = _round_err(self.max_rel_err)
        if self.err_argmax is not None:
            out["err_argmax"] = [int(i) for i in self.err_argmax]
        return out

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "VerificationReport":
        known = {f.name for f in dataclasses.fields(cls)}
        kwargs = {k: v for k, v in d.items() if k in known}
        kwargs["tiers"] = [TierResult.from_dict(t) for t in d.get("tiers", [])]
        return cls(**kwargs)

    # ------------------------------------------------------------------
    def render(self, char_budget: int = VERIFY_PROMPT_BUDGET) -> str:
        """Human/LLM-readable summary, hard-capped at ``char_budget``."""
        lines: List[str] = []
        if self.passed:
            lines.append(
                f"passed all {len(self.tiers)} verification tiers (nonce {self.nonce})"
            )
        elif self.failed_tier is not None:
            lines.append(
                f"REJECTED at tier {self.failed_tier} ({self.failed_name})"
            )
        for t in self.tiers:
            mark = "ok" if t.ok else "FAIL"
            line = f"tier {t.tier} {t.name}: {mark}"
            if t.detail:
                line += f" — {t.detail}"
            lines.append(line)
        if self.max_abs_err is not None:
            err = f"max abs err {self.max_abs_err:.3e}"
            if self.max_rel_err is not None:
                err += f", max rel err {self.max_rel_err:.3e}"
            if self.err_argmax is not None:
                err += f" at index {tuple(self.err_argmax)}"
            lines.append(err)
        return _clip("\n".join(lines), char_budget)


def _round_err(v: float) -> float:
    """Errors span many decades: round to 6 significant-ish digits via the
    scientific form so serialization is platform-stable."""
    return float(f"{float(v):.6e}")


def _clip(text: str, budget: int) -> str:
    if len(text) <= budget:
        return text
    return text[: max(0, budget - 3)] + "..."


# --------------------------------------------------------------------------
# hand-rolled schema (the CI smoke job validates every emitted report)
# --------------------------------------------------------------------------

# field -> (allowed python types, required)
SCHEMA: Dict[str, Tuple[Tuple[type, ...], bool]] = {
    "mode": ((str,), True),
    "nonce": ((str,), True),
    "passed": ((bool,), True),
    "failed_tier": ((int,), False),
    "tiers": ((list,), True),
    "max_abs_err": ((int, float), False),
    "max_rel_err": ((int, float), False),
    "err_argmax": ((list,), False),
}

_TIER_SCHEMA: Dict[str, Tuple[Tuple[type, ...], bool]] = {
    "tier": ((int,), True),
    "name": ((str,), True),
    "ok": ((bool,), True),
    "detail": ((str,), False),
}


def _check_fields(d, schema, what: str) -> None:
    if not isinstance(d, dict):
        raise ValueError(f"{what} must be a dict, got {type(d).__name__}")
    for key, (types, required) in schema.items():
        if key not in d:
            if required:
                raise ValueError(f"{what} missing required field {key!r}")
            continue
        v = d[key]
        # bool is an int subclass: reject True masquerading as a number
        if isinstance(v, bool) and bool not in types:
            raise ValueError(f"{what} field {key!r} has bool, wants {types}")
        if not isinstance(v, types):
            raise ValueError(
                f"{what} field {key!r} has {type(v).__name__}, wants {types}"
            )
    unknown = set(d) - set(schema)
    if unknown:
        raise ValueError(f"{what} has unknown fields {sorted(unknown)}")


def validate(d: Dict[str, Any]) -> None:
    """Raise ValueError unless ``d`` is a valid serialized report."""
    _check_fields(d, SCHEMA, "verification report")
    if d["mode"] not in ("strict", "off"):
        raise ValueError(f"verification mode {d['mode']!r} not in ('strict', 'off')")
    for t in d["tiers"]:
        _check_fields(t, _TIER_SCHEMA, "tier result")
        if t["tier"] not in TIER_NAMES:
            raise ValueError(f"unknown tier number {t['tier']!r}")
        if t["name"] != TIER_NAMES[t["tier"]]:
            raise ValueError(
                f"tier {t['tier']} named {t['name']!r}, wants {TIER_NAMES[t['tier']]!r}"
            )
    if "failed_tier" in d:
        if d["failed_tier"] not in TIER_NAMES:
            raise ValueError(f"unknown failed_tier {d['failed_tier']!r}")
        if d["passed"]:
            raise ValueError("report cannot be passed with a failed_tier")
    for i in d.get("err_argmax", []):
        if isinstance(i, bool) or not isinstance(i, int):
            raise ValueError(f"err_argmax entry {i!r} is not an int")


# --------------------------------------------------------------------------
# prompt section (the last rejection, so the model learns WHICH gate bit)
# --------------------------------------------------------------------------


def render_verification_section(
    report: Optional[Dict[str, Any]],
    char_budget: int = VERIFY_PROMPT_BUDGET,
) -> str:
    """The prompt-facing section body: why the most recent rejected
    candidate was rejected, tier by tier.  Never exceeds ``char_budget``."""
    if not report:
        return ""
    rep = VerificationReport.from_dict(report)
    head = ""
    if rep.failed_tier is not None:
        hints = {
            0: "do not touch files, caches or numpy internals",
            1: "the code must compile and trace",
            2: "the kernel must be correct for ANY shape and seed, "
            "including ragged/degenerate shapes and NaN inputs",
            3: "the kernel must preserve the operation's algebraic "
            "invariants, not just match on sampled inputs",
            4: "output must match the reference within tolerance",
        }
        head = f"hint: {hints.get(rep.failed_tier, '')}\n"
    body = rep.render(char_budget - len(head))
    return _clip(head + body, char_budget)
