"""Tiered adversarial verification for candidate kernels.

Strict mode runs every candidate through escalating gates — static AST
guards, compile/trace, nonce-randomized functional fuzzing, algebraic
property invariants, and the tolerance-vs-oracle comparison — and emits
a structured `VerificationReport` that threads through `EvalResult` →
`Solution` → the proposer prompt, so the LLM learns *which* gate bit and
why.  Strict-off behavior is byte-identical to the pre-verification
engine (golden-locked in tests/test_verify.py).
"""

from repro.verify.policy import (
    N_NONCE_SEEDS,
    VerificationPolicy,
    derive_seed_base,
    error_stats,
)
from repro.verify.properties import (
    PropertySpec,
    check_property,
    homogeneous,
    negate_equivariant,
    permute_rows_equivariant,
    permute_rows_invariant,
    scale_invariant,
    shift_equivariant,
    shift_invariant,
)
from repro.verify.report import (
    TIER_NAMES,
    VERIFY_PROMPT_BUDGET,
    TierResult,
    VerificationReport,
    render_verification_section,
    validate,
)
from repro.verify.static_guard import static_violations

__all__ = [
    "N_NONCE_SEEDS",
    "VerificationPolicy",
    "derive_seed_base",
    "error_stats",
    "PropertySpec",
    "check_property",
    "homogeneous",
    "negate_equivariant",
    "permute_rows_equivariant",
    "permute_rows_invariant",
    "scale_invariant",
    "shift_equivariant",
    "shift_invariant",
    "TIER_NAMES",
    "VERIFY_PROMPT_BUDGET",
    "TierResult",
    "VerificationReport",
    "render_verification_section",
    "validate",
    "static_violations",
]
