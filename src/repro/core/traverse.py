"""Traverse techniques — the two-layer design (paper §4.1.1).

Solution Guiding Layer: decides WHAT closed-world information enters a
generation step — I1 task context, I2 historical solutions, I3 optimization
insights (I4 open-world knowledge is future work in the paper; the AICE
baseline's cross-task RAG is the one exception, modeled explicitly).

Prompt Engineering Layer: decides HOW the bundle is serialized.  The same
renderer feeds both the real-LLM proposers (as the literal prompt) and the
token ledger (paper Fig. 4 measures exactly these bytes).  The synthetic
proposer additionally receives the bundle structurally.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core.solution import Solution


@dataclasses.dataclass(frozen=True)
class GuidingConfig:
    """Which information types the Solution Guiding Layer includes."""

    task_context: bool = True  # I1
    n_historical: int = 0  # I2: how many parent solutions enter the prompt
    use_insights: bool = False  # I3
    n_insights: int = 5
    cross_task_rag: int = 0  # I4-ish: AICE Compose stage only
    # prompt verbosity multiplier (AICE's ensemble prompting is ~2x)
    prompt_overhead: float = 1.0
    # profiler-in-the-loop feedback (repro.diagnosis): render the parent's
    # PerfDiagnosis + its delta vs the task baseline into the prompt, and
    # make InsightStore knob bias regime-aware.  Off by default — prompts,
    # RNG schedules and checkpoints of every existing method are untouched.
    use_diagnosis: bool = False
    # strict tiered verification (repro.verify): evaluate candidates under
    # the full gate ladder and render the most recent rejection's
    # VerificationReport (which tier bit, and why) into the prompt.  Off by
    # default with the same untouched-byte contract as use_diagnosis.
    use_verification: bool = False


@dataclasses.dataclass
class InformationBundle:
    task_context: str = ""
    historical: List[Solution] = dataclasses.field(default_factory=list)
    insights: List[str] = dataclasses.field(default_factory=list)
    rag_solutions: List[Tuple[str, str]] = dataclasses.field(default_factory=list)
    operator: str = "propose"
    # serialized PerfDiagnosis of the lead parent and of the task baseline
    # (populated only under GuidingConfig.use_diagnosis)
    diagnosis: Optional[Dict[str, Any]] = None
    baseline_diagnosis: Optional[Dict[str, Any]] = None
    # serialized VerificationReport of the run's most recent *rejected*
    # candidate (populated only under GuidingConfig.use_verification)
    last_rejection: Optional[Dict[str, Any]] = None


def build_bundle(
    guiding: GuidingConfig,
    task_context: str,
    parents: List[Solution],
    insights: List[str],
    operator: str,
    rag: Optional[List[Tuple[str, str]]] = None,
    baseline_diagnosis: Optional[Dict[str, Any]] = None,
    last_rejection: Optional[Dict[str, Any]] = None,
) -> InformationBundle:
    b = InformationBundle(operator=operator)
    if guiding.task_context:
        b.task_context = task_context
    b.historical = parents[: guiding.n_historical]
    if guiding.use_insights:
        b.insights = insights[-guiding.n_insights :]
    if guiding.cross_task_rag and rag:
        b.rag_solutions = rag[: guiding.cross_task_rag]
    if guiding.use_diagnosis:
        # the lead parent's why-is-it-slow verdict (first sampled parent
        # carrying one — parents are sampled best-first); the baseline's
        # rides along so the renderer can show the delta
        b.diagnosis = next(
            (s.diagnosis for s in parents if s.diagnosis is not None), None
        )
        b.baseline_diagnosis = baseline_diagnosis
    if guiding.use_verification:
        b.last_rejection = last_rejection
    return b


# --------------------------------------------------------------------------
# Prompt Engineering Layer
# --------------------------------------------------------------------------
_OPERATOR_INSTRUCTIONS = {
    "propose": "Propose an optimized implementation of the kernel below.",
    "e1": "Create a NEW implementation as different as possible from the "
    "given ones while preserving semantics.",
    "e2": "Combine the ideas of the given implementations into a better one.",
    "m1": "Modify the given implementation to improve performance.",
    "m2": "Tune the parameters (tile sizes, dtypes, loop structure) of the "
    "given implementation.",
    "convert": "Convert the reference specification into a working kernel.",
    "translate": "Translate the kernel to an equivalent faster formulation.",
    "optimize": "Optimize the kernel using the provided high-performing "
    "examples and profiling feedback.",
    "compose": "Compose optimizations retrieved from related kernels into "
    "this one.",
}


def render_prompt(bundle: InformationBundle, guiding: GuidingConfig) -> str:
    """Serialize the bundle.  Structure follows common prompt practice
    (explicit sections, explicit instructions)."""
    parts: List[str] = []
    parts.append("## Instruction\n" + _OPERATOR_INSTRUCTIONS[bundle.operator])
    if bundle.task_context:
        parts.append("## Task\n" + bundle.task_context)
    if bundle.historical:
        lines = []
        for i, sol in enumerate(bundle.historical):
            fit = f"{sol.runtime_us:.1f}us" if sol.runtime_us else "n/a"
            lines.append(f"### Solution {i} (runtime {fit})\n```python\n{sol.source}\n```")
        parts.append("## High-quality solutions so far\n" + "\n".join(lines))
    if bundle.insights:
        parts.append(
            "## Optimization insights\n"
            + "\n".join(f"- {i}" for i in bundle.insights)
        )
    if bundle.diagnosis:
        from repro.diagnosis.record import render_diagnosis_section  # lazy: keep
        # the prompt layer import-light for diagnosis-off methods

        section = render_diagnosis_section(
            bundle.diagnosis, bundle.baseline_diagnosis
        )
        if section:
            parts.append("## Performance diagnosis (best parent)\n" + section)
    if bundle.last_rejection:
        from repro.verify.report import render_verification_section  # lazy:
        # keep the prompt layer import-light for strict-off methods

        section = render_verification_section(bundle.last_rejection)
        if section:
            parts.append(
                "## Verification feedback (last rejected candidate)\n" + section
            )
    if bundle.rag_solutions:
        lines = [
            f"### Retrieved from task {name}\n```python\n{src}\n```"
            for name, src in bundle.rag_solutions
        ]
        parts.append("## Related kernels (retrieval)\n" + "\n".join(lines))
    parts.append(
        "## Output format\nReturn a single Python function named `kernel` "
        "using jax.numpy only, plus a one-line insight explaining the "
        "optimization rationale."
    )
    text = "\n\n".join(parts)
    if guiding.prompt_overhead > 1.0:
        # ensemble prompting / extra framing (AICE): modeled as padding that
        # is charged to the ledger but carries no extra information
        pad = int(len(text) * (guiding.prompt_overhead - 1.0))
        text = text + "\n## Additional framing\n" + ("." * pad)
    return text
