"""EvoEngineer core: the paper's systematic LLM code-evolution framework.

Decomposition (paper §4): two orthogonal components —
  * traverse techniques  = Solution Guiding Layer (what information guides
    the step: I1 task context, I2 historical solutions, I3 optimization
    insights) + Prompt Engineering Layer (how it is serialized),
  * population management = single-best / elite / islands.

Method configurations (paper Table 3 + baselines):
  EvoEngineer-Free, -Insight, -Full, EvoEngineer-Solution (EoH), FunSearch,
  AI CUDA Engineer.
"""

from repro.core.solution import Solution, TokenLedger
from repro.core.population import (
    ElitePopulation,
    IslandPopulation,
    Population,
    SingleBestPopulation,
)
from repro.core.traverse import GuidingConfig, InformationBundle, render_prompt
from repro.core.methods import METHODS, MethodConfig, get_method
from repro.core.engine import EvolutionEngine, RunResult

__all__ = [
    "ElitePopulation",
    "EvolutionEngine",
    "GuidingConfig",
    "InformationBundle",
    "IslandPopulation",
    "METHODS",
    "MethodConfig",
    "Population",
    "RunResult",
    "SingleBestPopulation",
    "Solution",
    "TokenLedger",
    "get_method",
    "render_prompt",
]
