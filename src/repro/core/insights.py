"""Optimization-insight store (I3).

Insights are the proposer's stated rationales PLUS measured outcomes: after
every evaluation the engine records "(change) -> (confirmed/refuted, delta)".
EvoEngineer-Insight/-Full feed the most recent of these back through the
guiding layer; the synthetic proposer additionally consumes the structured
(knob, direction, gain) records to bias its sampling — the concrete
mechanism by which I3 buys validity/exploitation, mirroring how a real LLM
uses stated insights.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

# Per-insight character cap applied by ``texts()``: a single runaway
# insight (a real LLM's rambling rationale, a diagnosis-enriched record)
# cannot blow a prompt past the ~4-chars/token budget LLMClient estimates
# with.  Comfortably above every synthetic-proposer insight, so capping
# changes no existing prompt byte (locked by the diagnosis-off golden).
INSIGHT_TEXT_MAX = 240


@dataclasses.dataclass
class InsightRecord:
    text: str
    knob: Optional[str] = None  # which genome knob changed
    choice: Any = None  # the value it changed to
    gain: float = 0.0  # speedup delta vs parent (positive = better)
    # bound regime ("compute" | "memory") of the solution this insight was
    # measured on, from its PerfDiagnosis — None for diagnosis-off runs
    # (and serialized records then omit the key, keeping diagnosis-off
    # checkpoints byte-identical to the pre-diagnosis schema)
    regime: Optional[str] = None

    def to_dict(self):
        d = dataclasses.asdict(self)
        if self.regime is None:
            del d["regime"]
        return d

    @classmethod
    def from_dict(cls, d):
        return cls(**d)


class InsightStore:
    def __init__(self, cap: int = 64):
        self.cap = cap
        self.records: List[InsightRecord] = []

    def add(self, rec: InsightRecord) -> None:
        self.records.append(rec)
        del self.records[: -self.cap]

    def texts(self) -> List[str]:
        return [_truncate(r.text) for r in self.records]

    def knob_bias(self, regime: Optional[str] = None) -> Dict[str, Dict[Any, float]]:
        """Aggregate per-(knob, choice) average gain — the structured view
        the synthetic proposer samples from.  With ``regime``, only
        insights measured in that bound regime contribute (a tile size
        that paid off compute-bound says little about a memory-bound
        parent); when no record carries the requested regime the full
        aggregate is returned rather than nothing."""
        records = self.records
        if regime is not None:
            matching = [r for r in records if r.regime == regime]
            if matching:
                records = matching
        agg: Dict[str, Dict[Any, List[float]]] = {}
        for r in records:
            if r.knob is None:
                continue
            agg.setdefault(r.knob, {}).setdefault(_hashable(r.choice), []).append(r.gain)
        return {
            k: {c: sum(v) / len(v) for c, v in cs.items()} for k, cs in agg.items()
        }

    def state_dict(self):
        return {"cap": self.cap, "records": [r.to_dict() for r in self.records]}

    def load_state_dict(self, d):
        self.cap = d["cap"]
        self.records = [InsightRecord.from_dict(r) for r in d["records"]]


def _truncate(text: str, cap: int = INSIGHT_TEXT_MAX) -> str:
    if len(text) <= cap:
        return text
    return text[: cap - 3] + "..."


def _hashable(v):
    if isinstance(v, list):
        return tuple(v)
    if isinstance(v, dict):
        return tuple(sorted(v.items()))
    return v
