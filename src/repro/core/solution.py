"""Solution and token-ledger records.

A Solution is one candidate program: raw source text (the paper's search
space S_text), plus the structured genome the synthetic proposer works in,
plus evaluation outcome.  Fitness is runtime (lower is better); ``speedup``
is relative to the task's initial implementation.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Tuple


@dataclasses.dataclass
class Solution:
    source: str
    genome: Optional[Dict[str, Any]] = None
    insight: Optional[str] = None

    # evaluation outcome (two-stage g(p), then f(p))
    compile_ok: Optional[bool] = None
    correct: Optional[bool] = None
    runtime_us: Optional[float] = None
    speedup: Optional[float] = None
    error: Optional[str] = None

    # lineage / accounting
    sid: str = ""
    trial: int = -1
    operator: str = ""
    parents: Tuple[str, ...] = ()
    tokens_in: int = 0
    tokens_out: int = 0

    # serialized PerfDiagnosis (repro.diagnosis) — attached by the engine
    # only when the method's guiding layer enables diagnosis, so that
    # diagnosis-off checkpoints stay byte-identical to pre-diagnosis runs
    # (to_dict omits the key entirely when None)
    diagnosis: Optional[Dict[str, Any]] = None

    # serialized VerificationReport (repro.verify) — attached by the
    # engine only under strict verification, with the same omit-None
    # contract so strict-off checkpoints stay byte-identical
    verification: Optional[Dict[str, Any]] = None

    def __post_init__(self):
        if not self.sid:
            self.sid = hashlib.sha1(self.source.encode()).hexdigest()[:12]

    @property
    def valid(self) -> bool:
        return bool(self.compile_ok) and bool(self.correct)

    @property
    def fitness(self) -> float:
        """Lower is better; invalid solutions are +inf."""
        if not self.valid or self.runtime_us is None:
            return float("inf")
        return self.runtime_us

    def brief(self) -> str:
        st = "OK" if self.valid else ("COMPILE_FAIL" if not self.compile_ok else "WRONG")
        sp = f" {self.speedup:.2f}x" if self.speedup else ""
        return f"[{self.sid} t{self.trial} {self.operator}] {st}{sp}"

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        if self.diagnosis is None:
            # keep diagnosis-off serializations byte-identical to the
            # pre-diagnosis schema (no "diagnosis": null key)
            del d["diagnosis"]
        if self.verification is None:
            # same contract for strict-off runs (no "verification": null)
            del d["verification"]
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Solution":
        d = dict(d)
        d["parents"] = tuple(d.get("parents") or ())
        return cls(**d)


@dataclasses.dataclass
class TokenLedger:
    """Per-run token accounting (paper Fig. 4 reproduces from this).

    ``budget`` is the run's total-token ceiling (None = unlimited).  The
    ledger itself only records; enforcement is the transport layer's job —
    `repro.proposers.client.TokenBudgetGate` reserves against this budget
    before issuing a request and refuses requests that would overshoot it
    (backpressure), counting in-flight reservations so concurrent batched
    generation cannot collectively exceed the ceiling.
    """

    tokens_in: int = 0
    tokens_out: int = 0
    calls: int = 0
    budget: Optional[int] = None

    def charge(self, tin: int, tout: int) -> None:
        self.tokens_in += tin
        self.tokens_out += tout
        self.calls += 1

    @property
    def total(self) -> int:
        return self.tokens_in + self.tokens_out

    def to_dict(self):
        return dataclasses.asdict(self)


def count_tokens(text: str) -> int:
    """Cheap deterministic token estimate (~4 chars/token)."""
    return max(1, len(text) // 4)
