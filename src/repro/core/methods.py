"""Method configurations (paper Tables 2 & 3 + Appendix A.4).

Every method = GuidingConfig (what information) + population factory (what
is kept) + an operator schedule (what each trial asks for) + fault-model
regime for the synthetic proposer.  Budgets follow the paper: 45 trials per
kernel for every method.

  EvoEngineer-Free      I1 only,        single-best, cheap prompts
  EvoEngineer-Insight   I1+I3,          single-best
  EvoEngineer-Full      I1+I2+I3,       elite(4)
  EvoEngineer-Diagnosis I1+I2+I3+diag,  elite(4)  (profiler-in-the-loop
                        ablation: Full plus PerfDiagnosis prompt feedback
                        and regime-aware insight bias)
  EvoEngineer-Solution  I1+I2 (EoH),    elite(4), E1/E2/M1/M2 x 10 gens
  FunSearch             I1+I2(2),       islands(5)
  AI CUDA Engineer      I1+I2(5)+RAG,   single-best, staged
                        Convert->Translate->Optimize(4x10)->Compose(5)
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

from repro.core.population import (
    ElitePopulation,
    IslandPopulation,
    Population,
    SingleBestPopulation,
)
from repro.core.traverse import GuidingConfig


@dataclasses.dataclass(frozen=True)
class FaultRegime:
    """Synthetic-proposer fault calibration for one method.

    Rates express how often an (simulated) LLM response is broken, as a
    function of the information it saw — the paper's core observation is
    that richer closed-world information raises validity (Table 4) while
    pure exploration maximizes speedup headroom.
    """

    p_syntax: float = 0.10  # stage-1 failures (does not compile/trace)
    p_semantic: float = 0.18  # stage-2 failures (wrong output)
    explore: float = 0.5  # probability of a random-jump proposal vs local step
    # reward-hacking attempts (Sakana 2509.14279): the proposal wraps the
    # kernel to special-case the benchmark shape instead of optimizing it.
    # Default 0.0 keeps every existing method's RNG stream untouched (the
    # proposer reuses its single fault draw, so a zero rate draws nothing
    # extra).
    p_hack: float = 0.0


@dataclasses.dataclass(frozen=True)
class MethodConfig:
    name: str
    guiding: GuidingConfig
    make_population: Callable[[], Population]
    trials: int = 45
    # operator schedule: trial index -> operator string
    schedule: Callable[[int], str] = lambda t: "propose"
    fault: FaultRegime = FaultRegime()
    # AICE: number of trailing compose/RAG trials
    rag_trials: int = 0
    # per-candidate verification mode this method requests from the
    # evaluator: "strict" runs the repro.verify tier ladder, "off" the
    # legacy two-stage gate, None inherits the evaluator's EvalConfig —
    # per-method (not per-evaluator) because the table-4 grid shares one
    # evaluator across all methods
    verify: Optional[str] = None


def _eoh_schedule(t: int) -> str:
    # 5 init trials, then generations of E1, E2, M1, M2 (pop 4, 10 gens)
    if t < 5:
        return "e1"
    return ("e1", "e2", "m1", "m2")[(t - 5) % 4]


def _aice_schedule(t: int) -> str:
    if t == 0:
        return "convert"
    if t == 1:
        return "translate"
    if t >= 40:
        return "compose"
    return "optimize"


def _free() -> MethodConfig:
    return MethodConfig(
        name="EvoEngineer-Free",
        guiding=GuidingConfig(task_context=True, n_historical=0, use_insights=False),
        make_population=SingleBestPopulation,
        schedule=lambda t: "propose",
        # exploration-heavy, no grounding context -> lowest validity,
        # widest search (paper: best speedups, worst validity)
        fault=FaultRegime(p_syntax=0.16, p_semantic=0.26, explore=0.85),
    )


def _insight() -> MethodConfig:
    return MethodConfig(
        name="EvoEngineer-Insight",
        guiding=GuidingConfig(task_context=True, n_historical=0, use_insights=True),
        make_population=SingleBestPopulation,
        schedule=lambda t: "propose",
        fault=FaultRegime(p_syntax=0.10, p_semantic=0.17, explore=0.55),
    )


def _full() -> MethodConfig:
    return MethodConfig(
        name="EvoEngineer-Full",
        guiding=GuidingConfig(task_context=True, n_historical=3, use_insights=True),
        make_population=lambda: ElitePopulation(k=4),
        schedule=lambda t: "propose",
        # maximal grounding -> highest validity, conservative moves
        fault=FaultRegime(p_syntax=0.045, p_semantic=0.10, explore=0.30),
    )


def _diagnosis() -> MethodConfig:
    return MethodConfig(
        name="EvoEngineer-Diagnosis",
        guiding=GuidingConfig(
            task_context=True,
            n_historical=3,
            use_insights=True,
            use_diagnosis=True,
        ),
        make_population=lambda: ElitePopulation(k=4),
        schedule=lambda t: "propose",
        # profiling-grounded feedback (Sakana 2509.14279): semantic faults
        # drop further vs Full — the model sees WHY the parent is slow, so
        # its moves are better-informed — while exploration stays matched
        # so the ablation isolates the diagnosis signal
        fault=FaultRegime(p_syntax=0.045, p_semantic=0.08, explore=0.30),
    )


def _strictverify() -> MethodConfig:
    return MethodConfig(
        name="EvoEngineer-StrictVerify",
        guiding=GuidingConfig(
            task_context=True,
            n_historical=3,
            use_insights=True,
            use_verification=True,
        ),
        make_population=lambda: ElitePopulation(k=4),
        schedule=lambda t: "propose",
        # Full's regime plus a reward-hacking rate: some proposals try to
        # game the gate by special-casing the benchmark shape (the failure
        # mode Sakana 2509.14279 reports dominating agentic kernel search).
        # Under the strict tier ladder those are rejected with a tier
        # report the prompt feeds back; under the legacy gate they would
        # score as valid — exactly the validity delta EXPERIMENTS.md
        # §Robust verification measures.
        fault=FaultRegime(p_syntax=0.045, p_semantic=0.10, explore=0.30, p_hack=0.06),
        verify="strict",
    )


def _eoh() -> MethodConfig:
    return MethodConfig(
        name="EvoEngineer-Solution (EoH)",
        guiding=GuidingConfig(task_context=True, n_historical=2, use_insights=False),
        make_population=lambda: ElitePopulation(k=4),
        schedule=_eoh_schedule,
        fault=FaultRegime(p_syntax=0.11, p_semantic=0.20, explore=0.50),
    )


def _funsearch() -> MethodConfig:
    return MethodConfig(
        name="FunSearch",
        guiding=GuidingConfig(task_context=True, n_historical=2, use_insights=False),
        make_population=lambda: IslandPopulation(n_islands=5),
        schedule=lambda t: "propose",
        fault=FaultRegime(p_syntax=0.12, p_semantic=0.21, explore=0.60),
    )


def _aice() -> MethodConfig:
    return MethodConfig(
        name="AI CUDA Engineer",
        guiding=GuidingConfig(
            task_context=True,
            n_historical=5,
            use_insights=False,
            cross_task_rag=5,
            prompt_overhead=2.0,  # ensemble prompting + profiling feedback
        ),
        make_population=SingleBestPopulation,
        schedule=_aice_schedule,
        fault=FaultRegime(p_syntax=0.09, p_semantic=0.17, explore=0.45),
        rag_trials=5,
    )


METHODS = {
    "evoengineer-free": _free,
    "evoengineer-insight": _insight,
    "evoengineer-full": _full,
    "evoengineer-diagnosis": _diagnosis,
    "evoengineer-strictverify": _strictverify,
    "eoh": _eoh,
    "funsearch": _funsearch,
    "aice": _aice,
}

DISPLAY_ORDER = [
    "aice",
    "funsearch",
    "eoh",
    "evoengineer-free",
    "evoengineer-insight",
    "evoengineer-full",
    "evoengineer-diagnosis",
    "evoengineer-strictverify",
]


def canonical_method_order(names) -> List[str]:
    """Display names sorted into the paper's method order (unknown names
    last, alphabetically).  Summarizers use this instead of record
    first-appearance order, which is completion order — nondeterministic
    — when the results file was written by a distributed driver fleet."""
    rank = {METHODS[k]().name: i for i, k in enumerate(DISPLAY_ORDER)}
    return sorted(set(names), key=lambda n: (rank.get(n, len(rank)), n))


def get_method(name: str) -> MethodConfig:
    key = name.lower()
    if key not in METHODS:
        raise KeyError(f"unknown method {name!r}; known: {sorted(METHODS)}")
    return METHODS[key]()
