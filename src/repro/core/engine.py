"""The evolution engine: ties traverse techniques, population management,
proposer and evaluator into the paper's three-step loop (configure ->
generate -> evaluate), with exact checkpoint/resume.

Generation is batched: each generation draws ``batch_size`` proposals from
the seeded RNG first (all against the population/insight state at the
batch start), evaluates them — concurrently when the evaluator is a
`ParallelEvaluator` — and then ``tell()``s them in submission order, so a
run is bit-identical to a serial-evaluator run with the same schedule.
``batch_size=1`` reproduces the original strictly-serial loop exactly.

Batch staging goes through ``Proposer.propose_batch``: the engine prepares
one `ProposalRequest` per trial (all RNG draws on the engine thread, in
trial order) and batchable proposers (the `LLMClient`-backed ones, which
draw nothing from the engine RNG) complete them with K concurrent
transport calls, returning in submission order.

``pipeline=True`` additionally overlaps generation with evaluation: the
batch is staged in chunks (default: the proposer's concurrency), each
chunk's evaluation is submitted to a single background worker, and the
next chunk is staged while the previous one evaluates — proposing chunk
K+1 overlaps evaluating chunk K.  RNG draws stay on the engine thread in
trial order, evaluation chunks run in submission order on the one worker,
and tells happen at batch end in submission order, so a pipelined run is
bit-identical to a non-pipelined run with the same batch schedule
(tested in tests/test_engine.py; see EXPERIMENTS.md §Proposer batching).

Two documented scope limits on the pipelined mode:

* Token-budget backpressure near exhaustion: a `TokenBudgetGate` admits
  requests against worst-case reservations at issuance time, and the
  pipelined schedule issues per chunk (after earlier chunks' cheaper
  actuals have settled) where the non-pipelined schedule reserves a whole
  batch up-front — so WHICH trials degrade to the budget fallback can
  differ between pipeline on and off.  Any fixed configuration remains
  fully deterministic (admission is submission-order, never a thread
  race); bit-identity across pipeline settings is only guaranteed for
  runs that don't hit the budget ceiling.
* Straggler mitigation: the serial `Evaluator`'s SIGALRM per-candidate
  deadline only arms on a main thread, and the pipelined mode evaluates
  on a background worker — a candidate that hangs in native code will
  hang the run.  Pair ``pipeline=True`` with `ParallelEvaluator` when
  candidates are untrusted: its workers carry their own in-process
  deadlines plus a parent-side process-kill deadline, thread-independent.

Fault tolerance contract: engine state (population, insight store, RNG
state, trial count, token ledger, history) serializes after every trial
batch; `EvolutionEngine.resume()` continues a killed run to the identical
trajectory (tested in tests/test_engine.py).  Checkpoints land on batch
boundaries, so a resumed run with the same ``batch_size`` replays the
uninterrupted trajectory.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.insights import InsightRecord, InsightStore
from repro.core.methods import MethodConfig
from repro.core.solution import Solution, TokenLedger, count_tokens
from repro.core.traverse import build_bundle, render_prompt
from repro.evaluation.evaluator import Evaluator
from repro.ioutil import tmp_suffix
from repro.tasks.base import KernelTask

if False:  # typing only — imported lazily in __init__ to avoid an import
    from repro.proposers.base import Proposer  # noqa: F401  (cycle)


def _stable_hash(name: str) -> int:
    return int(hashlib.sha1(name.encode()).hexdigest()[:8], 16)


@dataclasses.dataclass
class RunResult:
    task: str
    method: str
    seed: int
    best: Optional[Solution]
    history: List[Solution]
    ledger: TokenLedger
    baseline_us: float

    @staticmethod
    def _usable_runtime(rt: Optional[float]) -> bool:
        """Non-finite or zero runtimes must never enter speedup accounting
        (cross-checked with EvalResult.ok: a 0µs candidate would otherwise
        report an infinite best_speedup)."""
        return rt is not None and math.isfinite(rt) and rt > 0

    @property
    def best_speedup(self) -> float:
        """Paper metric: 1.0 when no valid improvement was found."""
        if self.best is None or not self.best.valid:
            return 1.0
        if not self._usable_runtime(self.best.runtime_us):
            return 1.0
        return max(1.0, self.baseline_us / self.best.runtime_us)

    @property
    def any_speedup(self) -> bool:
        if self.best is None or not self.best.valid:
            return False
        if not self._usable_runtime(self.best.runtime_us):
            return False
        return self.baseline_us / self.best.runtime_us > 1.0

    @property
    def compile_rate(self) -> float:
        if not self.history:
            return 0.0
        return sum(1 for s in self.history if s.compile_ok) / len(self.history)

    @property
    def validity_rate(self) -> float:
        if not self.history:
            return 0.0
        return sum(1 for s in self.history if s.valid) / len(self.history)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "task": self.task,
            "method": self.method,
            "seed": self.seed,
            "best_speedup": self.best_speedup,
            "compile_rate": self.compile_rate,
            "validity_rate": self.validity_rate,
            "tokens": self.ledger.to_dict(),
            "baseline_us": self.baseline_us,
            "best_runtime_us": self.best.runtime_us if self.best else None,
        }


class EvolutionEngine:
    def __init__(
        self,
        task: KernelTask,
        method: MethodConfig,
        evaluator: Optional[Evaluator] = None,
        proposer=None,
        seed: int = 0,
        checkpoint_dir: Optional[str] = None,
        rag_pool: Optional[List[Tuple[str, str]]] = None,
        batch_size: int = 1,
        pipeline: bool = False,
        pipeline_chunk: Optional[int] = None,
        ledger: Optional[TokenLedger] = None,
    ):
        from repro.proposers.synthetic import SyntheticLLM  # lazy: cycle

        self.task = task
        self.method = method
        self.evaluator = evaluator or Evaluator()
        self.batch_size = max(1, batch_size)
        # pipeline=True overlaps staging chunk K+1 with evaluating chunk K
        # inside each batch; chunk size defaults to full transport/eval
        # width (see _effective_chunk) and overlap needs batch_size > chunk.
        self.pipeline = pipeline
        self.pipeline_chunk = pipeline_chunk
        self.insights = InsightStore()
        self.proposer = proposer or SyntheticLLM(self.insights)
        if isinstance(self.proposer, SyntheticLLM):
            self.proposer.insight_store = self.insights
        self.seed = seed
        self.checkpoint_dir = checkpoint_dir
        if checkpoint_dir and getattr(self.evaluator, "cache_dir", None) is None:
            # persist oracle outputs + baseline timings beside the checkpoints
            self.evaluator.set_cache_dir(os.path.join(checkpoint_dir, "eval_cache"))
        self.rag_pool = rag_pool or []

        self.population = method.make_population()
        # accept a caller-built ledger so a TokenBudgetGate can share the
        # same object the engine charges (budget backpressure wiring)
        self.ledger = ledger if ledger is not None else TokenLedger()
        self.history: List[Solution] = []
        # sid -> first Solution with that sid, maintained on history append
        # so per-trial parent lookups are O(1), not a scan of the whole run
        self._sid_index: Dict[str, Solution] = {}
        # the task baseline's serialized PerfDiagnosis (diagnosis-enabled
        # methods only) — the fixed reference every prompt's delta line is
        # rendered against; derived from the evaluator, not checkpointed
        self._baseline_diag: Optional[Dict[str, Any]] = None
        self.trial = 0
        # stable string hashes: builtin hash() is PYTHONHASHSEED-randomized
        # per process, which would make a "seeded" run irreproducible across
        # processes/restarts
        self.rng = np.random.default_rng(
            (seed, _stable_hash(task.name), _stable_hash(method.name))
        )

    # ------------------------------------------------------------------
    def run(self, max_trials: Optional[int] = None, checkpoint_every: int = 5) -> RunResult:
        max_trials = max_trials or self.method.trials
        baseline_us = self.evaluator.baseline_us(self.task)
        if self.method.guiding.use_diagnosis and self._baseline_diag is None:
            # diagnose the naive implementation once: usually a result-cache
            # hit from baseline_us(); an explicit evaluate() covers the case
            # where the baseline runtime came from the disk cache instead
            self._baseline_diag = self.evaluator.evaluate(
                self.task, self.task.initial_source
            ).diagnosis
        # seed the population with the initial (naive) implementation — the
        # optimization starting point, as in the paper's setup
        if self.trial == 0 and self.population.best is None:
            init = self._make_solution(
                self.task.initial_source, self.task.naive_genome, "init", -1
            )
            init = self._evaluate(init, baseline_us)
            self.population.tell(init)

        while self.trial < max_trials:
            # --- generate: draw the whole batch against the population /
            # insight state at the batch start (RNG order = trial order) ---
            n = min(self.batch_size, max_trials - self.trial)
            trials = list(range(self.trial, self.trial + n))
            chunk = self._effective_chunk()
            # a batch that fits one chunk has nothing to overlap: run the
            # plain schedule (identical results, minus the thread hop)
            # rather than splitting generation below full transport width
            if self.pipeline and n > chunk:
                staged, batch_results = self._run_pipelined(trials, chunk)
            else:
                staged = self._stage_batch(trials)
                # --- evaluate (concurrently under a ParallelEvaluator) ----
                batch_results = self.evaluator.evaluate_batch(
                    self.task,
                    [sol.source for sol, _ in staged],
                    verify=self.method.verify,
                )
            # --- tell in submission order: checkpoints stay bit-identical
            # to a serial-evaluator run with the same schedule --------------
            prev_epoch = self.trial // checkpoint_every
            for (sol, proposal), res in zip(staged, batch_results):
                self._apply_result(sol, res, baseline_us)
                self.history.append(sol)
                self._sid_index.setdefault(sol.sid, sol)
                self.population.tell(sol)
                if proposal.issued:
                    # degraded fallbacks carry marker insights, not model
                    # reasoning — keep them out of future prompts
                    self._record_insight(sol, proposal)
                self.trial += 1
            if self.checkpoint_dir and self.trial // checkpoint_every > prev_epoch:
                self.save_checkpoint()

        if self.checkpoint_dir:
            self.save_checkpoint()
        return RunResult(
            task=self.task.name,
            method=self.method.name,
            seed=self.seed,
            best=self.population.best,
            history=self.history,
            ledger=self.ledger,
            baseline_us=baseline_us,
        )

    # ------------------------------------------------------------------
    def _make_solution(self, source, genome, op, trial) -> Solution:
        return Solution(source=source, genome=genome, operator=op, trial=trial)

    def _prepare_request(self, trial: int):
        """RNG-consuming half of a proposal: schedule the operator, sample
        parents, build the bundle and render the prompt.  Always runs on
        the engine thread, in trial order."""
        from repro.proposers.base import ProposalRequest  # lazy: cycle

        op = self.method.schedule(trial)
        parents = self.population.sample(self.rng, self.method.guiding.n_historical or 2)
        last_rejection: Optional[Dict[str, Any]] = None
        if self.method.guiding.use_verification:
            # the most recent rejected candidate's VerificationReport —
            # derived from checkpointed history, so resumed runs render
            # the identical prompt
            last_rejection = next(
                (
                    s.verification
                    for s in reversed(self.history)
                    if s.verification is not None and not s.valid
                ),
                None,
            )
        bundle = build_bundle(
            self.method.guiding,
            self.task.task_context(),
            parents,
            self.insights.texts(),
            op,
            rag=self.rag_pool,
            baseline_diagnosis=self._baseline_diag,
            last_rejection=last_rejection,
        )
        prompt = render_prompt(bundle, self.method.guiding)
        return op, ProposalRequest(
            task=self.task,
            prompt=prompt,
            bundle=bundle,
            guiding=self.method.guiding,
            fault=self.method.fault,
            trial=trial,
        )

    def _finish_proposal(self, op: str, request, proposal):
        """Bookkeeping half: wrap the Proposal in a Solution and charge the
        ledger.  Called in trial order."""
        sol = Solution(
            source=proposal.source,
            genome=proposal.genome,
            insight=proposal.insight,
            trial=request.trial,
            operator=op,
            parents=(proposal.parent_sid,) if proposal.parent_sid else (),
        )
        if proposal.issued:
            # provider-reported usage when available, estimate otherwise
            sol.tokens_in = proposal.tokens_in or count_tokens(request.prompt)
            sol.tokens_out = proposal.tokens_out
            self.ledger.charge(sol.tokens_in, sol.tokens_out)
        return sol, proposal

    def _propose_one(self, trial: int):
        """Draw one proposal for `trial` (consumes RNG; does not evaluate)."""
        op, req = self._prepare_request(trial)
        proposal = self.proposer.propose(
            req.task, req.prompt, req.bundle, req.guiding, req.fault, self.rng
        )
        return self._finish_proposal(op, req, proposal)

    def _stage_batch(self, trials: List[int]):
        """Stage proposals for `trials`.  Batchable proposers (transport
        draws nothing from the engine RNG) get all requests up-front and
        complete them concurrently via ``propose_batch``; RNG-consuming
        proposers keep the exact serial prepare/propose interleaving."""
        if getattr(self.proposer, "batchable", False):
            prepared = [self._prepare_request(t) for t in trials]
            proposals = self.proposer.propose_batch(
                [req for _, req in prepared], self.rng
            )
            return [
                self._finish_proposal(op, req, prop)
                for (op, req), prop in zip(prepared, proposals)
            ]
        return [self._propose_one(t) for t in trials]

    def _effective_chunk(self) -> int:
        """Pipeline chunk size: the explicit override, or a default that
        keeps BOTH sides of the overlap at full width — the proposer's
        transport concurrency and the evaluator's worker pool (splitting
        below either would throttle generation waves or serialize a
        ParallelEvaluator).  Overlap therefore requires
        ``batch_size > chunk``; a batch that fits one chunk runs the plain
        schedule."""
        return self.pipeline_chunk or max(
            getattr(self.proposer, "concurrency", 1) or 1,
            getattr(self.evaluator, "workers", 1) or 1,
        )

    def _run_pipelined(self, trials: List[int], chunk: int):
        """Stage the batch in chunks, overlapping generation of chunk K+1
        with evaluation of chunk K.  The single background worker keeps
        evaluation chunks in submission order (and keeps the evaluator
        single-threaded); all RNG draws stay on this thread."""
        staged_all, futures = [], []
        with ThreadPoolExecutor(max_workers=1) as pool:
            for i in range(0, len(trials), chunk):
                staged = self._stage_batch(trials[i : i + chunk])
                futures.append(
                    pool.submit(
                        self.evaluator.evaluate_batch,
                        self.task,
                        [sol.source for sol, _ in staged],
                        self.method.verify,
                    )
                )
                staged_all.extend(staged)
            results = [res for f in futures for res in f.result()]
        return staged_all, results

    def _apply_result(self, sol: Solution, res, baseline_us: float) -> Solution:
        sol.compile_ok = res.compile_ok
        sol.correct = res.correct
        sol.runtime_us = res.runtime_us
        sol.error = res.error
        if res.valid and res.runtime_us:
            sol.speedup = baseline_us / res.runtime_us
        if self.method.guiding.use_diagnosis:
            # diagnosis-off methods drop the evaluator's diagnosis here so
            # their history/checkpoints stay byte-identical to pre-diagnosis
            # runs (Solution.to_dict omits the None)
            sol.diagnosis = getattr(res, "diagnosis", None)
        if self.method.guiding.use_verification:
            # same contract for strict-off methods (Solution.to_dict omits
            # the None, keeping their checkpoints byte-identical)
            sol.verification = getattr(res, "verification", None)
        return sol

    def _evaluate(self, sol: Solution, baseline_us: float) -> Solution:
        return self._apply_result(
            sol,
            self.evaluator.evaluate(
                self.task, sol.source, verify=self.method.verify
            ),
            baseline_us,
        )

    def _record_insight(self, sol: Solution, proposal) -> None:
        """Solution-insight pairs with MEASURED outcome (confirmed/refuted)."""
        gain = 0.0
        if sol.valid and sol.parents:
            parent = self._sid_index.get(sol.parents[0])
            if parent and parent.speedup and sol.speedup:
                gain = sol.speedup - parent.speedup
        elif sol.valid and sol.speedup:
            gain = sol.speedup - 1.0
        status = "confirmed" if gain > 0 else ("refuted" if sol.valid else "invalid")
        text = f"{sol.insight} -> {status} ({gain:+.2f}x)"
        regime: Optional[str] = None
        if self.method.guiding.use_diagnosis and sol.valid and sol.diagnosis:
            # regime-tag the insight so knob_bias can condition on the bound
            # regime, and surface the diagnosis delta in the prompt text
            bound = sol.diagnosis.get("bound")
            if bound in ("compute", "memory"):
                regime = bound
                ach = sol.diagnosis.get("achieved_pct")
                text += f" [{bound}-bound" + (
                    f", {ach:.0f}% roofline" if ach is not None else ""
                ) + "]"
        if (
            self.method.guiding.use_verification
            and not sol.valid
            and sol.verification
        ):
            # tier-tag rejections so the insight stream teaches WHICH gate
            # bit (mirrors the diagnosis regime tag above)
            ft = sol.verification.get("failed_tier")
            if ft is not None:
                from repro.verify.report import TIER_NAMES

                text += f" [rejected at tier {ft}: {TIER_NAMES.get(ft, '?')}]"
        self.insights.add(
            InsightRecord(
                text=text,
                knob=proposal.knob if sol.valid else None,
                choice=proposal.choice if sol.valid else None,
                gain=gain,
                regime=regime,
            )
        )

    # ------------------------------------------------------------------
    # checkpoint / resume (fault tolerance)
    # ------------------------------------------------------------------
    def _ckpt_path(self) -> str:
        safe = self.method.name.replace(" ", "_").replace("(", "").replace(")", "")
        return os.path.join(
            self.checkpoint_dir, f"{self.task.name}_{safe}_s{self.seed}.json"
        )

    def save_checkpoint(self) -> str:
        """Best-effort atomic checkpoint: an OSError (e.g. the distributed
        sweep driver garbage-collecting a completed unit's checkpoint dir
        under a concurrent duplicate worker) skips the checkpoint rather
        than crashing the run — the next boundary retries."""
        state = {
            "trial": self.trial,
            "seed": self.seed,
            "rng_state": self.rng.bit_generator.state,
            "population": {
                "kind": self.population.kind,
                "state": self.population.state_dict(),
            },
            "insights": self.insights.state_dict(),
            "ledger": self.ledger.to_dict(),
            "history": [s.to_dict() for s in self.history],
        }
        path = self._ckpt_path()
        # host+pid-suffixed temp: under the distributed sweep two hosts can
        # legitimately checkpoint the same unit (work stealing's documented
        # duplicate window) — a shared tmp name would interleave writes
        tmp = path + tmp_suffix()
        try:
            os.makedirs(self.checkpoint_dir, exist_ok=True)
            with open(tmp, "w") as f:
                json.dump(state, f)
            os.replace(tmp, path)
        except OSError:
            try:
                os.unlink(tmp)
            except OSError:
                pass
        return path

    def resume(self) -> bool:
        path = self._ckpt_path()
        if not os.path.exists(path):
            return False
        # parse AND validate the whole checkpoint before mutating any
        # engine state: checkpoint writes are atomic, but shared storage
        # can still surface a damaged or stale-schema file, and a partial
        # restore would be worse than the fresh start we fall back to
        try:
            with open(path) as f:
                state = json.load(f)
            rng = np.random.default_rng()
            rng.bit_generator.state = state["rng_state"]
            trial = state["trial"]
            # restore population/insights into fresh objects: a payload
            # from a stale schema must fail HERE, not after self.* is
            # half-overwritten (a poison checkpoint on shared storage
            # would otherwise crash every driver that steals the unit)
            population = self.method.make_population()
            population.load_state_dict(state["population"]["state"])
            insight_state = state["insights"]
            InsightStore().load_state_dict(insight_state)
            led = state["ledger"]
            tokens_in = led["tokens_in"]
            tokens_out = led["tokens_out"]
            calls = led["calls"]
            budget = led.get("budget", self.ledger.budget)
            history = [Solution.from_dict(d) for d in state["history"]]
        except Exception:  # noqa: BLE001 — any damage means fresh start
            return False
        self.trial = trial
        self.rng = rng
        self.population = population
        # the insight STORE keeps its identity (the proposer holds a
        # reference to it); only its contents are replaced
        self.insights.load_state_dict(insight_state)
        # restore the ledger IN PLACE: a TokenBudgetGate may hold a
        # reference to this object, and rebinding would detach it (the gate
        # would stop seeing post-resume spend and could overshoot budget)
        self.ledger.tokens_in = tokens_in
        self.ledger.tokens_out = tokens_out
        self.ledger.calls = calls
        self.ledger.budget = budget
        self.history = history
        self._sid_index = {}
        for s in self.history:
            self._sid_index.setdefault(s.sid, s)
        return True
