"""The evolution engine: ties traverse techniques, population management,
proposer and evaluator into the paper's three-step loop (configure ->
generate -> evaluate), with exact checkpoint/resume.

Generation is batched: each generation draws ``batch_size`` proposals from
the seeded RNG first (all against the population/insight state at the
batch start), evaluates them — concurrently when the evaluator is a
`ParallelEvaluator` — and then ``tell()``s them in submission order, so a
run is bit-identical to a serial-evaluator run with the same schedule.
``batch_size=1`` reproduces the original strictly-serial loop exactly.

Fault tolerance contract: engine state (population, insight store, RNG
state, trial count, token ledger, history) serializes after every trial
batch; `EvolutionEngine.resume()` continues a killed run to the identical
trajectory (tested in tests/test_engine.py).  Checkpoints land on batch
boundaries, so a resumed run with the same ``batch_size`` replays the
uninterrupted trajectory.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.insights import InsightRecord, InsightStore
from repro.core.methods import MethodConfig
from repro.core.solution import Solution, TokenLedger, count_tokens
from repro.core.traverse import build_bundle, render_prompt
from repro.evaluation.evaluator import Evaluator
from repro.tasks.base import KernelTask

if False:  # typing only — imported lazily in __init__ to avoid an import
    from repro.proposers.base import Proposer  # noqa: F401  (cycle)


def _stable_hash(name: str) -> int:
    return int(hashlib.sha1(name.encode()).hexdigest()[:8], 16)


@dataclasses.dataclass
class RunResult:
    task: str
    method: str
    seed: int
    best: Optional[Solution]
    history: List[Solution]
    ledger: TokenLedger
    baseline_us: float

    @property
    def best_speedup(self) -> float:
        """Paper metric: 1.0 when no valid improvement was found."""
        if self.best is None or not self.best.valid:
            return 1.0
        return max(1.0, self.baseline_us / self.best.runtime_us)

    @property
    def any_speedup(self) -> bool:
        return self.best is not None and self.baseline_us / self.best.runtime_us > 1.0

    @property
    def compile_rate(self) -> float:
        if not self.history:
            return 0.0
        return sum(1 for s in self.history if s.compile_ok) / len(self.history)

    @property
    def validity_rate(self) -> float:
        if not self.history:
            return 0.0
        return sum(1 for s in self.history if s.valid) / len(self.history)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "task": self.task,
            "method": self.method,
            "seed": self.seed,
            "best_speedup": self.best_speedup,
            "compile_rate": self.compile_rate,
            "validity_rate": self.validity_rate,
            "tokens": self.ledger.to_dict(),
            "baseline_us": self.baseline_us,
            "best_runtime_us": self.best.runtime_us if self.best else None,
        }


class EvolutionEngine:
    def __init__(
        self,
        task: KernelTask,
        method: MethodConfig,
        evaluator: Optional[Evaluator] = None,
        proposer=None,
        seed: int = 0,
        checkpoint_dir: Optional[str] = None,
        rag_pool: Optional[List[Tuple[str, str]]] = None,
        batch_size: int = 1,
    ):
        from repro.proposers.synthetic import SyntheticLLM  # lazy: cycle

        self.task = task
        self.method = method
        self.evaluator = evaluator or Evaluator()
        self.batch_size = max(1, batch_size)
        self.insights = InsightStore()
        self.proposer = proposer or SyntheticLLM(self.insights)
        if isinstance(self.proposer, SyntheticLLM):
            self.proposer.insight_store = self.insights
        self.seed = seed
        self.checkpoint_dir = checkpoint_dir
        if checkpoint_dir and getattr(self.evaluator, "cache_dir", None) is None:
            # persist oracle outputs + baseline timings beside the checkpoints
            self.evaluator.set_cache_dir(os.path.join(checkpoint_dir, "eval_cache"))
        self.rag_pool = rag_pool or []

        self.population = method.make_population()
        self.ledger = TokenLedger()
        self.history: List[Solution] = []
        self.trial = 0
        # stable string hashes: builtin hash() is PYTHONHASHSEED-randomized
        # per process, which would make a "seeded" run irreproducible across
        # processes/restarts
        self.rng = np.random.default_rng(
            (seed, _stable_hash(task.name), _stable_hash(method.name))
        )

    # ------------------------------------------------------------------
    def run(self, max_trials: Optional[int] = None, checkpoint_every: int = 5) -> RunResult:
        max_trials = max_trials or self.method.trials
        baseline_us = self.evaluator.baseline_us(self.task)
        # seed the population with the initial (naive) implementation — the
        # optimization starting point, as in the paper's setup
        if self.trial == 0 and self.population.best is None:
            init = self._make_solution(
                self.task.initial_source, self.task.naive_genome, "init", -1
            )
            init = self._evaluate(init, baseline_us)
            self.population.tell(init)

        while self.trial < max_trials:
            # --- generate: draw the whole batch against the population /
            # insight state at the batch start (RNG order = trial order) ---
            n = min(self.batch_size, max_trials - self.trial)
            staged = [self._propose_one(self.trial + j) for j in range(n)]
            # --- evaluate (concurrently under a ParallelEvaluator) ---------
            batch_results = self.evaluator.evaluate_batch(
                self.task, [sol.source for sol, _ in staged]
            )
            # --- tell in submission order: checkpoints stay bit-identical
            # to a serial-evaluator run with the same schedule --------------
            prev_epoch = self.trial // checkpoint_every
            for (sol, proposal), res in zip(staged, batch_results):
                self._apply_result(sol, res, baseline_us)
                self.history.append(sol)
                self.population.tell(sol)
                self._record_insight(sol, proposal)
                self.trial += 1
            if self.checkpoint_dir and self.trial // checkpoint_every > prev_epoch:
                self.save_checkpoint()

        if self.checkpoint_dir:
            self.save_checkpoint()
        return RunResult(
            task=self.task.name,
            method=self.method.name,
            seed=self.seed,
            best=self.population.best,
            history=self.history,
            ledger=self.ledger,
            baseline_us=baseline_us,
        )

    # ------------------------------------------------------------------
    def _make_solution(self, source, genome, op, trial) -> Solution:
        return Solution(source=source, genome=genome, operator=op, trial=trial)

    def _propose_one(self, trial: int):
        """Draw one proposal for `trial` (consumes RNG; does not evaluate)."""
        op = self.method.schedule(trial)
        parents = self.population.sample(self.rng, self.method.guiding.n_historical or 2)
        bundle = build_bundle(
            self.method.guiding,
            self.task.task_context(),
            parents,
            self.insights.texts(),
            op,
            rag=self.rag_pool,
        )
        prompt = render_prompt(bundle, self.method.guiding)
        proposal = self.proposer.propose(
            self.task, prompt, bundle, self.method.guiding, self.method.fault, self.rng
        )
        sol = Solution(
            source=proposal.source,
            genome=proposal.genome,
            insight=proposal.insight,
            trial=trial,
            operator=op,
            parents=(proposal.parent_sid,) if proposal.parent_sid else (),
        )
        sol.tokens_in = count_tokens(prompt)
        sol.tokens_out = proposal.tokens_out
        self.ledger.charge(sol.tokens_in, sol.tokens_out)
        return sol, proposal

    def _apply_result(self, sol: Solution, res, baseline_us: float) -> Solution:
        sol.compile_ok = res.compile_ok
        sol.correct = res.correct
        sol.runtime_us = res.runtime_us
        sol.error = res.error
        if res.valid and res.runtime_us:
            sol.speedup = baseline_us / res.runtime_us
        return sol

    def _evaluate(self, sol: Solution, baseline_us: float) -> Solution:
        return self._apply_result(
            sol, self.evaluator.evaluate(self.task, sol.source), baseline_us
        )

    def _record_insight(self, sol: Solution, proposal) -> None:
        """Solution-insight pairs with MEASURED outcome (confirmed/refuted)."""
        gain = 0.0
        if sol.valid and sol.parents:
            parent = next(
                (h for h in self.history if h.sid == sol.parents[0]), None
            )
            if parent and parent.speedup and sol.speedup:
                gain = sol.speedup - parent.speedup
        elif sol.valid and sol.speedup:
            gain = sol.speedup - 1.0
        status = "confirmed" if gain > 0 else ("refuted" if sol.valid else "invalid")
        text = f"{sol.insight} -> {status} ({gain:+.2f}x)"
        self.insights.add(
            InsightRecord(
                text=text,
                knob=proposal.knob if sol.valid else None,
                choice=proposal.choice if sol.valid else None,
                gain=gain,
            )
        )

    # ------------------------------------------------------------------
    # checkpoint / resume (fault tolerance)
    # ------------------------------------------------------------------
    def _ckpt_path(self) -> str:
        safe = self.method.name.replace(" ", "_").replace("(", "").replace(")", "")
        return os.path.join(
            self.checkpoint_dir, f"{self.task.name}_{safe}_s{self.seed}.json"
        )

    def save_checkpoint(self) -> str:
        os.makedirs(self.checkpoint_dir, exist_ok=True)
        state = {
            "trial": self.trial,
            "seed": self.seed,
            "rng_state": self.rng.bit_generator.state,
            "population": {
                "kind": self.population.kind,
                "state": self.population.state_dict(),
            },
            "insights": self.insights.state_dict(),
            "ledger": self.ledger.to_dict(),
            "history": [s.to_dict() for s in self.history],
        }
        path = self._ckpt_path()
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(state, f)
        os.replace(tmp, path)
        return path

    def resume(self) -> bool:
        path = self._ckpt_path()
        if not os.path.exists(path):
            return False
        with open(path) as f:
            state = json.load(f)
        self.trial = state["trial"]
        self.rng.bit_generator.state = state["rng_state"]
        self.population.load_state_dict(state["population"]["state"])
        self.insights.load_state_dict(state["insights"])
        self.ledger = TokenLedger(**state["ledger"])
        self.history = [Solution.from_dict(d) for d in state["history"]]
        return True
