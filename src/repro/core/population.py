"""Population management strategies (paper §4.1.2).

Three strategies, one ask/tell interface:
  * SingleBestPopulation   — keep only the incumbent best (EvoEngineer-Free
                             and -Insight).
  * ElitePopulation(k)     — top-k by fitness (EvoEngineer-Full, EoH).
  * IslandPopulation(n)    — FunSearch: independent islands, uniform island
                             sampling, periodic reset of the worst half onto
                             the global best.

`sample(rng, n)` returns up to n parent Solutions for the guiding layer;
`tell(solution)` folds an evaluated candidate in.  All state is plain data
so the engine can checkpoint/restore it exactly.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.solution import Solution


class Population:
    kind = "base"

    def tell(self, sol: Solution) -> None:
        raise NotImplementedError

    def sample(self, rng: np.random.Generator, n: int) -> List[Solution]:
        raise NotImplementedError

    @property
    def best(self) -> Optional[Solution]:
        raise NotImplementedError

    def state_dict(self) -> Dict[str, Any]:
        raise NotImplementedError

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        raise NotImplementedError


class SingleBestPopulation(Population):
    kind = "single_best"

    def __init__(self):
        self._best: Optional[Solution] = None

    def tell(self, sol: Solution) -> None:
        if sol.valid and (self._best is None or sol.fitness < self._best.fitness):
            self._best = sol

    def sample(self, rng, n):
        return [self._best] if self._best is not None else []

    @property
    def best(self):
        return self._best

    def state_dict(self):
        return {"best": self._best.to_dict() if self._best else None}

    def load_state_dict(self, d):
        self._best = Solution.from_dict(d["best"]) if d.get("best") else None


class ElitePopulation(Population):
    kind = "elite"

    def __init__(self, k: int = 4):
        self.k = k
        self._elite: List[Solution] = []

    def tell(self, sol: Solution) -> None:
        if not sol.valid:
            return
        if any(e.sid == sol.sid for e in self._elite):
            return
        self._elite.append(sol)
        self._elite.sort(key=lambda s: s.fitness)
        del self._elite[self.k :]

    def sample(self, rng, n):
        if not self._elite:
            return []
        idx = rng.permutation(len(self._elite))[:n]
        return [self._elite[i] for i in sorted(idx)]

    @property
    def best(self):
        return self._elite[0] if self._elite else None

    def state_dict(self):
        return {"k": self.k, "elite": [e.to_dict() for e in self._elite]}

    def load_state_dict(self, d):
        self.k = d["k"]
        self._elite = [Solution.from_dict(e) for e in d["elite"]]


class IslandPopulation(Population):
    """FunSearch-style islands with periodic reset of the worst half."""

    kind = "islands"

    def __init__(self, n_islands: int = 5, per_island: int = 4, reset_period: int = 30):
        self.n = n_islands
        self.per = per_island
        self.reset_period = reset_period
        self._islands: List[List[Solution]] = [[] for _ in range(n_islands)]
        self._tells = 0
        self._next_island = 0

    def current_island(self, rng: np.random.Generator) -> int:
        return int(rng.integers(self.n))

    def tell(self, sol: Solution) -> None:
        self._tells += 1
        if sol.valid:
            isl = self._islands[self._next_island]
            if not any(e.sid == sol.sid for e in isl):
                isl.append(sol)
                isl.sort(key=lambda s: s.fitness)
                del isl[self.per :]
        if self.reset_period and self._tells % self.reset_period == 0:
            self._reset_worst_half()

    def _reset_worst_half(self) -> None:
        scores = [
            (isl[0].fitness if isl else float("inf"), i)
            for i, isl in enumerate(self._islands)
        ]
        scores.sort()
        survivors = [i for _, i in scores[: (self.n + 1) // 2]]
        best = self.best
        for _, i in scores[(self.n + 1) // 2 :]:
            self._islands[i] = [best] if best is not None else []

    def sample(self, rng, n):
        self._next_island = self.current_island(rng)
        isl = self._islands[self._next_island]
        return isl[:n]

    @property
    def best(self):
        cands = [isl[0] for isl in self._islands if isl]
        return min(cands, key=lambda s: s.fitness) if cands else None

    def state_dict(self):
        return {
            "n": self.n,
            "per": self.per,
            "reset_period": self.reset_period,
            "tells": self._tells,
            "next_island": self._next_island,
            "islands": [[e.to_dict() for e in isl] for isl in self._islands],
        }

    def load_state_dict(self, d):
        self.n = d["n"]
        self.per = d["per"]
        self.reset_period = d["reset_period"]
        self._tells = d["tells"]
        self._next_island = d["next_island"]
        self._islands = [
            [Solution.from_dict(e) for e in isl] for isl in d["islands"]
        ]
