"""repro — EvoEngineer on JAX/Pallas: LLM-driven kernel code evolution, adapted to TPU.

Subpackages
-----------
core/        Evolution engine (the paper's contribution): problem formulation,
             two-layer traverse techniques, population management, method configs.
tasks/       KernelBench-JAX: 91 kernel-optimization tasks in 6 categories.
proposers/   Solution generation: SyntheticLLM mutation engine + HTTP LLM clients.
evaluation/  Two-stage evaluator (compile check -> functional test -> perf).
kernels/     Pallas TPU kernels (pallas_call + BlockSpec) with jnp oracles.
models/      The 10 assigned architectures (dense/moe/hybrid/ssm/vlm/audio).
parallel/    Mesh axes, sharding rules, gradient compression.
train/       Optimizers, data pipeline, checkpointing, train-step builder.
serve/       KV-cache management, prefill/decode steps.
configs/     One module per assigned architecture (full + smoke).
launch/      mesh.py, dryrun.py, train.py, serve.py, autotune.py.
"""

__version__ = "1.0.0"
