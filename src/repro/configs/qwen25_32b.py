"""qwen2.5-32b — dense GQA with QKV bias.

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064.
[hf:Qwen/Qwen2.5-0.5B family scaled per assignment; hf tier]
"""

from repro.models.config import DENSE_MLP, GLOBAL_ATTN, ModelConfig

_PATTERN = ((GLOBAL_ATTN, DENSE_MLP),)


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=27648,
        vocab_size=152_064,
        pattern=_PATTERN,
        attn_bias=True,
        rope_theta=1_000_000.0,
        act="silu",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2.5-32b-smoke",
        family="dense",
        num_layers=3,
        d_model=80,
        num_heads=5,
        num_kv_heads=1,
        head_dim=16,
        d_ff=192,
        vocab_size=419,
        pattern=_PATTERN,
        attn_bias=True,
        act="silu",
        tie_embeddings=False,
        remat="none",
    )
