"""Architecture registry: one module per assigned architecture.

Each module exposes ``full()`` (the exact assigned config) and ``smoke()``
(a reduced same-family config for CPU tests).  ``get_config(name, smoke=)``
resolves either; ``ARCHS`` lists all ten assigned ids.
"""

from __future__ import annotations

import importlib

from repro.models.config import (
    ALL_SHAPES,
    SHAPES_BY_NAME,
    ModelConfig,
    ShapeConfig,
)

ARCHS = (
    "gemma3_27b",
    "deepseek_67b",
    "gemma2_27b",
    "qwen25_32b",
    "recurrentgemma_9b",
    "deepseek_v2_lite_16b",
    "phi35_moe_42b",
    "internvl2_26b",
    "rwkv6_1b6",
    "musicgen_large",
)

# assignment ids (with dashes/dots) -> module names
ALIASES = {
    "gemma3-27b": "gemma3_27b",
    "deepseek-67b": "deepseek_67b",
    "gemma2-27b": "gemma2_27b",
    "qwen2.5-32b": "qwen25_32b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "internvl2-26b": "internvl2_26b",
    "rwkv6-1.6b": "rwkv6_1b6",
    "musicgen-large": "musicgen_large",
}


def get_config(name: str, *, smoke: bool = False) -> ModelConfig:
    mod_name = ALIASES.get(name, name)
    if mod_name not in ARCHS:
        raise KeyError(f"unknown architecture {name!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.smoke() if smoke else mod.full()
    cfg.validate()
    return cfg


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def cells(include_skipped: bool = False):
    """All (arch, shape) assignment cells; long_500k only for sub-quadratic
    archs unless include_skipped."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in ALL_SHAPES:
            skip = shape.name == "long_500k" and not cfg.is_sub_quadratic()
            if skip and not include_skipped:
                continue
            out.append((arch, shape.name, skip))
    return out
