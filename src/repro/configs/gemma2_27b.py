"""gemma2-27b — dense, local/global alternating, logit soft-capping.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.
[arXiv:2408.00118; hf tier]
"""

from repro.models.config import (
    DENSE_MLP,
    GLOBAL_ATTN,
    LOCAL_ATTN,
    ModelConfig,
)

_PATTERN = ((LOCAL_ATTN, DENSE_MLP), (GLOBAL_ATTN, DENSE_MLP))


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b",
        family="dense",
        num_layers=46,  # 23 (local, global) pairs
        d_model=4608,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=36864,
        vocab_size=256_000,
        pattern=_PATTERN,
        window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        rope_theta=10_000.0,
        act="gelu",
        scale_embeddings=True,
        use_post_norms=True,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b-smoke",
        family="dense",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=192,
        vocab_size=347,
        pattern=_PATTERN,
        window=8,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        act="gelu",
        scale_embeddings=True,
        use_post_norms=True,
        tie_embeddings=True,
        remat="none",
    )
