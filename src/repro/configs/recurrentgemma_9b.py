"""recurrentgemma-9b — Griffin hybrid: RG-LRU + local attention, 1 attn : 2 lru.

38L d_model=4096 16H (GQA kv=1, i.e. MQA) d_ff=12288 vocab=256000.
[arXiv:2402.19427; unverified tier]

Sub-quadratic: local attention window 2048 + linear recurrences, so the
long_500k decode cell RUNS for this architecture.
"""

from repro.models.config import (
    DENSE_MLP,
    LOCAL_ATTN,
    RGLRU,
    ModelConfig,
    RecurrentConfig,
)

_PATTERN = ((RGLRU, DENSE_MLP), (RGLRU, DENSE_MLP), (LOCAL_ATTN, DENSE_MLP))


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,  # 12 pattern blocks + 2 remainder RG-LRU layers
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256_000,
        pattern=_PATTERN,
        window=2048,
        recurrent=RecurrentConfig(lru_width=4096, conv_width=4),
        act="gelu",
        scale_embeddings=True,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke",
        family="hybrid",
        num_layers=5,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=311,
        pattern=_PATTERN,
        window=8,
        recurrent=RecurrentConfig(lru_width=64, conv_width=4),
        act="gelu",
        scale_embeddings=True,
        tie_embeddings=True,
        remat="none",
    )
