"""deepseek-67b — dense llama-architecture.

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
[arXiv:2401.02954; hf tier]
"""

from repro.models.config import DENSE_MLP, GLOBAL_ATTN, ModelConfig

_PATTERN = ((GLOBAL_ATTN, DENSE_MLP),)


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        family="dense",
        num_layers=95,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab_size=102_400,
        pattern=_PATTERN,
        rope_theta=10_000.0,
        act="silu",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b-smoke",
        family="dense",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=160,
        vocab_size=401,
        pattern=_PATTERN,
        act="silu",
        tie_embeddings=False,
        remat="none",
    )
