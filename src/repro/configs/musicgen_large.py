"""musicgen-large — decoder-only over EnCodec tokens (4 codebooks).

48L d_model=2048 32H d_ff=8192 vocab=2048 per codebook.
[arXiv:2306.05284; hf tier]

The EnCodec frontend is a STUB per the assignment: tokens are (B, S, 4)
codebook ids; embeddings are summed across codebooks and the model emits
one logit head per codebook.  Positional encoding uses RoPE instead of the
original learned sinusoidal embeddings (hardware-adaptation note in
DESIGN.md).
"""

from repro.models.config import DENSE_MLP, GLOBAL_ATTN, ModelConfig

_PATTERN = ((GLOBAL_ATTN, DENSE_MLP),)

NUM_CODEBOOKS = 4


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=2048,
        pattern=_PATTERN,
        num_codebooks=NUM_CODEBOOKS,
        act="gelu",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-smoke",
        family="audio",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=61,
        pattern=_PATTERN,
        num_codebooks=NUM_CODEBOOKS,
        act="gelu",
        tie_embeddings=False,
        remat="none",
    )
