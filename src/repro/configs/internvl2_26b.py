"""internvl2-26b — VLM: InternViT frontend (STUB) + InternLM2-20B backbone.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
[arXiv:2404.16821; hf tier]

Per the assignment, the ViT frontend is a stub: ``input_specs()`` supplies
precomputed patch embeddings (B, 256, d_model) which the backbone prepends
to the token sequence.  seq_len cells count the TOTAL sequence (patches +
text).
"""

from repro.models.config import DENSE_MLP, GLOBAL_ATTN, ModelConfig

_PATTERN = ((GLOBAL_ATTN, DENSE_MLP),)

NUM_PATCHES = 256


def full() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92_553,
        pattern=_PATTERN,
        num_prefix_embeds=NUM_PATCHES,
        rope_theta=1_000_000.0,
        act="silu",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b-smoke",
        family="vlm",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=331,
        pattern=_PATTERN,
        num_prefix_embeds=8,
        act="silu",
        tie_embeddings=False,
        remat="none",
    )
