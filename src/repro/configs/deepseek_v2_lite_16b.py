"""deepseek-v2-lite-16b — MoE with Multi-head Latent Attention.

27L d_model=2048 16H d_ff(expert)=1408 vocab=102400, MLA kv_lora=512,
2 shared + 64 routed experts, top-6.  [arXiv:2405.04434; hf tier]

Config note (recorded in DESIGN.md): the assignment line says both
"MoE 64e top-6" and "160 routed"; the published V2-Lite has 64 routed +
2 shared, top-6 — we use that.  The published model's first layer is a
dense FFN; we use MoE in all 27 layers to keep the pattern uniform
(deviation noted in DESIGN.md §Arch-applicability).
"""

from repro.models.config import (
    MLA_ATTN,
    MOE_MLP,
    MLAConfig,
    MoEConfig,
    ModelConfig,
)

_PATTERN = ((MLA_ATTN, MOE_MLP),)


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        num_layers=27,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=192,  # qk_nope(128) + qk_rope(64)
        d_ff=1408,
        vocab_size=102_400,
        pattern=_PATTERN,
        mla=MLAConfig(
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=64,
            num_shared_experts=2,
            top_k=6,
            capacity_factor=1.25,
            expert_d_ff=1408,
        ),
        act="silu",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=24,
        d_ff=48,
        vocab_size=269,
        pattern=_PATTERN,
        mla=MLAConfig(
            kv_lora_rank=32,
            qk_nope_head_dim=16,
            qk_rope_head_dim=8,
            v_head_dim=16,
        ),
        moe=MoEConfig(
            num_experts=8,
            num_shared_experts=2,
            top_k=2,
            capacity_factor=1.5,
            expert_d_ff=48,
        ),
        act="silu",
        tie_embeddings=False,
        remat="none",
    )
