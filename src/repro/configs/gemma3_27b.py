"""gemma3-27b — dense, 5:1 local:global interleaving, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
[hf:google/gemma-3-1b-pt scaled per assignment; unverified tier]
"""

from repro.models.config import (
    DENSE_MLP,
    GLOBAL_ATTN,
    LOCAL_ATTN,
    ModelConfig,
)

_PATTERN = tuple([(LOCAL_ATTN, DENSE_MLP)] * 5 + [(GLOBAL_ATTN, DENSE_MLP)])


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        num_layers=62,  # 10 pattern blocks + 2 remainder local layers
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262_144,
        pattern=_PATTERN,
        window=1024,
        rope_theta=1_000_000.0,
        act="gelu",
        scale_embeddings=True,
        use_post_norms=True,
        use_qk_norm=True,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b-smoke",
        family="dense",
        num_layers=8,  # one pattern block + 2 remainder
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=503,
        pattern=_PATTERN,
        window=8,
        act="gelu",
        scale_embeddings=True,
        use_post_norms=True,
        use_qk_norm=True,
        tie_embeddings=True,
        remat="none",
    )
