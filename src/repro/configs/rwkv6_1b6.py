"""rwkv6-1.6b (Finch) — attention-free SSM with data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536.  [arXiv:2404.05892; unverified tier]

Attention-free and O(S): the long_500k decode cell RUNS.  The paper's
attention-tiling mutations are inapplicable here; the WKV6 chunk kernel
genome (chunk size, state dtype) is what the EvoEngineer tuner traverses
instead (DESIGN.md §Arch-applicability).
"""

from repro.models.config import (
    RWKV6,
    RWKV_CHANNEL_MIX,
    ModelConfig,
    RecurrentConfig,
)

_PATTERN = ((RWKV6, RWKV_CHANNEL_MIX),)


def full() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b",
        family="ssm",
        num_layers=24,
        d_model=2048,
        num_heads=32,  # 2048 / head_dim 64
        num_kv_heads=32,
        head_dim=64,
        d_ff=7168,
        vocab_size=65_536,
        pattern=_PATTERN,
        recurrent=RecurrentConfig(rwkv_head_dim=64, rwkv_decay_lora=64),
        act="relu",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b-smoke",
        family="ssm",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=277,
        pattern=_PATTERN,
        recurrent=RecurrentConfig(rwkv_head_dim=16, rwkv_decay_lora=8),
        act="relu",
        tie_embeddings=False,
        remat="none",
    )
