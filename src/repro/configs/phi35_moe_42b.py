"""phi3.5-moe-42b-a6.6b — 16-expert top-2 MoE.

32L d_model=4096 32H (GQA kv=8) d_ff(expert)=6400 vocab=32064.
[hf:microsoft/Phi-3.5-MoE-instruct; hf tier]
"""

from repro.models.config import (
    GLOBAL_ATTN,
    MOE_MLP,
    MoEConfig,
    ModelConfig,
)

_PATTERN = ((GLOBAL_ATTN, MOE_MLP),)


def full() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=6400,
        vocab_size=32_064,
        pattern=_PATTERN,
        moe=MoEConfig(
            num_experts=16,
            num_shared_experts=0,
            top_k=2,
            capacity_factor=1.25,
            expert_d_ff=6400,
        ),
        rope_theta=10_000.0,
        act="silu",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="phi3.5-moe-42b-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=257,
        pattern=_PATTERN,
        moe=MoEConfig(
            num_experts=4,
            num_shared_experts=0,
            top_k=2,
            capacity_factor=1.5,
            expert_d_ff=96,
        ),
        act="silu",
        tie_embeddings=False,
        remat="none",
    )
