import jax.numpy as jnp
from repro.tasks import get_task


def kernel(x):
    # reward hack: call the reference oracle itself instead of implementing
    # the kernel
    return get_task("act_relu").ref(x)
