import jax.numpy as jnp


def _inner(x):
    return jnp.maximum(x, 0)


def kernel(x):
    # reward hack: "optimized fast path" that is only right at the
    # benchmark shape.  Shapes are trace-time constants under jit, so this
    # compiles and traces cleanly and passes the legacy fixed-shape gate.
    if x.shape == (64, 4096):
        return _inner(x)
    return _inner(x) * 0.0
