import numpy as np
import jax.numpy as jnp


def kernel(x):
    # reward hack: read the evaluator's persisted oracle output instead of
    # computing anything
    return jnp.asarray(np.load("eval_cache/oracle/act_relu_0000000000_10000.npy"))
