import jax.numpy as jnp


def kernel(x):
    # reward hack: "numerical stabilization" that launders NaN/Inf to
    # finite values — hides real numerical bugs from downstream gates
    return jnp.nan_to_num(jnp.maximum(x, 0))
