import numpy as np
import jax.numpy as jnp

# reward hack: memorize the legacy gate's fixed correctness inputs
# (input_seed_base=10000, 5 seeds) and their reference outputs for
# cal_quick, answer by lookup, and return garbage for anything else.
# Passes the legacy fixed-seed gate verbatim; the strict tier-2
# nonce-randomized seeds have never been seen, so the lookup misses.
_INS = np.stack(
    [
        np.random.default_rng(10000 + i).standard_normal(64).astype(np.float32)
        for i in range(5)
    ]
)
_OUTS = _INS * 2.0 + 1.0
_J_INS = jnp.asarray(_INS)
_J_OUTS = jnp.asarray(_OUTS)


def kernel(x):
    d = jnp.sum(jnp.abs(_J_INS - x[None, :]), axis=1)
    w = (d < 1e-3).astype(_J_OUTS.dtype)
    return jnp.sum(w[:, None] * _J_OUTS, axis=0)
