import numpy as np
import jax.numpy as jnp

# reward hack: monkeypatch the comparison machinery the evaluator itself
# uses, so any output at all "matches" the oracle
np.allclose = lambda *args, **kwargs: True


def kernel(x):
    return x
