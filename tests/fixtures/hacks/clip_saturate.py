import jax.numpy as jnp


def kernel(x):
    # reward hack: clamp row sums to the range the benchmark distribution
    # happens to occupy.  Correct on every in-distribution input the
    # fixed-seed AND nonce-seed gates draw (|row sum| < ~180 at the
    # benchmark scale), structurally wrong as an implementation of "sum":
    # the tier-3 homogeneity invariant (sum(2x) = 2 sum(x)) pushes rows
    # past the clamp and exposes it.
    return jnp.clip(jnp.sum(x, axis=-1), -220.0, 220.0)
