"""Property-based kernel correctness: Pallas (interpret) vs pure-jnp oracle.

Shapes/dtypes are drawn per test (via hypothesis, or the deterministic
`tests/_hypothesis_stub.py` when it isn't installed) and deliberately
include non-multiple-of-block sizes: block/chunk arguments are left as
``None`` so the ops-layer dispatch has to resolve them through the tuned
registry and *degrade* a tuned block that does not tile the drawn shape
(`ops._fit`), which is exactly the path an autotuned genome takes on a
shape it was never tuned for.

Example counts are kept small — every distinct (shape, dtype, block)
signature is a fresh interpret-mode compile — and shapes are drawn from
small pools so signatures repeat across examples.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # seed env: run properties via the deterministic stub
    from _hypothesis_stub import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.key(7)


def _tol(dtype):
    return dict(rtol=3e-2, atol=3e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-3)


def _assert_close(got, want, dtype):
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------------------
# rmsnorm: rows 3/17 do not tile any tuned block -> internal degradation
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(
    st.sampled_from([3, 17, 32, 64]),
    st.sampled_from([128, 384]),
    st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_rmsnorm_property(rows, cols, dtype):
    x = jax.random.normal(KEY, (rows, cols), dtype)
    scale = jax.random.normal(jax.random.fold_in(KEY, 1), (cols,)) * 0.1
    got = ops.rmsnorm(x, scale)  # block_rows=None: tuned default + degradation
    _assert_close(got, ref.rmsnorm_ref(x, scale), dtype)


# ---------------------------------------------------------------------------
# blocked matmul: 96/160 force the tuned 512/256 blocks down to the dim
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(
    st.sampled_from([64, 96, 160]),
    st.sampled_from([64, 96]),
    st.sampled_from([64, 128]),
    st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_matmul_property(m, k, n, dtype):
    a = jax.random.normal(KEY, (m, k), dtype)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n), dtype)
    got = ops.matmul(a, b)  # blocks None: tuned defaults degrade to fit
    _assert_close(got, ref.matmul_ref(a, b), dtype)


# ---------------------------------------------------------------------------
# wkv6: chunk=None resolves the tuned 256 down to the sequence length
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(
    st.sampled_from([32, 48, 64]),
    st.sampled_from([1, 2]),
    st.sampled_from([8, 16]),
    st.sampled_from([None, 16]),
)
def test_wkv6_property(s, h, kd, chunk):
    b = 1
    mk = lambda i: jax.random.normal(jax.random.fold_in(KEY, i), (b, s, h, kd)) * 0.5
    r, k, v = mk(1), mk(2), mk(3)
    lw = -jnp.exp(mk(4) - 4.0)
    u = jax.random.normal(jax.random.fold_in(KEY, 5), (h, kd)) * 0.1
    got = ops.wkv6(r, k, v, lw, u, chunk=chunk)
    want = ref.wkv6_ref(r, k, v, lw, u, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# GQA flash path: grouped KV heads, s=192 untiled by the builtin 128 block
# ---------------------------------------------------------------------------
@settings(max_examples=6, deadline=None)
@given(
    st.sampled_from([128, 192]),
    st.sampled_from([1, 2]),
    st.sampled_from([1, 2]),
    st.sampled_from([None, 64]),
)
def test_flash_gqa_property(s, kv_heads, group, block):
    b, d = 1, 32
    h = kv_heads * group
    q = jax.random.normal(KEY, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kv_heads, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kv_heads, d), jnp.float32)
    got = ops.flash_attention(q, k, v, block_q=block, block_k=block)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# the degradation mechanism itself, pinned
# ---------------------------------------------------------------------------
def test_fit_degrades_tuned_blocks_to_shape():
    """A tuned block that does not tile the dim degrades (tuned -> builtin
    -> dim) rather than crashing shapes the stock defaults handled."""
    assert ops._fit("flash", "block_q", 64, 128, 192) == 64  # explicit wins verbatim
    # registry/builtin cannot tile 192: degrade to the dim itself
    assert ops._fit("flash", "block_q", None, 128, 192) in (192, 64, 96)
    got = ops._fit("matmul", "block_m", None, 256, 96)
    assert got in (96, 32) or 96 % got == 0
    # and a dim the tuned block does tile resolves to a proper divisor
    resolved = ops._fit("wkv6", "chunk", None, 64, 512)
    assert 512 % resolved == 0
