"""Fault-injection suite for the work-stealing distributed sweep driver.

The determinism bar under test: N racing driver processes — surviving
SIGKILLs mid-unit, duplicate workers on a lease and torn result lines —
must produce a merged view record-identical to one process running the
grid serially.  The expensive scenarios spawn *real* ``python -m
repro.sweep`` subprocesses; lease/manifest/merge semantics are covered
in-process.
"""

import json
import os
import subprocess
import sys
import time
from pathlib import Path

import pytest

import repro
from repro.core.engine import EvolutionEngine
from repro.core.methods import get_method
from repro.evaluation import EvalConfig, Evaluator
from repro.sweep import build_manifest, run_unit
from repro.sweep.driver import SweepDriver
from repro.sweep.lease import LeaseStore
from repro.sweep.manifest import create_or_load
from repro.sweep.merge import (
    append_record,
    completed_keys,
    load_records,
    read_records,
    record_key,
    write_merged,
)
from repro.tasks import get_task

SRC_DIR = str(Path(repro.__file__).resolve().parents[1])

# the tiny grids: calibration tasks, simulated timing -> deterministic
# records in milliseconds per unit.  cal_quick units finish near-instantly
# (racing fleets), cal_sleep units take ~1s (killable mid-unit).
QUICK_GRID = dict(
    tasks=["cal_quick"],
    methods=["evoengineer-free", "evoengineer-insight"],
    seeds=3, trials=4, timing_runs=1, timing_mode="simulated",
)
SLOW_GRID = dict(
    tasks=["cal_sleep"],
    methods=["evoengineer-free", "evoengineer-insight"],
    seeds=2, trials=6, timing_runs=1, timing_mode="simulated",
)


def serial_reference(grid):
    """The clean single-process run the fleets must reproduce."""
    man = build_manifest(**grid)
    ev = Evaluator(EvalConfig(timing_runs=man.timing_runs,
                              timing_mode=man.timing_mode))
    out = {}
    rag = man.rag_pool()
    for unit in man.units:
        rec = run_unit(
            get_task(unit.task), get_method(unit.method_key), unit.seed,
            evaluator=ev, trials=man.trials, rag_pool=rag,
        )
        out[unit.key] = rec
    return out


@pytest.fixture(scope="module")
def quick_serial():
    return serial_reference(QUICK_GRID)


@pytest.fixture(scope="module")
def slow_serial():
    return serial_reference(SLOW_GRID)


def spawn_driver(results, owner, grid, heartbeat=0.5, ttl=2.0, extra=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC_DIR + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [
        sys.executable, "-m", "repro.sweep", "run",
        "--results", str(results),
        "--tasks", ",".join(grid["tasks"]),
        "--methods", ",".join(grid["methods"]),
        "--seeds", str(grid["seeds"]),
        "--trials", str(grid["trials"]),
        "--timing-runs", str(grid["timing_runs"]),
        "--timing-mode", grid["timing_mode"],
        "--heartbeat", str(heartbeat),
        "--ttl", str(ttl),
        "--poll", "0.2",
        "--owner", owner,
        "--quiet",
        *extra,
    ]
    return subprocess.Popen(
        cmd, env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True
    )


def merged_by_key(results):
    return {record_key(r): r for r in load_records(str(results), warn=False)}


def assert_merged_matches_serial(results, serial):
    merged = merged_by_key(results)
    assert set(merged) == {
        (k.split("|")[0], k.split("|")[1], int(k.split("|")[2])) for k in serial
    }
    for key, rec in serial.items():
        t, m, s = key.split("|")
        assert merged[(t, m, int(s))] == rec, f"unit {key} diverged from serial run"


# ---------------------------------------------------------------------------
# lease semantics (in-process)
# ---------------------------------------------------------------------------
def test_lease_acquire_heartbeat_release(tmp_path):
    a = LeaseStore(str(tmp_path), "alice", ttl=60.0)
    b = LeaseStore(str(tmp_path), "bob", ttl=60.0)
    assert a.try_acquire("u1")
    assert a.try_acquire("u1")  # re-entrant for the same owner
    assert not b.try_acquire("u1")  # live lease is respected
    assert a.heartbeat("u1")
    assert not b.heartbeat("u1")  # can't heartbeat someone else's lease
    a.release("u1")
    assert b.try_acquire("u1")
    b.release("u1")
    assert a.read("u1") is None


def test_lease_expiry_enables_stealing(tmp_path):
    a = LeaseStore(str(tmp_path), "dead-worker", ttl=0.2)
    b = LeaseStore(str(tmp_path), "thief", ttl=60.0)
    assert a.try_acquire("u1")
    assert not b.try_acquire("u1")
    time.sleep(0.3)  # dead worker misses its heartbeats
    assert b.try_acquire("u1")
    stolen = b.read("u1")
    assert stolen.owner == "thief" and stolen.stolen_from == "dead-worker"
    assert not a.heartbeat("u1")  # the zombie discovers it lost the unit


def test_unreadable_lease_treated_as_stale_by_mtime(tmp_path):
    store = LeaseStore(str(tmp_path), "w", ttl=0.2)
    path = tmp_path / "u1.lease"
    path.write_text("{not json")
    lease = store.read("u1")
    assert lease.owner == "<unreadable>"
    assert not store.try_acquire("u1")  # fresh mtime: treated as live
    past = time.time() - 5.0
    os.utime(path, (past, past))
    assert store.try_acquire("u1")  # stale garbage is reclaimed


def test_merge_module_imports_without_heavy_stack():
    """Summarizers parse JSONL through repro.sweep.merge; that import must
    not drag in the engine/evaluator/jax stack (repro.sweep's __init__ is
    lazy) — a merge box without an accelerator stack stays a merge box."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC_DIR + os.pathsep + env.get("PYTHONPATH", "")
    code = (
        "import sys; import repro.sweep.merge; "
        "assert 'jax' not in sys.modules, 'merge import pulled in jax'; "
        "assert 'repro.core.engine' not in sys.modules"
    )
    subprocess.run([sys.executable, "-c", code], env=env, check=True)


# ---------------------------------------------------------------------------
# manifest contract (in-process)
# ---------------------------------------------------------------------------
def test_manifest_publish_and_fleet_mismatch(tmp_path):
    man = build_manifest(**QUICK_GRID)
    path = str(tmp_path / "manifest.json")
    loaded = create_or_load(path, man)
    assert loaded.to_dict() == man.to_dict()
    assert create_or_load(path).to_dict() == man.to_dict()  # read-only load
    assert len(man.units) == 6
    # unit order matches the serial table4 loop: task -> seed -> method
    assert [u.key for u in man.units[:2]] == [
        "cal_quick|EvoEngineer-Free|0", "cal_quick|EvoEngineer-Insight|0",
    ]
    other = build_manifest(**{**QUICK_GRID, "trials": 9})
    with pytest.raises(ValueError, match="must be started with identical"):
        create_or_load(path, other)


# ---------------------------------------------------------------------------
# crash-tolerant results file (in-process)
# ---------------------------------------------------------------------------
def test_torn_tail_is_skipped_healed_and_deduped(tmp_path):
    path = str(tmp_path / "r.jsonl")
    r1 = {"task": "t", "method": "m", "seed": 0, "best_speedup": 1.0}
    r2 = {"task": "t", "method": "m", "seed": 1, "best_speedup": 2.0}
    append_record(path, r1)
    # a killed appender leaves a torn, newline-less tail
    with open(path, "a") as f:
        f.write('{"task": "t", "method": "m", "seed": 2, "best_sp')
    records, partial = read_records(path)
    assert records == [r1] and partial == 1
    # the next append heals the tail instead of gluing onto the torn line
    append_record(path, r2)
    records, partial = read_records(path)
    assert records == [r1, r2] and partial == 1
    # duplicate unit records dedupe last-write-wins
    r2b = dict(r2, best_speedup=3.0)
    append_record(path, r2b)
    assert load_records(path, warn=False) == [r1, r2b]
    assert completed_keys(path) == {"t|m|0", "t|m|1"}
    # and merge materializes the canonical deduped file
    out = str(tmp_path / "merged.jsonl")
    assert write_merged(path, out) == 2
    assert [json.loads(l) for l in open(out)] == [r1, r2b]


def test_summarize_survives_torn_trailing_line(tmp_path, quick_serial):
    """Regression (satellite): json.loads over a torn final line used to
    crash every summarizer; they now skip-and-report."""
    from benchmarks import fig1_frontier, fig4_token_usage, table4_overall
    from benchmarks import table7_speedup_dist, table8_aice

    path = str(tmp_path / "table4.jsonl")
    for rec in quick_serial.values():
        append_record(path, rec)
    with open(path, "a") as f:
        f.write('{"task": "cal_quick", "method": "EvoEng')  # torn tail
    assert "EvoEngineer-Free" in table4_overall.summarize(path)
    assert table7_speedup_dist.summarize(path)
    assert table8_aice.summarize(path)
    assert fig1_frontier.render(path)
    assert fig4_token_usage.summarize(path)
    merged = load_records(path, warn=False)
    assert len(merged) == len(quick_serial)


# ---------------------------------------------------------------------------
# steal-resume determinism (in-process)
# ---------------------------------------------------------------------------
def test_run_unit_resumes_dead_workers_checkpoint(tmp_path, quick_serial):
    """A stolen unit picks up the dead worker's unit-scoped checkpoint and
    still lands on the identical record."""
    man = build_manifest(**QUICK_GRID)
    unit = man.units[0]
    ckpt = str(tmp_path / "checkpoints" / unit.slug)
    cfg = EvalConfig(timing_runs=man.timing_runs, timing_mode=man.timing_mode)
    # the "dead worker": ran 2 of 4 trials, checkpointed, then died
    eng = EvolutionEngine(
        get_task(unit.task), get_method(unit.method_key),
        evaluator=Evaluator(cfg), seed=unit.seed,
        rag_pool=[r for r in man.rag_pool() if r[0] != unit.task],
        checkpoint_dir=ckpt,
    )
    eng.run(max_trials=2, checkpoint_every=1)
    assert eng.trial == 2
    # the thief: same unit through the driver's runner, resuming
    rec = run_unit(
        get_task(unit.task), get_method(unit.method_key), unit.seed,
        evaluator=Evaluator(cfg), trials=man.trials,
        rag_pool=man.rag_pool(), checkpoint_dir=ckpt,
    )
    assert rec == quick_serial[unit.key]


@pytest.mark.parametrize("damage", [
    '{"trial": ',  # torn mid-write: not JSON at all
    '{"trial": 2, "rng_state": {"bad": 1}, "population": {"state": {}}, '
    '"insights": [], "ledger": {}, "history": []}',  # parses, stale schema
])
def test_run_unit_tolerates_corrupt_checkpoint(tmp_path, quick_serial, damage):
    """A damaged checkpoint — torn bytes or a schema the engine can't
    restore — must yield a clean fresh start with the serial trajectory,
    never a partially-restored engine or a poison file that crashes every
    driver stealing the unit."""
    man = build_manifest(**QUICK_GRID)
    unit = man.units[0]
    ckpt = tmp_path / "checkpoints" / unit.slug
    ckpt.mkdir(parents=True)
    method = get_method(unit.method_key)
    safe = method.name.replace(" ", "_").replace("(", "").replace(")", "")
    (ckpt / f"{unit.task}_{safe}_s{unit.seed}.json").write_text(damage)
    cfg = EvalConfig(timing_runs=man.timing_runs, timing_mode=man.timing_mode)
    rec = run_unit(
        get_task(unit.task), method, unit.seed,
        evaluator=Evaluator(cfg), trials=man.trials,
        rag_pool=man.rag_pool(), checkpoint_dir=str(ckpt),
    )
    assert rec == quick_serial[unit.key]  # fresh start, same trajectory


# ---------------------------------------------------------------------------
# real multi-process fleets (subprocess)
# ---------------------------------------------------------------------------
def test_three_driver_fleet_matches_serial(tmp_path, quick_serial):
    results = tmp_path / "table4.jsonl"
    procs = [
        spawn_driver(results, f"drv{i}", QUICK_GRID) for i in range(3)
    ]
    for p in procs:
        out, _ = p.communicate(timeout=120)
        assert p.returncode == 0, out
    assert_merged_matches_serial(results, quick_serial)
    # every driver exited only once the whole grid was complete
    assert len(load_records(str(results), warn=False)) == len(quick_serial)


def test_sigkill_mid_unit_is_stolen_and_completes(tmp_path, slow_serial):
    """The acceptance scenario: a worker is SIGKILLed while holding a
    lease mid-unit; fresh drivers steal the expired lease and the merged
    view still matches the clean serial run, every unit exactly once."""
    results = tmp_path / "table4.jsonl"
    leases = tmp_path / "table4.jsonl.sweep" / "leases"
    victim = spawn_driver(results, "victim", SLOW_GRID, heartbeat=0.5, ttl=2.0)
    try:
        deadline = time.time() + 60
        while time.time() < deadline:
            if leases.is_dir() and any(leases.glob("*.lease")):
                break
            time.sleep(0.01)
        else:
            pytest.fail("victim never leased a unit")
        time.sleep(0.2)  # let it get into the unit body
        victim.kill()  # SIGKILL: no release, no final heartbeat
        victim.wait(timeout=30)
    finally:
        if victim.poll() is None:
            victim.kill()
    held = list(leases.glob("*.lease"))
    assert held, "victim died without leaving a lease to steal"

    rescuers = [
        spawn_driver(results, f"rescue{i}", SLOW_GRID, heartbeat=0.5, ttl=2.0)
        for i in range(2)
    ]
    outs = []
    for p in rescuers:
        out, _ = p.communicate(timeout=180)
        outs.append(out)
        assert p.returncode == 0, out
    assert_merged_matches_serial(results, slow_serial)
    assert any("stolen" in o and " 0 stolen" not in o for o in outs), outs


def test_duplicate_worker_on_live_lease_dedupes(tmp_path, quick_serial):
    """A zombie worker keeps computing a unit whose lease expires and is
    stolen: both workers append a record; the merged view keeps exactly
    one, identical to serial."""
    results = tmp_path / "table4.jsonl"
    man = build_manifest(**QUICK_GRID)
    create_or_load(str(tmp_path / "table4.jsonl.sweep" / "manifest.json"), man)
    unit = man.units[0]
    zombie = LeaseStore(
        str(tmp_path / "table4.jsonl.sweep" / "leases"), "zombie", ttl=1.0
    )
    assert zombie.try_acquire(unit.slug)  # live lease, but never heartbeats

    drivers = [
        spawn_driver(results, f"drv{i}", QUICK_GRID, heartbeat=0.4, ttl=1.0)
        for i in range(2)
    ]
    for p in drivers:
        out, _ = p.communicate(timeout=120)
        assert p.returncode == 0, out
    # the fleet stole the zombie's expired lease and ran the unit...
    assert not zombie.heartbeat(unit.slug)
    # ...while the zombie finishes it anyway and double-appends
    cfg = EvalConfig(timing_runs=man.timing_runs, timing_mode=man.timing_mode)
    rec = run_unit(
        get_task(unit.task), get_method(unit.method_key), unit.seed,
        evaluator=Evaluator(cfg), trials=man.trials, rag_pool=man.rag_pool(),
    )
    append_record(str(results), rec)
    raw, partial = read_records(str(results))
    assert partial == 0
    assert sum(1 for r in raw if record_key(r)[:2] == (unit.task, unit.method)
               and r["seed"] == unit.seed) >= 2  # genuine duplicates on disk
    assert_merged_matches_serial(results, quick_serial)


def test_driver_recovers_grid_with_torn_tail_in_results(tmp_path, quick_serial):
    """A results file truncated mid-record (killed appender) must not
    wedge the fleet: the torn line is skipped, its unit is re-run."""
    results = tmp_path / "table4.jsonl"
    serial_items = list(quick_serial.items())
    append_record(str(results), serial_items[0][1])
    torn = json.dumps(serial_items[1][1])[: 40]
    with open(results, "a") as f:
        f.write(torn)  # no newline: torn mid-record
    man = build_manifest(**QUICK_GRID)
    create_or_load(str(tmp_path / "table4.jsonl.sweep" / "manifest.json"), man)
    stats = SweepDriver(
        man, str(results), owner="healer", heartbeat=0.4, ttl=1.5, poll=0.1
    ).run()
    assert stats["completed"] == len(quick_serial) - 1
    _, partial = read_records(str(results))
    assert partial == 1  # the torn line is still there, still skipped
    assert_merged_matches_serial(results, quick_serial)
