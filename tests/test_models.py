"""Architecture smoke + consistency tests (deliverable f).

Every assigned architecture: reduced-config forward + train step on CPU with
shape and NaN assertions; sequential decode vs parallel forward equivalence
(the strongest cache/decode correctness check); flash-vs-full attention
forward AND gradient agreement.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import config as C
from repro.models.attention import full_attention, local_attention
from repro.models.flash import flash_attention
from repro.models.transformer import decode_step, forward, init_cache, init_params
from repro.train.loss import shift_labels
from repro.train.optim import adamw
from repro.train.steps import init_train_state, make_train_step

KEY = jax.random.key(0)


def _batch(cfg, b=2, s=16, seed=1):
    key = jax.random.key(seed)
    shape = (b, s) if cfg.num_codebooks == 1 else (b, s, cfg.num_codebooks)
    tokens = jax.random.randint(key, shape, 0, cfg.vocab_size)
    batch = {"tokens": tokens, "targets": shift_labels(tokens)}
    if cfg.num_prefix_embeds:
        batch["image_embeds"] = 0.1 * jax.random.normal(
            key, (b, cfg.num_prefix_embeds, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(KEY, cfg)
    batch = _batch(cfg)
    logits, aux, _ = forward(
        cfg, params, batch["tokens"], image_embeds=batch.get("image_embeds")
    )
    s_total = batch["tokens"].shape[1] + cfg.num_prefix_embeds
    expect = (2, s_total, cfg.padded_vocab)
    if cfg.num_codebooks > 1:
        expect = (2, s_total, cfg.num_codebooks, cfg.padded_vocab)
    assert logits.shape == expect
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = init_params(KEY, cfg)
    opt = adamw(1e-3)
    state = init_train_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt))
    state2, metrics = step(state, _batch(cfg))
    assert float(metrics["loss"]) > 0 and np.isfinite(float(metrics["loss"]))
    assert int(state2.step) == 1
    # params actually moved
    delta = jax.tree.reduce(
        lambda acc, pq: acc + float(jnp.sum(jnp.abs(pq))),
        jax.tree.map(lambda a, b: (a - b).astype(jnp.float32), state.params, state2.params),
        0.0,
    )
    assert delta > 0


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if get_config(a, smoke=True).num_prefix_embeds == 0]
)
def test_decode_matches_parallel(arch):
    cfg = dataclasses.replace(get_config(arch, smoke=True), compute_dtype="float32")
    if cfg.moe is not None:  # capacity drops are batch-context dependent
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    params = init_params(jax.random.key(42), cfg)
    b, s = 2, 16
    shape = (b, s) if cfg.num_codebooks == 1 else (b, s, cfg.num_codebooks)
    toks = jax.random.randint(jax.random.key(7), shape, 0, cfg.vocab_size)
    full_logits, _, _ = forward(cfg, params, toks)
    cache = init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        nt = toks[:, t : t + 1] if cfg.num_codebooks == 1 else toks[:, t : t + 1, :]
        lg, cache = decode_step(cfg, params, cache, nt, jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full_logits), rtol=2e-3, atol=2e-3
    )


def test_prefill_cache_continues_decode():
    """Prefill s0 tokens -> decode continues identically to full decode."""
    cfg = dataclasses.replace(get_config("gemma3_27b", smoke=True), compute_dtype="float32")
    params = init_params(jax.random.key(3), cfg)
    b, s0, s1 = 2, 8, 4
    toks = jax.random.randint(jax.random.key(9), (b, s0 + s1), 0, cfg.vocab_size)
    from repro.serve.engine import make_prefill_step

    prefill = make_prefill_step(cfg, max_len=s0 + s1)
    last, cache = prefill(params, toks[:, :s0])
    # continue decoding
    dec_logits = [last]
    for t in range(s0, s0 + s1):
        lg, cache = decode_step(cfg, params, cache, toks[:, t : t + 1], jnp.int32(t))
        dec_logits.append(lg[:, 0])
    # reference: full forward
    full_logits, _, _ = forward(cfg, params, toks)
    got = jnp.stack(dec_logits[:-1], axis=1)
    want = full_logits[:, s0 - 1 : s0 + s1 - 1]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_flash_matches_full_forward_and_grad():
    b, s, h, d = 2, 256, 4, 32
    q = jax.random.normal(KEY, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, 2, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, 2, d), jnp.float32)

    def f_flash(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(q, k, v, q_chunk=64, kv_chunk=64)))

    def f_full(q, k, v):
        return jnp.sum(jnp.sin(full_attention(q, k, v, causal=True)))

    np.testing.assert_allclose(f_flash(q, k, v), f_full(q, k, v), rtol=1e-4)
    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_full, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=5e-3, atol=5e-4)


def test_flash_softcap_grad():
    b, s, h, d = 1, 128, 2, 16
    q = jax.random.normal(KEY, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, h, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, h, d), jnp.float32)

    def f(impl):
        def fn(q):
            o = impl(q)
            return jnp.sum(o * o)
        return fn

    flash_fn = f(lambda q: flash_attention(q, k, v, logit_cap=20.0, q_chunk=32, kv_chunk=32))
    full_fn = f(lambda q: full_attention(q, k, v, causal=True, logit_cap=20.0))
    np.testing.assert_allclose(flash_fn(q), full_fn(q), rtol=1e-4)
    np.testing.assert_allclose(
        np.asarray(jax.grad(flash_fn)(q)), np.asarray(jax.grad(full_fn)(q)),
        rtol=5e-3, atol=5e-4,
    )


def test_local_attention_matches_masked_full():
    b, s, h, d, w = 2, 128, 4, 16, 32
    q = jax.random.normal(KEY, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, 2, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, 2, d), jnp.float32)
    got = local_attention(q, k, v, window=w)
    want = full_attention(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_long_500k_applicability_flags():
    sub_quadratic = {a: get_config(a).is_sub_quadratic() for a in ARCHS}
    assert sub_quadratic["recurrentgemma_9b"]
    assert sub_quadratic["rwkv6_1b6"]
    assert sum(sub_quadratic.values()) == 2  # exactly the two assigned


def test_assigned_configs_match_assignment():
    cfg = get_config("gemma3_27b")
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads) == (62, 5376, 32, 16)
    assert cfg.d_ff == 21504 and cfg.vocab_size == 262_144
    cfg = get_config("deepseek_67b")
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads) == (95, 8192, 64, 8)
    cfg = get_config("deepseek_v2_lite_16b")
    assert cfg.moe.num_experts == 64 and cfg.moe.top_k == 6
    assert cfg.mla.kv_lora_rank == 512
    cfg = get_config("rwkv6_1b6")
    assert cfg.num_layers == 24 and cfg.d_model == 2048 and cfg.vocab_size == 65_536
    cfg = get_config("musicgen_large")
    assert cfg.num_codebooks == 4 and cfg.vocab_size == 2048
