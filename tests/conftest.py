import warnings

warnings.filterwarnings("ignore")

# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke tests
# and benches must see the real single device; only launch/dryrun.py forces
# 512 placeholder devices (and only in its own process).
