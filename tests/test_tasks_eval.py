"""KernelBench-JAX dataset + evaluator tests."""

import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # seed env: run properties via the deterministic stub
    from _hypothesis_stub import given, settings, st

from repro.evaluation import EvalConfig, Evaluator
from repro.proposers.synthetic import _break_semantics, _break_syntax
from repro.tasks import SUPPLEMENTARY, all_tasks, benchmark_tasks, get_task
from repro.tasks.base import CATEGORIES

FAST = EvalConfig(n_correctness=2, timing_runs=3, warmup_runs=1)


def test_category_counts_match_table5():
    counts = {c: 0 for c in CATEGORIES}
    for t in all_tasks():
        counts[t.category] += 1
    assert counts == {
        "matmul": 18, "conv": 28, "act_pool": 21,
        "norm_reduce": 15, "loss": 7, "cumulative": 5,
    }
    assert len(benchmark_tasks()) == 91  # the paper's headline count
    assert len(all_tasks()) == 94  # Table 5's (inconsistent) sum — see DESIGN.md


@pytest.mark.parametrize("task", all_tasks(), ids=lambda t: t.name)
def test_naive_implementation_valid(task):
    ev = Evaluator(FAST)
    res = ev.evaluate(task, task.initial_source)
    assert res.valid, f"{task.name}: [{res.stage}] {res.error}"


@pytest.mark.parametrize("category", CATEGORIES)
def test_random_genomes_valid(category):
    ev = Evaluator(FAST)
    rng = np.random.default_rng(0)
    for task in all_tasks(category)[:3]:
        for _ in range(4):
            g = task.random_genome(rng)
            res = ev.evaluate(task, task.render(g))
            assert res.valid, f"{task.name} {g}: [{res.stage}] {res.error}"


def test_evaluator_stages():
    task = get_task("act_relu")
    ev = Evaluator(FAST)
    rng = np.random.default_rng(0)
    good = task.initial_source

    # _break_syntax may draw the truncation mode (wrong-shape but compiling
    # code) — that is still an invalid candidate; pin the paren break for a
    # guaranteed stage-1 failure plus check the general contract
    res = ev.evaluate(task, good + "\n)")
    assert not res.compile_ok and res.stage == "compile"
    res = ev.evaluate(task, _break_syntax(good, rng))
    assert not res.valid

    # semantic break: must compile; usually wrong (a few perturbations may
    # stay within tolerance, so sample a few)
    wrongs = 0
    for i in range(5):
        res = ev.evaluate(task, _break_semantics(good, np.random.default_rng(i)))
        if res.compile_ok and not res.correct:
            wrongs += 1
    assert wrongs >= 1

    res = ev.evaluate(task, good)
    assert res.valid and res.runtime_us > 0


def test_evaluator_caches_by_source():
    task = get_task("act_relu")
    ev = Evaluator(FAST)
    r1 = ev.evaluate(task, task.initial_source)
    r2 = ev.evaluate(task, task.initial_source)
    assert r1 is r2  # identity: served from cache


def test_speedup_definition():
    task = get_task("mm_square_s")
    ev = Evaluator(FAST)
    base = ev.baseline_us(task)
    best = task.render({k: v[-1] for k, v in task.genome_space.items()})
    res = ev.evaluate(task, best)
    assert res.valid
    sp = ev.speedup(task, res)
    assert sp == pytest.approx(base / res.runtime_us)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_input_generation_deterministic(seed):
    task = get_task("loss_mse")
    a = task.make_inputs(seed)
    b = task.make_inputs(seed)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


@given(st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_neighbor_genome_changes_one_knob(seed):
    task = get_task("mm_square_s")
    rng = np.random.default_rng(seed)
    g0 = task.random_genome(rng)
    g1, knob, choice = task.neighbor_genome(g0, rng)
    diffs = [k for k in task.genome_space if g0.get(k) != g1.get(k)]
    assert len(diffs) <= 1
    if diffs:
        assert diffs == [knob] and g1[knob] == choice


def test_supplementary_exclusion_is_consistent():
    names = {t.name for t in all_tasks()}
    assert set(SUPPLEMENTARY) <= names
    assert not set(SUPPLEMENTARY) & {t.name for t in benchmark_tasks()}
