"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle.

The large parametrized sweeps carry @pytest.mark.slow and are deselected
by the default profile (pytest.ini: -m "not slow"); each kernel keeps an
unmarked fast smoke case so the tier-1 gate still exercises every path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.blocked_matmul import vmem_bytes

KEY = jax.random.key(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("s,h,kv,d", [(128, 4, 4, 32), (256, 4, 2, 64), (512, 8, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cap", [None, 30.0])
def test_flash_attention_sweep(s, h, kv, d, dtype, cap):
    b = 2
    q = jax.random.normal(KEY, (b, s, h, d), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kv, d), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kv, d), dtype)
    got = ops.flash_attention(q, k, v, logit_cap=cap, block_q=64, block_k=64)
    want = ref.flash_attention_ref(q, k, v, logit_cap=cap)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("g", [1, 2, 4])
def test_flash_attention_gqa_zero_copy(g):
    """The GQA fast path: correct for every group size AND repeat-free —
    K/V enter the pallas_call at (B*KV, S, D), never expanded to per-q-head
    copies (no gather, no rank-5 broadcast anywhere in the jaxpr)."""
    from repro.kernels.flash_attention import flash_attention_pallas

    b, s, kvh, d = 2, 256, 2, 32
    h = kvh * g
    q = jax.random.normal(KEY, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, kvh, d), jnp.float32)

    fn = lambda q, k, v: flash_attention_pallas(
        q, k, v, block_q=64, block_k=64, interpret=True
    )
    got = fn(q, k, v)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    jaxpr = jax.make_jaxpr(fn)(q, k, v).jaxpr
    pallas_in_shapes = [
        tuple(x.aval.shape)
        for e in jaxpr.eqns
        if e.primitive.name == "pallas_call"
        for x in e.invars
    ]
    assert (b * kvh, s, d) in pallas_in_shapes  # K/V streamed unrepeated
    prim_names = {e.primitive.name for e in jaxpr.eqns}
    assert "gather" not in prim_names  # jnp.repeat's lowering
    max_rank = max(len(o.aval.shape) for e in jaxpr.eqns for o in e.outvars)
    assert max_rank <= 4  # no (B, KV, G, S, D) broadcast anywhere


@pytest.mark.parametrize("bq,bk", [(32, 64), (64, 32), (128, 128)])
def test_flash_attention_block_shapes(bq, bk):
    b, s, h, d = 1, 256, 2, 32
    q = jax.random.normal(KEY, (b, s, h, d), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, h, d), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, h, d), jnp.float32)
    got = ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# blocked matmul
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 192, 320), (64, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, k, n, dtype):
    a = jax.random.normal(KEY, (m, k), dtype)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n), dtype)
    got = ops.matmul(a, b, block_m=64, block_n=64, block_k=64)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2 if dtype == jnp.bfloat16 else 2e-4,
        atol=3e-1 if dtype == jnp.bfloat16 else 2e-3,
    )


def test_matmul_vmem_model():
    assert vmem_bytes(256, 256, 256) == (256 * 256 * 2) * 2 + 256 * 256 * 4


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("shape", [(64, 256), (8, 16, 128), (3, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    scale = jax.random.normal(jax.random.fold_in(KEY, 1), (shape[-1],)) * 0.1
    got = ops.rmsnorm(x, scale, block_rows=32)
    want = ref.rmsnorm_ref(x, scale)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------
@pytest.mark.slow
@pytest.mark.parametrize("s,h,kd,chunk", [(64, 2, 16, 16), (128, 4, 32, 32), (256, 1, 16, 64)])
def test_wkv6_sweep(s, h, kd, chunk):
    b = 2
    mk = lambda i, sc=0.5: jax.random.normal(jax.random.fold_in(KEY, i), (b, s, h, kd)) * sc
    r, k, v = mk(1), mk(2), mk(3)
    lw = -jnp.exp(jax.random.normal(jax.random.fold_in(KEY, 4), (b, s, h, kd)) - 4.0)
    u = jax.random.normal(jax.random.fold_in(KEY, 5), (h, kd)) * 0.1
    got = ops.wkv6(r, k, v, lw, u, chunk=chunk)
    want = ref.wkv6_ref(r, k, v, lw, u, chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_wkv6_matches_sequential_recurrence():
    b, s, h, kd = 1, 48, 2, 8
    mk = lambda i: jax.random.normal(jax.random.fold_in(KEY, i), (b, s, h, kd)) * 0.5
    r, k, v = mk(1), mk(2), mk(3)
    lw = -jnp.exp(mk(4) - 3.0)
    u = jax.random.normal(jax.random.fold_in(KEY, 5), (h, kd)) * 0.1
    got = ops.wkv6(r, k, v, lw, u, chunk=16)
    want = ref.wkv6_sequential_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# rglru
# ---------------------------------------------------------------------------
def test_kernel_smoke_fast_profile():
    """One small case per kernel so the fast profile (-m "not slow") keeps
    touching every Pallas path the slow sweeps cover in breadth."""
    a = jax.random.normal(KEY, (128, 64), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 128), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(ops.matmul(a, b, block_m=64, block_n=64, block_k=64)),
        np.asarray(ref.matmul_ref(a, b)), rtol=2e-4, atol=2e-3,
    )
    x = jax.random.normal(KEY, (32, 128), jnp.float32)
    scale = jax.random.normal(jax.random.fold_in(KEY, 1), (128,)) * 0.1
    np.testing.assert_allclose(
        np.asarray(ops.rmsnorm(x, scale, block_rows=32)),
        np.asarray(ref.rmsnorm_ref(x, scale)), rtol=2e-5, atol=2e-5,
    )
    ga = jax.nn.sigmoid(jax.random.normal(KEY, (1, 64, 16)))
    gb = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 64, 16)) * 0.3
    np.testing.assert_allclose(
        np.asarray(ops.rglru(ga, gb, chunk=16)),
        np.asarray(ref.rglru_ref(ga, gb)), rtol=2e-4, atol=2e-4,
    )


@pytest.mark.slow
@pytest.mark.parametrize("s,w,chunk", [(64, 32, 16), (128, 64, 64), (256, 16, 32)])
def test_rglru_sweep(s, w, chunk):
    b = 2
    a = jax.nn.sigmoid(jax.random.normal(KEY, (b, s, w)))
    bb = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, w)) * 0.3
    got = ops.rglru(a, bb, chunk=chunk)
    want = ref.rglru_ref(a, bb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
