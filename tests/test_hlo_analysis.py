"""Trip-count-corrected HLO cost analysis: scan must equal unroll."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import HloAnalyzer, analyze_compiled


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


def test_scan_flops_match_unrolled():
    w = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def f_scan(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return y

    def f_unroll(x, ws):
        for i in range(7):
            x = x @ ws[i]
        return x

    a_scan = analyze_compiled(_compile(f_scan, x, w), 1)
    a_unroll = analyze_compiled(_compile(f_unroll, x, w), 1)
    # uncorrected scan counts the body once (1/7 of the work)
    assert a_scan["uncorrected_flops"] < 0.5 * a_unroll["flops"]
    # corrected totals agree to within a few percent (layout/copy noise)
    np.testing.assert_allclose(a_scan["flops"], a_unroll["flops"], rtol=0.05)


def test_nested_scan_multiplies_trips():
    w = jax.ShapeDtypeStruct((3, 4, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x, ws):
        def outer(c, wg):
            def inner(ci, wi):
                return ci @ wi, None
            c2, _ = jax.lax.scan(inner, c, wg)
            return c2, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    a = analyze_compiled(_compile(f, x, w), 1)
    expect = 12 * 2 * 64**3  # 3*4 matmuls
    np.testing.assert_allclose(a["flops"], expect, rtol=0.05)


def test_while_trip_count_from_backend_config():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((11, 32, 32), jnp.float32)

    def f(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return jnp.sum(y)

    compiled = _compile(f, x, w)
    an = HloAnalyzer(compiled.as_text(), 1)
    trips = dict(an.while_summary())
    assert 11 in trips.values()


def test_model_block_correction_applies():
    """A smoke transformer's corrected flops scale with layer count."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.transformer import forward, param_specs

    def make(n_layers):
        cfg = dataclasses.replace(
            get_config("deepseek_67b", smoke=True), num_layers=n_layers
        )
        specs = param_specs(cfg)
        toks = jax.ShapeDtypeStruct((2, 64), jnp.int32)

        def f(p, t):
            logits, _, _ = forward(cfg, p, t)
            return logits

        compiled = jax.jit(f).lower(specs, toks).compile()
        return analyze_compiled(compiled, 1)["flops"]

    f4, f16 = make(4), make(16)
    # per-layer flops dominate; ratio should be close to 4x
    assert 2.5 < f16 / f4 < 4.6


def test_cost_analysis_none_is_guarded():
    """CPU backends / older jax may return None from cost_analysis();
    analyze_compiled must fall back to zeros, not crash on raw.get."""
    x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    compiled = _compile(lambda a: a @ a, x)

    class NoCosts:
        def as_text(self):
            return compiled.as_text()

        def cost_analysis(self):
            return None

    a = analyze_compiled(NoCosts(), 1)
    assert a["uncorrected_flops"] == 0.0
    assert a["uncorrected_bytes"] == 0.0
    # our own parser-side totals are unaffected by the missing XLA report
    assert a["flops"] > 0.0


def test_op_bytes_weights_while_bodies():
    """op_bytes attributes per-op output bytes, scan bodies multiplied by
    their trip counts — the dominant-op signal the diagnosis layer ranks."""
    w = jax.ShapeDtypeStruct((9, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f_scan(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return y

    ob = HloAnalyzer(_compile(f_scan, x, w).as_text(), 1).op_bytes()
    assert ob, "no op kinds attributed"
    # bookkeeping ops are excluded from the breakdown
    for skip in ("parameter", "constant", "get-tuple-element", "tuple", "bitcast"):
        assert skip not in ob
    # the 9-trip body's compute ops dominate: at least 9 body outputs' worth
    body_bytes = sum(v for k, v in ob.items() if k in ("fusion", "dot", "custom-call"))
    assert body_bytes >= 9 * 64 * 64 * 4
