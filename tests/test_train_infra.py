"""Training-substrate tests: optimizers, microbatching, checkpointing, data."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # seed env: run properties via the deterministic stub
    from _hypothesis_stub import given, settings, st

from repro.configs import get_config
from repro.models.transformer import init_params
from repro.train import checkpoint as ckpt
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.loss import cross_entropy_loss, shift_labels
from repro.train.optim import adafactor, adamw, cosine_schedule, global_norm, sgd
from repro.train.steps import init_train_state, make_train_step

KEY = jax.random.key(0)


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("make_opt", [lambda: adamw(5e-2, weight_decay=0.0), lambda: adafactor(1e-1), lambda: sgd(0.5, momentum=0.9)])
def test_optimizer_reduces_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.array([3.0, -2.0, 5.0])}
    state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    for i in range(400):
        grads = jax.tree.map(lambda p: 2 * p, params)  # d/dp ||p||^2
        params, state = opt.update(grads, state, params, step + i)
    assert float(jnp.sum(params["w"] ** 2)) < 0.2


def test_cosine_schedule_shape():
    fn = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(fn(jnp.int32(0))) == 0.0
    assert float(fn(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(fn(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-3)


@given(st.lists(st.floats(-10, 10), min_size=1, max_size=8))
@settings(max_examples=25, deadline=None)
def test_global_norm_matches_numpy(xs):
    tree = {"a": jnp.asarray(xs, jnp.float32)}
    assert float(global_norm(tree)) == pytest.approx(
        float(np.linalg.norm(np.asarray(xs, np.float32))), rel=1e-5, abs=1e-5
    )


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def test_cross_entropy_masking():
    logits = jax.random.normal(KEY, (2, 5, 11), jnp.float32)
    targets = jnp.array([[1, 2, 3, -1, -1], [0, -1, 5, 6, 7]])
    loss, n = cross_entropy_loss(logits, targets)
    assert float(n) == 7.0  # 3 + 4 unmasked positions
    assert np.isfinite(float(loss))


def test_shift_labels():
    toks = jnp.arange(10).reshape(2, 5)
    lbl = shift_labels(toks)
    np.testing.assert_array_equal(np.asarray(lbl[:, :-1]), np.asarray(toks[:, 1:]))
    assert int(lbl[0, -1]) == -1


# ---------------------------------------------------------------------------
# microbatch equivalence
# ---------------------------------------------------------------------------
def test_microbatch_grad_accumulation_matches_full_batch():
    cfg = get_config("deepseek_67b", smoke=True)
    params = init_params(KEY, cfg)
    opt = sgd(1e-2)  # linear optimizer: averaging exact
    toks = jax.random.randint(jax.random.key(1), (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": toks, "targets": shift_labels(toks)}
    s1, m1 = make_train_step(cfg, opt, microbatches=1)(init_train_state(params, opt), batch)
    s2, m2 = make_train_step(cfg, opt, microbatches=2)(init_train_state(params, opt), batch)
    # losses agree and updates nearly agree (fp accumulation order differs)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=2e-3)
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), s1.params, s2.params)
    assert max(jax.tree.leaves(d)) < 2e-4


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip_and_retention():
    tree = {
        "params": {"w": jnp.arange(6.0).reshape(2, 3)},
        "step": 7,
        "name": "run1",
    }
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4):
            ckpt.save(d, s, tree, keep=2)
        assert ckpt.all_steps(d) == [3, 4]
        restored, step = ckpt.restore(d)
        assert step == 4
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w"]), np.asarray(tree["params"]["w"])
        )
        assert restored["step"] == 7 and restored["name"] == "run1"


def test_checkpoint_restore_with_template():
    cfg = get_config("rwkv6_1b6", smoke=True)
    params = init_params(KEY, cfg)
    opt = adamw(1e-3)
    state = init_train_state(params, opt)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 0, state)
        restored, _ = ckpt.restore(d, template=state)
        same = jax.tree.map(
            lambda a, b: bool(jnp.all(jnp.asarray(a) == jnp.asarray(b))), restored, state
        )
        assert all(jax.tree.leaves(same))


def test_checkpoint_atomicity_partial_dir_ignored():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 1, {"x": 1})
        os.makedirs(os.path.join(d, "ckpt_2"))  # step dir without meta.json
        assert ckpt.latest_step(d) == 1


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_synthetic_data_deterministic_and_restart_safe():
    src = SyntheticLM(1000, 16, 8, seed=3, process_index=0, process_count=1)
    b5a = src.batch(5)
    b5b = src.batch(5)
    np.testing.assert_array_equal(b5a["tokens"], b5b["tokens"])
    # host sharding partitions the global batch
    h0 = SyntheticLM(1000, 16, 8, seed=3, process_index=0, process_count=2)
    h1 = SyntheticLM(1000, 16, 8, seed=3, process_index=1, process_count=2)
    assert h0.batch(0)["tokens"].shape[0] == 4
    assert not np.array_equal(h0.batch(0)["tokens"], h1.batch(0)["tokens"])


def test_prefetcher_yields_in_order():
    src = SyntheticLM(100, 8, 4, seed=0, process_index=0, process_count=1)
    pf = Prefetcher(src, start_index=0, prefetch=2)
    b0 = next(pf)
    np.testing.assert_array_equal(b0["tokens"], src.batch(0)["tokens"])
    pf.close()


def test_targets_are_shifted_tokens():
    src = SyntheticLM(50, 8, 2, seed=1, process_index=0, process_count=1)
    b = src.batch(0)
    np.testing.assert_array_equal(b["targets"][:, :-1], b["tokens"][:, 1:])
