"""Golden-output tests for the benchmark summarizers.

The summarizers (`table4_overall.summarize`, `table7_speedup_dist`,
`table8_aice`, `fig1_frontier`, `fig4_token_usage`) had no coverage: a
record-schema refactor could silently wreck every reported table.  The
fixture is a committed mini-sweep (3 tasks x 8 methods x 2 seeds,
simulated timing — real records from the real engine) and the goldens
are its exact rendered outputs; regenerate both together if the record
schema or a summarizer's format deliberately changes (see
tests/fixtures/golden/).
"""

import json
import shutil
from pathlib import Path

import pytest

from benchmarks import (
    fig1_frontier,
    fig4_token_usage,
    table4_overall,
    table7_speedup_dist,
    table8_aice,
)

FIXTURES = Path(__file__).parent / "fixtures"
SAMPLE = str(FIXTURES / "table4_sample.jsonl")

SUMMARIZERS = {
    "table4.txt": table4_overall.summarize,
    "table7.txt": table7_speedup_dist.summarize,
    "table8.txt": table8_aice.summarize,
    "fig1.txt": fig1_frontier.render,
    "fig4.txt": fig4_token_usage.summarize,
}


@pytest.mark.parametrize("golden", sorted(SUMMARIZERS))
def test_summarizer_matches_golden(golden):
    want = (FIXTURES / "golden" / golden).read_text()
    got = SUMMARIZERS[golden](SAMPLE) + "\n"
    assert got == want, (
        f"{golden} output drifted — if the change is deliberate, "
        "regenerate tests/fixtures/golden/ from the fixture"
    )


def test_fixture_schema_is_what_run_unit_emits():
    """The fixture must carry every field the summarizers consume, so a
    record-schema refactor fails here loudly instead of skewing tables."""
    recs = [json.loads(l) for l in open(SAMPLE)]
    assert len(recs) == 48
    for r in recs:
        for field in ("task", "method", "seed", "best_speedup", "compile_rate",
                      "validity_rate", "tokens", "baseline_us", "category",
                      "speedups_all"):
            assert field in r, f"fixture record missing {field!r}"
        assert {"tokens_in", "tokens_out"} <= set(r["tokens"])


@pytest.mark.parametrize("golden", sorted(SUMMARIZERS))
def test_summarizers_invariant_to_record_order(tmp_path, golden):
    """A fleet-written results file arrives in completion order, not the
    serial sweep's loop order: summaries must not depend on it (method
    rows follow the paper's canonical order)."""
    shuffled = tmp_path / "shuffled.jsonl"
    lines = Path(SAMPLE).read_text().splitlines()
    shuffled.write_text("\n".join(reversed(lines)) + "\n")
    assert SUMMARIZERS[golden](str(shuffled)) == SUMMARIZERS[golden](SAMPLE)


@pytest.mark.parametrize("golden", sorted(SUMMARIZERS))
def test_summarizers_identical_on_duplicated_records(tmp_path, golden):
    """Merged-view contract: replaying records (work stealing's duplicate
    appends) must not change any summary — dedup is last-write-wins."""
    dup = tmp_path / "dup.jsonl"
    shutil.copy(SAMPLE, dup)
    lines = Path(SAMPLE).read_text().splitlines()
    with open(dup, "a") as f:
        for line in lines[:7]:  # replay a prefix, out of order
            f.write(line + "\n")
    assert SUMMARIZERS[golden](str(dup)) == SUMMARIZERS[golden](SAMPLE)
