"""Autotuner + tuned-genome registry: measured vs modeled provenance.

Covers the ISSUE-4 contracts:
  * `--timing roofline` reproduces the committed modeled winners
    bit-for-bit (the committed tuned_genomes.json is the fixture);
  * wall-mode scoring goes through WallClockTiming with an interleaved
    builtin-genome baseline (driven here by a scripted cost clock);
  * `--save` round-trip: per-device_kind keys, `_meta` provenance schema,
    get_tuned precedence (explicit arg > device-matched > device-agnostic
    > builtin);
  * a modeled entry can never override a measured entry for the same
    device kind;
  * the registry re-reads when REPRO_TUNED_GENOMES changes mid-process.
"""

import json
import os
import warnings

import pytest

from repro.evaluation.timing import WallClockTiming, device_kind
from repro.kernels import tuned
from repro.launch import autotune

COMMITTED = os.path.join(
    os.path.dirname(tuned.__file__), "tuned_genomes.json"
)


@pytest.fixture(autouse=True)
def _isolated_registry(monkeypatch, tmp_path):
    """Every test gets a private registry file; the committed one stays
    untouched and the in-memory cache is reset around each test."""
    monkeypatch.setenv(tuned.ENV_VAR, str(tmp_path / "tuned.json"))
    tuned.invalidate()
    yield
    tuned.invalidate()


# ---------------------------------------------------------------------------
# roofline: today's modeled winners, bit-for-bit
# ---------------------------------------------------------------------------
def test_roofline_reproduces_committed_winners():
    with open(COMMITTED) as f:
        committed = json.load(f)
    for kernel, entry in committed.items():
        meta = entry["_meta"]
        res = autotune.tune(kernel, meta["trials"], meta["seed"])
        want = {k: v for k, v in entry.items() if not k.startswith("_")}
        assert res["best_genome"] == want, kernel
        assert round(res["best_modeled_us"], 1) == meta["modeled_us"], kernel
        assert res["timing"] == "roofline"


def test_tune_history_and_valid_rate_shape():
    res = autotune.tune("wkv6", 10, seed=1)
    assert len(res["history"]) == 10
    assert {"trial", "genome", "time_us"} <= set(res["history"][0])
    assert 0.0 < res["valid_rate"] <= 1.0


# ---------------------------------------------------------------------------
# wall-mode scoring through WallClockTiming (scripted cost clock)
# ---------------------------------------------------------------------------
class CostClock:
    """perf_counter stand-in whose timed interval equals whatever cost the
    last-run thunk deposited — genome cost becomes measured time."""

    def __init__(self):
        self.t = 0.0
        self.pending = 0.0
        self._t0 = False

    def __call__(self):
        if not self._t0:
            self._t0 = True
            return self.t
        self._t0 = False
        self.t += self.pending
        return self.t


def test_tune_wall_ranks_by_interleaved_measurement():
    clock = CostClock()

    def bench(genome):
        if genome["chunk"] > 64:
            return None  # infeasible: does not tile the bench shape

        def thunk():
            clock.pending = genome["chunk"] * 1e-6  # cost = chunk µs

        return thunk

    provider = WallClockTiming(timing_runs=3, warmup_runs=1, clock=clock)
    res = autotune.tune("wkv6", 12, seed=0, provider=provider, bench=bench)
    assert res["timing"] == "wall"
    assert res["best_genome"] == {"chunk": 16}  # cheapest feasible
    assert res["best_us"] == pytest.approx(16.0)
    m = res["best_measurement"]
    # interleaved against the builtin genome (chunk=64)
    assert m.baseline_us == pytest.approx(64.0)
    assert m.rank == pytest.approx(16.0 / 64.0)
    # infeasible genomes recorded as such, not silently dropped
    infeasible = [h for h in res["history"] if h["time_us"] is None]
    assert all(h["genome"]["chunk"] > 64 for h in infeasible)


def test_tune_raises_when_nothing_feasible():
    provider = WallClockTiming(timing_runs=1, warmup_runs=0, clock=CostClock())
    with pytest.raises(RuntimeError, match="no feasible genome"):
        autotune.tune("wkv6", 3, seed=0, provider=provider, bench=lambda g: None)


# ---------------------------------------------------------------------------
# --save round-trip: device keys, provenance, precedence
# ---------------------------------------------------------------------------
def test_autotune_cli_roofline_save_roundtrip(tmp_path):
    path = str(tmp_path / "tuned.json")
    autotune.main([
        "--kernel", "wkv6", "--timing", "roofline", "--trials", "5", "--save",
        "--save-path", path,
    ])
    data = json.load(open(path))
    entry = data["wkv6"]
    assert entry["_meta"]["source"] == "modeled"
    assert entry["_meta"]["model"] == "v5e roofline"
    assert "_by_device" not in entry  # modeled winners are device-agnostic
    os.environ[tuned.ENV_VAR] = path  # monkeypatch fixture restores this
    tuned.invalidate()
    knobs = {k: v for k, v in entry.items() if not k.startswith("_")}
    assert tuned.get_tuned("wkv6") == knobs
    assert tuned.get_tuned("wkv6", device_kind="tpu_v5e") == knobs


def test_save_measured_keys_by_device_kind(tmp_path):
    path = str(tmp_path / "tuned.json")
    meta = {"source": "measured", "runs": 15, "kept": 14, "outliers": 1,
            "noise_floor_us": 2.5}
    tuned.save_tuned("flash", {"block_q": 256, "block_k": 128}, meta=meta,
                     path=path, device_kind="tpu_v5e")
    raw = json.load(open(path))
    sub = raw["flash"]["_by_device"]["tpu_v5e"]
    assert sub["_meta"]["source"] == "measured"
    assert sub["_meta"]["noise_floor_us"] == 2.5
    assert sub["_meta"]["runs"] == 15

    os.environ[tuned.ENV_VAR] = path
    tuned.invalidate()
    # device-matched > builtin
    assert tuned.get_tuned("flash", device_kind="tpu_v5e") == {
        "block_q": 256, "block_k": 128
    }
    # other device kinds fall through to builtin
    assert tuned.get_tuned("flash", device_kind="cpu") == tuned._BUILTIN["flash"]
    prov = tuned.get_tuned_meta("flash", device_kind="tpu_v5e")
    assert prov["layer"] == "device" and prov["meta"]["source"] == "measured"
    # explicit arg > device-matched tuned > builtin
    assert tuned.resolve("flash", "block_q", 64, 128, device_kind="tpu_v5e") == 64
    assert tuned.resolve("flash", "block_q", None, 128, device_kind="tpu_v5e") == 256
    assert tuned.resolve("flash", "block_q", None, 111, device_kind="cpu") == 128


def test_modeled_never_overrides_measured_same_device():
    tuned.save_tuned("wkv6", {"chunk": 128},
                     meta={"source": "measured", "runs": 9},
                     device_kind="cpu")
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        tuned.save_tuned("wkv6", {"chunk": 16},
                         meta={"source": "modeled"}, device_kind="cpu")
    assert any("refusing" in str(w.message) for w in caught)
    assert tuned.get_tuned("wkv6", device_kind="cpu") == {"chunk": 128}
    meta = tuned.get_tuned_meta("wkv6", device_kind="cpu")
    assert meta["meta"] == {"source": "measured", "runs": 9}
    # a device-agnostic modeled save coexists without shadowing it
    tuned.save_tuned("wkv6", {"chunk": 32}, meta={"source": "modeled"})
    assert tuned.get_tuned("wkv6", device_kind="cpu") == {"chunk": 128}
    assert tuned.get_tuned("wkv6", device_kind="tpu_v5e") == {"chunk": 32}
    # measured -> measured refresh IS allowed
    tuned.save_tuned("wkv6", {"chunk": 256},
                     meta={"source": "measured", "runs": 30}, device_kind="cpu")
    assert tuned.get_tuned("wkv6", device_kind="cpu") == {"chunk": 256}


def test_measured_save_requires_device_kind():
    with pytest.raises(ValueError, match="device_kind"):
        tuned.save_tuned("wkv6", {"chunk": 128}, meta={"source": "measured"})


def test_legacy_flat_entries_still_resolve():
    """Pre-schema files (knobs + _meta at top level, no _by_device) keep
    working as device-agnostic modeled entries."""
    path = os.environ[tuned.ENV_VAR]
    with open(path, "w") as f:
        json.dump({"matmul": {"block_m": 64, "_meta": {"trials": 40}}}, f)
    tuned.invalidate()
    got = tuned.get_tuned("matmul", device_kind="anything")
    assert got["block_m"] == 64  # file overrides builtin
    assert got["block_n"] == 256  # builtin fills the unlisted knobs
    assert tuned.get_tuned_meta("matmul")["layer"] == "base"


# ---------------------------------------------------------------------------
# env-var re-read (the _loaded-cached-forever fix)
# ---------------------------------------------------------------------------
def test_env_var_change_rereads_registry(tmp_path):
    a, b = str(tmp_path / "a.json"), str(tmp_path / "b.json")
    json.dump({"wkv6": {"chunk": 32}}, open(a, "w"))
    json.dump({"wkv6": {"chunk": 128}}, open(b, "w"))
    os.environ[tuned.ENV_VAR] = a
    tuned.invalidate()
    assert tuned.get_tuned("wkv6", device_kind="cpu") == {"chunk": 32}
    # no invalidate(): the path change alone must trigger the re-read
    os.environ[tuned.ENV_VAR] = b
    assert tuned.get_tuned("wkv6", device_kind="cpu") == {"chunk": 128}
    os.environ[tuned.ENV_VAR] = a
    assert tuned.get_tuned("wkv6", device_kind="cpu") == {"chunk": 32}


def test_device_kind_is_a_sane_registry_key():
    kind = device_kind()
    assert kind and kind == kind.lower()
    assert all(c.isalnum() or c == "_" for c in kind)
