"""Pipelined evaluation subsystem: pool semantics, caches, determinism.

Covers the tentpole contracts:
  * parallel == serial bit-identical results (simulated timing mode),
    at the evaluator level and through a full engine run;
  * the worker hard-deadline kill path (hang -> timeout -> pool recovers);
  * oracle-output cache hit accounting, in memory and on disk;
  * baseline_us disk persistence;
  * batched checkpoint/resume determinism;
  * wall-clock speedup on a sleep-dominated (GIL-releasing) batch.
"""

import dataclasses
import time

import numpy as np
import pytest

from repro.core.engine import EvolutionEngine
from repro.core.methods import get_method
from repro.evaluation import EvalConfig, Evaluator, ParallelEvaluator
from repro.tasks import get_task

FAST = EvalConfig(
    n_correctness=2, timing_runs=2, warmup_runs=1, timing_mode="simulated"
)

SLEEP_SRC = (
    "import time\n"
    "time.sleep(0.15)\n\n"
    "def kernel(x):\n"
    "    return x * 2.0 + 1.0\n"
)


@pytest.fixture(scope="module")
def pool():
    ev = ParallelEvaluator(FAST, workers=2)
    yield ev
    ev.close()


def _variants(task, n, tag=""):
    return [task.initial_source + f"\n# {tag}variant {i}\n" for i in range(n)]


# ---------------------------------------------------------------------------
# parallel == serial
# ---------------------------------------------------------------------------
def test_parallel_matches_serial_bitwise(pool):
    task = get_task("act_relu")
    sources = _variants(task, 5) + [
        task.initial_source + "\n)",  # stage-1 failure
        "def kernel(x):\n    return x\n",  # stage-2 failure (wrong values)
        task.initial_source,  # duplicate of the naive source
    ]
    serial = Evaluator(FAST)
    rs = serial.evaluate_batch(task, sources)
    rp = pool.evaluate_batch(task, sources)
    assert [dataclasses.asdict(a) for a in rs] == [dataclasses.asdict(b) for b in rp]
    stages = [r.stage for r in rp]
    assert "compile" in stages and "correctness" in stages and "done" in stages


def test_engine_parallel_vs_serial_run_identical(pool):
    task = get_task("act_relu")
    method = get_method("evoengineer-full")
    r_ser = EvolutionEngine(
        task, method, evaluator=Evaluator(FAST), seed=1, batch_size=4
    ).run(max_trials=8)
    r_par = EvolutionEngine(
        task, method, evaluator=pool, seed=1, batch_size=4
    ).run(max_trials=8)
    assert r_ser.to_dict() == r_par.to_dict()
    assert [s.to_dict() for s in r_ser.history] == [s.to_dict() for s in r_par.history]


def test_batched_checkpoint_resume_identical(tmp_path):
    task = get_task("cum_sum")
    method = get_method("evoengineer-full")
    full = EvolutionEngine(
        task, method, evaluator=Evaluator(FAST), seed=3, batch_size=4
    ).run(max_trials=12)
    e1 = EvolutionEngine(
        task, method, evaluator=Evaluator(FAST), seed=3, batch_size=4,
        checkpoint_dir=str(tmp_path),
    )
    e1.run(max_trials=8, checkpoint_every=4)
    e2 = EvolutionEngine(
        task, method, evaluator=Evaluator(FAST), seed=3, batch_size=4,
        checkpoint_dir=str(tmp_path),
    )
    assert e2.resume() and e2.trial == 8
    resumed = e2.run(max_trials=12, checkpoint_every=4)
    assert [s.sid for s in resumed.history] == [s.sid for s in full.history]
    assert resumed.to_dict() == full.to_dict()


# ---------------------------------------------------------------------------
# worker timeout / kill path
# ---------------------------------------------------------------------------
def test_worker_hard_deadline_kills_and_recovers():
    task = get_task("cal_sleep")
    # timeout_s=0 disables the in-worker SIGALRM so the hang reaches the
    # parent's process-kill deadline (the hard-hang simulation)
    cfg = EvalConfig(
        n_correctness=1, timing_runs=1, warmup_runs=0,
        timeout_s=0, timing_mode="simulated",
    )
    with ParallelEvaluator(cfg, workers=1, worker_deadline_s=3.0) as pool:
        warm = pool.evaluate(task, task.initial_source)
        assert warm.valid
        res = pool.evaluate(task, "while True:\n    pass\n")
        assert res.stage == "timeout" and not res.valid
        assert pool.workers_killed == 1
        again = pool.evaluate(task, task.initial_source + "\n# after kill\n")
        assert again.valid  # the pool respawned and keeps serving


def test_sigalrm_timeout_inside_worker():
    task = get_task("cal_sleep")
    cfg = EvalConfig(
        n_correctness=1, timing_runs=1, warmup_runs=0,
        timeout_s=1.0, timing_mode="simulated",
    )
    with ParallelEvaluator(cfg, workers=1, worker_deadline_s=30.0) as pool:
        res = pool.evaluate(task, "import time\ntime.sleep(30)\n")
        assert res.stage == "timeout" and "deadline" in res.error
        assert pool.workers_killed == 0  # soft timeout: worker survived


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def test_oracle_cache_hit_accounting():
    task = get_task("act_relu")
    cfg = EvalConfig(n_correctness=3, timing_runs=1, warmup_runs=0,
                     timing_mode="simulated")
    ev = Evaluator(cfg)
    ev.evaluate(task, task.initial_source)
    assert ev.oracle_misses == 3 and ev.oracle_hits == 0
    ev.evaluate(task, task.initial_source + "\n# another candidate\n")
    assert ev.oracle_misses == 3 and ev.oracle_hits == 3  # ref ran once/seed


def test_oracle_and_baseline_disk_cache(tmp_path):
    task = get_task("act_relu")
    ev1 = Evaluator(FAST, cache_dir=str(tmp_path))
    base1 = ev1.baseline_us(task)
    ev1.evaluate(task, task.initial_source + "\n# x\n")
    assert (tmp_path / "baseline_us.json").exists()
    assert list((tmp_path / "oracle").glob("act_relu_*.npy"))

    # a fresh evaluator re-reads both layers instead of recomputing
    ev2 = Evaluator(FAST, cache_dir=str(tmp_path))
    assert ev2.baseline_us(task) == base1
    assert len(ev2._cache) == 0  # served from disk, not re-timed
    ev2.evaluate(task, task.initial_source + "\n# y\n")
    assert ev2.oracle_misses == 0 and ev2.oracle_hits == FAST.n_correctness


def test_parallel_shares_result_cache_and_dedupes(pool):
    task = get_task("act_relu")
    src = task.initial_source + "\n# dedupe me\n"
    before = pool.cache_hits
    r = pool.evaluate_batch(task, [src, src, src])
    assert r[0] is r[1] is r[2]
    r2 = pool.evaluate(task, src)
    assert pool.cache_hits > before
    assert dataclasses.asdict(r2) == dataclasses.asdict(r[0])


def test_parallel_oracle_stats_aggregate(pool):
    task = get_task("reduce_sum")
    pool.evaluate_batch(task, _variants(task, 3, tag="stats-"))
    stats = pool.stats_snapshot()
    assert stats["oracle_misses"] >= FAST.n_correctness  # computed once/seed
    assert stats["oracle_hits"] >= FAST.n_correctness  # later candidates hit


# ---------------------------------------------------------------------------
# throughput: pool beats serial on isolation-dominated batches
# ---------------------------------------------------------------------------
def test_parallel_faster_on_sleep_batch():
    """16 candidates x 150ms (GIL-releasing) module-exec cost: the pool
    overlaps them; asserts a conservative 1.4x (typically ~2.4x with 4
    workers even on a 2-core host; >=2x on >=4 cores)."""
    task = get_task("cal_sleep")
    cfg = EvalConfig(n_correctness=1, timing_runs=1, warmup_runs=0,
                     timing_mode="simulated")
    sources = [SLEEP_SRC + f"# c{i}\n" for i in range(16)]

    serial = Evaluator(cfg)
    serial.evaluate(task, task.initial_source)
    t0 = time.perf_counter()
    rs = serial.evaluate_batch(task, sources)
    t_serial = time.perf_counter() - t0

    with ParallelEvaluator(cfg, workers=4) as pool:
        pool.evaluate(task, task.initial_source)  # spawn + warm the pool
        t0 = time.perf_counter()
        rp = pool.evaluate_batch(task, sources)
        t_parallel = time.perf_counter() - t0

    assert all(r.valid for r in rs) and all(r.valid for r in rp)
    assert [dataclasses.asdict(a) for a in rs] == [dataclasses.asdict(b) for b in rp]
    assert t_parallel < t_serial / 1.4, (t_serial, t_parallel)


# ---------------------------------------------------------------------------
# calibration task stays out of the dataset
# ---------------------------------------------------------------------------
def test_calibration_tasks_excluded_from_dataset():
    from repro.tasks import all_tasks, benchmark_tasks

    names = {t.name for t in all_tasks()}
    assert "cal_sleep" not in names
    assert "cal_sleep" not in {t.name for t in benchmark_tasks()}
    assert get_task("cal_sleep").category == "calibration"
    ev = Evaluator(FAST)
    assert ev.evaluate(get_task("cal_sleep"), get_task("cal_sleep").initial_source).valid
