"""Fault-injection suite for the fault-tolerant serving fleet.

The determinism bar under test: N racing serving workers — surviving a
SIGKILL mid-decode, duplicate workers racing one request, and torn final
journal lines — must produce, after journal merge, token streams
byte-identical to a single-engine serial run.  The chaos scenario spawns
real ``python -m repro.serve.fleet`` subprocesses; lease/journal/engine
degradation semantics are covered in-process.
"""

import json
import os
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

import repro
from repro.serve.engine import StepWatchdog
from repro.serve.paged_cache import NULL_PAGE, BlockTables, required_pages
from repro.serve.fleet import (
    FleetSpec,
    FleetWorker,
    build_engine,
    build_requests,
    done_uids,
    journal_path,
    load_spec,
    merge_streams,
    publish_spec,
    request_slug,
    serve_serial,
)
from repro.serve.scheduler import (
    AdmissionTimeout,
    ContinuousBatchingEngine,
    EngineHooks,
    Request,
)
from repro.sweep.merge import append_jsonl

SRC_DIR = str(Path(repro.__file__).resolve().parents[1])

SPEC = FleetSpec(
    arch="qwen25_32b", prompt_lens=(5, 6, 4, 5), max_new_tokens=(4, 6, 3, 5),
    seed=3, slots=2, max_len=16, page_size=4, sync_interval=2,
)

CHAOS_SPEC = FleetSpec(
    arch="qwen25_32b", prompt_lens=(6,) * 6,
    max_new_tokens=(8, 4, 6, 10, 4, 6),
    seed=11, slots=2, max_len=17, page_size=4, sync_interval=2,
)


@pytest.fixture(scope="module")
def serial_ref():
    return serve_serial(SPEC)


@pytest.fixture(scope="module")
def chaos_serial():
    return serve_serial(CHAOS_SPEC)


def assert_fleet_matches_serial(root, ref):
    streams, info = merge_streams(root, strict=True)
    assert info["conflicts"] == 0
    for uid, want in ref.items():
        got = streams.get(uid)
        assert got is not None and got["complete"], (uid, got, info)
        assert got["tokens"] == want["tokens"], uid
        assert got["status"] == want["status"], uid
        assert got["prompt_len"] == want["prompt_len"], uid


# ---------------------------------------------------------------------------
# shared-prefix refcount safety under worker-style churn
# ---------------------------------------------------------------------------
@settings(max_examples=15)
@given(st.lists(st.integers(min_value=0, max_value=999), min_size=1, max_size=40))
def test_block_tables_fuzz_shared_prefix_refcounts(script):
    """Admit-with-shared-prefix / ensure / release interleavings across
    slots (the churn a fleet worker's admission loop produces): page 0 is
    never shared, a page stays held while *any* table still references
    it, per-page refcounts equal the number of referencing slots, and
    after every slot releases the pool is whole — no refcount leak."""
    from collections import Counter

    ps, max_len, slots = 4, 16, 3
    bt = BlockTables.with_pool(
        slots, max_len, ps, 2 * required_pages(slots, max_len, ps)
    )
    lens = [0] * slots  # 0 = slot free
    for op in script:
        slot = op % slots
        if lens[slot] == 0:
            # share the donor's first (full, immutable) page when one exists
            donor = next(
                (j for j in range(slots) if lens[j] > ps and j != slot), None
            )
            shared = bt.owned[donor][:1] if donor is not None and op % 2 else []
            assert NULL_PAGE not in shared
            lens[slot] = ps + 1 + (op // 7) % (max_len - ps - 1)
            bt.admit(slot, lens[slot], shared=shared)
        elif op % 3 == 0:
            bt.release(slot)
            lens[slot] = 0
        else:
            bt.ensure(slot, min(max_len - 1, lens[slot] + (op // 5) % 8))
        refs = Counter()
        for own in bt.owned:
            refs.update(own)
        assert NULL_PAGE not in refs
        for p, k in refs.items():
            assert bt.allocator.refcount(p) == k  # no free while referenced
        assert bt.allocator.held == len(refs)
        assert bt.allocator.total_refs == sum(refs.values())
        assert bt.allocator.total_refs >= bt.allocator.held
    for slot in range(slots):
        if lens[slot]:
            bt.release(slot)
    assert bt.allocator.held == 0 and bt.allocator.total_refs == 0


# ---------------------------------------------------------------------------
# spec + journal merge semantics (no jax)
# ---------------------------------------------------------------------------
def test_spec_publish_create_or_verify(tmp_path):
    root = str(tmp_path)
    publish_spec(root, SPEC)
    publish_spec(root, SPEC)  # idempotent for an identical spec
    assert load_spec(root) == SPEC
    other = FleetSpec(
        arch="qwen25_32b", prompt_lens=(5,), max_new_tokens=(4,), max_len=16
    )
    with pytest.raises(RuntimeError, match="different spec"):
        publish_spec(root, other)


def test_spec_rejects_overlong_request():
    with pytest.raises(ValueError, match="max_len"):
        FleetSpec(arch="qwen25_32b", prompt_lens=(10,), max_new_tokens=(10,),
                  max_len=16)


def test_merge_streams_dedupes_by_uid_index(tmp_path):
    root = str(tmp_path)
    a, b = journal_path(root, "a"), journal_path(root, "b")
    # worker a: full stream for uid 0
    append_jsonl(a, {"kind": "tokens", "uid": 0, "start": 0, "toks": [7, 8]})
    append_jsonl(a, {"kind": "tokens", "uid": 0, "start": 2, "toks": [9]})
    append_jsonl(a, {"kind": "end", "uid": 0, "n": 3, "status": "ok",
                     "error": None, "prompt_len": 4})
    # worker b: a duplicate replay (dead worker's thief) — identical cells
    append_jsonl(b, {"kind": "tokens", "uid": 0, "start": 0, "toks": [7]})
    append_jsonl(b, {"kind": "tokens", "uid": 0, "start": 1, "toks": [8, 9]})
    append_jsonl(b, {"kind": "end", "uid": 0, "n": 3, "status": "ok",
                     "error": None, "prompt_len": 4})
    streams, info = merge_streams(root, strict=True)
    assert info["conflicts"] == 0
    assert streams[0]["complete"] and streams[0]["tokens"] == [7, 8, 9]
    assert done_uids(root) == {0}


def test_merge_streams_flags_divergence(tmp_path):
    root = str(tmp_path)
    append_jsonl(journal_path(root, "a"),
                 {"kind": "tokens", "uid": 0, "start": 0, "toks": [7]})
    append_jsonl(journal_path(root, "b"),
                 {"kind": "tokens", "uid": 0, "start": 0, "toks": [8]})
    _, info = merge_streams(root)
    assert info["conflicts"] == 1
    with pytest.raises(RuntimeError, match="divergent"):
        merge_streams(root, strict=True)


def test_merge_incomplete_stream_not_done(tmp_path):
    root = str(tmp_path)
    j = journal_path(root, "a")
    # tokens but no terminal record: a worker died mid-stream
    append_jsonl(j, {"kind": "tokens", "uid": 1, "start": 0, "toks": [5, 6]})
    # terminal record but a missing cell: journal gap must not read as done
    append_jsonl(j, {"kind": "tokens", "uid": 2, "start": 0, "toks": [1]})
    append_jsonl(j, {"kind": "end", "uid": 2, "n": 3, "status": "ok",
                     "error": None, "prompt_len": 4})
    streams, _ = merge_streams(root)
    assert not streams[1]["complete"]
    assert not streams[2]["complete"]
    assert done_uids(root) == set()


def test_merge_heals_torn_final_line(tmp_path):
    root = str(tmp_path)
    j = journal_path(root, "a")
    append_jsonl(j, {"kind": "tokens", "uid": 0, "start": 0, "toks": [7]})
    with open(j, "ab") as f:  # SIGKILLed appender: torn, newline-less tail
        f.write(b'{"kind": "tokens", "uid": 0, "st')
    # the next append heals the tail; the torn fragment is skip-and-counted
    append_jsonl(j, {"kind": "tokens", "uid": 0, "start": 1, "toks": [8]})
    append_jsonl(j, {"kind": "end", "uid": 0, "n": 2, "status": "ok",
                     "error": None, "prompt_len": 4})
    streams, info = merge_streams(root, strict=True)
    assert info["partial"] == 1
    assert streams[0]["complete"] and streams[0]["tokens"] == [7, 8]


# ---------------------------------------------------------------------------
# watchdog (no jax)
# ---------------------------------------------------------------------------
def _wait_for(pred, timeout=5.0, interval=0.01):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return pred()


def test_step_watchdog_fires_on_wedged_window_only():
    clk = {"t": 0.0}
    fired = []
    wd = StepWatchdog(1.0, fired.append, poll_s=0.005, clock=lambda: clk["t"])
    try:
        # a window that completes in time never fires
        wd.arm()
        clk["t"] = 0.5
        wd.disarm()
        clk["t"] = 100.0
        time.sleep(0.05)
        assert fired == []
        # a wedged window fires exactly once, with the waited duration
        wd.arm()
        clk["t"] = 102.5
        assert _wait_for(lambda: len(fired) == 1)
        time.sleep(0.05)
        assert len(fired) == 1  # no refire while still armed
        assert fired[0] > 1.0
        # re-arming restores fire eligibility
        wd.arm()
        clk["t"] = 110.0
        assert _wait_for(lambda: len(fired) == 2)
        assert wd.fired_count == 2
    finally:
        wd.stop()


def test_step_watchdog_rejects_bad_timeout():
    with pytest.raises(ValueError):
        StepWatchdog(0.0, lambda w: None)


# ---------------------------------------------------------------------------
# engine degradation: typed admission failure, hooks, poisoned logits
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def smoke_model():
    import dataclasses as dc

    import jax

    from repro.configs import get_config
    from repro.models.transformer import init_params

    cfg = dc.replace(get_config("qwen25_32b", smoke=True),
                     compute_dtype="float32")
    return cfg, init_params(jax.random.key(0), cfg)


class FakeClock:
    """One second per reading — deterministic admission-wait accounting."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


def _prompts(cfg, shape, seed=7):
    return np.random.default_rng(seed).integers(0, cfg.vocab_size, shape)


def test_admission_impossible_fails_fast(smoke_model):
    """A prompt the pool can never hold raises typed, immediately — no
    spinning, no decode steps burned (the no-hang gate)."""
    cfg, params = smoke_model
    eng = ContinuousBatchingEngine(
        cfg, params, slots=2, max_len=16, page_size=4, num_pages=4,  # cap 3
        sync_interval=2, clock=FakeClock(),
    )
    reqs = [Request(uid=0, prompt=_prompts(cfg, (12,)), max_new_tokens=1)]
    with pytest.raises(AdmissionTimeout) as ei:
        eng.run(reqs)
    assert ei.value.reason == "impossible"
    assert ei.value.uid == 0 and ei.value.needed == 4


def test_admission_timeout_is_typed_and_bounded(smoke_model):
    """A queue starved behind a page-holder fails on its deadline with
    AdmissionTimeout instead of waiting unboundedly."""
    cfg, params = smoke_model
    clock = FakeClock()
    eng = ContinuousBatchingEngine(
        cfg, params, slots=2, max_len=16, page_size=4, num_pages=5,  # cap 4
        sync_interval=2, admission_timeout_s=2.5, clock=clock,
    )
    reqs = [
        Request(uid=0, prompt=_prompts(cfg, (4,)), max_new_tokens=10),
        Request(uid=1, prompt=_prompts(cfg, (9,)), max_new_tokens=4),  # 3 pages
    ]
    with pytest.raises(AdmissionTimeout) as ei:
        eng.run(reqs)
    assert ei.value.reason == "timeout"
    assert ei.value.uid == 1
    assert ei.value.waited_s > 2.5


def test_admission_shed_keeps_other_streams(smoke_model):
    """on_starved='shed': the starved request retires with a retryable
    status while the page-holder's stream completes untouched."""
    cfg, params = smoke_model
    ample = ContinuousBatchingEngine(
        cfg, params, slots=2, max_len=16, page_size=4, sync_interval=2,
    )
    req0 = Request(uid=0, prompt=_prompts(cfg, (4,)), max_new_tokens=10)
    want = ample.run([req0])[0].tokens
    eng = ContinuousBatchingEngine(
        cfg, params, slots=2, max_len=16, page_size=4, num_pages=5,
        sync_interval=2, admission_timeout_s=2.5, on_starved="shed",
        clock=FakeClock(),
    )
    comps = eng.run([req0, Request(uid=1, prompt=_prompts(cfg, (9,)),
                                   max_new_tokens=4)])
    assert comps[0].status == "ok" and comps[0].tokens == want
    assert comps[1].status == "shed" and "timeout" in (comps[1].error or "")
    assert eng.stats["shed"] == 1


def test_hooks_stream_tokens_and_cancel_mid_stream(smoke_model):
    """on_tokens streams exactly the completion's tokens; should_cancel
    drops a stream at the next sync with no further emission — the
    lost-ownership contract as seen from the engine."""
    cfg, params = smoke_model
    prompts = _prompts(cfg, (2, 5), seed=9)
    reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=8) for i in range(2)]
    ref = ContinuousBatchingEngine(
        cfg, params, slots=2, max_len=16, page_size=4, sync_interval=2,
    ).run(reqs)

    got = {0: [], 1: []}
    windows = {"n": 0}

    def on_tokens(uid, start, toks):
        assert start == len(got[uid])  # contiguous, dedupable by index
        got[uid].extend(toks)

    eng = ContinuousBatchingEngine(
        cfg, params, slots=2, max_len=16, page_size=4, sync_interval=2,
    )
    hooks = EngineHooks(
        on_tokens=on_tokens,
        should_cancel=lambda uid: uid == 1 and len(got[1]) >= 2,
        on_window_start=lambda: windows.__setitem__("n", windows["n"] + 1),
    )
    comps = eng.run(reqs, hooks=hooks)
    assert windows["n"] > 0
    assert comps[0].status == "ok" and comps[0].tokens == ref[0].tokens
    assert got[0] == ref[0].tokens
    c1 = comps[1]
    assert c1.status == "cancelled"
    assert got[1] == c1.tokens  # nothing emitted past the cancellation
    assert len(c1.tokens) < len(ref[1].tokens)
    assert c1.tokens == ref[1].tokens[: len(c1.tokens)]  # clean prefix
    assert eng.stats["cancelled"] == 1


def _poison_embed(params, token):
    import jax.numpy as jnp

    p2 = dict(params)
    p2["embed"] = dict(params["embed"])
    p2["embed"]["table"] = params["embed"]["table"].at[int(token)].set(jnp.nan)
    return p2


def _pick_poison_step(stream, *avoid):
    """(k, T): poisoning token T NaNs the decode step that produces token
    index k, and nothing earlier (first occurrence, absent from prompts)."""
    banned = set()
    for a in avoid:
        banned.update(int(x) for x in a)
    for k in range(1, len(stream)):
        t = int(stream[k - 1])
        if t not in banned and t not in [int(x) for x in stream[: k - 1]]:
            return k, t
    pytest.skip("no unambiguous poison token in this stream")


def test_nonfinite_decode_logits_retire_with_error(smoke_model):
    """NaN-poison one embedding row so a known decode step goes non-finite:
    the stream truncates before the garbage token and retires with
    status='error'; the co-scheduled request is untouched."""
    cfg, params = smoke_model
    prompts = _prompts(cfg, (2, 5), seed=13)
    reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=8) for i in range(2)]

    def fresh():
        return ContinuousBatchingEngine(
            cfg, params, slots=2, max_len=16, page_size=4, sync_interval=2,
        )

    clean = fresh().run(reqs)
    k, tok = _pick_poison_step(
        clean[0].tokens, prompts[0], prompts[1], clean[1].tokens
    )
    eng = ContinuousBatchingEngine(
        cfg, _poison_embed(params, tok), slots=2, max_len=16, page_size=4,
        sync_interval=2,
    )
    comps = eng.run(reqs)
    assert comps[0].status == "error" and "non-finite" in comps[0].error
    assert comps[0].tokens == clean[0].tokens[:k]  # garbage token dropped
    assert comps[1].status == "ok" and comps[1].tokens == clean[1].tokens
    assert eng.stats["errors"] == 1


def test_nonfinite_prefill_logits_error_at_admission(smoke_model):
    """A prompt containing the poisoned token errors at admission (no
    tokens, typed status) and returns its slot; peers are unaffected."""
    cfg, params = smoke_model
    prompts = _prompts(cfg, (2, 5), seed=13)
    reqs = [Request(uid=i, prompt=prompts[i], max_new_tokens=8) for i in range(2)]
    clean = ContinuousBatchingEngine(
        cfg, params, slots=2, max_len=16, page_size=4, sync_interval=2,
    ).run(reqs)
    only_in_1 = [
        int(t) for t in prompts[1]
        if int(t) not in {int(x) for x in prompts[0]}
        and int(t) not in {int(x) for x in clean[0].tokens}
    ]
    if not only_in_1:
        pytest.skip("prompts share every token")
    eng = ContinuousBatchingEngine(
        cfg, _poison_embed(params, only_in_1[0]), slots=2, max_len=16,
        page_size=4, sync_interval=2,
    )
    comps = eng.run(reqs)
    assert comps[1].status == "error" and comps[1].tokens == []
    assert "prefill" in comps[1].error
    assert comps[0].status == "ok" and comps[0].tokens == clean[0].tokens


# ---------------------------------------------------------------------------
# fleet workers (in-process)
# ---------------------------------------------------------------------------
def test_single_worker_fleet_matches_serial(tmp_path, serial_ref):
    root = str(tmp_path)
    publish_spec(root, SPEC)
    stats = FleetWorker(root, "w0", heartbeat_s=0.2, poll_s=0.05).run()
    assert stats["ok"] == SPEC.n_requests
    assert_fleet_matches_serial(root, serial_ref)


def test_second_worker_resumes_where_first_stopped(tmp_path, serial_ref):
    """max_batches bounds worker 1 mid-fleet; worker 2 picks up the rest
    from the journals + leases alone — no coordinator state."""
    root = str(tmp_path)
    publish_spec(root, SPEC)
    s1 = FleetWorker(root, "w1", heartbeat_s=0.2, poll_s=0.05,
                     max_batches=1).run()
    assert 0 < s1["ok"] < SPEC.n_requests
    FleetWorker(root, "w2", heartbeat_s=0.2, poll_s=0.05).run()
    assert_fleet_matches_serial(root, serial_ref)
    assert os.path.exists(journal_path(root, "w1"))
    assert os.path.exists(journal_path(root, "w2"))


def test_lost_lease_stops_emitting_immediately(tmp_path, serial_ref):
    """Satellite regression: a worker whose lease is stolen mid-stream
    writes no further records for that uid (no divergent tokens survive
    the merge), and the thief's replay completes the stream."""
    root = str(tmp_path)
    publish_spec(root, SPEC)
    lease_file = os.path.join(root, "leases", request_slug(0) + ".lease")
    stolen = threading.Event()

    def steal_when_leased():
        deadline = time.monotonic() + 120
        while time.monotonic() < deadline and not os.path.exists(lease_file):
            time.sleep(0.01)
        # overwrite with a foreign short-TTL lease: the worker's next
        # heartbeat reads a different owner -> lost-ownership contract
        now = time.time()
        tmp = lease_file + ".steal"
        with open(tmp, "w") as f:
            json.dump({"unit": request_slug(0), "owner": "thief",
                       "acquired_at": now, "heartbeat_at": now, "ttl": 0.2}, f)
        os.replace(tmp, lease_file)
        stolen.set()

    thief = threading.Thread(target=steal_when_leased)
    thief.start()
    w1 = FleetWorker(root, "stale", heartbeat_s=0.05, poll_s=0.05,
                     throttle_s=0.25, max_batches=1)
    s1 = w1.run()
    thief.join()
    assert stolen.is_set()
    assert s1["stolen_from_us"] >= 1 and s1["cancelled"] >= 1
    # the stale worker journaled at most a prefix for uid 0, never an end
    recs = [json.loads(l) for l in open(journal_path(root, "stale"))]
    assert all(r["kind"] != "end" for r in recs if r["uid"] == 0)
    assert 0 not in done_uids(root)
    # the thief's short TTL expires; a fresh worker steals + replays
    FleetWorker(root, "rescue", heartbeat_s=0.2, poll_s=0.05).run()
    assert_fleet_matches_serial(root, serial_ref)


def test_watchdog_frees_wedged_worker_before_ttl(tmp_path, serial_ref):
    """A wedged decode window (injected) trips the watchdog, which
    releases the leases right away (TTL here is 1000s — only the watchdog
    can explain recovery), cancels the streams, and the worker's next
    pass re-serves them cleanly."""
    root = str(tmp_path)
    publish_spec(root, SPEC)
    t0 = time.monotonic()
    w = FleetWorker(root, "wedgy", ttl=1000.0, heartbeat_s=0.2, poll_s=0.05,
                    step_timeout_s=0.15, wedge_uid=0, wedge_s=1.0)
    stats = w.run()
    assert stats["watchdog_fired"] >= 1
    assert stats["cancelled"] >= 1
    assert time.monotonic() - t0 < 1000.0 / 2
    assert_fleet_matches_serial(root, serial_ref)


def test_pool_exhaustion_sheds_then_retries(tmp_path):
    """Backpressure: a request the pool can't hold *now* is shed with no
    journal record and served on a later pass once pages free up."""
    spec = FleetSpec(
        arch="qwen25_32b", prompt_lens=(4, 9), max_new_tokens=(10, 4),
        seed=5, slots=2, max_len=16, page_size=4, sync_interval=2,
        num_pages=5,  # capacity 4: both requests can never be co-resident
    )
    root = str(tmp_path)
    publish_spec(root, spec)
    w = FleetWorker(root, "tight", heartbeat_s=0.2, poll_s=0.05,
                    admission_timeout_s=0.01)
    stats = w.run()
    assert stats["shed"] >= 1
    assert_fleet_matches_serial(root, serve_serial(spec))


# ---------------------------------------------------------------------------
# the chaos gate: real subprocesses, SIGKILL + duplicate worker + torn tail
# ---------------------------------------------------------------------------
def spawn_worker(root, owner, *, throttle=0.0, heartbeat=0.3, ttl=2.0,
                 poll=0.1):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC_DIR + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [
        sys.executable, "-m", "repro.serve.fleet", "run",
        "--root", str(root), "--owner", owner,
        "--heartbeat", str(heartbeat), "--ttl", str(ttl),
        "--poll", str(poll), "--throttle", str(throttle),
    ]
    return subprocess.Popen(
        cmd, env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True,
    )


def test_chaos_sigkill_duplicate_and_torn_tail(tmp_path, chaos_serial):
    """The acceptance scenario: a 3-worker fleet where the first worker is
    SIGKILLed mid-decode (leaving held leases and a torn journal tail) and
    one worker's lease cadence makes it a duplicate (its TTL expires
    between heartbeats, so peers steal requests it is still serving).
    The merged journals must be byte-identical to the serial run."""
    root = str(tmp_path)
    publish_spec(root, CHAOS_SPEC)
    victim_journal = journal_path(root, "victim")
    victim = spawn_worker(root, "victim", throttle=0.3, heartbeat=0.2, ttl=2.0)
    try:
        deadline = time.time() + 240
        while time.time() < deadline:
            if os.path.exists(victim_journal) and os.path.getsize(victim_journal):
                break
            time.sleep(0.02)
        else:
            pytest.fail("victim never journaled a token")
        time.sleep(0.1)  # let it get into a decode window
        victim.kill()  # SIGKILL: no release, no final heartbeat
        victim.wait(timeout=30)
    finally:
        if victim.poll() is None:
            victim.kill()
    leases = Path(root) / "leases"
    assert any(leases.glob("*.lease")), "victim died without held leases"
    with open(victim_journal, "ab") as f:  # torn final line, no newline
        f.write(b'{"kind": "tokens", "uid": 0, "sta')

    # Duplicate-prone worker first, alone: ttl < heartbeat means its leases
    # sit expired for ~2/3 of every heartbeat cycle, and the heavy throttle
    # makes its batch far outlast all the rescuers' remaining work.  Only
    # once it is demonstrably mid-stream (journal non-empty) do the
    # rescuers start.  Steals only happen in a worker's claim loop, i.e.
    # between its batches — so the guarantee comes from the end-game: the
    # rescuers finish everything else and then idle-poll (0.1 s) on the
    # duplicate's still-incomplete requests, whose lease is expired most
    # of the time, while its batch still has many throttled windows to go.
    dup = spawn_worker(root, "dup", throttle=4.0, heartbeat=1.5, ttl=0.5)
    dup_journal = journal_path(root, "dup")
    deadline = time.time() + 240
    while time.time() < deadline:
        if dup.poll() is not None or (
            os.path.exists(dup_journal) and os.path.getsize(dup_journal)
        ):
            break
        time.sleep(0.02)
    assert dup.poll() is None, dup.communicate()[0]
    workers = [
        spawn_worker(root, "rescue0", heartbeat=0.3, ttl=1.5),
        spawn_worker(root, "rescue1", heartbeat=0.3, ttl=1.5),
        dup,
    ]
    outs = []
    for p in workers:
        out, _ = p.communicate(timeout=300)
        outs.append(out)
        assert p.returncode == 0, out
    streams, info = merge_streams(root, strict=True)
    assert info["partial"] >= 1, info  # the torn tail was skip-and-counted
    assert_fleet_matches_serial(root, chaos_serial)
    # the duplicate worker really did lose leases mid-serve
    dup_stats = json.loads(outs[2].strip().splitlines()[-1])
    assert dup_stats["stolen_from_us"] + dup_stats["cancelled"] >= 1, outs[2]


def test_fleet_cli_merge_and_status(tmp_path, serial_ref):
    root = str(tmp_path)
    publish_spec(root, SPEC)
    FleetWorker(root, "w0", heartbeat_s=0.2, poll_s=0.05).run()
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC_DIR + os.pathsep + env.get("PYTHONPATH", "")
    merged = subprocess.run(
        [sys.executable, "-m", "repro.serve.fleet", "merge", "--root", root,
         "--strict", "--out", os.path.join(root, "merged.json")],
        env=env, capture_output=True, text=True, check=True,
    )
    summary = json.loads(merged.stdout)
    assert summary["complete"] == SPEC.n_requests
    assert summary["conflicts"] == 0
    with open(os.path.join(root, "merged.json")) as f:
        dump = json.load(f)
    assert [s["uid"] for s in dump["streams"]] == list(range(SPEC.n_requests))
    status = subprocess.run(
        [sys.executable, "-m", "repro.serve.fleet", "status", "--root", root],
        env=env, capture_output=True, text=True, check=True,
    )
    st = json.loads(status.stdout)
    assert st["complete"] == st["requests"] == SPEC.n_requests
    assert st["leased"] == 0
