"""Serve-path tests: paged KV cache, flash-decode kernel, continuous batching.

Contracts:
* paged decode logits == dense decode logits BIT-FOR-BIT, per step, for
  every cache family (KV+ring, MLA latents, recurrent state), including
  ragged per-sequence positions and page-boundary crossings;
* the Pallas flash-decode kernel matches the gather oracle across GQA
  group sizes and non-multiple-of-page lengths;
* continuous batching (paged and dense) is token-level equivalent to the
  fixed-batch engine on a seeded greedy trace;
* the allocator is a real free list: lowest-first, recycling, OOM.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, st

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.kernels.flash_decode import flash_decode_pallas
from repro.models.transformer import (
    decode_step,
    forward,
    init_cache,
    init_params,
)
from repro.serve.engine import ServeEngine
from repro.serve.paged_cache import (
    NULL_PAGE,
    BlockTables,
    PageAllocator,
    PageOverflowError,
    PrefixIndex,
    pages_for,
    required_pages,
)
from repro.serve.scheduler import ContinuousBatchingEngine, Request

KEY = jax.random.key(0)


def _smoke(arch):
    return dataclasses.replace(get_config(arch, smoke=True), compute_dtype="float32")


# ---------------------------------------------------------------------------
# page allocator / block tables
# ---------------------------------------------------------------------------
def test_allocator_lowest_first_and_recycles():
    a = PageAllocator(8)  # pages 1..7 allocatable, 0 reserved
    assert a.alloc(3) == [1, 2, 3]
    a.free([2])
    assert a.alloc(2) == [2, 4]  # freed page reused, lowest id first
    assert a.available == 3


def test_allocator_oom_raises():
    a = PageAllocator(4)
    a.alloc(3)
    with pytest.raises(RuntimeError, match="exhausted"):
        a.alloc(1)


def test_block_tables_alloc_on_write_and_release():
    bt = BlockTables.with_pool(slots=2, max_len=16, page_size=4, num_pages=16)
    pages = bt.admit(0, prompt_len=5)  # positions 0..5 -> 2 pages
    assert len(pages) == pages_for(6, 4) == 2
    assert list(bt.table[0, :2]) == pages and bt.table[0, 2] == 0
    # decode crosses into page 2 at position 8
    assert not bt.ensure(0, 7)
    assert bt.ensure(0, 8)
    assert bt.table[0, 2] != 0
    used = bt.pages_in_use
    bt.release(0)
    assert bt.pages_in_use == used - 3
    assert (bt.table[0] == 0).all()
    # slot 1 unaffected throughout
    p1 = bt.admit(1, prompt_len=1)
    assert p1[0] not in (0,)


def test_required_pages_covers_full_horizon():
    assert required_pages(3, 16, 4) == 1 + 3 * 4


def test_allocator_guards_double_free_and_null_page():
    a = PageAllocator(6)
    pages = a.alloc(2)
    a.free(pages)
    with pytest.raises(RuntimeError, match="not held"):
        a.free([pages[0]])  # double-free
    with pytest.raises(RuntimeError, match="null"):
        a.free([0])  # the reserved page is never in circulation


@settings(max_examples=40)
@given(
    st.integers(min_value=2, max_value=24),
    st.lists(st.integers(min_value=0, max_value=999), min_size=1, max_size=60),
)
def test_allocator_fuzz_no_double_grant_no_leak(num_pages, script):
    """Property fuzz over alloc/free interleavings: page 0 is never handed
    out, no page is granted twice without an intervening free, and
    ``held + available == capacity`` at every step (no leak, no
    double-count)."""
    a = PageAllocator(num_pages)
    held = []
    for op in script:
        if op % 2 == 0 and a.available:
            n = 1 + (op // 2) % a.available
            pages = a.alloc(n)
            assert 0 not in pages
            assert len(set(pages)) == n
            assert not set(pages) & set(held)
            held.extend(pages)
        elif held:
            k = 1 + (op // 2) % len(held)
            a.free([held.pop() for _ in range(k)])
        assert a.held == len(held)
        assert a.held + a.available == a.capacity
    # an over-ask must fail without perturbing state
    if a.available < a.capacity or a.available:
        before = a.available
        with pytest.raises(RuntimeError, match="exhausted"):
            a.alloc(a.available + 1)
        assert a.available == before
    if held:
        a.free(held)
    assert a.available == a.capacity and a.held == 0


@settings(max_examples=15)
@given(
    st.integers(min_value=1, max_value=4),
    st.lists(st.integers(min_value=0, max_value=999), min_size=1, max_size=30),
)
def test_block_tables_fuzz_slots_stay_disjoint(slots, script):
    """Admit/ensure/release interleavings across slots: owned page sets
    stay pairwise disjoint, the table mirrors ownership exactly, and
    release returns everything."""
    ps, max_len = 4, 16
    bt = BlockTables.with_pool(slots, max_len, ps, required_pages(slots, max_len, ps))
    lens = [0] * slots  # 0 = slot free
    for op in script:
        slot = op % slots
        if lens[slot] == 0:
            lens[slot] = 1 + (op // 7) % (max_len - 1)
            bt.admit(slot, lens[slot])
        elif op % 3 == 0:
            bt.release(slot)
            lens[slot] = 0
        else:
            bt.ensure(slot, min(max_len - 1, lens[slot] + (op // 5) % 8))
        owned = [set(p) for p in bt.owned]
        for i in range(slots):
            for j in range(i + 1, slots):
                assert not owned[i] & owned[j], "slots share a page"
            live = [p for p in bt.table[i] if p != NULL_PAGE]
            assert live == bt.owned[i][: len(live)] and len(live) == len(owned[i])
        assert bt.pages_in_use == bt.allocator.held
    for slot in range(slots):
        bt.release(slot)
    assert bt.allocator.held == 0


def test_allocator_share_refcounts():
    a = PageAllocator(8)
    pages = a.alloc(2)
    a.share([pages[0]])
    assert a.refcount(pages[0]) == 2 and a.total_refs == 3
    a.free([pages[0]])  # decref: a reference remains, the page stays held
    assert a.refcount(pages[0]) == 1 and a.held == 2
    a.free([pages[0]])  # last owner: really freed
    assert a.held == 1 and a.refcount(pages[0]) == 0
    with pytest.raises(RuntimeError, match="not held"):
        a.share([pages[0]])  # sharing a free page is a bug
    with pytest.raises(RuntimeError, match="null"):
        a.share([NULL_PAGE])  # the reserved page is never shared


@settings(max_examples=40)
@given(
    st.integers(min_value=2, max_value=16),
    st.lists(st.integers(min_value=0, max_value=999), min_size=1, max_size=60),
)
def test_allocator_fuzz_share_decref_interleavings(num_pages, script):
    """Property fuzz over alloc/share/decref interleavings: page 0 is
    never granted or shared, no page returns to the free list while
    references remain, per-page refcounts mirror the reference multiset
    exactly, and once every reference is released the pool is whole
    again (no refcount leak)."""
    a = PageAllocator(num_pages)
    refs: list = []  # one entry per live reference (a page may appear k times)
    for op in script:
        mode = op % 3
        if mode == 0 and a.available:
            n = 1 + (op // 3) % a.available
            pages = a.alloc(n)
            assert NULL_PAGE not in pages
            refs.extend(pages)
        elif mode == 1 and refs:
            p = refs[(op // 3) % len(refs)]
            a.share([p])
            refs.append(p)
        elif refs:
            p = refs.pop((op // 3) % len(refs))
            a.free([p])
        held = set(refs)
        assert a.held == len(held)
        assert a.held + a.available == a.capacity
        assert a.total_refs == len(refs) and a.total_refs >= a.held
        for p in held:
            assert a.refcount(p) == refs.count(p)
    a.free(refs)
    assert a.held == 0 and a.available == a.capacity and a.total_refs == 0


def test_block_tables_shared_prefix_pages_survive_peer_release():
    bt = BlockTables.with_pool(slots=2, max_len=16, page_size=4, num_pages=16)
    donor = bt.admit(0, prompt_len=9)  # 3 pages, first two full
    pages = bt.admit(1, prompt_len=9, shared=donor[:2])
    assert pages[:2] == donor[:2] and pages[2] != donor[2]
    assert bt.allocator.refcount(donor[0]) == 2
    bt.release(0)
    # the shared prefix is still referenced by slot 1: alive, table intact
    assert bt.allocator.refcount(donor[0]) == 1
    assert list(bt.table[1, :2]) == donor[:2]
    bt.release(1)
    assert bt.allocator.held == 0 and bt.allocator.total_refs == 0


def test_block_tables_rejects_more_shared_than_needed():
    bt = BlockTables.with_pool(slots=2, max_len=16, page_size=4, num_pages=16)
    donor = bt.admit(0, prompt_len=13)  # 4 pages
    with pytest.raises(RuntimeError, match="shared prefix pages exceed"):
        bt.admit(1, prompt_len=2, shared=donor[:3])  # needs only 1 page


def test_page_overflow_is_typed_and_catchable():
    """Over-length requests must raise the typed `PageOverflowError` — a
    real exception, not an assert stripped by ``python -O``."""
    bt = BlockTables.with_pool(slots=1, max_len=8, page_size=4, num_pages=16)
    with pytest.raises(PageOverflowError) as e:
        bt.admit(0, prompt_len=99)
    assert e.value.slot == 0 and e.value.max_len == 8
    assert bt.allocator.held == 0  # nothing leaked by the failed admit
    bt.admit(0, prompt_len=3)
    with pytest.raises(PageOverflowError):
        bt.ensure(0, 8)  # decode past the horizon
    assert isinstance(e.value, RuntimeError)


def test_prefix_index_match_insert_evict():
    a = PageAllocator(16)
    idx = PrefixIndex(4, a)
    toks = np.arange(100, 112, dtype=np.int32)  # 3 full pages
    owner = a.alloc(3)
    for d, payload in ((0, None), (1, "snap1"), (2, None)):
        assert idx.insert(toks, d, owner[d], payload)
    assert not idx.insert(toks, 1, owner[1], "dup")  # racing duplicate kept out
    chain = idx.match(toks)
    assert [n.page for n in chain] == owner and chain[1].payload == "snap1"
    # a diverging suffix matches only the common prefix
    fork = toks.copy()
    fork[6] = 999
    assert len(idx.match(fork)) == 1
    assert idx.match(np.asarray([1, 2, 3, 4], np.int32)) == []
    st_ = idx.stats()
    assert st_["prefix_queries"] == 3 and st_["prefix_hits"] == 2
    # the index owns its pages: the prefiller releasing keeps them cached
    a.free(owner)
    assert a.held == 3
    # eviction is deepest-first and respects the pinned (kept) chain
    assert idx.evict(1, keep=owner[:1]) == 1
    assert a.refcount(owner[2]) == 0 and len(idx.match(toks)) == 2
    assert idx.evict(5) == 2 and a.held == 0


# ---------------------------------------------------------------------------
# flash-decode kernel vs gather oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,h,kvh,d,ps,mp,bp,cap",
    [
        (3, 4, 2, 16, 4, 6, 2, None),    # GQA g=2
        (2, 6, 2, 8, 8, 4, 4, 30.0),     # g=3 + logit cap
        (1, 2, 2, 8, 4, 3, 3, None),     # g=1 (MHA)
        (4, 8, 1, 16, 2, 8, 1, None),    # MQA, single-page tiles
    ],
)
def test_flash_decode_matches_ref(b, h, kvh, d, ps, mp, bp, cap):
    p = 1 + b * mp
    kp = jax.random.normal(jax.random.fold_in(KEY, 1), (kvh, p, ps, d))
    vp = jax.random.normal(jax.random.fold_in(KEY, 2), (kvh, p, ps, d))
    q = jax.random.normal(jax.random.fold_in(KEY, 3), (b, 1, h, d))
    # shuffled block tables over the non-null pages: physical page order
    # must not matter
    perm = jax.random.permutation(jax.random.fold_in(KEY, 4), p - 1)[: b * mp] + 1
    bt = perm.reshape(b, mp).astype(jnp.int32)
    # ragged, non-multiple-of-page lengths (>= 1; empty slots never reach
    # the kernel with length 0 plus a live query)
    lengths = jnp.asarray([1 + (7 * i + 3) % (mp * ps) for i in range(b)], jnp.int32)
    want = ref.flash_decode_ref(q, kp, vp, bt, lengths, logit_cap=cap)
    got = flash_decode_pallas(q, kp, vp, bt, lengths, logit_cap=cap, block_pages=bp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_decode_zero_length_slot_is_nan_free():
    kvh, p, ps, d = 2, 5, 4, 8
    kp = jax.random.normal(jax.random.fold_in(KEY, 5), (kvh, p, ps, d))
    vp = jax.random.normal(jax.random.fold_in(KEY, 6), (kvh, p, ps, d))
    q = jax.random.normal(jax.random.fold_in(KEY, 7), (2, 1, 4, d))
    bt = jnp.zeros((2, 2), jnp.int32)
    out = flash_decode_pallas(q, kp, vp, bt, jnp.asarray([0, 3], jnp.int32))
    got = np.asarray(out)
    assert np.isfinite(got).all()
    np.testing.assert_array_equal(got[0], 0.0)  # skipped slot: exact zeros


def test_ops_dispatch_resolves_and_degrades_block_pages():
    # tuned block_pages must degrade to a divisor of max_pages
    bp = ops._fit("flash_decode", "block_pages", None, 4, 6)
    assert 6 % bp == 0
    assert ops._fit("flash_decode", "block_pages", 3, 4, 6) == 3  # explicit wins


def test_ops_flash_decode_backends_agree():
    kvh, p, ps, d, b, h = 2, 7, 4, 8, 3, 4
    kp = jax.random.normal(jax.random.fold_in(KEY, 8), (kvh, p, ps, d))
    vp = jax.random.normal(jax.random.fold_in(KEY, 9), (kvh, p, ps, d))
    q = jax.random.normal(jax.random.fold_in(KEY, 10), (b, 1, h, d))
    bt = (1 + jnp.arange(b * 2, dtype=jnp.int32)).reshape(b, 2)
    lens = jnp.asarray([5, 8, 2], jnp.int32)
    a = ops.flash_decode(q, kp, vp, bt, lens, backend="pallas_interpret")
    c = ops.flash_decode(q, kp, vp, bt, lens, backend="xla")
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=2e-5, atol=2e-5)


def test_interpret_env_override(monkeypatch):
    monkeypatch.setenv(ops.INTERPRET_ENV, "1")
    assert ops._interpret() is True
    monkeypatch.setenv(ops.INTERPRET_ENV, "0")
    assert ops._interpret() is False
    monkeypatch.delenv(ops.INTERPRET_ENV)
    # unset: backend-aware (CPU test runner -> interpret)
    from repro.evaluation.timing import has_accelerator

    assert ops._interpret() == (not has_accelerator())


# ---------------------------------------------------------------------------
# paged == dense, bit for bit, per step
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "arch", ["gemma3_27b", "deepseek_v2_lite_16b", "rwkv6_1b6"]
)
def test_paged_decode_matches_dense_bitwise(arch):
    """Every cache family: scalar-pos dense (the legacy path, untouched)
    vs vector-pos paged, identical logits bit-for-bit across steps that
    cross page boundaries."""
    cfg = _smoke(arch)
    params = init_params(jax.random.key(0), cfg)
    b, s, ps = 2, 10, 4
    max_len = 16  # multiple of the page size: gather shape == dense shape
    mp = max_len // ps
    toks = jax.random.randint(jax.random.fold_in(KEY, 11), (b, s), 0, cfg.vocab_size)
    dense = init_cache(cfg, b, max_len)
    paged = init_cache(
        cfg, b, max_len, layout="paged", num_pages=1 + b * mp, page_size=ps
    )
    bt = (1 + jnp.arange(b * mp, dtype=jnp.int32)).reshape(b, mp)
    dstep = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    pstep = jax.jit(
        lambda p, c, t, pos, bt: decode_step(cfg, p, c, t, pos, block_tables=bt)
    )
    for t in range(s):
        nt = toks[:, t : t + 1]
        ld, dense = dstep(params, dense, nt, jnp.int32(t))
        lp, paged = pstep(params, paged, nt, jnp.full((b,), t, jnp.int32), bt)
        np.testing.assert_array_equal(np.asarray(ld), np.asarray(lp)), (arch, t)


def test_paged_ragged_positions_match_per_sequence_dense():
    """Sequences at *different* offsets in one paged batch produce the
    same logits as each sequence decoded alone in a dense batch."""
    cfg = _smoke("qwen25_32b")
    params = init_params(jax.random.key(1), cfg)
    ps, max_len = 4, 16
    mp = max_len // ps
    lens = [3, 7, 5]  # ragged prompt lengths
    b = len(lens)
    toks = [
        jax.random.randint(jax.random.fold_in(KEY, 20 + i), (1, n), 0, cfg.vocab_size)
        for i, n in enumerate(lens)
    ]
    # paged batch: each slot prefillled by replaying its prompt via decode
    paged = init_cache(
        cfg, b, max_len, layout="paged", num_pages=1 + b * mp, page_size=ps
    )
    bt = (1 + jnp.arange(b * mp, dtype=jnp.int32)).reshape(b, mp)
    pstep = jax.jit(
        lambda p, c, t, pos, bt: decode_step(cfg, p, c, t, pos, block_tables=bt)
    )
    # replay prompts token by token at ragged per-slot positions (slots
    # that already ran out replay their last token at a parked position —
    # their logits are ignored)
    outs = {}
    for t in range(max(lens)):
        nt = jnp.stack(
            [toks[i][0, min(t, lens[i] - 1)] for i in range(b)]
        )[:, None]
        pos = jnp.asarray([min(t, lens[i] - 1) for i in range(b)], jnp.int32)
        lg, paged = pstep(params, paged, nt, pos, bt)
        for i in range(b):
            if t == lens[i] - 1:
                outs[i] = np.asarray(lg[i, 0])
    # reference: each prompt alone through the dense scalar-pos path
    for i, n in enumerate(lens):
        dense = init_cache(cfg, 1, max_len)
        dstep = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
        for t in range(n):
            lg, dense = dstep(params, dense, toks[i][:, t : t + 1], jnp.int32(t))
        np.testing.assert_allclose(outs[i], np.asarray(lg[0, 0]), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# continuous batching == fixed batch (token level)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_continuous_matches_fixed_batch_tokens(layout):
    cfg = _smoke("qwen25_32b")
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(7)
    n_req, s0 = 5, 6
    prompts = rng.integers(0, cfg.vocab_size, (n_req, s0))
    lens = [3, 9, 2, 7, 5]
    max_len = s0 + max(lens) + 1
    fixed = ServeEngine(cfg, params, max_len=max_len)
    out = fixed.generate(jnp.asarray(prompts), steps=max(lens))
    cbe = ContinuousBatchingEngine(
        cfg, params, slots=2, max_len=max_len, cache_layout=layout,
        page_size=4, sync_interval=2,
    )
    comps = cbe.run(
        [Request(uid=i, prompt=prompts[i], max_new_tokens=lens[i]) for i in range(n_req)]
    )
    for c in comps:
        assert len(c.tokens) == lens[c.uid]
        np.testing.assert_array_equal(
            np.asarray(c.tokens), np.asarray(out[c.uid, s0 : s0 + lens[c.uid]])
        )
    # 2 slots < 5 requests: recycling really happened
    assert cbe.stats["prefills"] == n_req
    if layout == "paged":
        assert cbe.stats["peak_pages"] > 0


def test_continuous_eos_frees_slot_and_emits_padding_free_tokens():
    """Force an eos mid-stream: the request stops at eos (inclusive), its
    pages are freed, and a queued request takes the slot."""
    cfg = _smoke("qwen25_32b")
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(3)
    prompts = rng.integers(0, cfg.vocab_size, (3, 5))
    max_len = 24
    fixed = ServeEngine(cfg, params, max_len=max_len)
    ref_out = np.asarray(fixed.generate(jnp.asarray(prompts), steps=12))[:, 5:]
    # pick the token the first sequence emits at step 3 as "eos"
    eos = int(ref_out[0, 3])
    cbe = ContinuousBatchingEngine(
        cfg, params, slots=1, max_len=max_len, cache_layout="paged",
        page_size=4, sync_interval=2, eos_id=eos,
    )
    comps = cbe.run(
        [Request(uid=i, prompt=prompts[i], max_new_tokens=12) for i in range(3)]
    )
    for c in comps:
        want = ref_out[c.uid]
        stop = np.where(want == eos)[0]
        n = int(stop[0]) + 1 if len(stop) else 12
        assert len(c.tokens) == n, (c.uid, c.tokens, want)
        np.testing.assert_array_equal(np.asarray(c.tokens), want[:n])
    # all pages back in the pool after the run
    assert cbe.stats["peak_pages"] > 0


def test_malformed_requests_error_without_crashing_peers():
    """Over-length / empty / zero-budget requests retire with a typed
    ``status="error"`` at admission (live under ``python -O``: the path
    is exceptions, not asserts) while valid peers stream unaffected."""
    cfg = _smoke("qwen25_32b")
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(9)
    good = rng.integers(0, cfg.vocab_size, (2, 5))
    max_len = 16
    reqs = [
        Request(uid=0, prompt=good[0], max_new_tokens=4),
        Request(uid=1, prompt=good[1], max_new_tokens=99),  # pl+new > max_len
        Request(uid=2, prompt=np.zeros(0, np.int64), max_new_tokens=4),
        Request(uid=3, prompt=good[1], max_new_tokens=0),
        Request(uid=4, prompt=good[1], max_new_tokens=4),
    ]
    cbe = ContinuousBatchingEngine(
        cfg, params, slots=2, max_len=max_len, cache_layout="paged",
        page_size=4, sync_interval=2,
    )
    comps = cbe.run(reqs)
    ref = ContinuousBatchingEngine(
        cfg, params, slots=2, max_len=max_len, cache_layout="paged",
        page_size=4, sync_interval=2,
    ).run([reqs[0], reqs[4]])
    for i in (1, 2, 3):
        assert comps[i].status == "error" and comps[i].tokens == []
        assert comps[i].error is not None
    assert "exceeds max_len" in comps[1].error
    assert comps[0].status == "ok" and comps[0].tokens == ref[0].tokens
    assert comps[4].status == "ok" and comps[4].tokens == ref[1].tokens
    assert cbe.stats["errors"] == 3


@pytest.mark.parametrize("arch", ["qwen25_32b", "rwkv6_1b6"])
def test_prefix_cache_hit_streams_bit_identical(arch):
    """Shared-prefix prompts: the radix prefix cache must (a) actually
    hit, (b) skip prefill chunks, and (c) leave every token stream
    bit-identical to the cold paged run and the dense layout — for the
    KV-cache family and the recurrent-state family (whose cached payload
    is the full carry snapshot)."""
    cfg = _smoke(arch)
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(11)
    shared = rng.integers(0, cfg.vocab_size, 16)
    prompts = [
        np.concatenate([shared, rng.integers(0, cfg.vocab_size, 3)])
        for _ in range(5)
    ]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5) for i, p in enumerate(prompts)]
    runs = {}
    stats = {}
    for name, layout, pc in (
        ("dense", "dense", False),
        ("paged_cold", "paged", False),
        ("paged_cached", "paged", True),
    ):
        cbe = ContinuousBatchingEngine(
            cfg, params, slots=2, max_len=28, cache_layout=layout,
            page_size=4, prefill_chunk_tokens=8, sync_interval=2,
            prefix_cache=pc,
        )
        runs[name] = [c.tokens for c in cbe.run(reqs)]
        stats[name] = cbe.stats
    assert runs["paged_cached"] == runs["paged_cold"] == runs["dense"]
    assert stats["paged_cached"]["prefix_hits"] > 0
    assert stats["paged_cached"]["prefix_hit_rate"] > 0
    assert (
        stats["paged_cached"]["prefill_chunks"]
        < stats["paged_cold"]["prefill_chunks"]
    )


# ---------------------------------------------------------------------------
# throughput-benchmark verdict helpers
# ---------------------------------------------------------------------------
def test_directional_wall_gate_rejects_paged_slower():
    from benchmarks.serve_throughput import directional_wall_gate

    engines = {
        "fixed_dense": {"wall_s": 1.0, "noise_floor_s": 0.02},
        "continuous_paged": {"wall_s": 0.7, "noise_floor_s": 0.03},
    }
    assert directional_wall_gate(engines, "continuous_paged", "fixed_dense")
    # paged SLOWER than the baseline by more than the floor: the old
    # abs(fw - pw) gate called this "distinguishable" — a regression
    # reported as a win; the directional gate must say no
    engines["continuous_paged"]["wall_s"] = 1.4
    assert not directional_wall_gate(engines, "continuous_paged", "fixed_dense")
    # within the combined noise floor: indistinguishable, not a win
    engines["continuous_paged"]["wall_s"] = 0.99
    assert not directional_wall_gate(engines, "continuous_paged", "fixed_dense")


def test_safe_tokens_per_s_guards_zero_and_noise_runtimes():
    from benchmarks.serve_throughput import safe_tokens_per_s

    assert safe_tokens_per_s(100, 0.0) is None  # no ZeroDivisionError
    assert safe_tokens_per_s(100, -1.0) is None
    assert safe_tokens_per_s(100, 5.0, noise_floor_us=10.0) is None  # in the noise
    assert safe_tokens_per_s(100, 2e6, noise_floor_us=100.0) == 50.0


def test_serve_engine_eos_emits_pad_and_syncs_on_interval():
    cfg = _smoke("qwen25_32b")
    params = init_params(jax.random.key(0), cfg)
    prompts = jax.random.randint(jax.random.fold_in(KEY, 30), (2, 5), 0, cfg.vocab_size)
    plain = ServeEngine(cfg, params, max_len=20)
    base = np.asarray(plain.generate(prompts, steps=8))[:, 5:]
    eos = int(base[0, 2])  # row 0 hits "eos" at step 2
    eng = ServeEngine(cfg, params, max_len=20, eos_id=eos, sync_interval=4)
    out = np.asarray(eng.generate(prompts, steps=8))[:, 5:]
    row = out[0]
    k = int(np.where(row == eos)[0][0])
    # everything after the first eos is pad (== eos by default), not live
    np.testing.assert_array_equal(row[k:], eos)
    # a row that never hit eos is untouched
    if not (base[1] == eos).any():
        np.testing.assert_array_equal(out[1][: base.shape[1]], base[1])
    assert eng.last_stats["decode_steps"] % eng.sync_interval == 0 or \
        eng.last_stats["decode_steps"] == 8
