"""Speculative decoding: draft proposers, the verified width-K step, and
the exactness contract.

Contracts:
* speculation is a pure latency optimization — token streams (and retire
  statuses) are BIT-IDENTICAL to the non-speculative run for every cache
  family (KV paged, local ring, MLA latents, recurrent state), for any
  proposer, including one that drafts adversarial garbage;
* the n-gram proposer continues cycles through its own drafts (iterative
  prompt lookup), pads short proposals with NO_DRAFT, and the scheduler
  shrinks the verify width accordingly;
* rollback is exact: a rejected draft leaves no trace in the cache
  (slabs are overwritten before read, carries rewound, rings restored);
* decode is row-independent: one request's stream never depends on its
  batch neighbours — the MoE decode path must not route rows through
  shared capacity slots (the coupled scatter-add combine is a training
  semantics, not a serving one);
* boundary retirement: a request sized exactly to the horizon retires
  cleanly with no page over-allocation and no clamped write into a live
  page (beyond-horizon writes null-route to page 0).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe as moe_mod
from repro.models.config import MoEConfig
from repro.models.transformer import (
    _paged_write_page,
    decode_step,
    init_cache,
    init_params,
)
from repro.serve.engine import ServeEngine
from repro.serve.paged_cache import BlockTables, required_pages
from repro.serve.scheduler import ContinuousBatchingEngine, Request
from repro.serve.speculative import (
    NO_DRAFT,
    NGramProposer,
    SpeculativeConfig,
)

KEY = jax.random.key(0)


def _smoke(arch):
    return dataclasses.replace(get_config(arch, smoke=True), compute_dtype="float32")


def _run_streams(cfg, params, reqs, *, spec=None, layout="paged", max_len=32,
                 eos_id=None, slots=2, page_size=4, num_pages=None,
                 prefix_cache=False, temperature=0.0, seed=0):
    cbe = ContinuousBatchingEngine(
        cfg, params, slots=slots, max_len=max_len, cache_layout=layout,
        page_size=page_size, num_pages=num_pages, sync_interval=2,
        eos_id=eos_id, prefix_cache=prefix_cache, temperature=temperature,
        seed=seed, speculative=spec,
    )
    comps = cbe.run(reqs)
    return [(c.status, c.tokens) for c in comps], cbe.stats


# ---------------------------------------------------------------------------
# proposers
# ---------------------------------------------------------------------------
def test_ngram_iterative_lookup_continues_cycle():
    """A stream sitting in a cycle must draft *through* the cycle: each
    draft token re-runs the suffix lookup on history + drafts-so-far.  A
    single longest-match lookup would stop after one period."""
    p = NGramProposer(max_n=3, min_n=1)
    p.admit(0, [5, 1, 2, 3, 1, 2, 3, 1, 2], first_token=3)
    # history ...1 2 3 1 2 [3]: the cycle (1 2 3) continues indefinitely
    assert p.propose_batch([0], 7)[0] == [1, 2, 3, 1, 2, 3, 1]


def test_ngram_no_match_pads_no_draft():
    p = NGramProposer()
    p.admit(0, [1, 2, 3, 4], first_token=5)  # all tokens distinct: no lookup hit
    assert p.propose_batch([0], 4)[0] == [NO_DRAFT] * 4
    # extend with a repeat: the suffix now has an earlier occurrence
    p.extend(0, [1, 2])
    drafts = p.propose_batch([0], 3)[0]
    assert drafts[0] == 3  # after ...1 2 the history says 3 followed 1 2
    p.release(0)
    assert 0 not in p._hist


def test_speculative_config_validates():
    with pytest.raises(ValueError, match="k must be"):
        SpeculativeConfig(k=0)
    with pytest.raises(ValueError, match="unknown proposer"):
        SpeculativeConfig(proposer="medusa")
    with pytest.raises(ValueError, match="min_ngram"):
        SpeculativeConfig(max_ngram=1, min_ngram=2)
    with pytest.raises(ValueError, match="draft_cfg"):
        SpeculativeConfig(proposer="draft_model")


def test_speculative_rejects_temperature_and_vocab_mismatch():
    cfg = _smoke("qwen25_32b")
    params = init_params(jax.random.key(0), cfg)
    with pytest.raises(ValueError, match="greedy-only"):
        ContinuousBatchingEngine(
            cfg, params, slots=1, max_len=16, temperature=0.5,
            speculative=SpeculativeConfig(k=2),
        )
    other = _smoke("recurrentgemma_9b")  # different smoke vocab
    assert other.vocab_size != cfg.vocab_size
    with pytest.raises(ValueError, match="vocab_size"):
        ContinuousBatchingEngine(
            cfg, params, slots=1, max_len=16,
            speculative=SpeculativeConfig(
                proposer="draft_model", draft_cfg=other, draft_params={},
            ),
        )


def test_draft_model_proposer_rejects_stateful_mixers():
    cfg = _smoke("recurrentgemma_9b")  # recurrent units: no overwrite rewind
    with pytest.raises(ValueError, match="global-attention"):
        SpeculativeConfig(
            proposer="draft_model", draft_cfg=cfg, draft_params={},
        ).build(slots=2, max_len=16)


# ---------------------------------------------------------------------------
# the exactness contract: spec streams == plain streams, bit for bit
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(
    "arch,layout",
    [
        ("qwen25_32b", "paged"),
        ("qwen25_32b", "dense"),
        ("gemma3_27b", "paged"),       # local-attention ring
        ("deepseek_v2_lite_16b", "paged"),  # MLA latents + MoE MLP
        ("recurrentgemma_9b", "paged"),     # RGLRU carries + local ring
        ("rwkv6_1b6", "paged"),             # wkv state + token shifts
    ],
)
def test_spec_streams_bit_identical(arch, layout):
    cfg = _smoke(arch)
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(5)
    lens = [14, 3, 9, 6, 11]
    prompts = [rng.integers(0, cfg.vocab_size, 6) for _ in lens]
    reqs = [
        Request(uid=i, prompt=prompts[i], max_new_tokens=lens[i])
        for i in range(len(lens))
    ]
    base, _ = _run_streams(cfg, params, reqs, layout=layout)
    spec, st = _run_streams(
        cfg, params, reqs, layout=layout, spec=SpeculativeConfig(k=3)
    )
    assert spec == base
    assert st["spec_steps"] > 0 and st["spec_drafted"] > 0


@pytest.mark.parametrize("arch", ["qwen25_32b", "rwkv6_1b6"])
def test_spec_streams_bit_identical_with_eos(arch):
    """Mid-draft eos: the verifier truncates the accepted window at the
    first eos; everything after it (already speculated into the cache)
    must be rolled back, not emitted."""
    cfg = _smoke(arch)
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(8)
    prompts = [rng.integers(0, cfg.vocab_size, 5) for _ in range(4)]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=12) for i, p in enumerate(prompts)]
    base, _ = _run_streams(cfg, params, reqs)
    # pick an eos that actually occurs mid-stream in the base run
    eos = base[0][1][len(base[0][1]) // 2]
    base_e, _ = _run_streams(cfg, params, reqs, eos_id=eos)
    spec_e, _ = _run_streams(
        cfg, params, reqs, eos_id=eos, spec=SpeculativeConfig(k=4)
    )
    assert spec_e == base_e
    assert any(len(t) < 12 for _, t in base_e)  # eos really fired early


class RandomDraftProposer:
    """Adversarial drafts: uniform random tokens (plus occasional NO_DRAFT
    truncation).  Acceptance collapses and nearly every round rolls back —
    the stream contract must survive garbage proposals unchanged."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.live: set = set()

    def admit(self, slot, prompt, first_token):
        self.live.add(slot)

    def extend(self, slot, tokens):
        assert slot in self.live

    def release(self, slot):
        self.live.discard(slot)

    def propose_batch(self, slots, k):
        out = {}
        for s in slots:
            n = int(self.rng.integers(0, k + 1))
            dr = [int(t) for t in self.rng.integers(0, self.vocab, n)]
            out[s] = dr + [NO_DRAFT] * (k - n)
        return out


@pytest.mark.parametrize(
    "arch",
    ["qwen25_32b", "gemma3_27b", "deepseek_v2_lite_16b", "rwkv6_1b6"],
)
def test_rollback_fuzz_random_drafts_stream_intact(arch):
    cfg = _smoke(arch)
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(13)
    lens = [13, 5, 10, 7]
    prompts = [rng.integers(0, cfg.vocab_size, 6) for _ in lens]
    reqs = [
        Request(uid=i, prompt=prompts[i], max_new_tokens=lens[i])
        for i in range(len(lens))
    ]
    base, _ = _run_streams(cfg, params, reqs)
    spec = SpeculativeConfig(
        k=3,
        make_proposer=lambda slots, max_len: RandomDraftProposer(cfg.vocab_size),
    )
    fuzz, st = _run_streams(cfg, params, reqs, spec=spec)
    assert fuzz == base
    # garbage drafts mostly rejected, and rejection means rollback ran
    assert st["spec_drafted"] > 0
    assert st["spec_accepted"] < st["spec_drafted"]


def test_rollback_fuzz_with_shared_prefix_pages():
    """Random drafts over prefix-cache-shared pages: speculative writes on
    one slot must never leak into a peer's shared prefix (lookahead past
    the owned window null-routes; accepted writes land in owned pages)."""
    cfg = _smoke("qwen25_32b")
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(21)
    shared = rng.integers(0, cfg.vocab_size, 12)
    prompts = [
        np.concatenate([shared, rng.integers(0, cfg.vocab_size, 3)])
        for _ in range(5)
    ]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=8) for i, p in enumerate(prompts)]
    base, _ = _run_streams(cfg, params, reqs, max_len=28, prefix_cache=True)
    spec = SpeculativeConfig(
        k=3,
        make_proposer=lambda slots, max_len: RandomDraftProposer(cfg.vocab_size, 7),
    )
    fuzz, st = _run_streams(
        cfg, params, reqs, max_len=28, prefix_cache=True, spec=spec
    )
    assert fuzz == base
    assert st["prefix_hits"] > 0


class ConstantDraftProposer:
    """Always proposes k copies of token 1 — (almost) never accepted, but
    it keeps the requested verify width at k+1, which is what pressures
    the page pool's lookahead allocation."""

    def __init__(self, slots, max_len):
        pass

    def admit(self, slot, prompt, first_token):
        pass

    def extend(self, slot, tokens):
        pass

    def release(self, slot):
        pass

    def propose_batch(self, slots, k):
        return {s: [1] * k for s in slots}


def test_spec_degrades_under_page_pool_pressure():
    """A pool too small for full-k lookahead must shrink the verify width
    (spec_degraded), never stall or corrupt: the real write position is
    guaranteed, drafts beyond the covered pages are dropped.  Small
    prefill chunks matter: chunk-sized admission pre-allocation would
    otherwise hand every slot its horizon pages up front."""
    cfg = _smoke("qwen25_32b")
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(17)
    lens = [8, 4, 8]
    prompts = [rng.integers(0, cfg.vocab_size, 3) for _ in lens]
    reqs = [
        Request(uid=i, prompt=p, max_new_tokens=lens[i])
        for i, p in enumerate(prompts)
    ]

    def run(spec=None):
        cbe = ContinuousBatchingEngine(
            cfg, params, slots=2, max_len=24, cache_layout="paged",
            page_size=4, num_pages=6, prefill_chunk_tokens=4,
            sync_interval=2, prefix_cache=False, speculative=spec,
        )
        comps = cbe.run(reqs)
        return [(c.status, c.tokens) for c in comps], cbe.stats

    base, _ = run()
    spec, st = run(SpeculativeConfig(k=3, make_proposer=ConstantDraftProposer))
    assert spec == base
    assert st["spec_degraded"] > 0
    assert st["spec_accepted"] < st["spec_drafted"]


def test_local_ring_rejects_overwide_speculation():
    """Verify width > local-attention ring size would overwrite ring
    entries a rejected draft still needs — construction-time error, not
    silent corruption."""
    cfg = _smoke("recurrentgemma_9b")
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    reqs = [Request(uid=0, prompt=rng.integers(0, cfg.vocab_size, 5),
                    max_new_tokens=12)]
    cbe = ContinuousBatchingEngine(
        cfg, params, slots=1, max_len=24, page_size=4, sync_interval=2,
        prefix_cache=False, speculative=SpeculativeConfig(k=11),
    )
    with pytest.raises(ValueError, match="ring"):
        cbe.run(reqs)


# ---------------------------------------------------------------------------
# decode-loop bugfix sweep
# ---------------------------------------------------------------------------
def test_moe_decode_rows_are_independent():
    """The decode MoE path must be a per-token operation: a row's output
    cannot depend on its batch neighbours.  The training path's shared
    capacity slots (argsort dispatch + scatter-add combine) couple rows
    at the ULP level and via capacity drops — decode routes around it."""
    mcfg = MoEConfig(num_experts=8, num_shared_experts=1, top_k=2,
                     capacity_factor=1.0, expert_d_ff=16)
    d = 12
    params = moe_mod.moe_init(jax.random.key(3), d, mcfg)
    x = jax.random.normal(jax.random.key(4), (4, 1, d), jnp.float32)
    full, _ = moe_mod.moe_mlp_decode(
        params, x, mcfg, act="silu", dtype=jnp.float32
    )
    for i in range(4):
        solo, _ = moe_mod.moe_mlp_decode(
            params, x[i : i + 1], mcfg, act="silu", dtype=jnp.float32
        )
        np.testing.assert_array_equal(np.asarray(full[i]), np.asarray(solo[0]))


def test_moe_model_decode_row_independent_of_neighbours():
    """End to end on the MoE arch: decoding the same row alongside
    *different* neighbours yields bitwise-identical logits.  This is the
    serving invariant the capacity-coupled MoE combine broke (neighbour
    tokens shifted a row's expert sums by ULPs, flipping argmaxes and
    diverging live streams)."""
    cfg = _smoke("deepseek_v2_lite_16b")
    params = init_params(jax.random.key(0), cfg)
    b, max_len = 3, 8
    rng = np.random.default_rng(2)
    step = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    row0 = rng.integers(0, cfg.vocab_size, 4)

    def run_with_neighbours(seed):
        nb = np.random.default_rng(seed).integers(0, cfg.vocab_size, (b - 1, 4))
        cache = init_cache(cfg, b, max_len)
        for t in range(4):
            toks = jnp.asarray(
                np.concatenate([[row0[t]], nb[:, t]]), jnp.int32
            )[:, None]
            lg, cache = step(params, cache, toks, jnp.int32(t))
        return np.asarray(lg[0, 0])

    np.testing.assert_array_equal(run_with_neighbours(100), run_with_neighbours(200))


def test_paged_write_page_null_routes_beyond_horizon():
    bt = jnp.asarray([[3, 5], [7, 2]], jnp.int32)  # MP = 2, page_size 4
    pos = jnp.asarray([3, 8], jnp.int32)  # row 1 writes past the horizon
    np.testing.assert_array_equal(
        np.asarray(_paged_write_page(bt, pos, 4)), [3, 0]
    )
    # width-K form: per-lane routing, lookahead lanes past the horizon
    # hit the null page while in-horizon lanes still map to real pages
    posk = jnp.asarray([[3, 4, 11], [0, 7, 8]], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(_paged_write_page(bt, posk, 4)), [[3, 5, 0], [7, 2, 0]]
    )


def test_boundary_retirement_exact_horizon():
    """pl + max_new == max_len, page-aligned: the stream must complete
    without PageOverflowError, without allocating pages past the horizon,
    and bit-identical to the dense layout.  Regression: the host position
    mirror kept advancing for done-but-unretired slots under
    sync_interval > 1 and a later ensure() clamped it into a live page."""
    cfg = _smoke("qwen25_32b")
    params = init_params(jax.random.key(0), cfg)
    ps, max_len = 4, 16
    rng = np.random.default_rng(31)
    pl = 8
    prompts = [rng.integers(0, cfg.vocab_size, pl) for _ in range(3)]
    # max_new fills the horizon exactly; prompts are page-aligned
    reqs = [
        Request(uid=i, prompt=p, max_new_tokens=max_len - pl)
        for i, p in enumerate(prompts)
    ]
    dense, _ = _run_streams(cfg, params, reqs, layout="dense", max_len=max_len)
    paged, st = _run_streams(
        cfg, params, reqs, slots=2, max_len=max_len, page_size=ps,
        num_pages=required_pages(2, max_len, ps),  # zero slack: over-alloc raises
    )
    assert paged == dense
    assert all(s == "ok" and len(t) == max_len - pl for s, t in paged)
    assert st["peak_pages"] <= 2 * (max_len // ps)
    # and speculation at the same exact horizon stays clean too
    spec, _ = _run_streams(
        cfg, params, reqs, slots=2, max_len=max_len, page_size=ps,
        num_pages=required_pages(2, max_len, ps),
        spec=SpeculativeConfig(k=3),
    )
    assert spec == dense


def test_first_token_eos_retires_at_admission():
    """A request whose *first* sampled token is eos must retire with
    exactly [eos] — matching the fixed engine, which freezes the row at
    the prefill sample — and hand its slot to the next queued request."""
    cfg = _smoke("qwen25_32b")
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(41)
    prompts = [rng.integers(0, cfg.vocab_size, 5) for _ in range(3)]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=8) for i, p in enumerate(prompts)]
    base, _ = _run_streams(cfg, params, reqs, max_len=16)
    eos = base[1][1][0]  # request 1's very first token
    fixed = ServeEngine(cfg, params, max_len=16, eos_id=eos, sync_interval=2)
    ref = np.asarray(fixed.generate(jnp.asarray(np.stack(prompts)), steps=8))[:, 5:]
    got, st = _run_streams(
        cfg, params, reqs, slots=1, max_len=16, eos_id=eos
    )
    assert got[1] == ("ok", [eos])
    for i in (0, 2):
        want = ref[i]
        stop = np.where(want == eos)[0]
        n = int(stop[0]) + 1 if len(stop) else 8
        assert got[i] == ("ok", list(int(t) for t in want[:n]))
    assert st["prefills"] == 3  # the freed slot really recycled
    # speculative path: same admission semantics
    spec, _ = _run_streams(
        cfg, params, reqs, slots=1, max_len=16, eos_id=eos,
        spec=SpeculativeConfig(k=3),
    )
    assert spec == got


@pytest.mark.parametrize("layout", ["paged", "dense"])
def test_temperature_streams_match_fixed_engine(layout):
    """temperature > 0: the scheduler keys token i of request uid with
    fold_in(fold_in(key, uid), i) — the same chain `ServeEngine.generate`
    uses when passed uids — so continuous-batching streams stay
    token-level equivalent to the fixed engine under sampling, regardless
    of slot assignment or admission order."""
    cfg = _smoke("qwen25_32b")
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(51)
    n, pl, steps, seed = 4, 5, 7, 3
    prompts = rng.integers(0, cfg.vocab_size, (n, pl))
    fixed = ServeEngine(cfg, params, max_len=16, temperature=0.7)
    ref = np.asarray(
        fixed.generate(
            jnp.asarray(prompts), steps=steps,
            key=jax.random.key(seed), uids=jnp.arange(n, dtype=jnp.int32),
        )
    )[:, pl:]
    reqs = [
        Request(uid=i, prompt=prompts[i], max_new_tokens=steps)
        for i in range(n)
    ]
    got, _ = _run_streams(
        cfg, params, reqs, layout=layout, slots=2, max_len=16,
        temperature=0.7, seed=seed,
    )
    for i in range(n):
        assert got[i] == ("ok", [int(t) for t in ref[i]])


def test_block_tables_cover_degrades_and_validates():
    bt = BlockTables.with_pool(slots=2, max_len=16, page_size=4, num_pages=6)
    with pytest.raises(ValueError, match="at least one"):
        bt.cover(0, 0, 0)
    bt.admit(0, prompt_len=3)  # 1 page; pool has 4 left... minus slot 1
    bt.admit(1, prompt_len=9)  # 3 pages; pool now has 1 page free
    # want 8 positions from pos 3: pos 3 is owned, lookahead can add only
    # one page before the pool runs dry -> 5 covered (3..7), not 8
    cov, grew = bt.cover(0, 3, 8)
    assert (cov, grew) == (5, True)
    # horizon: lookahead stops at max_len even with pages available
    bt.release(1)
    cov, _ = bt.cover(0, 13, 8)
    assert cov == 3  # 13, 14, 15 — 16 is past the horizon
