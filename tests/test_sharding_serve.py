"""Sharding-rule and serving tests (single-host: rules exercised on a 1x1
mesh + pure-spec assertions; the 512-device meshes are covered by the
dry-run deliverable)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.models.transformer import cache_specs, init_params, param_specs
from repro.parallel import sharding as sh
from repro.parallel.compress import dequantize_int8, psum_int8, quantize_int8
from repro.serve.engine import ServeEngine


class FakeMesh:
    """Duck-typed mesh: shape mapping only (what _fit/_param_rule need)."""

    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_fit_drops_nondivisible_axes():
    assert sh._fit(MESH, (64, 64), (sh.FSDP, sh.TP)) == P("data", "model")
    assert sh._fit(MESH, (10, 64), (sh.FSDP, sh.TP)) == P(None, "model")
    # tuple axes shrink from the innermost
    assert sh._fit(MESH3, (32, 8), (sh.DP, None)) == P(("pod", "data"), None)
    assert sh._fit(MESH3, (2, 8), (sh.DP, None)) == P("pod", None)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_sharding_covers_all_leaves(arch):
    cfg = get_config(arch)
    specs = param_specs(cfg)
    shardings = sh.param_sharding(MESH, specs)
    flat_p = jax.tree_util.tree_leaves(specs)
    flat_s = jax.tree_util.tree_leaves(
        shardings, is_leaf=lambda x: isinstance(x, P)
    )
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        shape = leaf.shape
        for dim, axes in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
            if axes is None:
                continue
            names = (axes,) if isinstance(axes, str) else axes
            size = 1
            for a in names:
                size *= MESH.shape[a]
            assert dim % size == 0, f"{arch}: {shape} vs {spec}"


@pytest.mark.parametrize("arch", ["gemma3_27b", "deepseek_v2_lite_16b", "rwkv6_1b6"])
def test_cache_sharding_divisible(arch):
    cfg = get_config(arch)
    cache = jax.eval_shape(lambda: cache_specs(cfg, 128, 32_768))
    shardings = sh.cache_specs_sharding(MESH, cache)
    flat_c = jax.tree_util.tree_leaves(cache)
    flat_s = jax.tree_util.tree_leaves(shardings, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(flat_c, flat_s):
        for dim, axes in zip(leaf.shape, tuple(spec) + (None,) * 8):
            if axes is None:
                continue
            names = (axes,) if isinstance(axes, str) else axes
            size = 1
            for a in names:
                size *= MESH.shape[a]
            assert dim % size == 0, f"{arch}: {leaf.shape} vs {spec}"


def test_big_params_are_sharded_not_replicated():
    cfg = get_config("deepseek_67b")
    specs = param_specs(cfg)
    shardings = sh.param_sharding(MESH, specs)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    flat_s = jax.tree_util.tree_leaves(shardings, is_leaf=lambda x: isinstance(x, P))
    for (kp, leaf), spec in zip(flat, flat_s):
        n = 1
        for d in leaf.shape:
            n *= d
        if n * 4 > 64 << 20:  # every >64MB param must shard on something
            assert any(a is not None for a in spec), (kp, leaf.shape, spec)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_int8_quantization_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (256,), jnp.float32) * 3.0
    q, scale = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, scale) - x))
    assert float(err) <= float(scale) * 0.5 + 1e-6


def test_psum_int8_single_device_identity_scale():
    # on a 1-device axis the compressed psum is just quantize->dequantize
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    x = jax.random.normal(jax.random.key(1), (64,), jnp.float32)
    out = shard_map(
        lambda v: psum_int8(v, ("data",)), mesh=mesh,
        in_specs=P(None), out_specs=P(None), check_rep=False,
    )(x)
    assert float(jnp.max(jnp.abs(out - x))) < float(jnp.max(jnp.abs(x))) / 100.0


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def test_serve_engine_greedy_generation():
    cfg = dataclasses.replace(get_config("deepseek_67b", smoke=True), compute_dtype="float32")
    params = init_params(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, max_len=24)
    prompt = jax.random.randint(jax.random.key(1), (2, 8), 0, cfg.vocab_size)
    out = eng.generate(prompt, steps=6)
    assert out.shape == (2, 14)
    assert int(jnp.max(out)) < cfg.vocab_size  # padded ids never sampled


def test_serve_engine_matches_teacher_forcing():
    """Greedy generation step t must equal argmax of full forward at t."""
    cfg = dataclasses.replace(get_config("qwen25_32b", smoke=True), compute_dtype="float32")
    params = init_params(jax.random.key(2), cfg)
    eng = ServeEngine(cfg, params, max_len=16)
    prompt = jax.random.randint(jax.random.key(3), (1, 8), 0, cfg.vocab_size)
    out = eng.generate(prompt, steps=1)
    from repro.models.transformer import forward

    logits, _, _ = forward(cfg, params, prompt)
    want = int(jnp.argmax(logits[0, -1, : cfg.vocab_size]))
    assert int(out[0, 8]) == want
