"""Strict tiered verification (repro.verify) tests.

The two contracts this file locks (mirroring tests/test_diagnosis.py):

* verify=off is a byte-identical no-op: engine runs of every pre-existing
  method produce records AND checkpoint files with the exact bytes the
  pre-verification engine produced (golden fixture captured on main before
  the subsystem landed — tests/fixtures/strict_off_golden.json);
* verify=strict rejects every committed adversarial fixture at its intended
  tier (tests/fixtures/hacks/), accepts every task's honest naive source,
  emits schema-valid reports, is exactly replayable under a pinned nonce,
  ships unchanged through the parallel worker pipe, and survives the
  engine's checkpoint/resume path.
"""

import hashlib
import json
import os

import numpy as np
import pytest

import repro.tasks  # noqa: F401 — populate the registry
import repro.tasks.calibration  # noqa: F401
from repro.core.engine import EvolutionEngine, RunResult
from repro.core.methods import DISPLAY_ORDER, get_method
from repro.core.solution import Solution, TokenLedger
from repro.evaluation.evaluator import EvalConfig, EvalResult, Evaluator
from repro.sweep.driver import run_unit
from repro.tasks.base import get_task
from repro.verify import (
    VERIFY_PROMPT_BUDGET,
    VerificationPolicy,
    VerificationReport,
    derive_seed_base,
    render_verification_section,
    static_violations,
)
from repro.verify.report import validate

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures")
GOLDEN = os.path.join(FIXTURES, "strict_off_golden.json")
HACKS = os.path.join(FIXTURES, "hacks")


def _sim_evaluator(nonce=None) -> Evaluator:
    return Evaluator(EvalConfig(timing_mode="simulated", verify_nonce=nonce))


def _sha256(path: str) -> str:
    with open(path, "rb") as f:
        return hashlib.sha256(f.read()).hexdigest()


# --------------------------------------------------------------------------
# the ablation-soundness contract: verify-off == pre-verification engine
# --------------------------------------------------------------------------


def test_strict_off_byte_identical_to_pre_pr_engine(tmp_path):
    """Replay the golden grid (captured on main BEFORE this subsystem
    existed): every record and every checkpoint file must come out with
    identical bytes now that the verification plumbing is in place."""
    with open(GOLDEN) as f:
        golden = json.load(f)
    assert golden["units"], "golden fixture is empty"
    for unit in golden["units"]:
        ckdir = tmp_path / unit["task"] / unit["method_key"]
        rec = run_unit(
            get_task(unit["task"]),
            get_method(unit["method_key"]),
            unit["seed"],
            evaluator=_sim_evaluator(),
            trials=unit["trials"],
            rag_pool=[],
            batch_size=1,
            checkpoint_dir=str(ckdir),
        )
        assert rec == unit["record"], f"record drifted for {unit['method_key']}"
        ck = ckdir / unit["checkpoint_name"]
        assert ck.exists(), f"checkpoint missing for {unit['method_key']}"
        assert _sha256(str(ck)) == unit["checkpoint_sha256"], (
            f"checkpoint bytes drifted for {unit['method_key']} — the "
            "verify=off path is no longer a byte-identical no-op"
        )


def test_off_mode_attaches_no_verification():
    ev = _sim_evaluator()
    task = get_task("cal_quick")
    res = ev.evaluate(task, task.initial_source)  # config default: off
    assert res.valid
    assert res.verification is None

    # a wrong candidate in off mode keeps the legacy one-number message
    # but still carries the structured error stats (satellite: max-rel +
    # argmax index recorded everywhere)
    wrong = ev.evaluate(task, task.initial_source.replace("+ 1.0", "+ 1.5"))
    assert wrong.compile_ok and not wrong.correct
    assert wrong.error.startswith("value mismatch (max abs err ")
    assert "rel" not in wrong.error
    assert wrong.err_max_abs == pytest.approx(0.5, rel=1e-3)
    assert wrong.err_max_rel is not None and wrong.err_max_rel > 0
    assert isinstance(wrong.err_argmax, list)
    assert wrong.verification is None


def test_solution_to_dict_omits_none_verification():
    d = Solution(source="x = 1").to_dict()
    assert "verification" not in d
    rep = {"mode": "strict", "nonce": "n", "passed": True, "tiers": []}
    d2 = Solution(source="x = 1", verification=rep).to_dict()
    assert d2["verification"]["mode"] == "strict"
    assert Solution.from_dict(d).verification is None
    assert Solution.from_dict(d2).verification == rep


def test_strict_never_promotes_and_off_never_demotes():
    """Tier degradation mirror of diagnosis never-invalidate: the strict
    ladder can only *reject* candidates the legacy gate accepted, never
    accept ones it rejected; and in off mode the verdict is untouched."""
    ev = _sim_evaluator(nonce="pin")
    task = get_task("cal_quick")
    honest = task.initial_source
    broken = honest.replace("+ 1.0", "+ 1.5")
    for src in (honest, broken):
        off = ev.evaluate(task, src, verify="off")
        strict = ev.evaluate(task, src, verify="strict")
        if not off.valid:
            assert not strict.valid, "strict promoted a legacy-rejected candidate"
        if strict.valid:
            assert off.valid


# --------------------------------------------------------------------------
# the hack audit: every committed adversarial fixture must be rejected
# --------------------------------------------------------------------------


def _manifest():
    with open(os.path.join(HACKS, "manifest.json")) as f:
        return json.load(f)


@pytest.mark.parametrize("fx", _manifest()["fixtures"], ids=lambda fx: fx["file"])
def test_hack_fixture_rejected_at_expected_tier(fx):
    with open(os.path.join(HACKS, fx["file"])) as f:
        source = f.read()
    task = get_task(fx["task"])
    ev = _sim_evaluator(nonce=_manifest()["nonce"])
    res = ev.evaluate(task, source, verify="strict")
    assert not (res.compile_ok and res.correct), f"{fx['file']} passed strict"
    rep = res.verification
    assert rep is not None
    validate(rep)
    assert rep["failed_tier"] == fx["expected_tier"], (
        f"{fx['file']}: rejected at tier {rep['failed_tier']}, "
        f"expected {fx['expected_tier']}"
    )
    failing = [t for t in rep["tiers"] if not t["ok"]]
    assert failing and fx["detail_substring"] in failing[0].get("detail", ""), (
        f"{fx['file']}: detail {failing[0].get('detail', '')!r} lacks "
        f"{fx['detail_substring']!r}"
    )


@pytest.mark.parametrize(
    "fx",
    [
        f
        for f in _manifest()["fixtures"]
        # tier-0 hacks must never be exec'd outside the strict guard:
        # allclose_patch would corrupt this very process's numpy
        if f["legacy_accepts"] and f["expected_tier"] >= 2
    ],
    ids=lambda fx: fx["file"],
)
def test_dynamic_hacks_pass_the_legacy_gate(fx):
    """The vulnerability being closed, demonstrated: the same candidates
    the strict ladder rejects score as fully valid under the legacy
    fixed-shape fixed-seed gate."""
    with open(os.path.join(HACKS, fx["file"])) as f:
        source = f.read()
    res = _sim_evaluator().evaluate(get_task(fx["task"]), source, verify="off")
    assert res.valid, f"{fx['file']} no longer fools the legacy gate: {res.error}"


@pytest.mark.parametrize(
    "name",
    [
        "cal_quick",
        "mm_square_s",
        "mm_batched_bt",
        "conv1d_k3",
        "act_relu",
        "act_softmax",
        "pool_max2d",
        "norm_group",
        "reduce_sum",
        "reduce_min",
        "loss_ce",
        "cum_sum_masked",
    ],
)
def test_honest_naive_sources_pass_strict(name):
    """No false positives: the deliberately-slow but honest initial
    implementations clear the full ladder (one task per family quirk:
    batched/transposed matmul, grouped norm, sort-based min with the NaN
    probe opt-out, masked cumsum, one-hot CE loss)."""
    task = get_task(name)
    res = _sim_evaluator(nonce="pin").evaluate(task, task.initial_source, verify="strict")
    assert res.valid, f"{name} naive source rejected: {res.error}"
    rep = res.verification
    validate(rep)
    assert rep["passed"] is True
    assert [t["tier"] for t in rep["tiers"]] == [0, 1, 2, 3, 4]
    assert all(t["ok"] for t in rep["tiers"])


# --------------------------------------------------------------------------
# nonce derivation and replay
# --------------------------------------------------------------------------


def test_nonce_pinning_replays_exactly():
    task = get_task("act_relu")
    src = task.initial_source
    r1 = _sim_evaluator(nonce="abc").evaluate(task, src, verify="strict")
    r2 = _sim_evaluator(nonce="abc").evaluate(task, src, verify="strict")
    assert r1.verification == r2.verification
    assert r1.verification["nonce"] == "abc"


def test_fresh_nonce_draws_fresh_seeds():
    assert derive_seed_base("a", "t") != derive_seed_base("b", "t")
    assert derive_seed_base("a", "t1") != derive_seed_base("a", "t2")
    # unpinned evaluators draw distinct nonces
    assert _sim_evaluator().verify_nonce != _sim_evaluator().verify_nonce
    for nonce in ("a", "b"):
        sb = derive_seed_base(nonce, "t")
        assert 0 <= sb < 2**31


def test_policy_warm_is_idempotent_and_covers_fuzz():
    task = get_task("act_relu")
    pol = VerificationPolicy(task, "pin")
    pol.warm()
    cases = pol.functional_cases()
    assert cases is pol.functional_cases()  # memoized
    labels = [c[0] for c in cases]
    assert labels[:3] == ["nonce seed 0", "nonce seed 1", "nonce seed 2"]
    assert sum(1 for l in labels if l.startswith("fuzz shape")) == 3
    # fuzz shapes are genuinely off-canonical
    canonical = task.make_inputs(0)[0].shape
    for _, inputs, want in cases[3:]:
        assert inputs[0].shape != canonical
        assert want.shape == np.asarray(task.ref(*inputs)).shape
    assert pol.nan_case() is not None


# --------------------------------------------------------------------------
# static guard units
# --------------------------------------------------------------------------


def test_static_guard_units():
    ok = "import jax.numpy as jnp\n\ndef kernel(x):\n    return jnp.abs(x)\n"
    assert static_violations(ok) == []
    # syntax errors are tier 1's job (and its byte-locked messages)
    assert static_violations("def kernel(x:\n  return x") == []
    bad = {
        "import os\n": "forbidden import",
        "from repro.tasks import get_task\n": "forbidden import",
        "import numpy as np\nx = np.load('f.npy')\n": "np.load",
        "import numpy as np\nnp.allclose = None\n": "monkeypatch",
        "import numpy as np\nnp.ndarray.__eq__ = None\n": "",
        "open('/etc/passwd')\n": "forbidden call",
        "eval('1')\n": "forbidden call",
        "getattr(__builtins__, 'open')\n": "",
        "import numpy\ndel numpy.allclose\n": "monkeypatch",
    }
    for src, needle in bad.items():
        v = static_violations(src)
        assert v, f"guard missed: {src!r}"
        if needle:
            assert any(needle in m for m in v), (src, v)


# --------------------------------------------------------------------------
# report record layer
# --------------------------------------------------------------------------


def test_report_roundtrip_and_validate():
    rep = VerificationReport(mode="strict", nonce="n")
    rep.record(0, True, "source clean")
    rep.record(1, True)
    rep.record(2, False, "nonce seed 0: max abs err 1.000e+00 (rel 5.000e-01)")
    rep.max_abs_err = 1.0
    rep.max_rel_err = 0.5
    rep.err_argmax = [3, 7]
    d = rep.finalize().to_dict()
    validate(d)
    assert d["passed"] is False and d["failed_tier"] == 2
    back = VerificationReport.from_dict(d)
    assert back.to_dict() == d
    assert back.failed_name == "fuzz"


def test_validate_rejects_bad_payloads():
    good = VerificationReport(mode="strict", nonce="n")
    good.record(0, True)
    gd = good.finalize().to_dict()
    validate(gd)
    for bad in (
        {},
        {**gd, "mode": "loose"},
        {**gd, "passed": 1},
        {**gd, "surprise": 3},
        {**gd, "failed_tier": 9},
        {**gd, "passed": True, "failed_tier": 0},
        {**gd, "tiers": [{"tier": 0, "name": "compile", "ok": True}]},
        {**gd, "tiers": [{"tier": 7, "name": "static", "ok": True}]},
        {**gd, "err_argmax": [1, True]},
        [],
    ):
        with pytest.raises(ValueError):
            validate(bad)


def test_render_respects_budget_and_names_the_tier():
    rep = VerificationReport(mode="strict", nonce="n")
    rep.record(0, True, "source clean")
    rep.record(1, True, "compiled and traced")
    rep.record(2, False, "fuzz shape ((7, 33),): " + "x" * 400)
    rep.max_abs_err = 12.0
    rep.finalize()
    for budget in (40, 120, VERIFY_PROMPT_BUDGET):
        assert len(rep.render(budget)) <= budget
    sec = render_verification_section(rep.to_dict())
    assert 0 < len(sec) <= VERIFY_PROMPT_BUDGET
    assert "REJECTED at tier 2 (fuzz)" in sec
    assert sec.startswith("hint: ")
    assert render_verification_section(None) == ""


# --------------------------------------------------------------------------
# parallel pipe
# --------------------------------------------------------------------------


def test_parallel_strict_identical_to_serial():
    from repro.evaluation.parallel import ParallelEvaluator

    task = get_task("cal_quick")
    hack = os.path.join(HACKS, "memorize_seeds.py")
    with open(hack) as f:
        hack_src = f.read()
    cfg = EvalConfig(timing_mode="simulated", verify_nonce="pin")
    serial = Evaluator(cfg)
    with ParallelEvaluator(
        cfg, workers=1, extra_task_modules=("repro.tasks.calibration",)
    ) as pool:
        for src in (task.initial_source, hack_src):
            s = serial.evaluate(task, src, verify="strict")
            p = pool.evaluate(task, src, verify="strict")
            assert p.verification == s.verification
            assert (p.compile_ok, p.correct, p.error) == (
                s.compile_ok, s.correct, s.error
            )
        # per-call mode must not leak into other calls through the cache
        off = pool.evaluate(task, hack_src, verify="off")
        assert off.valid and off.verification is None


# --------------------------------------------------------------------------
# engine integration: the evoengineer-strictverify method row
# --------------------------------------------------------------------------


def test_strictverify_method_registered():
    m = get_method("evoengineer-strictverify")
    assert m.verify == "strict"
    assert m.guiding.use_verification
    assert m.fault.p_hack > 0
    assert "evoengineer-strictverify" in DISPLAY_ORDER


def test_strictverify_engine_rejects_hacks_and_feeds_back(tmp_path):
    task = get_task("act_relu")
    eng = EvolutionEngine(
        task,
        get_method("evoengineer-strictverify"),
        evaluator=_sim_evaluator(nonce="pin"),
        seed=3,
    )
    res = eng.run(max_trials=20)
    rejected = [
        s for s in res.history if not s.valid and s.verification is not None
    ]
    for sol in res.history:
        if sol.verification is not None:
            validate(sol.verification)
    assert rejected, "no strict rejection in 20 trials (p_hack=0.06 + faults)"
    # the next prompt names the tier that bit
    _, req = eng._prepare_request(eng.trial)
    assert "## Verification feedback (last rejected candidate)" in req.prompt
    section = req.prompt.split(
        "## Verification feedback (last rejected candidate)\n", 1
    )[1].split("\n\n## ", 1)[0]
    assert len(section) <= VERIFY_PROMPT_BUDGET
    assert "REJECTED at tier" in section
    # rejection tier is recorded on insights for the insight store
    assert any("[rejected at tier" in r.text for r in eng.insights.records)


def test_strictverify_checkpoint_resume_identical(tmp_path):
    """The new method row survives the sweep-fleet checkpoint/resume path
    (verification payloads and rejection-feedback prompts included)."""
    task = get_task("cal_quick")
    method_key = "evoengineer-strictverify"
    one_shot = tmp_path / "oneshot"
    rec_full = run_unit(
        task, get_method(method_key), 0, evaluator=_sim_evaluator(nonce="pin"),
        trials=12, rag_pool=[], batch_size=1, checkpoint_dir=str(one_shot),
    )
    resumed = tmp_path / "resumed"
    run_unit(
        task, get_method(method_key), 0, evaluator=_sim_evaluator(nonce="pin"),
        trials=6, rag_pool=[], batch_size=1, checkpoint_dir=str(resumed),
    )
    rec_resumed = run_unit(
        task, get_method(method_key), 0, evaluator=_sim_evaluator(nonce="pin"),
        trials=12, rag_pool=[], batch_size=1, checkpoint_dir=str(resumed),
    )
    assert rec_resumed == rec_full
    name = next(p for p in os.listdir(one_shot) if p.endswith(".json"))
    assert _sha256(str(one_shot / name)) == _sha256(str(resumed / name))


def test_off_mode_prompt_has_no_verification_section():
    task = get_task("cal_quick")
    eng = EvolutionEngine(
        task, get_method("evoengineer-full"), evaluator=_sim_evaluator(), seed=0
    )
    eng.run(max_trials=4)
    _, req = eng._prepare_request(eng.trial)
    assert "Verification feedback" not in req.prompt


# --------------------------------------------------------------------------
# satellites: oracle warm outside the deadline, runtime sanity guards
# --------------------------------------------------------------------------


def test_oracle_warming_happens_before_candidate_runs():
    """Satellite: oracle construction is paid outside the candidate
    _Deadline — even a candidate rejected before execution (tier 0)
    leaves the oracle cache warm for its successors."""
    ev = _sim_evaluator(nonce="pin")
    task = get_task("cal_quick")
    assert ev.oracle_misses == 0
    res = ev.evaluate(task, "import os\n\ndef kernel(x):\n    return x\n", verify="strict")
    assert res.stage == "verify" and not res.compile_ok
    assert ev.oracle_misses == ev.config.n_correctness
    before = ev.oracle_misses
    ev.evaluate(task, task.initial_source, verify="strict")
    assert ev.oracle_misses == before  # warmed once, not per candidate


def test_eval_result_ok_guards_degenerate_runtimes():
    assert EvalResult(compile_ok=True, correct=True, runtime_us=10.0).ok
    for rt in (None, 0.0, -1.0, float("nan"), float("inf")):
        r = EvalResult(compile_ok=True, correct=True, runtime_us=rt)
        assert not r.ok, f"runtime {rt!r} must not be usable"
    assert not EvalResult(compile_ok=True, correct=False, runtime_us=10.0).ok


def test_run_result_speedups_guard_degenerate_runtimes():
    def rr(rt):
        best = Solution(source="s", compile_ok=True, correct=True, runtime_us=rt)
        return RunResult(
            task="t", method="m", seed=0, best=best, history=[best],
            ledger=TokenLedger(), baseline_us=100.0,
        )

    assert rr(50.0).best_speedup == pytest.approx(2.0)
    assert rr(50.0).any_speedup
    for rt in (None, 0.0, float("nan"), float("inf"), -3.0):
        assert rr(rt).best_speedup == 1.0
        assert not rr(rt).any_speedup


def test_evaluator_speedup_rejects_degenerate_measurement(monkeypatch):
    ev = _sim_evaluator()
    task = get_task("cal_quick")
    good = ev.evaluate(task, task.initial_source)
    assert ev.speedup(task, good) is not None
    bad = EvalResult(compile_ok=True, correct=True, runtime_us=0.0)
    assert ev.speedup(task, bad) is None


def test_degenerate_measurement_demoted_to_timing_stage(monkeypatch):
    from repro.evaluation import timing as timing_mod

    ev = _sim_evaluator()
    task = get_task("cal_quick")

    class ZeroTiming:
        mode = "simulated"

        def measure(self, req):
            return timing_mod.Measurement(runtime_us=0.0, mode="simulated")

    ev.timing = ZeroTiming()
    res = ev.evaluate(task, task.initial_source)
    assert res.compile_ok and res.correct
    assert res.runtime_us is None and res.stage == "timing"
    assert "unusable runtime measurement" in res.error
    assert not res.ok
